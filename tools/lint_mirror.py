#!/usr/bin/env python3
"""Reference mirror of `crest lint` (rust/src/lint/) for toolchain-free CI.

This is a line-for-line port of the Rust contract checker — the lexer in
`rust/src/lint/lex.rs` and the rules in `rust/src/lint/rules.rs` — kept
in sync by hand so environments without a Rust toolchain can still run
the contract checks (and so the checker itself has an independent
implementation to diff against). `python3 tools/lint_mirror.py [root]`
prints the same `file:line: [RULE-ID] message` diagnostics and exits
nonzero on any finding.

If this mirror and `crest lint` ever disagree, the Rust implementation
is the specification.
"""

import sys
from pathlib import Path

# --------------------------------------------------------------------- lexer

IDENT, NUM, STR, PUNCT = "Ident", "Num", "Str", "Punct"


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind, self.text, self.line = kind, text, line


class Comment:
    __slots__ = ("line", "end_line", "text", "trailing")

    def __init__(self, line, end_line, text, trailing):
        self.line, self.end_line, self.text, self.trailing = line, end_line, text, trailing


class Lexed:
    def __init__(self):
        self.toks = []
        self.comments = []
        self.n_lines = 0
        self._code_lines = None

    def line_has_code(self, line):
        if self._code_lines is None:
            self._code_lines = {t.line for t in self.toks}
        return line in self._code_lines


def is_ident_start(c):
    return c.isalpha() or c == "_"


def is_ident_cont(c):
    return c.isalnum() or c == "_"


class Lexer:
    def __init__(self, src):
        self.cs = list(src)
        self.i = 0
        self.line = 1
        self.line_has_tok = False
        self.out = Lexed()

    def peek(self, ahead=0):
        j = self.i + ahead
        return self.cs[j] if j < len(self.cs) else None

    def bump(self):
        c = self.peek()
        if c is not None:
            self.i += 1
            if c == "\n":
                self.line += 1
                self.line_has_tok = False
        return c

    def push(self, kind, text, line):
        self.line_has_tok = True
        self.out.toks.append(Tok(kind, text, line))

    def line_comment(self):
        start, trailing = self.line, self.line_has_tok
        text = []
        self.i += 2
        while self.peek() is not None and self.peek() != "\n":
            text.append(self.peek())
            self.bump()
        self.out.comments.append(Comment(start, start, "".join(text), trailing))

    def block_comment(self):
        start, trailing = self.line, self.line_has_tok
        text = []
        self.i += 2
        depth = 1
        while depth > 0:
            a, b = self.peek(), self.peek(1)
            if a == "/" and b == "*":
                depth += 1
                self.bump()
                self.bump()
            elif a == "*" and b == "/":
                depth -= 1
                self.bump()
                self.bump()
            elif a is not None:
                text.append(a)
                self.bump()
            else:
                break
        self.out.comments.append(Comment(start, self.line, "".join(text), trailing))

    def string_body(self, line):
        text = []
        while self.peek() is not None:
            c = self.peek()
            if c == "\\":
                text.append(c)
                self.bump()
                e = self.peek()
                if e is not None:
                    text.append(e)
                    self.bump()
            elif c == '"':
                self.bump()
                break
            else:
                text.append(c)
                self.bump()
        self.push(STR, "".join(text), line)

    def raw_string_body(self, line):
        hashes = 0
        while self.peek() == "#":
            hashes += 1
            self.bump()
        if self.peek() != '"':
            return
        self.bump()
        text = []
        while self.peek() is not None:
            c = self.peek()
            if c == '"':
                if all(self.peek(1 + k) == "#" for k in range(hashes)):
                    self.bump()
                    for _ in range(hashes):
                        self.bump()
                    break
                text.append(c)
                self.bump()
            else:
                text.append(c)
                self.bump()
        self.push(STR, "".join(text), line)

    def quote(self):
        self.bump()  # the '
        c = self.peek()
        if c == "\\":
            self.bump()
            self.bump()
            while self.peek() is not None:
                done = self.peek() == "'"
                self.bump()
                if done:
                    break
        elif c is not None and self.peek(1) == "'":
            self.bump()
            self.bump()
        elif c is not None and is_ident_start(c):
            while self.peek() is not None and is_ident_cont(self.peek()):
                self.bump()

    def run(self):
        while self.peek() is not None:
            c = self.peek()
            if c == "/" and self.peek(1) == "/":
                self.line_comment()
            elif c == "/" and self.peek(1) == "*":
                self.block_comment()
            elif c == '"':
                line = self.line
                self.bump()
                self.string_body(line)
            elif c == "'":
                self.quote()
            elif c.isspace():
                self.bump()
            elif is_ident_start(c):
                line = self.line
                ident = []
                while self.peek() is not None and is_ident_cont(self.peek()):
                    ident.append(self.peek())
                    self.bump()
                ident = "".join(ident)
                prefix = ident in ("r", "b", "br")
                if self.peek() == '"' and prefix:
                    if ident == "b":
                        self.bump()
                        self.string_body(line)
                    else:
                        self.raw_string_body(line)
                elif self.peek() == "#" and prefix and ident != "b":
                    self.raw_string_body(line)
                elif self.peek() == "'" and ident == "b":
                    self.quote()
                else:
                    self.push(IDENT, ident, line)
            elif c.isdigit():
                line = self.line
                num = []
                while self.peek() is not None:
                    c2 = self.peek()
                    nxt = self.peek(1)
                    frac = c2 == "." and nxt is not None and nxt.isdigit()
                    if not (c2.isalnum() or c2 == "_" or frac):
                        break
                    num.append(c2)
                    self.bump()
                self.push(NUM, "".join(num), line)
            elif c == ":" and self.peek(1) == ":":
                line = self.line
                self.bump()
                self.bump()
                self.push(PUNCT, "::", line)
            else:
                line = self.line
                self.bump()
                self.push(PUNCT, c, line)
        self.out.n_lines = self.line
        return self.out


def lex(src):
    return Lexer(src).run()


# --------------------------------------------------------------------- rules

DET_MODULES = [
    "rust/src/coreset/",
    "rust/src/sweep/",
    "rust/src/data/",
    "rust/src/kernel.rs",
    "rust/src/runtime/native.rs",
]
CLOCK_MODULES = DET_MODULES + ["rust/src/report.rs"]
FMA_MODULES = ["rust/src/kernel.rs", "rust/src/runtime/native.rs"]
UNSAFE_SCOPES = {"rust/src/kernel.rs": "avx2", "rust/src/data/store.rs": "mm"}
ENV_READERS = [
    "rust/src/runtime_config.rs",
    "rust/src/util/logging.rs",
    "rust/src/bench_util/mod.rs",
    "rust/src/bench_util/scenario.rs",
]
ENV_READS = ("var", "var_os", "vars", "vars_os")
ENV_WRITES = ("set_var", "remove_var")
ARTIFACT_MODULES = [
    "rust/src/coreset/embed_cache.rs",
    "rust/src/data/cache.rs",
    "rust/src/data/shard.rs",
    "rust/src/data/store.rs",
    "rust/src/sweep/store.rs",
]
IO_FACADE_SCOPES = ["rust/src/util/artifact_io.rs"]
ALLOWABLE = [
    "DET-CLOCK",
    "DET-FMA",
    "DET-HASH",
    "ENV-HYGIENE",
    "IO-FACADE",
    "ISA-DISPATCH",
    "UNSAFE-SCOPE",
]


def reason_ok(reason):
    return sum(1 for ch in reason if ch.isalnum()) >= 3


def balance(toks, open_idx, op, cl):
    depth = 0
    for j in range(open_idx, len(toks)):
        t = toks[j]
        if t.kind == PUNCT:
            if t.text == op:
                depth += 1
            elif t.text == cl:
                depth -= 1
                if depth == 0:
                    return j
    return max(len(toks) - 1, 0)


class FileCx:
    def __init__(self, rel, lx):
        self.rel = rel
        self.lx = lx
        toks = lx.toks
        n = len(toks)
        self.attr_tok = [False] * n
        self.use_tok = [False] * n
        self.test_line = [False] * (lx.n_lines + 2)

        def punct(k, s):
            return k < n and toks[k].kind == PUNCT and toks[k].text == s

        attr_spans = []
        i = 0
        while i < n:
            if toks[i].kind == PUNCT and toks[i].text == "#":
                if punct(i + 1, "["):
                    o = i + 1
                elif punct(i + 1, "!") and punct(i + 2, "["):
                    o = i + 2
                else:
                    o = None
                if o is not None:
                    j = balance(toks, o, "[", "]")
                    for k in range(i, j + 1):
                        self.attr_tok[k] = True
                    span = toks[o : j + 1]
                    has_test = any(t.kind == IDENT and t.text == "test" for t in span)
                    has_not = any(t.kind == IDENT and t.text == "not" for t in span)
                    attr_spans.append((i, j, has_test and not has_not))
                    i = j + 1
                    continue
            i += 1

        i = 0
        while i < n:
            if toks[i].kind == IDENT and toks[i].text == "use" and not self.attr_tok[i]:
                j = i
                while j < n and not (toks[j].kind == PUNCT and toks[j].text == ";"):
                    self.use_tok[j] = True
                    j += 1
                if j < n:
                    self.use_tok[j] = True
                i = j + 1
                continue
            i += 1

        if rel.startswith("rust/tests/"):
            self.test_line = [True] * (lx.n_lines + 2)
        else:
            for astart, aend, is_test in attr_spans:
                if not is_test:
                    continue
                k = aend + 1
                while k < n and self.attr_tok[k]:
                    k += 1
                end_tok = max(n - 1, 0)
                m = k
                while m < n:
                    t = toks[m]
                    if t.kind == PUNCT and t.text == ";":
                        end_tok = m
                        break
                    if t.kind == PUNCT and t.text == "{":
                        end_tok = balance(toks, m, "{", "}")
                        break
                    m += 1
                frm = toks[astart].line
                to = toks[end_tok].line if end_tok < n else frm
                for line in range(frm, min(to, lx.n_lines + 1) + 1):
                    self.test_line[line] = True

        self.allows = []
        for c in lx.comments:
            trimmed = c.text.lstrip()
            if not trimmed.startswith("lint:allow"):
                continue
            rest = trimmed[len("lint:allow") :]
            rule, reason = "", ""
            if rest.startswith("(") and ")" in rest:
                rule, _, reason = rest[1:].partition(")")
                rule, reason = rule.strip(), reason.strip()
            if c.trailing:
                target = c.line
            else:
                target = None
                for ln in range(c.end_line + 1, lx.n_lines + 2):
                    if lx.line_has_code(ln):
                        target = ln
                        break
            self.allows.append((rule, reason, target, c.line))

    def is_test_line(self, line):
        return 0 <= line < len(self.test_line) and self.test_line[line]

    def suppressed(self, rule, line):
        return any(
            r == rule and t == line and r in ALLOWABLE and reason_ok(re)
            for (r, re, t, _) in self.allows
        )

    def safety_covered(self, line):
        def has_safety(ln):
            return any(
                c.line <= ln <= c.end_line and "SAFETY:" in c.text for c in self.lx.comments
            )

        if has_safety(line):
            return True
        ln = line
        for _ in range(10):
            if ln <= 1:
                return False
            ln -= 1
            if has_safety(ln):
                return True
            on_line = [k for k, t in enumerate(self.lx.toks) if t.line == ln]
            if not on_line:
                continue
            if all(self.attr_tok[k] for k in on_line):
                continue
            return False
        return False


def in_modules(rel, modules):
    return any(rel.startswith(m) if m.endswith("/") else rel == m for m in modules)


def crest_names(s):
    names = []
    i = 0
    while True:
        pos = s.find("CREST_", i)
        if pos < 0:
            break
        end = pos + len("CREST_")
        while end < len(s) and (s[end].isupper() or s[end].isdigit() or s[end] == "_"):
            end += 1
        name = s[pos:end].rstrip("_")
        if len(name) > len("CREST_"):
            names.append(name)
        i = end
    return names


def lint_file(rel, src, readme):
    lx = lex(src)
    cx = FileCx(rel, lx)
    toks = lx.toks
    out = []

    def push(line, rule, message):
        out.append((rel, line, rule, message))

    # DET-HASH / DET-CLOCK
    for scope, names, rule in (
        (DET_MODULES, ("HashMap", "HashSet"), "DET-HASH"),
        (CLOCK_MODULES, ("Instant", "SystemTime"), "DET-CLOCK"),
    ):
        if in_modules(rel, scope):
            for i, t in enumerate(toks):
                if t.kind != IDENT or t.text not in names:
                    continue
                if cx.use_tok[i] or cx.attr_tok[i] or cx.is_test_line(t.line):
                    continue
                if not cx.suppressed(rule, t.line):
                    push(t.line, rule, f"`{t.text}`")

    # DET-FMA
    if in_modules(rel, FMA_MODULES):
        for t in toks:
            if t.kind == IDENT and (t.text == "mul_add" or "fmadd" in t.text.lower()):
                if not cx.suppressed("DET-FMA", t.line):
                    push(t.line, "DET-FMA", f"`{t.text}`")

    # UNSAFE-SCOPE
    unsafe_idxs = [i for i, t in enumerate(toks) if t.kind == IDENT and t.text == "unsafe"]
    if unsafe_idxs:
        module = UNSAFE_SCOPES.get(rel)
        if module is None:
            last = 0
            for i in unsafe_idxs:
                line = toks[i].line
                if line != last and not cx.suppressed("UNSAFE-SCOPE", line):
                    push(line, "UNSAFE-SCOPE", "unsafe outside registered scopes")
                    last = line
        else:
            scoped_allow = any(
                cx.attr_tok[i]
                and toks[i].kind == IDENT
                and toks[i].text == "allow"
                and i + 2 < len(toks)
                and toks[i + 2].kind == IDENT
                and toks[i + 2].text == "unsafe_code"
                for i in range(len(toks))
            )
            if not scoped_allow:
                push(1, "UNSAFE-SCOPE", "missing scoped #[allow(unsafe_code)]")
            mod_span = None
            for i in range(len(toks) - 1):
                if (
                    toks[i].kind == IDENT
                    and toks[i].text == "mod"
                    and toks[i + 1].kind == IDENT
                    and toks[i + 1].text == module
                ):
                    m = i + 2
                    while m < len(toks) and not (
                        toks[m].kind == PUNCT and toks[m].text == "{"
                    ):
                        m += 1
                    if m < len(toks):
                        mod_span = (m, balance(toks, m, "{", "}"))
                    break
            if mod_span is None:
                push(1, "UNSAFE-SCOPE", f"registered module `{module}` not found")
            else:
                mstart, mend = mod_span
                covered = []
                for i in unsafe_idxs:
                    line = toks[i].line
                    if not (mstart <= i <= mend):
                        if not cx.suppressed("UNSAFE-SCOPE", line):
                            push(line, "UNSAFE-SCOPE", f"unsafe outside module `{module}`")
                        continue
                    if any(s <= i <= e for (s, e) in covered):
                        continue
                    if cx.safety_covered(line):
                        m = i + 1
                        while m < len(toks) and not (
                            toks[m].kind == PUNCT and toks[m].text == "{"
                        ):
                            m += 1
                        if m < len(toks):
                            covered.append((m, balance(toks, m, "{", "}")))
                        continue
                    if not cx.suppressed("UNSAFE-SCOPE", line):
                        push(line, "UNSAFE-SCOPE", "unsafe without SAFETY comment")

    # ENV-HYGIENE
    registered = rel in ENV_READERS
    for i in range(len(toks) - 2):
        w0, w1, w2 = toks[i], toks[i + 1], toks[i + 2]
        if not (w0.kind == IDENT and w0.text == "env" and w1.text == "::" and w2.kind == IDENT):
            continue
        call, line = w2.text, w2.line
        if call in ENV_READS and not registered and not cx.suppressed("ENV-HYGIENE", line):
            push(line, "ENV-HYGIENE", f"env::{call} outside runtime_config.rs")
        if (
            call in ENV_WRITES
            and not cx.is_test_line(line)
            and not cx.suppressed("ENV-HYGIENE", line)
        ):
            push(line, "ENV-HYGIENE", f"env::{call} outside test code")
    for t in toks:
        if t.kind != STR or cx.is_test_line(t.line):
            continue
        for name in crest_names(t.text):
            if name not in readme and not cx.suppressed("ENV-HYGIENE", t.line):
                push(t.line, "ENV-HYGIENE", f"`{name}` not documented in README.md")

    # IO-FACADE
    if in_modules(rel, ARTIFACT_MODULES) and rel not in IO_FACADE_SCOPES:
        last = 0
        for i, t in enumerate(toks):
            if t.kind != IDENT or t.text not in ("fs", "File"):
                continue
            if not (i + 1 < len(toks) and toks[i + 1].kind == PUNCT and toks[i + 1].text == "::"):
                continue
            line = t.line
            if cx.use_tok[i] or cx.attr_tok[i] or cx.is_test_line(line):
                continue
            if line == last or cx.suppressed("IO-FACADE", line):
                continue
            last = line
            push(line, "IO-FACADE", f"raw `{t.text}::` call bypasses the artifact_io facade")

    # ISA-DISPATCH
    in_kernel = rel == "rust/src/kernel.rs"
    for i, t in enumerate(toks):
        if t.kind != IDENT:
            continue
        line = t.line
        if not in_kernel:
            bad = None
            if t.text == "target_feature":
                bad = "#[target_feature] outside kernel.rs"
            elif t.text == "is_x86_feature_detected":
                bad = "feature detection outside kernel.rs"
            elif t.text == "avx2" and i + 1 < len(toks) and toks[i + 1].text == "::":
                bad = "direct avx2:: call outside kernel.rs"
            if bad and not cx.suppressed("ISA-DISPATCH", line):
                push(line, "ISA-DISPATCH", bad)
        elif t.text == "target_feature" and cx.attr_tok[i]:
            k = i
            while k < len(toks) and cx.attr_tok[k]:
                k += 1
            is_pub = False
            while k < len(toks) and not (toks[k].kind == IDENT and toks[k].text == "fn"):
                if toks[k].kind == IDENT and toks[k].text == "pub":
                    is_pub = True
                k += 1
            if is_pub and not cx.suppressed("ISA-DISPATCH", line):
                push(line, "ISA-DISPATCH", "#[target_feature] fn must be private")

    # LINT-ALLOW
    for rule, reason, target, cline in cx.allows:
        if not rule:
            push(cline, "LINT-ALLOW", "malformed lint:allow directive")
        elif rule not in ALLOWABLE:
            push(cline, "LINT-ALLOW", f"unknown rule id `{rule}`")
        elif not reason_ok(reason):
            push(cline, "LINT-ALLOW", f"lint:allow({rule}) carries no written reason")
        elif target is None:
            push(cline, "LINT-ALLOW", f"lint:allow({rule}) has no code line to attach to")

    out.sort(key=lambda d: (d[1], d[2], d[3]))
    return out


SCAN_ROOTS = ["rust/src", "rust/tests", "rust/benches", "examples"]
SKIP_DIRS = {"lint_fixtures"}


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    readme = (root / "README.md").read_text()
    files = []
    for sub in SCAN_ROOTS:
        base = root / sub
        if base.is_dir():
            for p in sorted(base.rglob("*.rs")):
                if SKIP_DIRS.isdisjoint(p.parts):
                    files.append(p)
    findings = []
    for p in files:
        rel = p.relative_to(root).as_posix()
        findings.extend(lint_file(rel, p.read_text(), readme))
    for rel, line, rule, msg in findings:
        print(f"{rel}:{line}: [{rule}] {msg}")
    if findings:
        print(f"lint mirror: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"lint mirror: clean ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
