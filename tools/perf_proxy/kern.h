/* Shared declarations for the kernel perf proxy.
 *
 * Two translation units implement the same panel set:
 *   kern_scalar.c  — line-for-line port of the Rust scalar tile panels,
 *                    compiled -O3 with the default x86-64 target (SSE2
 *                    autovectorization), standing in for the rustc
 *                    release build of the scalar path;
 *   kern_avx2.c    — port of the kernel::avx2 intrinsic panels, compiled
 *                    -O2 -mavx2 -mno-fma (the intrinsics pin the codegen,
 *                    matching target_feature(enable = "avx2") without
 *                    FMA contraction).
 *
 * Matrices are row-major float32, exactly the MatF32 layout.
 */
#ifndef PERF_PROXY_KERN_H
#define PERF_PROXY_KERN_H

#include <stddef.h>

#define MR 4
#define NR 16
#define PROD_BLOCK 64

#define DECL(isa)                                                              \
    float isa##_dot4(const float *a, const float *b, size_t n);                \
    void isa##_dot4_rows(const float *a, const float *m, size_t cols,          \
                         size_t lo, size_t hi, float *out);                    \
    void isa##_matmul_panel(float *rows_out, size_t rows, const float *x,      \
                            size_t d_in, const float *w, size_t d_out);        \
    void isa##_nt_panel(float *rows_out, size_t rows, size_t d_in,             \
                        const float *d, const float *w, size_t d_out,          \
                        const float *act);                                     \
    void isa##_wgrad_panel(float *gw, size_t kn, const float *input,           \
                           size_t rows, size_t d_in, const float *d,           \
                           size_t d_out);                                      \
    void isa##_euclid_block(const float *g, size_t cols, const float *sq,      \
                            size_t j, size_t n, float *out);                   \
    void isa##_prod_block(const float *a, size_t h, const float *g,            \
                          size_t c, const float *sq, size_t j, size_t n,       \
                          float *out);

DECL(scalar)
DECL(avx2)

#undef DECL

#endif
