/* Scalar tile panels — a direct port of rust/src/kernel.rs's scalar path
 * (dot4 / dot4_1x4 / dot4_2x2 / matmul_panel / nt_panel / wgrad_panel and
 * the blocked distance epilogues). Compiled -O3 without -mavx2 so gcc
 * autovectorizes to SSE2, the same ceiling rustc's release build has on
 * the default x86-64 target. */
#include "kern.h"

#include <string.h>

float scalar_dot4(const float *a, const float *b, size_t n) {
    float acc[4] = {0, 0, 0, 0};
    size_t c = n & ~(size_t)3;
    for (size_t k = 0; k < c; k += 4) {
        acc[0] += a[k] * b[k];
        acc[1] += a[k + 1] * b[k + 1];
        acc[2] += a[k + 2] * b[k + 2];
        acc[3] += a[k + 3] * b[k + 3];
    }
    float s = acc[0] + acc[1] + acc[2] + acc[3];
    for (size_t k = c; k < n; k++)
        s += a[k] * b[k];
    return s;
}

static void dot4_1x4(const float *a, const float *b0, const float *b1,
                     const float *b2, const float *b3, size_t n, float out[4]) {
    float acc[4][4];
    memset(acc, 0, sizeof acc);
    size_t c = n & ~(size_t)3;
    for (size_t k = 0; k < c; k += 4) {
        for (size_t l = 0; l < 4; l++) {
            float av = a[k + l];
            acc[0][l] += av * b0[k + l];
            acc[1][l] += av * b1[k + l];
            acc[2][l] += av * b2[k + l];
            acc[3][l] += av * b3[k + l];
        }
    }
    for (size_t r = 0; r < 4; r++)
        out[r] = acc[r][0] + acc[r][1] + acc[r][2] + acc[r][3];
    for (size_t k = c; k < n; k++) {
        float av = a[k];
        out[0] += av * b0[k];
        out[1] += av * b1[k];
        out[2] += av * b2[k];
        out[3] += av * b3[k];
    }
}

void scalar_dot4_rows(const float *a, const float *m, size_t cols, size_t lo,
                      size_t hi, float *out) {
    size_t i = lo, o = 0;
    for (; i + 4 <= hi; i += 4, o += 4)
        dot4_1x4(a, m + i * cols, m + (i + 1) * cols, m + (i + 2) * cols,
                 m + (i + 3) * cols, cols, out + o);
    for (; i < hi; i++, o++)
        out[o] = scalar_dot4(a, m + i * cols, cols);
}

void scalar_matmul_panel(float *rows_out, size_t rows, const float *x,
                         size_t d_in, const float *w, size_t d_out) {
    size_t i = 0;
    while (i + MR <= rows) {
        const float *x0 = x + i * d_in, *x1 = x0 + d_in, *x2 = x1 + d_in,
                    *x3 = x2 + d_in;
        size_t j = 0;
        while (j + NR <= d_out) {
            float acc[MR][NR];
            memset(acc, 0, sizeof acc);
            for (size_t k = 0; k < d_in; k++) {
                const float *wk = w + k * d_out + j;
                float xv[MR] = {x0[k], x1[k], x2[k], x3[k]};
                for (size_t r = 0; r < MR; r++)
                    for (size_t l = 0; l < NR; l++)
                        acc[r][l] += xv[r] * wk[l];
            }
            for (size_t r = 0; r < MR; r++) {
                float *o = rows_out + (i + r) * d_out + j;
                for (size_t l = 0; l < NR; l++)
                    o[l] += acc[r][l];
            }
            j += NR;
        }
        while (j < d_out) {
            float acc[MR] = {0, 0, 0, 0};
            for (size_t k = 0; k < d_in; k++) {
                float wv = w[k * d_out + j];
                acc[0] += x0[k] * wv;
                acc[1] += x1[k] * wv;
                acc[2] += x2[k] * wv;
                acc[3] += x3[k] * wv;
            }
            for (size_t r = 0; r < MR; r++)
                rows_out[(i + r) * d_out + j] += acc[r];
            j++;
        }
        i += MR;
    }
    while (i < rows) {
        const float *xi = x + i * d_in;
        float *orow = rows_out + i * d_out;
        size_t j = 0;
        while (j + NR <= d_out) {
            float acc[NR];
            memset(acc, 0, sizeof acc);
            for (size_t k = 0; k < d_in; k++) {
                const float *wk = w + k * d_out + j;
                for (size_t l = 0; l < NR; l++)
                    acc[l] += xi[k] * wk[l];
            }
            for (size_t l = 0; l < NR; l++)
                orow[j + l] += acc[l];
            j += NR;
        }
        while (j < d_out) {
            float acc = 0;
            for (size_t k = 0; k < d_in; k++)
                acc += xi[k] * w[k * d_out + j];
            orow[j] += acc;
            j++;
        }
        i++;
    }
}

static void dot4_2x2(const float *a0, const float *a1, const float *b0,
                     const float *b1, size_t n, float out[4]) {
    float acc[4][4];
    memset(acc, 0, sizeof acc);
    size_t c = n & ~(size_t)3;
    for (size_t k = 0; k < c; k += 4) {
        for (size_t l = 0; l < 4; l++) {
            float x0 = a0[k + l], x1 = a1[k + l];
            float y0 = b0[k + l], y1 = b1[k + l];
            acc[0][l] += x0 * y0;
            acc[1][l] += x0 * y1;
            acc[2][l] += x1 * y0;
            acc[3][l] += x1 * y1;
        }
    }
    for (size_t r = 0; r < 4; r++)
        out[r] = acc[r][0] + acc[r][1] + acc[r][2] + acc[r][3];
    for (size_t k = c; k < n; k++) {
        float x0 = a0[k], x1 = a1[k], y0 = b0[k], y1 = b1[k];
        out[0] += x0 * y0;
        out[1] += x0 * y1;
        out[2] += x1 * y0;
        out[3] += x1 * y1;
    }
}

void scalar_nt_panel(float *rows_out, size_t rows, size_t d_in, const float *d,
                     const float *w, size_t d_out, const float *act) {
    size_t i = 0;
    while (i + 2 <= rows) {
        const float *d0 = d + i * d_out, *d1 = d0 + d_out;
        size_t j = 0;
        while (j + 2 <= d_in) {
            int keep[4];
            if (act) {
                keep[0] = act[i * d_in + j] > 0.0f;
                keep[1] = act[i * d_in + j + 1] > 0.0f;
                keep[2] = act[(i + 1) * d_in + j] > 0.0f;
                keep[3] = act[(i + 1) * d_in + j + 1] > 0.0f;
            } else {
                keep[0] = keep[1] = keep[2] = keep[3] = 1;
            }
            if (keep[0] || keep[1] || keep[2] || keep[3]) {
                float s[4];
                dot4_2x2(d0, d1, w + j * d_out, w + (j + 1) * d_out, d_out, s);
                if (keep[0])
                    rows_out[i * d_in + j] += s[0];
                if (keep[1])
                    rows_out[i * d_in + j + 1] += s[1];
                if (keep[2])
                    rows_out[(i + 1) * d_in + j] += s[2];
                if (keep[3])
                    rows_out[(i + 1) * d_in + j + 1] += s[3];
            }
            j += 2;
        }
        while (j < d_in) {
            const float *wj = w + j * d_out;
            for (size_t r = 0; r < 2; r++) {
                int keep = act ? act[(i + r) * d_in + j] > 0.0f : 1;
                if (keep)
                    rows_out[(i + r) * d_in + j] +=
                        scalar_dot4(d + (i + r) * d_out, wj, d_out);
            }
            j++;
        }
        i += 2;
    }
    while (i < rows) {
        const float *di = d + i * d_out;
        for (size_t j = 0; j < d_in; j++) {
            int keep = act ? act[i * d_in + j] > 0.0f : 1;
            if (keep)
                rows_out[i * d_in + j] += scalar_dot4(di, w + j * d_out, d_out);
        }
        i++;
    }
}

void scalar_wgrad_panel(float *gw, size_t kn, const float *input, size_t rows,
                        size_t d_in, const float *d, size_t d_out) {
    size_t kk = 0;
    while (kk + MR <= kn) {
        size_t j = 0;
        while (j + NR <= d_out) {
            float acc[MR][NR];
            memset(acc, 0, sizeof acc);
            for (size_t i = 0; i < rows; i++) {
                const float *hi = input + i * d_in;
                const float *di = d + i * d_out + j;
                float hv[MR] = {hi[kk], hi[kk + 1], hi[kk + 2], hi[kk + 3]};
                for (size_t r = 0; r < MR; r++) {
                    if (hv[r] == 0.0f)
                        continue;
                    for (size_t l = 0; l < NR; l++)
                        acc[r][l] += hv[r] * di[l];
                }
            }
            for (size_t r = 0; r < MR; r++) {
                float *g = gw + (kk + r) * d_out + j;
                for (size_t l = 0; l < NR; l++)
                    g[l] += acc[r][l];
            }
            j += NR;
        }
        while (j < d_out) {
            float acc[MR] = {0, 0, 0, 0};
            for (size_t i = 0; i < rows; i++) {
                const float *hi = input + i * d_in;
                float dv = d[i * d_out + j];
                for (size_t r = 0; r < MR; r++) {
                    float h = hi[kk + r];
                    if (h != 0.0f)
                        acc[r] += h * dv;
                }
            }
            for (size_t r = 0; r < MR; r++)
                gw[(kk + r) * d_out + j] += acc[r];
            j++;
        }
        kk += MR;
    }
    while (kk < kn) {
        size_t j = 0;
        while (j + NR <= d_out) {
            float acc[NR];
            memset(acc, 0, sizeof acc);
            for (size_t i = 0; i < rows; i++) {
                float h = input[i * d_in + kk];
                if (h == 0.0f)
                    continue;
                const float *di = d + i * d_out + j;
                for (size_t l = 0; l < NR; l++)
                    acc[l] += h * di[l];
            }
            for (size_t l = 0; l < NR; l++)
                gw[kk * d_out + j + l] += acc[l];
            j += NR;
        }
        while (j < d_out) {
            float acc = 0;
            for (size_t i = 0; i < rows; i++) {
                float h = input[i * d_in + kk];
                if (h != 0.0f)
                    acc += h * d[i * d_out + j];
            }
            gw[kk * d_out + j] += acc;
            j++;
        }
        kk++;
    }
}

void scalar_euclid_block(const float *g, size_t cols, const float *sq, size_t j,
                         size_t n, float *out) {
    scalar_dot4_rows(g + j * cols, g, cols, 0, n, out);
    float sj = sq[j];
    for (size_t i = 0; i < n; i++) {
        float v = sq[i] + sj - 2.0f * out[i];
        out[i] = v > 0.0f ? v : 0.0f;
    }
}

void scalar_prod_block(const float *a, size_t h, const float *g, size_t c,
                       const float *sq, size_t j, size_t n, float *out) {
    const float *aj = a + j * h;
    const float *gj = g + j * c;
    float sj = sq[j];
    float gbuf[PROD_BLOCK];
    for (size_t lo = 0; lo < n; lo += PROD_BLOCK) {
        size_t len = n - lo < PROD_BLOCK ? n - lo : PROD_BLOCK;
        scalar_dot4_rows(gj, g, c, lo, lo + len, gbuf);
        scalar_dot4_rows(aj, a, h, lo, lo + len, out + lo);
        for (size_t k = 0; k < len; k++) {
            float v = sq[lo + k] + sj - 2.0f * out[lo + k] * gbuf[k];
            out[lo + k] = v > 0.0f ? v : 0.0f;
        }
    }
}
