/* Kernel perf-proxy driver.
 *
 * Benches every (kernel, ISA) pair at the exact shapes, names, warmup and
 * rep counts benches/perf.rs and benches/scaling.rs use, then prints a
 * CREST_BENCH_JSON-format array to stdout (the record fields match
 * bench_util::BenchResult::to_json, threads pinned to 1). Usage:
 *
 *   ./perf_proxy [quick|full]
 *
 * `quick` caps reps at 5 and warmup at 1, exactly like CREST_BENCH_QUICK;
 * run.sh runs both modes and assembles BENCH_perf.json.
 *
 * The AVX2 panels are only benched when the CPU reports AVX2 (mirroring
 * kernel::available_isas).
 */
#define _POSIX_C_SOURCE 199309L
#include "kern.h"

#include <cpuid.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ------------------------------------------------------------- plumbing */

static uint64_t lcg_state = 0x5eed1234abcd9876ULL;

static float frand(void) {
    lcg_state = lcg_state * 6364136223846793005ULL + 1442695040888963407ULL;
    return (float)((lcg_state >> 33) / (double)(1ULL << 31)) * 4.0f - 2.0f;
}

static float *randv(size_t n) {
    float *v = malloc(n * sizeof(float));
    for (size_t i = 0; i < n; i++)
        v[i] = frand();
    return v;
}

static float *reluv(size_t n) {
    float *v = randv(n);
    for (size_t i = 0; i < n; i++)
        if (v[i] < 0.0f)
            v[i] = 0.0f;
    return v;
}

static double now_secs(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

static int cmp_dbl(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

/* linear-interpolation percentile on a sorted copy (util::stats) */
static double percentile(double *xs, size_t n, double p) {
    double *v = malloc(n * sizeof(double));
    memcpy(v, xs, n * sizeof(double));
    qsort(v, n, sizeof(double), cmp_dbl);
    double rank = p / 100.0 * (double)(n - 1);
    size_t lo = (size_t)floor(rank), hi = (size_t)ceil(rank);
    double r = lo == hi ? v[lo] : v[lo] + (rank - lo) * (v[hi] - v[lo]);
    free(v);
    return r;
}

static int first_record = 1;

static void emit(const char *name, const char *isa, size_t reps, double *t,
                 uint64_t flops, int quick) {
    double mean = 0, mn = t[0];
    for (size_t i = 0; i < reps; i++) {
        mean += t[i];
        if (t[i] < mn)
            mn = t[i];
    }
    mean /= (double)reps;
    double p50 = percentile(t, reps, 50.0);
    double p95 = percentile(t, reps, 95.0);
    double *dev = malloc(reps * sizeof(double));
    for (size_t i = 0; i < reps; i++)
        dev[i] = fabs(t[i] - p50);
    double mad = percentile(dev, reps, 50.0);
    free(dev);
    printf("%s  {\"name\": \"%s\", \"reps\": %zu, \"threads\": 1, "
           "\"mean_secs\": %.9g, \"min_secs\": %.9g, \"p50_secs\": %.9g, "
           "\"p95_secs\": %.9g, \"mad_secs\": %.9g, \"quick\": %s, "
           "\"isa\": \"%s\"",
           first_record ? "[" : ",", name, reps, mean, mn, p50, p95, mad,
           quick ? "true" : "false", isa);
    if (flops > 0 && p50 > 0.0)
        printf(", \"flops\": %llu, \"gflops_p50\": %.6g",
               (unsigned long long)flops, (double)flops / p50 / 1e9);
    printf("}\n");
    first_record = 0;
}

static volatile float sink;

#define BENCH(label, isaname, warm, nreps, flops, quickflag, stmt)             \
    do {                                                                       \
        size_t w_ = (quickflag) && (warm) > 1 ? 1 : (warm);                    \
        size_t r_ = (quickflag) && (nreps) > 5 ? 5 : (nreps);                  \
        for (size_t it_ = 0; it_ < w_; it_++) {                                \
            stmt;                                                              \
        }                                                                      \
        double *t_ = malloc(r_ * sizeof(double));                              \
        for (size_t it_ = 0; it_ < r_; it_++) {                                \
            double t0_ = now_secs();                                           \
            stmt;                                                              \
            t_[it_] = now_secs() - t0_;                                        \
        }                                                                      \
        emit(label, isaname, r_, t_, flops, quickflag);                        \
        free(t_);                                                              \
    } while (0)

static int has_avx2(void) {
    unsigned eax, ebx, ecx, edx;
    if (!__get_cpuid_count(7, 0, &eax, &ebx, &ecx, &edx))
        return 0;
    return (ebx >> 5) & 1; /* AVX2 feature bit */
}

/* ---------------------------------------------------------------- shapes */

int main(int argc, char **argv) {
    int quick = argc > 1 && strcmp(argv[1], "quick") == 0;
    int avx2 = has_avx2();

    /* perf.rs kernel section: fixed odd shapes, threads pinned to 1 */
    const size_t m = 96, k = 67, n = 130;
    const size_t bn = 768, bc = 10, bh = 66;
    float *x = randv(m * k);
    float *w = randv(k * n);
    float *d = randv(m * n);
    float *wt = randv(k * n);
    float *act = reluv(m * k);
    float *g = randv(bn * bc);
    float *a = randv(bn * bh);
    float *gsq = malloc(bn * sizeof(float));
    float *asq = malloc(bn * sizeof(float));
    for (size_t i = 0; i < bn; i++) {
        gsq[i] = scalar_dot4(g + i * bc, g + i * bc, bc);
        asq[i] = scalar_dot4(a + i * bh, a + i * bh, bh);
    }
    float *out = calloc(m * n, sizeof(float));
    float *outk = calloc(m * k, sizeof(float));
    float *gw = calloc(k * n, sizeof(float));
    float *db = calloc(bn, sizeof(float));
    uint64_t mmf = 2ULL * m * k * n;
    char name[128];

    for (int pass = 0; pass < 2; pass++) {
        const char *isa = pass == 0 ? "scalar" : "avx2";
        if (pass == 1 && !avx2)
            break;
        snprintf(name, sizeof name, "kernel add_matmul m=%zu k=%zu n=%zu isa=%s", m, k, n, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, mmf, quick, scalar_matmul_panel(out, m, x, k, w, n));
        else
            BENCH(name, isa, 3, 20, mmf, quick, avx2_matmul_panel(out, m, x, k, w, n));
        snprintf(name, sizeof name, "kernel add_matmul_nt m=%zu k=%zu n=%zu isa=%s", m, k, n, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, mmf, quick, scalar_nt_panel(outk, m, k, d, wt, n, NULL));
        else
            BENCH(name, isa, 3, 20, mmf, quick, avx2_nt_panel(outk, m, k, d, wt, n, NULL));
        snprintf(name, sizeof name, "kernel add_matmul_nt_masked m=%zu k=%zu n=%zu isa=%s", m, k, n, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, mmf, quick, scalar_nt_panel(outk, m, k, d, wt, n, act));
        else
            BENCH(name, isa, 3, 20, mmf, quick, avx2_nt_panel(outk, m, k, d, wt, n, act));
        snprintf(name, sizeof name, "kernel accum_wgrad m=%zu k=%zu n=%zu isa=%s", m, k, n, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, mmf, quick, scalar_wgrad_panel(gw, k, x, m, k, d, n));
        else
            BENCH(name, isa, 3, 20, mmf, quick, avx2_wgrad_panel(gw, k, x, m, k, d, n));
        snprintf(name, sizeof name, "kernel dot4_rows n=%zu d=%zu isa=%s", bn, bh, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, 2ULL * bn * bh, quick, scalar_dot4_rows(a, a, bh, 0, bn, db));
        else
            BENCH(name, isa, 3, 20, 2ULL * bn * bh, quick, avx2_dot4_rows(a, a, bh, 0, bn, db));
        snprintf(name, sizeof name, "kernel euclid_block n=%zu c=%zu isa=%s", bn, bc, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, (uint64_t)(bn * (2 * bc + 4)), quick, scalar_euclid_block(g, bc, gsq, 0, bn, db));
        else
            BENCH(name, isa, 3, 20, (uint64_t)(bn * (2 * bc + 4)), quick, avx2_euclid_block(g, bc, gsq, 0, bn, db));
        snprintf(name, sizeof name, "kernel prod_block n=%zu c=%zu h=%zu isa=%s", bn, bc, bh, isa);
        if (pass == 0)
            BENCH(name, isa, 3, 20, (uint64_t)(bn * (2 * (bc + bh) + 6)), quick, scalar_prod_block(a, bh, g, bc, asq, 0, bn, db));
        else
            BENCH(name, isa, 3, 20, (uint64_t)(bn * (2 * (bc + bh) + 6)), quick, avx2_prod_block(a, bh, g, bc, asq, 0, bn, db));
        sink = out[0] + outk[0] + gw[0] + db[0];
    }

    /* scaling.rs SIMD section, t=1 row of the thread sweep */
    {
        const size_t sm = 512, sk = 256, sn = 256;
        float *sx = randv(sm * sk);
        float *sw = randv(sk * sn);
        float *so = calloc(sm * sn, sizeof(float));
        uint64_t sf = 2ULL * sm * sk * sn;
        size_t sreps = quick ? 5 : 10;
        snprintf(name, sizeof name, "add_matmul m=%zu k=%zu n=%zu isa=scalar t=1", sm, sk, sn);
        BENCH(name, "scalar", 2, sreps, sf, quick, scalar_matmul_panel(so, sm, sx, sk, sw, sn));
        if (avx2) {
            snprintf(name, sizeof name, "add_matmul m=%zu k=%zu n=%zu isa=avx2 t=1", sm, sk, sn);
            BENCH(name, "avx2", 2, sreps, sf, quick, avx2_matmul_panel(so, sm, sx, sk, sw, sn));
        }
        sink = so[0];
        free(sx);
        free(sw);
        free(so);
    }

    /* perf.rs gain scans: the dense O(n²·d) seeding pass over the prod and
     * euclid metrics (quick n=1024, full n=2048), threads pinned to 1 */
    {
        const size_t gn = quick ? 1024 : 2048, gc = 10, gh = 64;
        float *gg = randv(gn * gc);
        float *ga = randv(gn * gh);
        float *ggsq = malloc(gn * sizeof(float));
        float *gasq = malloc(gn * sizeof(float));
        float *mind = malloc(gn * sizeof(float));
        float *row = malloc(gn * sizeof(float));
        double *gain = malloc(gn * sizeof(double));
        for (size_t i = 0; i < gn; i++) {
            ggsq[i] = scalar_dot4(gg + i * gc, gg + i * gc, gc);
            gasq[i] = scalar_dot4(ga + i * gh, ga + i * gh, gh) * ggsq[i];
        }
        scalar_euclid_block(gg, gc, ggsq, 0, gn, mind);
        uint64_t ef = (uint64_t)gn * gn * (2 * gc + 4);
        uint64_t pf = (uint64_t)gn * gn * (2 * (gc + gh) + 6);
        snprintf(name, sizeof name, "gain scan euclid n=%zu c=%zu", gn, gc);
        BENCH(name, avx2 ? "avx2" : "scalar", 1, 8, ef, quick, {
            for (size_t j = 0; j < gn; j++) {
                if (avx2)
                    avx2_euclid_block(gg, gc, ggsq, j, gn, row);
                else
                    scalar_euclid_block(gg, gc, ggsq, j, gn, row);
                double s = 0;
                for (size_t i = 0; i < gn; i++) {
                    float v = mind[i] - row[i];
                    if (v > 0.0f)
                        s += v;
                }
                gain[j] = s;
            }
        });
        scalar_prod_block(ga, gh, gg, gc, gasq, 0, gn, mind);
        snprintf(name, sizeof name, "gain scan prod n=%zu h=%zu c=%zu", gn, gh, gc);
        BENCH(name, avx2 ? "avx2" : "scalar", 1, 8, pf, quick, {
            for (size_t j = 0; j < gn; j++) {
                if (avx2)
                    avx2_prod_block(ga, gh, gg, gc, gasq, j, gn, row);
                else
                    scalar_prod_block(ga, gh, gg, gc, gasq, j, gn, row);
                double s = 0;
                for (size_t i = 0; i < gn; i++) {
                    float v = mind[i] - row[i];
                    if (v > 0.0f)
                        s += v;
                }
                gain[j] = s;
            }
        });
        sink = (float)gain[0] + row[0];
        free(gg);
        free(ga);
        free(ggsq);
        free(gasq);
        free(mind);
        free(row);
        free(gain);
    }

    printf("]\n");
    free(x);
    free(w);
    free(d);
    free(wt);
    free(act);
    free(g);
    free(a);
    free(gsq);
    free(asq);
    free(out);
    free(outk);
    free(gw);
    free(db);
    return 0;
}
