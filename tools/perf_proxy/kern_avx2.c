/* AVX2 intrinsic panels — a port of rust/src/kernel.rs's `avx2` module.
 * Compiled -O2 -mavx2 -mno-fma: the intrinsics pin the vector shape the
 * Rust target_feature(enable = "avx2") functions emit, and -mno-fma keeps
 * gcc from contracting mul+add into FMA (the Rust layer never uses FMA —
 * it would change the bits vs the scalar path). */
#include "kern.h"

#include <immintrin.h>
#include <string.h>

static inline float fold4(const float *l) { return l[0] + l[1] + l[2] + l[3]; }

float avx2_dot4(const float *a, const float *b, size_t n) {
    size_t c = n & ~(size_t)3;
    __m128 acc = _mm_setzero_ps();
    size_t k = 0;
    for (; k < c; k += 4)
        acc = _mm_add_ps(acc, _mm_mul_ps(_mm_loadu_ps(a + k), _mm_loadu_ps(b + k)));
    float lanes[4];
    _mm_storeu_ps(lanes, acc);
    float s = fold4(lanes);
    for (k = c; k < n; k++)
        s += a[k] * b[k];
    return s;
}

static inline __m256 dup128(__m128 v) { return _mm256_set_m128(v, v); }

static void dot4_1x4(const float *a, const float *b0, const float *b1,
                     const float *b2, const float *b3, size_t n, float out[4]) {
    size_t c = n & ~(size_t)3;
    __m256 acc01 = _mm256_setzero_ps();
    __m256 acc23 = _mm256_setzero_ps();
    size_t k = 0;
    for (; k < c; k += 4) {
        __m256 ad = dup128(_mm_loadu_ps(a + k));
        __m256 b01 = _mm256_set_m128(_mm_loadu_ps(b1 + k), _mm_loadu_ps(b0 + k));
        __m256 b23 = _mm256_set_m128(_mm_loadu_ps(b3 + k), _mm_loadu_ps(b2 + k));
        acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(ad, b01));
        acc23 = _mm256_add_ps(acc23, _mm256_mul_ps(ad, b23));
    }
    float l01[8], l23[8];
    _mm256_storeu_ps(l01, acc01);
    _mm256_storeu_ps(l23, acc23);
    out[0] = fold4(l01);
    out[1] = fold4(l01 + 4);
    out[2] = fold4(l23);
    out[3] = fold4(l23 + 4);
    for (k = c; k < n; k++) {
        float av = a[k];
        out[0] += av * b0[k];
        out[1] += av * b1[k];
        out[2] += av * b2[k];
        out[3] += av * b3[k];
    }
}

void avx2_dot4_rows(const float *a, const float *m, size_t cols, size_t lo,
                    size_t hi, float *out) {
    size_t i = lo, o = 0;
    for (; i + 4 <= hi; i += 4, o += 4)
        dot4_1x4(a, m + i * cols, m + (i + 1) * cols, m + (i + 2) * cols,
                 m + (i + 3) * cols, cols, out + o);
    for (; i < hi; i++, o++)
        out[o] = avx2_dot4(a, m + i * cols, cols);
}

void avx2_matmul_panel(float *rows_out, size_t rows, const float *x,
                       size_t d_in, const float *w, size_t d_out) {
    size_t i = 0;
    while (i + MR <= rows) {
        const float *xr[MR] = {x + i * d_in, x + (i + 1) * d_in,
                               x + (i + 2) * d_in, x + (i + 3) * d_in};
        size_t j = 0;
        while (j + NR <= d_out) {
            __m256 acc[MR][2];
            for (size_t r = 0; r < MR; r++)
                acc[r][0] = acc[r][1] = _mm256_setzero_ps();
            for (size_t k = 0; k < d_in; k++) {
                const float *wp = w + k * d_out + j;
                __m256 w0 = _mm256_loadu_ps(wp);
                __m256 w1 = _mm256_loadu_ps(wp + 8);
                for (size_t r = 0; r < MR; r++) {
                    __m256 xv = _mm256_set1_ps(xr[r][k]);
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(xv, w0));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(xv, w1));
                }
            }
            for (size_t r = 0; r < MR; r++) {
                float *op = rows_out + (i + r) * d_out + j;
                _mm256_storeu_ps(op, _mm256_add_ps(_mm256_loadu_ps(op), acc[r][0]));
                _mm256_storeu_ps(op + 8,
                                 _mm256_add_ps(_mm256_loadu_ps(op + 8), acc[r][1]));
            }
            j += NR;
        }
        while (j < d_out) {
            float acc[MR] = {0, 0, 0, 0};
            for (size_t k = 0; k < d_in; k++) {
                float wv = w[k * d_out + j];
                for (size_t r = 0; r < MR; r++)
                    acc[r] += xr[r][k] * wv;
            }
            for (size_t r = 0; r < MR; r++)
                rows_out[(i + r) * d_out + j] += acc[r];
            j++;
        }
        i += MR;
    }
    while (i < rows) {
        const float *xi = x + i * d_in;
        float *orow = rows_out + i * d_out;
        size_t j = 0;
        while (j + NR <= d_out) {
            __m256 a0 = _mm256_setzero_ps();
            __m256 a1 = _mm256_setzero_ps();
            for (size_t k = 0; k < d_in; k++) {
                const float *wp = w + k * d_out + j;
                __m256 xv = _mm256_set1_ps(xi[k]);
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(xv, _mm256_loadu_ps(wp)));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(xv, _mm256_loadu_ps(wp + 8)));
            }
            _mm256_storeu_ps(orow + j,
                             _mm256_add_ps(_mm256_loadu_ps(orow + j), a0));
            _mm256_storeu_ps(orow + j + 8,
                             _mm256_add_ps(_mm256_loadu_ps(orow + j + 8), a1));
            j += NR;
        }
        while (j < d_out) {
            float acc = 0;
            for (size_t k = 0; k < d_in; k++)
                acc += xi[k] * w[k * d_out + j];
            orow[j] += acc;
            j++;
        }
        i++;
    }
}

static void dot4_2x2(const float *a0, const float *a1, const float *b0,
                     const float *b1, size_t n, float out[4]) {
    size_t c = n & ~(size_t)3;
    __m256 acc01 = _mm256_setzero_ps();
    __m256 acc23 = _mm256_setzero_ps();
    size_t k = 0;
    for (; k < c; k += 4) {
        __m256 bb = _mm256_set_m128(_mm_loadu_ps(b1 + k), _mm_loadu_ps(b0 + k));
        __m256 x0 = dup128(_mm_loadu_ps(a0 + k));
        __m256 x1 = dup128(_mm_loadu_ps(a1 + k));
        acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(x0, bb));
        acc23 = _mm256_add_ps(acc23, _mm256_mul_ps(x1, bb));
    }
    float l01[8], l23[8];
    _mm256_storeu_ps(l01, acc01);
    _mm256_storeu_ps(l23, acc23);
    out[0] = fold4(l01);
    out[1] = fold4(l01 + 4);
    out[2] = fold4(l23);
    out[3] = fold4(l23 + 4);
    for (k = c; k < n; k++) {
        float x0 = a0[k], x1 = a1[k], y0 = b0[k], y1 = b1[k];
        out[0] += x0 * y0;
        out[1] += x0 * y1;
        out[2] += x1 * y0;
        out[3] += x1 * y1;
    }
}

void avx2_nt_panel(float *rows_out, size_t rows, size_t d_in, const float *d,
                   const float *w, size_t d_out, const float *act) {
    size_t i = 0;
    while (i + 2 <= rows) {
        const float *d0 = d + i * d_out, *d1 = d0 + d_out;
        size_t j = 0;
        while (j + 2 <= d_in) {
            int keep[4];
            if (act) {
                keep[0] = act[i * d_in + j] > 0.0f;
                keep[1] = act[i * d_in + j + 1] > 0.0f;
                keep[2] = act[(i + 1) * d_in + j] > 0.0f;
                keep[3] = act[(i + 1) * d_in + j + 1] > 0.0f;
            } else {
                keep[0] = keep[1] = keep[2] = keep[3] = 1;
            }
            if (keep[0] || keep[1] || keep[2] || keep[3]) {
                float s[4];
                dot4_2x2(d0, d1, w + j * d_out, w + (j + 1) * d_out, d_out, s);
                if (keep[0])
                    rows_out[i * d_in + j] += s[0];
                if (keep[1])
                    rows_out[i * d_in + j + 1] += s[1];
                if (keep[2])
                    rows_out[(i + 1) * d_in + j] += s[2];
                if (keep[3])
                    rows_out[(i + 1) * d_in + j + 1] += s[3];
            }
            j += 2;
        }
        while (j < d_in) {
            const float *wj = w + j * d_out;
            for (size_t r = 0; r < 2; r++) {
                int keep = act ? act[(i + r) * d_in + j] > 0.0f : 1;
                if (keep)
                    rows_out[(i + r) * d_in + j] +=
                        avx2_dot4(d + (i + r) * d_out, wj, d_out);
            }
            j++;
        }
        i += 2;
    }
    while (i < rows) {
        const float *di = d + i * d_out;
        for (size_t j = 0; j < d_in; j++) {
            int keep = act ? act[i * d_in + j] > 0.0f : 1;
            if (keep)
                rows_out[i * d_in + j] += avx2_dot4(di, w + j * d_out, d_out);
        }
        i++;
    }
}

void avx2_wgrad_panel(float *gw, size_t kn, const float *input, size_t rows,
                      size_t d_in, const float *d, size_t d_out) {
    size_t kk = 0;
    while (kk + MR <= kn) {
        size_t j = 0;
        while (j + NR <= d_out) {
            __m256 acc[MR][2];
            for (size_t r = 0; r < MR; r++)
                acc[r][0] = acc[r][1] = _mm256_setzero_ps();
            for (size_t i = 0; i < rows; i++) {
                const float *hi = input + i * d_in;
                const float *di = d + i * d_out + j;
                __m256 d0 = _mm256_loadu_ps(di);
                __m256 d1 = _mm256_loadu_ps(di + 8);
                for (size_t r = 0; r < MR; r++) {
                    float h = hi[kk + r];
                    if (h == 0.0f)
                        continue;
                    __m256 hv = _mm256_set1_ps(h);
                    acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(hv, d0));
                    acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(hv, d1));
                }
            }
            for (size_t r = 0; r < MR; r++) {
                float *g = gw + (kk + r) * d_out + j;
                _mm256_storeu_ps(g, _mm256_add_ps(_mm256_loadu_ps(g), acc[r][0]));
                _mm256_storeu_ps(g + 8,
                                 _mm256_add_ps(_mm256_loadu_ps(g + 8), acc[r][1]));
            }
            j += NR;
        }
        while (j < d_out) {
            float acc[MR] = {0, 0, 0, 0};
            for (size_t i = 0; i < rows; i++) {
                const float *hi = input + i * d_in;
                float dv = d[i * d_out + j];
                for (size_t r = 0; r < MR; r++) {
                    float h = hi[kk + r];
                    if (h != 0.0f)
                        acc[r] += h * dv;
                }
            }
            for (size_t r = 0; r < MR; r++)
                gw[(kk + r) * d_out + j] += acc[r];
            j++;
        }
        kk += MR;
    }
    while (kk < kn) {
        size_t j = 0;
        while (j + NR <= d_out) {
            __m256 a0 = _mm256_setzero_ps();
            __m256 a1 = _mm256_setzero_ps();
            for (size_t i = 0; i < rows; i++) {
                float h = input[i * d_in + kk];
                if (h == 0.0f)
                    continue;
                const float *di = d + i * d_out + j;
                __m256 hv = _mm256_set1_ps(h);
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(hv, _mm256_loadu_ps(di)));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(hv, _mm256_loadu_ps(di + 8)));
            }
            float *g = gw + kk * d_out + j;
            _mm256_storeu_ps(g, _mm256_add_ps(_mm256_loadu_ps(g), a0));
            _mm256_storeu_ps(g + 8, _mm256_add_ps(_mm256_loadu_ps(g + 8), a1));
            j += NR;
        }
        while (j < d_out) {
            float acc = 0;
            for (size_t i = 0; i < rows; i++) {
                float h = input[i * d_in + kk];
                if (h != 0.0f)
                    acc += h * d[i * d_out + j];
            }
            gw[kk * d_out + j] += acc;
            j++;
        }
        kk++;
    }
}

void avx2_euclid_block(const float *g, size_t cols, const float *sq, size_t j,
                       size_t n, float *out) {
    avx2_dot4_rows(g + j * cols, g, cols, 0, n, out);
    float sj = sq[j];
    for (size_t i = 0; i < n; i++) {
        float v = sq[i] + sj - 2.0f * out[i];
        out[i] = v > 0.0f ? v : 0.0f;
    }
}

void avx2_prod_block(const float *a, size_t h, const float *g, size_t c,
                     const float *sq, size_t j, size_t n, float *out) {
    const float *aj = a + j * h;
    const float *gj = g + j * c;
    float sj = sq[j];
    float gbuf[PROD_BLOCK];
    for (size_t lo = 0; lo < n; lo += PROD_BLOCK) {
        size_t len = n - lo < PROD_BLOCK ? n - lo : PROD_BLOCK;
        avx2_dot4_rows(gj, g, c, lo, lo + len, gbuf);
        avx2_dot4_rows(aj, a, h, lo, lo + len, out + lo);
        for (size_t k = 0; k < len; k++) {
            float v = sq[lo + k] + sj - 2.0f * out[lo + k] * gbuf[k];
            out[lo + k] = v > 0.0f ? v : 0.0f;
        }
    }
}
