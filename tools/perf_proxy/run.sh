#!/bin/sh
# Build and run the kernel perf proxy in both quick and full modes and
# assemble the merged record array (printed to stdout; redirect into
# BENCH_perf.json to commit a baseline). See README.md for what the proxy
# does and does not stand in for.
set -e
cd "$(dirname "$0")"

CC="${CC:-gcc}"
# scalar TU: -O3, default x86-64 target (SSE2 autovec ceiling, like the
# rustc release build of the scalar path)
$CC -O3 -c kern_scalar.c -o kern_scalar.o
# avx2 TU: the intrinsics pin the codegen; -mno-fma forbids mul+add
# contraction, matching the Rust AVX2 layer's no-FMA rule
$CC -O2 -mavx2 -mno-fma -c kern_avx2.c -o kern_avx2.o
$CC -O2 -c main.c -o main.o
$CC main.o kern_scalar.o kern_avx2.o -lm -o perf_proxy

./perf_proxy quick > records_quick.json
./perf_proxy full > records_full.json

# merge the two arrays into one trajectory
python3 - <<'EOF'
import json
recs = json.load(open('records_quick.json')) + json.load(open('records_full.json'))
print(json.dumps(recs, indent=1))
EOF
