"""L2: the JAX compute graph — MLP fwd/bwd over a flat parameter vector.

Every function here is AOT-lowered by aot.py to one HLO-text artifact that
the Rust coordinator executes via PJRT; Python never runs at training time.

The model keeps its parameters as a single flat f32 vector so the Rust side
can do the CREST quadratic bookkeeping (EMA gradients, Hutchinson Hessian
diagonal, F^l(delta) evaluation — paper Eq. 6-10) with plain vector math
and no layout knowledge beyond the manifest offsets.

Artifacts per variant (shapes fixed at lowering time; see configs.py):

  train_step   (params, mom, x[m,d], y[m], gamma[m], lr) ->
               (params', mom', mean_loss, per_ex_loss[m])
  grad_embed   (params, x[r,d], y[r]) ->
               (gL[r,c], act[r,h], per_ex_loss[r])
  eval_chunk   (params, x[e,d], y[e]) ->
               (sum_loss, n_correct, per_ex_loss[e], correct[e])
  hess_probe   (params, x[r,d], y[r], z[p]) -> (Hz[p], grad[p], mean_loss)
  select_greedy(gL[r,c], act[r,h]) -> (indices[m], weights[m])
"""

import jax
import jax.numpy as jnp

from .configs import VariantSpec
from .kernels import fl_gains, lastlayer_grad, pairwise_gradprod


# ---------------------------------------------------------------------------
# Parameter (un)flattening
# ---------------------------------------------------------------------------

def unflatten(spec: VariantSpec, params: jnp.ndarray):
    """Flat f32[p_dim] -> [(W[i,o], b[o])] per dense layer."""
    layers = []
    for w_off, (i, o), b_off, b_len in spec.param_offsets():
        w = params[w_off:w_off + i * o].reshape(i, o)
        b = params[b_off:b_off + b_len]
        layers.append((w, b))
    return layers


def forward(spec: VariantSpec, params: jnp.ndarray, x: jnp.ndarray):
    """MLP forward: returns (logits[b, classes], last_hidden[b, h])."""
    layers = unflatten(spec, params)
    h = x
    for w, b in layers[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = layers[-1]
    return h @ w + b, h


def _per_example_loss(spec: VariantSpec, params, x, y):
    """CE loss, logit gradient, and penultimate activation per example.

    (grad, act) together define the last-layer weight gradient a ⊗ g — the
    selection embedding (see kernels/pairwise_prod.py)."""
    logits, act = forward(spec, params, x)
    y1h = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
    loss, grad = lastlayer_grad(logits, y1h)
    return loss, grad, act


def weighted_mean_loss(spec: VariantSpec, params, x, y, gamma):
    """(1/m) sum_j gamma_j * CE_j — CREST's weighted coreset objective.

    Differentiable through the Pallas kernel would require a custom VJP;
    instead the loss recomputes log-softmax with plain jnp (XLA fuses it),
    while the *embedding* path uses the kernel. Both agree to float32 eps
    (asserted by tests).
    """
    logits, _ = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    y1h = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
    ce = -jnp.sum(y1h * logp, axis=-1)
    return jnp.mean(gamma * ce), ce


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def make_train_step(spec: VariantSpec):
    """SGD + momentum + weight decay on the weighted loss (paper Eq. 2 with
    gamma weights; decoupled L2 on all parameters, the standard pipeline's
    regularizer)."""

    def train_step(params, mom, x, y, gamma, lr, wd):
        (loss, ce), grads = jax.value_and_grad(
            lambda p: weighted_mean_loss(spec, p, x, y, gamma), has_aux=True
        )(params)
        grads = grads + wd * params
        mom_new = spec.momentum * mom + grads
        params_new = params - lr * mom_new
        return params_new, mom_new, loss, ce

    return train_step


def make_grad_embed(spec: VariantSpec):
    """Selection embeddings for a size-r subset (Eq. 11): logit gradients
    g = p - y, penultimate activations a, and per-example losses."""

    def grad_embed(params, x, y):
        loss, grad, act = _per_example_loss(spec, params, x, y)
        return grad, act, loss

    return grad_embed


def make_eval_chunk(spec: VariantSpec):
    """Loss sum / correct count over one evaluation chunk."""

    def eval_chunk(params, x, y):
        logits, _ = forward(spec, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        y1h = jax.nn.one_hot(y, spec.classes, dtype=jnp.float32)
        ce = -jnp.sum(y1h * logp, axis=-1)
        pred = jnp.argmax(logits, axis=-1)
        correct = (pred == y).astype(jnp.float32)
        return jnp.sum(ce), jnp.sum(correct), ce, correct

    return eval_chunk


def make_hess_probe(spec: VariantSpec):
    """Hutchinson probe (paper Eq. 7): Hz plus the mean gradient.

    Hz = d/dw (g(w) . z) — one extra backprop through the gradient. The Rust
    side forms diag(H) ~ E[z * Hz] over Rademacher z and applies the EMA
    smoothing of Eq. (8)-(9).
    """

    def mean_loss(p, x, y):
        ones = jnp.ones((x.shape[0],), jnp.float32)
        loss, _ = weighted_mean_loss(spec, p, x, y, ones)
        return loss

    def hess_probe(params, x, y, z):
        loss, grad = jax.value_and_grad(mean_loss)(params, x, y)
        hz = jax.grad(lambda p: jnp.vdot(jax.grad(mean_loss)(p, x, y), z))(params)
        return hz, grad, loss

    return hess_probe


def make_select_greedy(spec: VariantSpec):
    """In-graph facility-location greedy (compiled alternative to host greedy).

    Selects m medoids from the r gradient embeddings via lax.fori_loop,
    calling the L1 kernels for the distance matrix and per-step gains.
    Returns the selected indices and the CRAIG gamma weights (cluster sizes).
    """

    def select_greedy(g, a):
        d = pairwise_gradprod(a, g)
        r = g.shape[0]
        big = jnp.float32(1e9)

        def body(i, state):
            mind, idxs = state
            gains = fl_gains(d, mind)
            j = jnp.argmax(gains).astype(jnp.int32)
            mind = jnp.minimum(mind, d[j])
            idxs = idxs.at[i].set(j)
            return mind, idxs

        mind0 = jnp.full((r,), big)
        idxs0 = jnp.zeros((spec.m,), jnp.int32)
        _, idxs = jax.lax.fori_loop(0, spec.m, body, (mind0, idxs0))
        assign = jnp.argmin(d[idxs, :], axis=0)
        weights = jnp.zeros((spec.m,), jnp.float32).at[assign].add(1.0)
        return idxs, weights

    return select_greedy


# ---------------------------------------------------------------------------
# Host-side init (mirrored in Rust; used by python tests only)
# ---------------------------------------------------------------------------

def init_params(spec: VariantSpec, key) -> jnp.ndarray:
    """He-normal weights, zero biases, as a flat vector (test-side only).

    The Rust coordinator performs its own identical-by-construction init
    (He-normal from its PCG32); exact bit equality with this function is
    not required — both are valid draws from the same distribution.
    """
    parts = []
    for (i, o) in spec.layer_shapes:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (i, o), jnp.float32) * jnp.sqrt(2.0 / i)
        parts.append(w.reshape(-1))
        parts.append(jnp.zeros((o,), jnp.float32))
    return jnp.concatenate(parts)
