"""Pallas kernel: fused softmax cross-entropy loss + last-layer gradient.

Produces, per example, the CE loss and the selection embedding
g^L = softmax(logits) - onehot(y) (the gradient of the loss w.r.t. the
pre-softmax input — Katharopoulos & Fleuret 2018, used by paper Eq. 11).

Fusing the two avoids materializing softmax twice: a single row-tiled pass
computes the numerically-stable log-softmax once and emits both outputs.
Row tiles of 64 keep each program's VMEM footprint at
2·(64·c)·4B + 64·4B ≈ 21 KiB for c = 40. interpret=True on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 64


def _lastlayer_kernel(logits_ref, y_ref, loss_ref, grad_ref):
    """One row tile: stable log-softmax -> (loss, p - y)."""
    z = logits_ref[...]  # (T, c)
    y = y_ref[...]  # (T, c) one-hot
    zmax = jnp.max(z, axis=1, keepdims=True)
    shifted = z - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=1, keepdims=True))
    logp = shifted - lse
    loss_ref[...] = -jnp.sum(y * logp, axis=1)
    grad_ref[...] = jnp.exp(logp) - y


@functools.partial(jax.jit, static_argnames=("tile",))
def lastlayer_grad(logits: jnp.ndarray, y_onehot: jnp.ndarray, tile: int = TILE):
    """(loss[b], grad[b, c]) from logits[b, c] and one-hot labels.

    ``b`` must be divisible by the row tile (or smaller than one tile).
    """
    b, c = logits.shape
    t = min(tile, b)
    if b % t != 0:
        raise ValueError(f"rows {b} not divisible by tile {t}")
    return pl.pallas_call(
        _lastlayer_kernel,
        grid=(b // t,),
        in_specs=[
            pl.BlockSpec((t, c), lambda i: (i, 0)),
            pl.BlockSpec((t, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, c), jnp.float32),
        ],
        interpret=True,
    )(logits, y_onehot)
