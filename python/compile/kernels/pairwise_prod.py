"""Pallas kernel: pairwise distances between last-layer *weight* gradients.

The last-layer weight gradient of example i factorizes as the outer product
`a_i ⊗ g_i` (penultimate activation × logit gradient). Its pairwise squared
Frobenius distance factorizes too:

    ||a1 g1^T - a2 g2^T||_F^2
        = |a1|^2|g1|^2 + |a2|^2|g2|^2 - 2 (a1·a2)(g1·g2)

so the full distance matrix needs only two MXU-shaped Gram matrices
(A A^T and G G^T) and an elementwise combine — never the h·c-dimensional
outer products. This is the selection metric CREST/CRAIG use for deep
networks: unlike plain (p - y), it distinguishes examples whose class-error
profiles coincide but whose representations differ.

Tiling matches pairwise.py: 2-D grid of (T, T) output tiles; each program
holds one row panel and one column panel of both A and G in VMEM
(4·(64·(h+c))·4 B ≈ 172 KiB for h=128, c=40). interpret=True on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 64


def _prod_kernel(ar_ref, gr_ref, ac_ref, gc_ref, o_ref):
    ar, gr = ar_ref[...], gr_ref[...]  # (T, h), (T, c) row panels
    ac, gc = ac_ref[...], gc_ref[...]  # (T, h), (T, c) column panels
    sq_r = jnp.sum(ar * ar, axis=1) * jnp.sum(gr * gr, axis=1)  # |a|^2|g|^2
    sq_c = jnp.sum(ac * ac, axis=1) * jnp.sum(gc * gc, axis=1)
    aa = jnp.dot(ar, ac.T, preferred_element_type=jnp.float32)  # MXU
    gg = jnp.dot(gr, gc.T, preferred_element_type=jnp.float32)  # MXU
    d = sq_r[:, None] + sq_c[None, :] - 2.0 * aa * gg
    o_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def pairwise_gradprod(a: jnp.ndarray, g: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """D[r, r] with D[i,j] = ||a_i g_i^T - a_j g_j^T||_F^2."""
    r, h = a.shape
    r2, c = g.shape
    if r != r2:
        raise ValueError(f"row mismatch {r} vs {r2}")
    t = min(tile, r)
    if r % t != 0:
        raise ValueError(f"rows {r} not divisible by tile {t}")
    grid = (r // t, r // t)
    return pl.pallas_call(
        _prod_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, h), lambda i, j: (i, 0)),
            pl.BlockSpec((t, c), lambda i, j: (i, 0)),
            pl.BlockSpec((t, h), lambda i, j: (j, 0)),
            pl.BlockSpec((t, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(a, g, a, g)
