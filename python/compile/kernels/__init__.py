# L1: Pallas kernels for the paper's selection hot-spots (interpret=True).
from .pairwise import pairwise_sqdist
from .pairwise_prod import pairwise_gradprod
from .lastlayer import lastlayer_grad
from .fl_gains import fl_gains
from . import ref

__all__ = ["pairwise_sqdist", "pairwise_gradprod", "lastlayer_grad", "fl_gains", "ref"]
