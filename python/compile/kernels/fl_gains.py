"""Pallas kernel: facility-location marginal gains for all candidates.

One greedy step of submodular maximization (paper Eq. 5/11) must score every
candidate j by how much it would reduce the ground set's total min-distance:

    gains[j] = sum_i max(mind[i] - D[j, i], 0)

This is the inner hot loop of selection — called m times per coreset. The
kernel tiles candidates into row blocks; each program reduces a (T, r) panel
of the distance matrix against the broadcast mind vector. VPU-shaped (pure
elementwise + row reduction, no MXU). VMEM per program for r = 320:
(64·320 + 320)·4B ≈ 81 KiB. interpret=True on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 64


def _gains_kernel(d_ref, mind_ref, o_ref):
    d = d_ref[...]  # (T, r) candidate rows
    mind = mind_ref[...]  # (r,)
    o_ref[...] = jnp.sum(jnp.maximum(mind[None, :] - d, 0.0), axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def fl_gains(dist: jnp.ndarray, mind: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """gains[r] over candidate rows of dist[r, r] given current mins mind[r]."""
    r = dist.shape[0]
    t = min(tile, r)
    if r % t != 0:
        raise ValueError(f"rows {r} not divisible by tile {t}")
    return pl.pallas_call(
        _gains_kernel,
        grid=(r // t,),
        in_specs=[
            pl.BlockSpec((t, r), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(dist, mind)
