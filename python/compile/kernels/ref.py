"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written in
plain jax.numpy with no Pallas, no tiling, no tricks. pytest asserts
allclose(kernel, ref) across shape/dtype sweeps (hypothesis).
"""

import jax
import jax.numpy as jnp


def pairwise_sqdist_ref(g: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance matrix D[i,j] = ||g_i - g_j||^2.

    The facility-location objective (paper Eq. 5/11) needs pairwise normed
    gradient differences; squared distance preserves the argmin structure
    and avoids the sqrt on the hot path.
    """
    diff = g[:, None, :] - g[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def lastlayer_grad_ref(logits: jnp.ndarray, y_onehot: jnp.ndarray):
    """Per-example softmax cross-entropy loss and last-layer gradient p - y.

    This is the paper's g^L (gradient of the loss w.r.t. the last layer's
    pre-softmax input), the low-dimensional selection embedding of
    Katharopoulos & Fleuret (2018) used by Eq. (11).
    """
    logz = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.sum(y_onehot * logz, axis=-1)
    grad = jnp.exp(logz) - y_onehot
    return loss, grad


def pairwise_gradprod_ref(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the last-layer weight-gradient distance: materializes the
    outer products a_i g_i^T explicitly (O(r^2·h·c), test-only)."""
    outer = a[:, :, None] * g[:, None, :]  # (r, h, c)
    flat = outer.reshape(a.shape[0], -1)
    diff = flat[:, None, :] - flat[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def fl_gains_ref(dist: jnp.ndarray, mind: jnp.ndarray) -> jnp.ndarray:
    """Marginal facility-location gains for every candidate.

    gains[j] = sum_i max(mind[i] - D[j,i], 0): how much adding candidate j
    reduces the total min-distance of the ground set. One lazy-greedy step
    evaluated for all candidates at once (the selection hot loop).
    """
    return jnp.sum(jnp.maximum(mind[None, :] - dist, 0.0), axis=1)


def greedy_select_ref(g: jnp.ndarray, m: int):
    """Reference facility-location greedy over gradient embeddings.

    Returns (indices[m], weights[m]) where weights[j] counts the ground-set
    elements whose nearest selected medoid is j (the per-element step sizes
    gamma_j of CRAIG / Eq. 4).
    """
    d = pairwise_sqdist_ref(g)
    r = g.shape[0]
    mind = jnp.full((r,), jnp.float32(1e9))
    idxs = []
    for _ in range(m):
        gains = fl_gains_ref(d, mind)
        j = int(jnp.argmax(gains))
        idxs.append(j)
        mind = jnp.minimum(mind, d[j])
    idxs_arr = jnp.array(idxs, jnp.int32)
    assign = jnp.argmin(d[idxs_arr, :], axis=0)
    weights = jnp.zeros((m,), jnp.float32).at[assign].add(1.0)
    return idxs_arr, weights
