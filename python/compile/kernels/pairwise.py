"""Pallas kernel: tiled pairwise squared-L2 distance between gradient embeddings.

This is the compute hot-spot of coreset selection: facility-location greedy
(paper Eq. 5/11) needs D[i,j] = ||g^L_i - g^L_j||^2 over the random subset's
last-layer gradients G[r, c].

TPU mapping (DESIGN.md §Hardware-Adaptation): the expansion
``D = sq[:,None] + sq[None,:] - 2 G G^T`` makes the dominant term an
MXU-shaped matmul. We tile the output into (TM, TN) blocks on a 2-D grid;
each program holds one (TM, c) row panel and one (TN, c) column panel in
VMEM and streams nothing else — the BlockSpec expresses the HBM→VMEM
schedule that a CUDA implementation would express with threadblocks and
shared memory. interpret=True on CPU (numerics identical; Mosaic lowering
is TPU-only).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/column tile. 64 divides every variant's r (128, 256, 320) and keeps
# the per-program VMEM footprint at 2·(64·c)·4B + (64·64)·4B ≈ 48 KiB for
# c = 40 — far under the ~16 MiB VMEM budget, leaving room for
# double-buffering by the pipeline.
TILE = 64


def _pairwise_kernel(gr_ref, gc_ref, o_ref):
    """One (TM, TN) output tile: distances between a row and a column panel."""
    gr = gr_ref[...]  # (TM, c) row panel, resident in VMEM
    gc = gc_ref[...]  # (TN, c) column panel
    sq_r = jnp.sum(gr * gr, axis=1)  # (TM,)
    sq_c = jnp.sum(gc * gc, axis=1)  # (TN,)
    # MXU term: -2 G_r G_c^T. float32 accumulate.
    cross = jnp.dot(gr, gc.T, preferred_element_type=jnp.float32)
    d = sq_r[:, None] + sq_c[None, :] - 2.0 * cross
    # Cancellation can push exact zeros slightly negative; clamp so greedy
    # gains stay non-negative.
    o_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def pairwise_sqdist(g: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """D[r, r] with D[i,j] = ||g_i - g_j||^2, tiled Pallas implementation.

    ``r`` must be divisible by ``tile`` (the AOT pipeline guarantees this;
    hosts pad the final chunk). Falls back to a single-block call when the
    input is smaller than one tile.
    """
    r, c = g.shape
    t = min(tile, r)
    if r % t != 0:
        raise ValueError(f"rows {r} not divisible by tile {t}")
    grid = (r // t, r // t)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, c), lambda i, j: (i, 0)),  # row panel
            pl.BlockSpec((t, c), lambda i, j: (j, 0)),  # column panel
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(g, g)
