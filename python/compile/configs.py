"""Model/dataset variant specifications shared by the AOT pipeline.

Each variant mirrors one of the paper's dataset/model pairs (Table 4),
scaled to the CPU-only proxy substrate described in DESIGN.md §2/§6.
The Rust coordinator reads the same numbers from artifacts/<v>/manifest.json,
so this file is the single source of truth for shapes.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class VariantSpec:
    """One model/dataset variant: shapes fixed at AOT-lowering time."""

    name: str
    d_in: int  # input feature dimension
    hidden: List[int]  # hidden layer widths
    classes: int  # number of classes
    m: int  # mini-batch (coreset) size — paper's m
    r: int  # random-subset size — paper's r
    eval_chunk: int  # examples per eval_chunk artifact call
    momentum: float = 0.9

    @property
    def layer_shapes(self) -> List[tuple]:
        """(in, out) for every dense layer, last layer included."""
        dims = [self.d_in] + list(self.hidden) + [self.classes]
        return [(dims[i], dims[i + 1]) for i in range(len(dims) - 1)]

    @property
    def p_dim(self) -> int:
        """Total flat parameter count (weights + biases)."""
        return sum(i * o + o for i, o in self.layer_shapes)

    def param_offsets(self):
        """[(w_off, w_shape, b_off, b_len)] per layer into the flat vector."""
        out, off = [], 0
        for i, o in self.layer_shapes:
            w_off = off
            off += i * o
            b_off = off
            off += o
            out.append((w_off, (i, o), b_off, o))
        return out


# The four paper datasets, proxied (DESIGN.md §6). r follows the paper's
# r = 0.01·n (vision) and r ≈ 0.005·n (SNLI) scaling against our proxy n.
VARIANTS = {
    "cifar10-proxy": VariantSpec(
        name="cifar10-proxy", d_in=64, hidden=[128, 64], classes=10,
        m=32, r=256, eval_chunk=512,
    ),
    "cifar100-proxy": VariantSpec(
        name="cifar100-proxy", d_in=96, hidden=[256, 128], classes=20,
        m=32, r=256, eval_chunk=512,
    ),
    "tinyimagenet-proxy": VariantSpec(
        name="tinyimagenet-proxy", d_in=128, hidden=[256, 128], classes=40,
        m=32, r=320, eval_chunk=512,
    ),
    "snli-proxy": VariantSpec(
        name="snli-proxy", d_in=96, hidden=[256], classes=3,
        m=32, r=128, eval_chunk=512,
    ),
}

DEFAULT_VARIANT = "cifar10-proxy"
