"""AOT pipeline: lower every L2 entry point to HLO *text* + manifest.json.

HLO text (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--variant cifar10-proxy]

Layout produced:
    artifacts/<variant>/{train_step,grad_embed,eval_chunk,hess_probe,
                         select_greedy}.hlo.txt
    artifacts/<variant>/manifest.json   # shapes + dtypes the Rust side needs
    artifacts/manifest.json             # index of variants

Python runs exactly once (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import VARIANTS, VariantSpec


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _spec_i32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def artifact_plan(spec: VariantSpec):
    """(name, fn, arg_specs, io_doc) for every artifact of one variant."""
    p, d, m, r, e, c = (
        spec.p_dim, spec.d_in, spec.m, spec.r, spec.eval_chunk, spec.classes,
    )
    h = spec.hidden[-1]  # penultimate width (selection embedding)
    return [
        (
            "train_step",
            model.make_train_step(spec),
            [_spec_f32(p), _spec_f32(p), _spec_f32(m, d), _spec_i32(m),
             _spec_f32(m), _spec_f32(), _spec_f32()],
            {
                "inputs": [
                    {"name": "params", "dtype": "f32", "shape": [p]},
                    {"name": "momentum", "dtype": "f32", "shape": [p]},
                    {"name": "x", "dtype": "f32", "shape": [m, d]},
                    {"name": "y", "dtype": "i32", "shape": [m]},
                    {"name": "gamma", "dtype": "f32", "shape": [m]},
                    {"name": "lr", "dtype": "f32", "shape": []},
                    {"name": "wd", "dtype": "f32", "shape": []},
                ],
                "outputs": [
                    {"name": "params", "dtype": "f32", "shape": [p]},
                    {"name": "momentum", "dtype": "f32", "shape": [p]},
                    {"name": "mean_loss", "dtype": "f32", "shape": []},
                    {"name": "per_ex_loss", "dtype": "f32", "shape": [m]},
                ],
            },
        ),
        (
            "grad_embed",
            model.make_grad_embed(spec),
            [_spec_f32(p), _spec_f32(r, d), _spec_i32(r)],
            {
                "inputs": [
                    {"name": "params", "dtype": "f32", "shape": [p]},
                    {"name": "x", "dtype": "f32", "shape": [r, d]},
                    {"name": "y", "dtype": "i32", "shape": [r]},
                ],
                "outputs": [
                    {"name": "grad_l", "dtype": "f32", "shape": [r, c]},
                    {"name": "act", "dtype": "f32", "shape": [r, h]},
                    {"name": "per_ex_loss", "dtype": "f32", "shape": [r]},
                ],
            },
        ),
        (
            "eval_chunk",
            model.make_eval_chunk(spec),
            [_spec_f32(p), _spec_f32(e, d), _spec_i32(e)],
            {
                "inputs": [
                    {"name": "params", "dtype": "f32", "shape": [p]},
                    {"name": "x", "dtype": "f32", "shape": [e, d]},
                    {"name": "y", "dtype": "i32", "shape": [e]},
                ],
                "outputs": [
                    {"name": "sum_loss", "dtype": "f32", "shape": []},
                    {"name": "n_correct", "dtype": "f32", "shape": []},
                    {"name": "per_ex_loss", "dtype": "f32", "shape": [e]},
                    {"name": "correct", "dtype": "f32", "shape": [e]},
                ],
            },
        ),
        (
            "hess_probe",
            model.make_hess_probe(spec),
            [_spec_f32(p), _spec_f32(r, d), _spec_i32(r), _spec_f32(p)],
            {
                "inputs": [
                    {"name": "params", "dtype": "f32", "shape": [p]},
                    {"name": "x", "dtype": "f32", "shape": [r, d]},
                    {"name": "y", "dtype": "i32", "shape": [r]},
                    {"name": "z", "dtype": "f32", "shape": [p]},
                ],
                "outputs": [
                    {"name": "hz", "dtype": "f32", "shape": [p]},
                    {"name": "grad", "dtype": "f32", "shape": [p]},
                    {"name": "mean_loss", "dtype": "f32", "shape": []},
                ],
            },
        ),
        (
            "select_greedy",
            model.make_select_greedy(spec),
            [_spec_f32(r, c), _spec_f32(r, h)],
            {
                "inputs": [
                    {"name": "grad_l", "dtype": "f32", "shape": [r, c]},
                    {"name": "act", "dtype": "f32", "shape": [r, h]},
                ],
                "outputs": [
                    {"name": "indices", "dtype": "i32", "shape": [m]},
                    {"name": "weights", "dtype": "f32", "shape": [m]},
                ],
            },
        ),
    ]


def variant_manifest(spec: VariantSpec, artifacts: dict) -> dict:
    return {
        "name": spec.name,
        "d_in": spec.d_in,
        "hidden": list(spec.hidden),
        "classes": spec.classes,
        "m": spec.m,
        "r": spec.r,
        "eval_chunk": spec.eval_chunk,
        "p_dim": spec.p_dim,
        "momentum": spec.momentum,
        "layer_shapes": [[i, o] for i, o in spec.layer_shapes],
        "artifacts": artifacts,
    }


def lower_variant(spec: VariantSpec, out_dir: str, verbose: bool = True) -> dict:
    vdir = os.path.join(out_dir, spec.name)
    os.makedirs(vdir, exist_ok=True)
    artifacts = {}
    for name, fn, arg_specs, io_doc in artifact_plan(spec):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {"file": fname, **io_doc}
        if verbose:
            print(f"  {spec.name}/{fname}: {len(text)} chars", file=sys.stderr)
    manifest = variant_manifest(spec, artifacts)
    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variant", action="append", default=None,
                    help="variant name(s); default: all")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    names = args.variant or list(VARIANTS)
    os.makedirs(args.out_dir, exist_ok=True)
    index = {"variants": []}
    for name in names:
        if name not in VARIANTS:
            ap.error(f"unknown variant {name!r}; known: {sorted(VARIANTS)}")
        lower_variant(VARIANTS[name], args.out_dir, verbose=not args.quiet)
        index["variants"].append(name)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(index, f, indent=2)
    if not args.quiet:
        print(f"wrote {len(names)} variants to {args.out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
