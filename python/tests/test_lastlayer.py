"""L1 kernel vs oracle: fused softmax-CE loss + last-layer gradient."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels import lastlayer_grad
from compile.kernels.ref import lastlayer_grad_ref


def _cases():
    return st.tuples(
        st.sampled_from([2, 32, 64, 128, 256]),  # batch
        st.sampled_from([2, 3, 10, 20, 40]),  # classes
        st.integers(0, 2**31 - 1),
    )


def _random_case(b, c, seed):
    rs = np.random.RandomState(seed)
    logits = rs.randn(b, c).astype(np.float32) * 3.0
    y = rs.randint(0, c, size=b)
    y1h = np.eye(c, dtype=np.float32)[y]
    return jnp.asarray(logits), jnp.asarray(y1h)


@given(case=_cases())
def test_matches_ref(case):
    b, c, seed = case
    logits, y1h = _random_case(b, c, seed)
    loss, grad = lastlayer_grad(logits, y1h)
    loss_ref, grad_ref = lastlayer_grad_ref(logits, y1h)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(grad_ref),
                               rtol=1e-5, atol=1e-6)


@given(case=_cases())
def test_gradient_is_autodiff_gradient(case):
    """The fused p - y must equal jax.grad of CE w.r.t. logits."""
    b, c, seed = case
    logits, y1h = _random_case(b, c, seed)

    def ce_sum(z):
        return -jnp.sum(y1h * jax.nn.log_softmax(z, axis=-1))

    want = jax.grad(ce_sum)(logits)
    _, got = lastlayer_grad(logits, y1h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_gradient_rows_sum_to_zero():
    logits, y1h = _random_case(64, 10, 7)
    _, grad = lastlayer_grad(logits, y1h)
    np.testing.assert_allclose(np.asarray(grad).sum(axis=1), 0.0, atol=1e-5)


def test_numerical_stability_large_logits():
    """No overflow for logits far outside float32 exp range."""
    logits = jnp.asarray([[500.0, -500.0, 0.0]] * 64, jnp.float32)
    y1h = jnp.asarray([[0.0, 1.0, 0.0]] * 64, jnp.float32)
    loss, grad = lastlayer_grad(logits, y1h)
    assert np.isfinite(np.asarray(loss)).all()
    assert np.isfinite(np.asarray(grad)).all()
    assert float(loss[0]) == pytest.approx(1000.0, rel=1e-4)


def test_perfect_prediction_small_loss_and_grad():
    c = 10
    logits = jnp.asarray(np.eye(c, dtype=np.float32)[np.arange(64) % c] * 50.0)
    y1h = jnp.asarray(np.eye(c, dtype=np.float32)[np.arange(64) % c])
    loss, grad = lastlayer_grad(logits, y1h)
    assert float(np.max(np.asarray(loss))) < 1e-4
    assert float(np.max(np.abs(np.asarray(grad)))) < 1e-4


def test_rejects_non_divisible_rows():
    with pytest.raises(ValueError):
        lastlayer_grad(jnp.zeros((100, 4)), jnp.zeros((100, 4)))
