"""L2 model graph: shapes, gradients, Hessian probe, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import VARIANTS, VariantSpec


@pytest.fixture(scope="module")
def tiny():
    return VariantSpec(name="tiny", d_in=6, hidden=[8], classes=3, m=8, r=16,
                       eval_chunk=16)


def _data(spec, n, seed=0):
    rs = np.random.RandomState(seed)
    x = rs.randn(n, spec.d_in).astype(np.float32)
    y = rs.randint(0, spec.classes, size=n).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def test_param_offsets_cover_vector(tiny):
    offs = tiny.param_offsets()
    total = 0
    for w_off, (i, o), b_off, b_len in offs:
        assert w_off == total
        total += i * o
        assert b_off == total
        total += b_len
    assert total == tiny.p_dim


def test_unflatten_roundtrip(tiny):
    p = jnp.arange(tiny.p_dim, dtype=jnp.float32)
    layers = model.unflatten(tiny, p)
    flat = jnp.concatenate(
        [jnp.concatenate([w.reshape(-1), b]) for w, b in layers])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(p))


def test_forward_shapes(tiny):
    params = model.init_params(tiny, jax.random.PRNGKey(0))
    x, _ = _data(tiny, 8)
    logits, h = model.forward(tiny, params, x)
    assert logits.shape == (8, tiny.classes)
    assert h.shape == (8, tiny.hidden[-1])


def test_train_step_decreases_loss(tiny):
    params = model.init_params(tiny, jax.random.PRNGKey(0))
    mom = jnp.zeros_like(params)
    x, y = _data(tiny, tiny.m)
    gamma = jnp.ones((tiny.m,), jnp.float32)
    step = jax.jit(model.make_train_step(tiny))
    first = None
    for _ in range(60):
        params, mom, loss, _ = step(params, mom, x, y, gamma, jnp.float32(0.05), jnp.float32(0.0))
        first = float(loss) if first is None else first
    assert float(loss) < 0.5 * first


def test_train_step_gamma_scales_gradient(tiny):
    """gamma=0 must freeze the parameters (weighted objective honors weights)."""
    params = model.init_params(tiny, jax.random.PRNGKey(1))
    mom = jnp.zeros_like(params)
    x, y = _data(tiny, tiny.m)
    step = jax.jit(model.make_train_step(tiny))
    p2, _, _, _ = step(params, mom, x, y, jnp.zeros((tiny.m,)), jnp.float32(0.1), jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(p2), np.asarray(params), atol=1e-7)


def test_grad_embed_matches_autodiff(tiny):
    """Kernel-produced g^L equals jax.grad of CE w.r.t. logits per example."""
    params = model.init_params(tiny, jax.random.PRNGKey(2))
    x, y = _data(tiny, tiny.r)
    grads, act, loss = jax.jit(model.make_grad_embed(tiny))(params, x, y)
    assert act.shape == (tiny.r, tiny.hidden[-1])

    def per_ex(p, xi, yi):
        logits, _ = model.forward(tiny, p, xi[None])
        return -jax.nn.log_softmax(logits)[0, yi]

    for i in [0, 3, 7]:
        logits, _ = model.forward(tiny, params, x[i][None])
        want = jax.grad(
            lambda z: -jax.nn.log_softmax(z)[0, y[i]])(logits)
        np.testing.assert_allclose(np.asarray(grads[i]), np.asarray(want[0]),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(loss[i]), float(per_ex(params, x[i], y[i])),
                                   rtol=1e-5)


def test_eval_chunk_counts(tiny):
    params = model.init_params(tiny, jax.random.PRNGKey(3))
    x, y = _data(tiny, tiny.eval_chunk)
    s, nc, per, corr = jax.jit(model.make_eval_chunk(tiny))(params, x, y)
    np.testing.assert_allclose(float(s), float(np.asarray(per).sum()), rtol=1e-5)
    np.testing.assert_allclose(float(nc), float(np.asarray(corr).sum()), rtol=1e-6)
    assert set(np.unique(np.asarray(corr))) <= {0.0, 1.0}


def test_hess_probe_grad_matches_value_and_grad(tiny):
    params = model.init_params(tiny, jax.random.PRNGKey(4))
    x, y = _data(tiny, tiny.r)
    z = jnp.zeros((tiny.p_dim,), jnp.float32)
    hz, grad, loss = jax.jit(model.make_hess_probe(tiny))(params, x, y, z)
    # z = 0 -> Hz = 0
    np.testing.assert_allclose(np.asarray(hz), 0.0, atol=1e-6)

    def mean_loss(p):
        ones = jnp.ones((tiny.r,), jnp.float32)
        l, _ = model.weighted_mean_loss(tiny, p, x, y, ones)
        return l

    want = jax.grad(mean_loss)(params)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(want),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(float(loss), float(mean_loss(params)), rtol=1e-5)


def test_hess_probe_is_linear_operator(tiny):
    """H(az1 + bz2) = aHz1 + bHz2 — the probe really is a matvec."""
    params = model.init_params(tiny, jax.random.PRNGKey(5))
    x, y = _data(tiny, tiny.r)
    rs = np.random.RandomState(0)
    z1 = jnp.asarray(rs.randn(tiny.p_dim).astype(np.float32))
    z2 = jnp.asarray(rs.randn(tiny.p_dim).astype(np.float32))
    probe = jax.jit(model.make_hess_probe(tiny))
    h1, _, _ = probe(params, x, y, z1)
    h2, _, _ = probe(params, x, y, z2)
    h3, _, _ = probe(params, x, y, 2.0 * z1 - 0.5 * z2)
    np.testing.assert_allclose(np.asarray(h3),
                               2.0 * np.asarray(h1) - 0.5 * np.asarray(h2),
                               rtol=1e-3, atol=1e-4)


def test_hutchinson_estimates_hessian_diagonal(tiny):
    """E[z * Hz] over Rademacher z converges to diag(H) (paper Eq. 7)."""
    params = model.init_params(tiny, jax.random.PRNGKey(6))
    x, y = _data(tiny, tiny.r)

    def mean_loss(p):
        ones = jnp.ones((tiny.r,), jnp.float32)
        l, _ = model.weighted_mean_loss(tiny, p, x, y, ones)
        return l

    exact = jnp.diag(jax.hessian(mean_loss)(params))
    probe = jax.jit(model.make_hess_probe(tiny))
    rs = np.random.RandomState(0)
    est = np.zeros(tiny.p_dim, np.float64)
    k = 300
    for _ in range(k):
        z = rs.choice([-1.0, 1.0], size=tiny.p_dim).astype(np.float32)
        hz, _, _ = probe(params, x, y, jnp.asarray(z))
        est += z * np.asarray(hz)
    est /= k
    # statistical agreement in norm, not element-wise
    num = np.linalg.norm(est - np.asarray(exact))
    den = np.linalg.norm(np.asarray(exact)) + 1e-8
    assert num / den < 0.35


def test_all_variant_specs_consistent():
    for spec in VARIANTS.values():
        assert spec.p_dim == sum(i * o + o for i, o in spec.layer_shapes)
        assert spec.r % 64 == 0 or spec.r < 64, spec.name
        assert spec.m <= spec.r
