"""L1 kernel vs oracle: pairwise squared-L2 distances (hypothesis sweep)."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis.extra import numpy as hnp

from compile.kernels import pairwise_sqdist
from compile.kernels.ref import pairwise_sqdist_ref


def _rows():
    # rows must divide by the tile (64) or be below one tile
    return st.sampled_from([4, 16, 63, 64, 128, 192, 256, 320])


def _cols():
    return st.sampled_from([1, 3, 10, 20, 40, 64])


@given(r=_rows(), c=_cols(), seed=st.integers(0, 2**31 - 1))
def test_matches_ref(r, c, seed):
    g = np.random.RandomState(seed).randn(r, c).astype(np.float32)
    got = np.asarray(pairwise_sqdist(jnp.asarray(g)))
    want = np.asarray(pairwise_sqdist_ref(jnp.asarray(g)))
    np.testing.assert_allclose(got, np.maximum(want, 0.0), rtol=1e-4, atol=1e-4)


@given(r=_rows(), c=_cols(), seed=st.integers(0, 2**31 - 1))
def test_symmetric_nonneg_zero_diag(r, c, seed):
    g = np.random.RandomState(seed).randn(r, c).astype(np.float32)
    d = np.asarray(pairwise_sqdist(jnp.asarray(g)))
    assert (d >= 0).all()
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-4)


@given(
    g=hnp.arrays(
        np.float32,
        st.tuples(st.sampled_from([8, 64]), st.sampled_from([2, 10])),
        elements=st.floats(-50, 50, width=32),
    )
)
def test_adversarial_values(g):
    """Large / repeated / zero values: the a2+b2-2ab expansion must stay sane."""
    got = np.asarray(pairwise_sqdist(jnp.asarray(g)))
    want = np.maximum(np.asarray(pairwise_sqdist_ref(jnp.asarray(g))), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


def test_identical_rows_distance_zero():
    g = np.ones((64, 10), np.float32) * 3.5
    d = np.asarray(pairwise_sqdist(jnp.asarray(g)))
    np.testing.assert_allclose(d, 0.0, atol=1e-4)


def test_rejects_non_divisible_rows():
    with pytest.raises(ValueError):
        pairwise_sqdist(jnp.zeros((100, 4)))  # 100 % 64 != 0


def test_jit_composes():
    """The kernel must lower inside a surrounding jit (the AOT path)."""
    f = jax.jit(lambda g: pairwise_sqdist(g).sum())
    g = np.random.RandomState(0).randn(64, 10).astype(np.float32)
    assert np.isfinite(float(f(jnp.asarray(g))))
