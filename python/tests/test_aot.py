"""AOT pipeline: lowering produces parseable HLO + consistent manifest,
and the lowered computations execute correctly when round-tripped through
the same XLA client the Rust side uses (text -> compile -> run)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.configs import VariantSpec

TINY = VariantSpec(name="tiny-aot", d_in=6, hidden=[8], classes=3, m=8, r=16,
                   eval_chunk=16)


@pytest.fixture(scope="module")
def lowered_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.lower_variant(TINY, str(out), verbose=False)
    return str(out)


def test_all_artifacts_written(lowered_dir):
    vdir = os.path.join(lowered_dir, TINY.name)
    names = {"train_step", "grad_embed", "eval_chunk", "hess_probe",
             "select_greedy"}
    files = set(os.listdir(vdir))
    for n in names:
        assert f"{n}.hlo.txt" in files
    assert "manifest.json" in files


def test_manifest_shapes(lowered_dir):
    with open(os.path.join(lowered_dir, TINY.name, "manifest.json")) as f:
        man = json.load(f)
    assert man["p_dim"] == TINY.p_dim
    arts = man["artifacts"]
    ts = arts["train_step"]
    assert ts["inputs"][0]["shape"] == [TINY.p_dim]
    assert ts["inputs"][2]["shape"] == [TINY.m, TINY.d_in]
    assert ts["inputs"][3]["dtype"] == "i32"
    assert arts["select_greedy"]["outputs"][0]["shape"] == [TINY.m]
    assert man["layer_shapes"] == [[6, 8], [8, 3]]


def test_hlo_text_is_parseable_module(lowered_dir):
    for name in ["train_step", "grad_embed", "eval_chunk", "hess_probe",
                 "select_greedy"]:
        path = os.path.join(lowered_dir, TINY.name, f"{name}.hlo.txt")
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_train_step_text_numerics_vs_python(lowered_dir):
    """Python-side execution of the same jitted fn the text came from; the
    rust integration test (rust/tests) re-checks the text path itself."""
    params = model.init_params(TINY, jax.random.PRNGKey(0))
    mom = jnp.zeros_like(params)
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(TINY.m, TINY.d_in).astype(np.float32))
    y = jnp.asarray(rs.randint(0, TINY.classes, TINY.m).astype(np.int32))
    gamma = jnp.ones((TINY.m,), jnp.float32)
    step = jax.jit(model.make_train_step(TINY))
    p2, m2, loss, ce = step(params, mom, x, y, gamma, jnp.float32(0.1), jnp.float32(0.0))
    assert np.isfinite(float(loss))
    assert p2.shape == (TINY.p_dim,)
    # momentum = grad on first step; update = params - lr*mom
    np.testing.assert_allclose(np.asarray(p2),
                               np.asarray(params) - 0.1 * np.asarray(m2),
                               rtol=1e-5, atol=1e-7)


def test_lowering_is_deterministic(lowered_dir, tmp_path):
    """Same spec -> byte-identical HLO text (required for artifact caching)."""
    out2 = tmp_path / "again"
    aot.lower_variant(TINY, str(out2), verbose=False)
    for name in ["train_step", "select_greedy"]:
        a = open(os.path.join(lowered_dir, TINY.name, f"{name}.hlo.txt")).read()
        b = open(os.path.join(str(out2), TINY.name, f"{name}.hlo.txt")).read()
        assert a == b, name
