"""In-graph greedy selection (select_greedy artifact) vs reference greedy."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

import numpy as _np

from compile import model
from compile.configs import VariantSpec
from compile.kernels.ref import greedy_select_ref, pairwise_sqdist_ref


def _tiny_spec(r=64, c=8, m=8):
    return VariantSpec(name="t", d_in=4, hidden=[4], classes=c, m=m, r=r,
                       eval_chunk=16)


def _unit_act(r, h=4):
    """Constant activations: the product metric reduces to h-scaled
    Euclidean distance on g, so the Euclidean reference greedy applies."""
    return jnp.ones((r, h), jnp.float32)


def _fl_cost(g, idxs):
    d = np.asarray(pairwise_sqdist_ref(jnp.asarray(g)))
    return float(d[np.asarray(idxs), :].min(axis=0).sum())


@given(seed=st.integers(0, 2**31 - 1))
def test_matches_reference_greedy(seed):
    """Kernel greedy and oracle greedy may break float ties differently;
    their facility-location objective values must agree tightly."""
    spec = _tiny_spec()
    g = np.random.RandomState(seed).randn(spec.r, spec.classes)
    g = jnp.asarray(g.astype(np.float32))
    idxs, w = jax.jit(model.make_select_greedy(spec))(g, _unit_act(spec.r))
    idxs_ref, w_ref = greedy_select_ref(g, spec.m)
    cost, cost_ref = _fl_cost(g, idxs), _fl_cost(g, idxs_ref)
    assert cost <= cost_ref * 1.02 + 1e-4
    assert float(np.asarray(w).sum()) == float(np.asarray(w_ref).sum())


@given(seed=st.integers(0, 2**31 - 1),
       r=st.sampled_from([16, 64, 128]),
       m=st.sampled_from([4, 8, 16]))
def test_weights_sum_to_r(seed, r, m):
    """Gamma weights are cluster sizes: they partition the ground set."""
    spec = _tiny_spec(r=r, m=m)
    g = jnp.asarray(np.random.RandomState(seed).randn(r, spec.classes)
                    .astype(np.float32))
    _, w = jax.jit(model.make_select_greedy(spec))(g, _unit_act(r))
    assert float(np.asarray(w).sum()) == float(r)
    assert (np.asarray(w) >= 0).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_indices_in_range(seed):
    spec = _tiny_spec()
    g = jnp.asarray(np.random.RandomState(seed).randn(spec.r, spec.classes)
                    .astype(np.float32))
    idxs, _ = jax.jit(model.make_select_greedy(spec))(g, _unit_act(spec.r))
    idxs = np.asarray(idxs)
    assert ((idxs >= 0) & (idxs < spec.r)).all()


def test_greedy_achieves_near_optimal_coverage():
    """Facility-location greedy is (1 - 1/e)-optimal; on a clustered input
    it must recover ~one medoid per cluster (full coverage)."""
    rs = np.random.RandomState(0)
    centers = rs.randn(8, 8).astype(np.float32) * 20
    g = np.repeat(centers, 8, axis=0) + rs.randn(64, 8).astype(np.float32) * 0.01
    spec = _tiny_spec(r=64, m=8)
    idxs, w = jax.jit(model.make_select_greedy(spec))(jnp.asarray(g), _unit_act(64))
    clusters = set(int(i) // 8 for i in np.asarray(idxs))
    assert len(clusters) == 8  # one medoid per cluster
    np.testing.assert_allclose(np.asarray(w), 8.0)  # balanced weights


def test_greedy_reduces_facility_location_cost():
    """Total min-distance after selection is tiny vs before on clustered data."""
    rs = np.random.RandomState(1)
    centers = rs.randn(4, 8).astype(np.float32) * 10
    g = np.repeat(centers, 16, axis=0) + rs.randn(64, 8).astype(np.float32) * 0.05
    spec = _tiny_spec(r=64, m=4)
    idxs, _ = jax.jit(model.make_select_greedy(spec))(jnp.asarray(g), _unit_act(64))
    d = np.asarray(pairwise_sqdist_ref(jnp.asarray(g)))
    cost = d[np.asarray(idxs), :].min(axis=0).sum()
    assert cost < 0.05 * d.mean() * 64
