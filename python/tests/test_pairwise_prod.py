"""L1 kernel vs oracle: last-layer weight-gradient pairwise distances."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels import pairwise_gradprod
from compile.kernels.ref import pairwise_gradprod_ref


def _case(r, h, c, seed):
    rs = np.random.RandomState(seed)
    a = rs.randn(r, h).astype(np.float32)
    g = rs.randn(r, c).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(g)


@given(r=st.sampled_from([4, 16, 64, 128]),
       h=st.sampled_from([4, 64, 128]),
       c=st.sampled_from([3, 10, 40]),
       seed=st.integers(0, 2**31 - 1))
def test_matches_materialized_outer_products(r, h, c, seed):
    a, g = _case(r, h, c, seed)
    got = np.asarray(pairwise_gradprod(a, g))
    want = np.maximum(np.asarray(pairwise_gradprod_ref(a, g)), 0.0)
    # float32 cancellation error scales with the |a|^2|g|^2 magnitudes
    scale = float(want.max()) + 1.0
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-5 * scale)


@given(seed=st.integers(0, 2**31 - 1))
def test_symmetric_nonneg_zero_diag(seed):
    a, g = _case(64, 16, 5, seed)
    d = np.asarray(pairwise_gradprod(a, g))
    assert (d >= 0).all()
    np.testing.assert_allclose(d, d.T, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-5 * (float(d.max()) + 1.0))


def test_identical_rows_zero_distance():
    a = jnp.ones((64, 8), jnp.float32) * 2.0
    g = jnp.ones((64, 4), jnp.float32) * -0.5
    d = np.asarray(pairwise_gradprod(a, g))
    np.testing.assert_allclose(d, 0.0, atol=1e-3)


def test_zero_gradient_row_distance_is_other_norm():
    """If g_i = 0 the outer product vanishes: d(i,j) = |a_j|^2 |g_j|^2."""
    a = jnp.ones((4, 2), jnp.float32)
    g = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [0.0, 2.0], [0.0, 0.0]], jnp.float32)
    d = np.asarray(pairwise_gradprod(a, g))
    assert d[0, 1] == pytest.approx(2.0, rel=1e-4)  # |a|^2=2, |g|^2=1
    assert d[0, 2] == pytest.approx(8.0, rel=1e-4)
    assert d[0, 3] == pytest.approx(0.0, abs=1e-5)


def test_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        pairwise_gradprod(jnp.zeros((8, 2)), jnp.zeros((9, 2)))
    with pytest.raises(ValueError):
        pairwise_gradprod(jnp.zeros((100, 2)), jnp.zeros((100, 2)))  # 100 % 64
