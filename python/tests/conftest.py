"""Shared pytest config: hypothesis profile for jit-heavy kernel tests.

Kernel calls trace+compile on first execution, so wall-clock per example is
dominated by compilation; deadlines are disabled and example counts kept
moderate. ``derandomize=True`` keeps CI runs reproducible.
"""

import os
import sys

import hypothesis

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")
