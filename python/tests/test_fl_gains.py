"""L1 kernel vs oracle: facility-location marginal gains."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile.kernels import fl_gains, pairwise_sqdist
from compile.kernels.ref import fl_gains_ref, pairwise_sqdist_ref


def _case(seed, r=64, c=8):
    rs = np.random.RandomState(seed)
    g = rs.randn(r, c).astype(np.float32)
    d = np.asarray(pairwise_sqdist_ref(jnp.asarray(g)))
    mind = rs.uniform(0, 20, size=r).astype(np.float32)
    return d, mind


@given(seed=st.integers(0, 2**31 - 1),
       r=st.sampled_from([16, 64, 128, 256, 320]))
def test_matches_ref(seed, r):
    d, mind = _case(seed, r=r)
    got = np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind)))
    want = np.asarray(fl_gains_ref(jnp.asarray(d), jnp.asarray(mind)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_gains_nonnegative(seed):
    d, mind = _case(seed)
    gains = np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind)))
    assert (gains >= 0).all()


@given(seed=st.integers(0, 2**31 - 1))
def test_gains_shrink_after_update(seed):
    """Submodularity: once mins are tightened by any selection, every
    candidate's marginal gain can only decrease."""
    d, mind = _case(seed)
    g0 = np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind)))
    j = int(np.argmax(g0))
    mind2 = np.minimum(mind, d[j])
    g1 = np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind2)))
    assert (g1 <= g0 + 1e-4).all()


def test_selected_candidate_gain_drops_to_zero():
    d, mind = _case(3)
    j = int(np.argmax(np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind)))))
    mind2 = np.minimum(mind, d[j])
    g1 = np.asarray(fl_gains(jnp.asarray(d), jnp.asarray(mind2)))
    assert g1[j] == pytest.approx(0.0, abs=1e-5)


def test_zero_mind_means_zero_gain():
    d, _ = _case(11)
    gains = np.asarray(fl_gains(jnp.asarray(d), jnp.zeros(64, np.float32)))
    np.testing.assert_allclose(gains, 0.0, atol=1e-6)
