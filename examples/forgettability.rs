//! Forgettability analysis (paper §5.2 "Importance of Examples"): relate
//! what CREST selects to ground-truth example structure — difficulty,
//! sub-cluster redundancy and label noise are known for the synthetic
//! proxies, so the paper's Fig. 5/7 story can be checked directly.
//!
//!   cargo run --release --example forgettability

use anyhow::{Context, Result};
use crest::api::Method;
use crest::config::ExperimentConfig;
use crest::coordinator::run_experiment;
use crest::data::{generate, SynthSpec};
use crest::report::Table;
use crest::runtime::Runtime;
use crest::util::cli::Cli;
use crest::util::stats;

fn main() -> Result<()> {
    crest::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("forgettability", "selection vs example structure")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("seed", "1", "seed")
        .parse(&args)?;
    let variant = p.str("variant");
    let seed = p.u64("seed")?;
    let rt = Runtime::load(std::path::Path::new("artifacts"), &variant)?;
    let splits = generate(&SynthSpec::preset(&variant, seed).context("preset")?);
    let ds = &splits.train;

    let cfg = ExperimentConfig::preset(&variant, Method::crest(), seed)?;
    let rep = run_experiment(&rt, &splits, cfg)?;

    // selection counts vs ground-truth difficulty quartiles
    println!("# selection frequency by ground-truth difficulty quartile");
    let mut order: Vec<usize> = (0..ds.n()).collect();
    order.sort_by(|&a, &b| ds.difficulty[a].partial_cmp(&ds.difficulty[b]).unwrap());
    let mut table = Table::new(&["difficulty quartile", "mean selections", "mean difficulty"]);
    for q in 0..4 {
        let lo = q * ds.n() / 4;
        let hi = (q + 1) * ds.n() / 4;
        let sel: Vec<f32> =
            order[lo..hi].iter().map(|&i| rep.selection_counts[i] as f32).collect();
        let diff: Vec<f32> = order[lo..hi].iter().map(|&i| ds.difficulty[i]).collect();
        table.row(&[
            format!("Q{} ({})", q + 1, ["easiest", "easy", "hard", "hardest"][q]),
            format!("{:.2}", stats::mean(&sel)),
            format!("{:.3}", stats::mean(&diff)),
        ]);
    }
    print!("{}", table.render());

    // forgettability of selected examples over time (Fig. 5 series)
    println!("\n# mean final forgettability of selected examples over training");
    let third = rep.forget_of_selected.len().max(1) / 3;
    for (name, range) in [
        ("early third", 0..third),
        ("middle third", third..2 * third),
        ("final third", 2 * third..rep.forget_of_selected.len()),
    ] {
        let scores: Vec<f32> =
            rep.forget_of_selected[range].iter().map(|&(_, s)| s).collect();
        println!("{name:>14}: {:.3}", stats::mean(&scores));
    }

    // exclusion vs ground truth
    println!("\n# who gets excluded as 'learned'?");
    if rep.excluded_indices.is_empty() {
        println!("(nothing excluded)");
    } else {
        let exc_diff: Vec<f32> =
            rep.excluded_indices.iter().map(|&i| ds.difficulty[i]).collect();
        let noisy = rep.excluded_indices.iter().filter(|&&i| ds.is_noisy[i]).count();
        println!(
            "excluded {} examples; mean difficulty {:.3} (dataset mean {:.3}); {} noisy",
            rep.excluded_indices.len(),
            stats::mean(&exc_diff),
            stats::mean(&ds.difficulty),
            noisy
        );
    }
    Ok(())
}
