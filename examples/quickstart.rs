//! Quickstart — the smallest complete use of the public API:
//! build an experiment with the `Experiment` builder (native CPU backend,
//! no artifacts needed), train with CREST under a 10% budget, and print
//! the result next to the Random baseline.
//!
//!   cargo run --release --example quickstart

use anyhow::Result;
use crest::api::Experiment;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;

    // 1. build: the builder validates the variant/method, loads the
    //    native runtime (an artifacts/ directory, when present, overrides
    //    the shapes) and generates the variant's synthetic proxy corpus
    let mut crest_exp = Experiment::builder()
        .variant(variant)
        .method("crest")
        .seed(seed)
        .budget_frac(0.1)
        .build()?;
    println!("{}", crest_exp.runtime().describe());
    let splits = crest_exp.splits();
    println!(
        "data: {} train / {} val / {} test, {} classes",
        splits.train.n(),
        splits.val.n(),
        splits.test.n(),
        splits.train.classes
    );

    // 2. run CREST at a 10% backprop budget
    let report = crest_exp.run()?;
    println!(
        "CREST: test acc {:.4} in {} steps ({} coreset updates, {} examples excluded)",
        report.final_test_acc, report.steps, report.n_selection_updates, report.n_excluded
    );

    // 3. compare against the Random baseline at the same budget,
    //    reusing the corpus the first experiment already generated
    let random = Experiment::builder()
        .variant(variant)
        .method("random")
        .seed(seed)
        .budget_frac(0.1)
        .splits(crest_exp.splits_arc())
        .build()?
        .run()?;
    println!("Random: test acc {:.4} in {} steps", random.final_test_acc, random.steps);
    Ok(())
}
