//! Quickstart — the smallest complete use of the public API:
//! load a variant's runtime (native CPU backend, no artifacts needed),
//! generate its proxy corpus, train with CREST under a 10% budget, and
//! print the result.
//!
//!   cargo run --release --example quickstart

use anyhow::{Context, Result};
use crest::config::{ExperimentConfig, MethodKind};
use crest::coordinator::run_experiment;
use crest::data::{generate, SynthSpec};
use crest::runtime::Runtime;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;

    // 1. runtime: native backend from the builtin manifest (an artifacts/
    //    directory, when present, overrides the shapes)
    let rt = Runtime::load(std::path::Path::new("artifacts"), variant)?;
    println!("{}", rt.describe());

    // 2. data: the variant's synthetic proxy corpus
    let splits = generate(&SynthSpec::preset(variant, seed).context("preset")?);
    println!(
        "data: {} train / {} val / {} test, {} classes",
        splits.train.n(),
        splits.val.n(),
        splits.test.n(),
        splits.train.classes
    );

    // 3. train with CREST at a 10% backprop budget
    let cfg = ExperimentConfig::preset(variant, MethodKind::Crest, seed)?;
    let report = run_experiment(&rt, &splits, cfg)?;
    println!(
        "CREST: test acc {:.4} in {} steps ({} coreset updates, {} examples excluded)",
        report.final_test_acc, report.steps, report.n_selection_updates, report.n_excluded
    );

    // 4. compare against the Random baseline at the same budget
    let cfg = ExperimentConfig::preset(variant, MethodKind::Random, seed)?;
    let random = run_experiment(&rt, &splits, cfg)?;
    println!("Random: test acc {:.4} in {} steps", random.final_test_acc, random.steps);
    Ok(())
}
