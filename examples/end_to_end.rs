//! End-to-end driver: exercises every layer of the stack on a real small
//! workload and reports the paper's headline metric.
//!
//! Pipeline proven here:
//!   runtime backend (native by default; PJRT-compiled artifacts behind the
//!   `pjrt` feature) → `Experiment` builder → CREST coordinator
//!   (Algorithm 1) → full-vs-budgeted training with loss curves →
//!   relative error + speedup. A `RunObserver` streams per-eval progress
//!   while each cell trains.
//!
//! Writes a JSON transcript to reports/end_to_end.json.
//!
//!   cargo run --release --example end_to_end -- [--variant cifar10-proxy]

use std::sync::Arc;

use anyhow::{Context, Result};
use crest::api::{EvalEvent, Experiment, Method, RunObserver, Signal};
use crest::data::{generate, SynthSpec};
use crest::metrics::relative_error_pct;
use crest::report::Table;
use crest::util::cli::Cli;
use crest::util::json::Json;

/// Streams one line per evaluation point — the observer-API replacement
/// for polling a finished report's history.
struct Progress {
    method: &'static str,
}

impl RunObserver for Progress {
    fn on_eval(&mut self, ev: &EvalEvent<'_>) -> Signal {
        println!(
            "  [{}] step {:>5}: test loss {:.2}, test acc {:.4}",
            self.method, ev.step, ev.test_loss, ev.test_acc
        );
        Signal::Continue
    }
}

fn main() -> Result<()> {
    crest::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("end_to_end", "full-stack training driver")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("seed", "1", "seed")
        .opt("epochs-full", "50", "full-run epochs")
        .opt("out", "reports/end_to_end.json", "JSON transcript path")
        .parse(&args)?;
    let variant = p.str("variant");
    let seed = p.u64("seed")?;

    // one corpus shared by all three cells (it derives from variant+seed)
    let splits =
        Arc::new(generate(&SynthSpec::preset(&variant, seed).context("preset")?));
    println!("== end-to-end: {variant}, n={} ==", splits.train.n());

    let mut transcript = Vec::new();
    let mut table = Table::new(&[
        "method", "budget", "test acc", "rel err %", "backprops", "wall (s)", "loss curve",
    ]);
    let mut full_acc = 0.0f32;
    for (method, label, budget) in [
        (Method::full(), "full", 1.0f32),
        (Method::random(), "random", 0.1),
        (Method::crest(), "crest", 0.1),
    ] {
        let rep = Experiment::builder()
            .variant(&variant)
            .with_method(method)
            .seed(seed)
            .budget_frac(budget)
            .epochs_full(p.usize("epochs-full")?)
            .splits(splits.clone())
            .observe(Box::new(Progress { method: label }))
            .build()?
            .run()?;
        if method.is_reference() {
            full_acc = rep.final_test_acc;
        }
        table.row(&[
            rep.method.clone(),
            format!("{:.0}%", budget * 100.0),
            format!("{:.4}", rep.final_test_acc),
            format!("{:.2}", relative_error_pct(rep.final_test_acc * 100.0, full_acc * 100.0)),
            format!("{}", rep.backprops),
            format!("{:.2}", rep.total_secs),
            format!("{} pts", rep.history.len()),
        ]);
        transcript.push(rep.to_json());
    }
    print!("{}", table.render());

    let out = p.str("out");
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, Json::Arr(transcript).to_string_pretty())?;
    println!("transcript written to {out}");
    Ok(())
}
