//! End-to-end driver: exercises every layer of the stack on a real small
//! workload and reports the paper's headline metric.
//!
//! Pipeline proven here:
//!   runtime backend (native by default; PJRT-compiled artifacts behind the
//!   `pjrt` feature) → CREST coordinator (Algorithm 1)
//!   → full-vs-budgeted training with loss curves → relative error + speedup.
//!
//! Writes a JSON transcript to reports/end_to_end.json.
//!
//!   cargo run --release --example end_to_end -- [--variant cifar10-proxy]

use anyhow::{Context, Result};
use crest::config::{ExperimentConfig, MethodKind};
use crest::coordinator::run_experiment;
use crest::data::{generate, SynthSpec};
use crest::metrics::relative_error_pct;
use crest::report::Table;
use crest::runtime::Runtime;
use crest::util::cli::Cli;
use crest::util::json::Json;

fn main() -> Result<()> {
    crest::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("end_to_end", "full-stack training driver")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("seed", "1", "seed")
        .opt("epochs-full", "50", "full-run epochs")
        .opt("out", "reports/end_to_end.json", "JSON transcript path")
        .parse(&args)?;
    let variant = p.str("variant");
    let seed = p.u64("seed")?;

    let rt = Runtime::load(std::path::Path::new("artifacts"), &variant)?;
    let splits = generate(&SynthSpec::preset(&variant, seed).context("preset")?);
    println!("== end-to-end: {variant}, n={} ==", splits.train.n());

    let mut transcript = Vec::new();
    let mut table = Table::new(&[
        "method", "budget", "test acc", "rel err %", "backprops", "wall (s)", "loss curve",
    ]);
    let mut full_acc = 0.0f32;
    for (method, budget) in [
        (MethodKind::Full, 1.0f32),
        (MethodKind::Random, 0.1),
        (MethodKind::Crest, 0.1),
    ] {
        let mut cfg = ExperimentConfig::preset(&variant, method, seed)?;
        cfg.epochs_full = p.usize("epochs-full")?;
        cfg.budget_frac = budget;
        let rep = run_experiment(&rt, &splits, cfg)?;
        if method == MethodKind::Full {
            full_acc = rep.final_test_acc;
        }
        let curve: Vec<String> =
            rep.history.iter().map(|h| format!("{:.2}", h.test_loss)).collect();
        println!("loss curve [{}]: {}", rep.method, curve.join(" "));
        table.row(&[
            rep.method.clone(),
            format!("{:.0}%", budget * 100.0),
            format!("{:.4}", rep.final_test_acc),
            format!("{:.2}", relative_error_pct(rep.final_test_acc * 100.0, full_acc * 100.0)),
            format!("{}", rep.backprops),
            format!("{:.2}", rep.total_secs),
            format!("{} pts", rep.history.len()),
        ]);
        transcript.push(rep.to_json());
    }
    print!("{}", table.render());

    let out = p.str("out");
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, Json::Arr(transcript).to_string_pretty())?;
    println!("transcript written to {out}");
    Ok(())
}
