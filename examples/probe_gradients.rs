//! Diagnostic: bias/variance of mini-batch gradient estimators at several
//! checkpoints along training (the measurement behind paper Figs. 1c/1d/9).
//!
//! Compares, against the full-data gradient:
//!   random-m     unweighted random mini-batches of size m
//!   random-r     unweighted random subsets of size r (large-batch ref)
//!   crest-mb     weighted facility-location mini-batch coresets from
//!                random subsets of size r
//!
//! Usage: cargo run --release --example probe_gradients -- [--variant V]

use anyhow::{Context, Result};
use crest::api::Method;
use crest::config::ExperimentConfig;
use crest::coreset::facility;
use crest::coreset::MiniBatchCoreset;
use crest::data::{generate, SynthSpec};
use crest::metrics::gradprobe;
use crest::model::init_params;
use crest::opt::LrSchedule;
use crest::runtime::Runtime;
use crest::train::TrainState;
use crest::util::cli::Cli;
use crest::util::rng::Rng;

fn main() -> Result<()> {
    crest::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("probe_gradients", "gradient bias/variance probes")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("artifacts", "artifacts", "artifact root")
        .opt("seed", "1", "seed")
        .opt("samples", "24", "mini-batches per estimate")
        .parse(&args)?;
    let variant = p.str("variant");
    let seed = p.u64("seed")?;
    let rt = Runtime::load(std::path::Path::new(&p.str("artifacts")), &variant)?;
    let splits = generate(&SynthSpec::preset(&variant, seed).context("preset")?);
    let ds = &splits.train;
    let cfg = ExperimentConfig::preset(&variant, Method::random(), seed)?;
    let k_samples = p.usize("samples")?;

    let m = rt.man.m;
    let r = rt.man.r;
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;
    let sched = LrSchedule::paper_default(cfg.base_lr);
    let total = 800usize;

    println!("{:>6} {:>12} {:>12} {:>12} {:>12} {:>12}", "step",
             "rand-m bias", "rand-m var", "crest bias", "crest var", "|∇L|");
    let checkpoints = [0usize, 50, 150, 400, 799];
    let mut next_cp = 0;
    for step in 0..total {
        if next_cp < checkpoints.len() && step == checkpoints[next_cp] {
            next_cp += 1;
            let full = gradprobe::full_gradient(&rt, &state.params, ds)?;
            let mut rng_a = rng.split();
            let rand_stats = gradprobe::bias_variance(&rt, &state.params, ds, &full,
                k_samples, || {
                    let idx = rng_a.sample_indices(ds.n(), m);
                    (idx, vec![1.0; m])
                })?;
            let mut rng_b = rng.split();
            // crest mini-batch coresets: fresh V_p each draw
            let mut crest_sampler = || -> (Vec<usize>, Vec<f32>) {
                let pool = rng_b.sample_indices(ds.n(), r);
                let (x, y) = ds.batch(&pool);
                let (gl, al, _) = rt.grad_embed(&state.params, &x, &y).unwrap();
                let sel = facility::facility_location_prod(&al, &gl, m);
                let mb = MiniBatchCoreset::from_selection(&sel, &pool, m);
                (mb.idx, mb.gamma)
            };
            let crest_stats = gradprobe::bias_variance(&rt, &state.params, ds, &full,
                k_samples, &mut crest_sampler)?;
            println!(
                "{:>6} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
                step, rand_stats.bias, rand_stats.variance,
                crest_stats.bias, crest_stats.variance, rand_stats.full_norm
            );
        }
        // advance training with random batches
        let idx = rng.sample_indices(ds.n(), m);
        let lr = sched.lr_at(step, total);
        state.step_batch(&rt, ds, &idx, &vec![1.0; m], lr, cfg.weight_decay)?;
    }
    Ok(())
}
