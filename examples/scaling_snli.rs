//! Scaling scenario (paper §5: "CREST is the only coreset method applicable
//! to SNLI with 570k examples"): run the largest proxy corpus and show why
//! per-epoch full-data selection does not scale while CREST's
//! random-subset selection cost is independent of n.
//!
//!   cargo run --release --example scaling_snli

use anyhow::{Context, Result};
use crest::api::Method;
use crest::config::ExperimentConfig;
use crest::coordinator::run_experiment;
use crest::coordinator::sources::full_embeddings;
use crest::data::{generate, SynthSpec};
use crest::model::init_params;
use crest::report::Table;
use crest::runtime::Runtime;
use crest::train::TrainState;
use crest::util::rng::Rng;
use std::time::Instant;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "snli-proxy";
    let seed = 1;
    let rt = Runtime::load(std::path::Path::new("artifacts"), variant)?;
    let splits = generate(&SynthSpec::preset(variant, seed).context("preset")?);
    let ds = &splits.train;
    println!("== scaling: {variant}, n = {} ==", ds.n());

    // selection-cost comparison at matched state
    let mut rng = Rng::new(seed);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;
    let (m, r) = (rt.man.m, rt.man.r);

    let t0 = Instant::now();
    let pool = rng.sample_indices(ds.n(), r);
    let (x, y) = ds.batch(&pool);
    let (gl, al, _) = rt.grad_embed(&state.params, &x, &y)?;
    let _sel = crest::coreset::facility::facility_location_prod(&al, &gl, m);
    let crest_sel = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let (gl_full, al_full, _) = full_embeddings(&rt, &state.params, ds)?;
    let embed_full = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let _sel = crest::coreset::craig::craig_select(&al_full, &gl_full, ds.n() / 10, &mut rng);
    let craig_sel = embed_full + t0.elapsed().as_secs_f64();

    let mut table = Table::new(&["selection scheme", "per update (s)", "per epoch (s)"]);
    table.row(&[
        format!("CREST mini-batch (r={r}, independent of n)"),
        format!("{crest_sel:.4}"),
        format!("{:.3}", crest_sel * (ds.n() / 10 / m) as f64),
    ]);
    table.row(&[
        format!("full-data coreset (n={})", ds.n()),
        format!("{craig_sel:.3}"),
        format!("{craig_sel:.3}"),
    ]);
    print!("{}", table.render());

    // budgeted training on the large corpus
    println!("\n== 10% budget training ==");
    let mut t = Table::new(&["method", "test acc", "wall (s)"]);
    for method in [Method::random(), Method::crest()] {
        let cfg = ExperimentConfig::preset(variant, method, seed)?;
        let rep = run_experiment(&rt, &splits, cfg)?;
        t.row(&[
            rep.method.clone(),
            format!("{:.4}", rep.final_test_acc),
            format!("{:.1}", rep.total_secs),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
