//! End-to-end acceptance tests for the approximate-selection layer
//! (ISSUE 7):
//!
//! * degenerate strategy parameters are the identity: for **every**
//!   registered method, `ClassSharded { shards: 1 }`, `Clustered { k: n }`
//!   and `Knn { neighbors: n }` reproduce `SelectionStrategy::Exact`
//!   bit for bit on `deterministic_json` — the approximate layer
//!   composes with the registry without per-method dispatch edits, and
//!   collapses to the exact path before consuming any randomness
//! * the determinism contract holds per strategy: a full experiment
//!   under each *non*-degenerate strategy is bitwise-identical across
//!   thread counts and across repeated runs
//! * `SelectionStrategy` round-trips through the builder and the JSON
//!   config override surface, and unknown spellings are rejected like
//!   any other unknown config value

use std::sync::Arc;

use crest::api::{Experiment, MethodRegistry, SelectionStrategy};
use crest::config::Method;
use crest::data::{generate, Splits, SynthSpec};
use crest::util::json::Json;
use crest::util::pool;

const SMOKE: &str = "smoke";

fn smoke_splits(seed: u64) -> Arc<Splits> {
    Arc::new(generate(&SynthSpec::preset(SMOKE, seed).unwrap()))
}

/// Run one smoke cell and return its deterministic report rendering.
fn run_cell(splits: &Arc<Splits>, method: Method, strat: SelectionStrategy, seed: u64) -> String {
    Experiment::builder()
        .variant(SMOKE)
        .with_method(method)
        .seed(seed)
        .budget_frac(0.1)
        .epochs_full(2)
        .configure(|cfg| cfg.eval_points = 2)
        .selection(strat)
        .splits(splits.clone())
        .build()
        .unwrap()
        .run()
        .unwrap()
        .deterministic_json()
        .to_string_pretty()
}

#[test]
fn degenerate_parameters_reproduce_exact_bitwise_for_every_method() {
    let splits = smoke_splits(7);
    let n = splits.train.n();
    // parameters at (or beyond) the ground-set size collapse each
    // approximate strategy to the exact traversal
    let degenerate = [
        SelectionStrategy::ClassSharded { shards: 1 },
        SelectionStrategy::Clustered { k: n },
        SelectionStrategy::Knn { neighbors: n },
    ];
    for method in MethodRegistry::all() {
        let exact = run_cell(&splits, method, SelectionStrategy::Exact, 7);
        for s in degenerate {
            let approx = run_cell(&splits, method, s, 7);
            assert_eq!(
                approx,
                exact,
                "{s} must reproduce exact output bitwise for {}",
                method.name()
            );
        }
    }
}

#[test]
fn approximate_strategies_are_bitwise_deterministic_across_thread_counts() {
    let splits = smoke_splits(11);
    // genuinely approximate parameterizations: small shard/cluster/
    // neighbor counts relative to the smoke ground set
    let strategies = [
        SelectionStrategy::ClassSharded { shards: 0 },
        SelectionStrategy::Clustered { k: 64 },
        SelectionStrategy::Knn { neighbors: 8 },
    ];
    for method in ["crest", "craig"] {
        let m = Method::parse(method).unwrap();
        for s in strategies {
            let t1 = pool::with_threads(1, || run_cell(&splits, m, s, 11));
            let t4 = pool::with_threads(4, || run_cell(&splits, m, s, 11));
            assert_eq!(t1, t4, "{s} for {method} must not depend on thread count");
            let again = pool::with_threads(4, || run_cell(&splits, m, s, 11));
            assert_eq!(t4, again, "{s} for {method} must be run-to-run deterministic");
        }
    }
}

#[test]
fn selection_round_trips_through_json_overrides() {
    let splits = smoke_splits(13);
    let m = Method::parse("craig").unwrap();
    // the JSON override surface and the typed builder argument are the
    // same knob: identical settings produce identical reports
    let typed = run_cell(&splits, m, SelectionStrategy::Clustered { k: 64 }, 13);
    let json = Experiment::builder()
        .variant(SMOKE)
        .with_method(m)
        .seed(13)
        .budget_frac(0.1)
        .epochs_full(2)
        .configure(|cfg| cfg.eval_points = 2)
        .override_json(&Json::obj().set("selection", "clustered:64"))
        .splits(splits.clone())
        .build()
        .unwrap()
        .run()
        .unwrap()
        .deterministic_json()
        .to_string_pretty();
    assert_eq!(typed, json, "builder and JSON override must set the same strategy");
    // unknown strategy spellings are rejected at parse time, not at run
    // time — same contract as any other config key
    assert!(SelectionStrategy::parse("voronoi").is_err());
    assert!(SelectionStrategy::parse("clustered:sixty-four").is_err());
    assert!(SelectionStrategy::parse("exact:3").is_err());
}
