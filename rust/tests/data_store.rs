//! Cross-layer tests for the pluggable data-store layer.
//!
//! The contract under test: the storage backend is invisible to every
//! consumer. A training run must produce a bitwise-identical
//! deterministic report whether the corpus lives in RAM (`MemStore`) or
//! in sharded files behind the mmap store, the prefetching loader must
//! stream identical batches from either, and the optional on-disk
//! embedding cache must never change a report.

use std::path::PathBuf;
use std::sync::Mutex;

use crest::api::MethodRegistry;
use crest::config::{ExperimentConfig, Method};
use crest::coordinator::run_experiment;
use crest::data::loader::Loader;
use crest::data::shard::{load_packed_splits, pack_splits};
use crest::data::{generate, Dataset, Splits, SynthSpec};
use crest::report::RunReport;
use crest::runtime::Runtime;

/// Serializes the tests in this binary: one of them mutates process-wide
/// env state (`CREST_EMBED_CACHE`), which must not leak into a
/// concurrently running experiment.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tdir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("crest_data_store_test_{}_{name}", std::process::id()))
}

/// Pack a generated corpus and reopen it through the mmap store, with
/// shard_rows small enough that every split spans several shards and
/// ends in a short tail.
fn packed_copy(mem: &Splits, name: &str, shard_rows: usize) -> (PathBuf, Splits) {
    let root = tdir(name);
    let _ = std::fs::remove_dir_all(&root);
    pack_splits(mem, &root, shard_rows).unwrap();
    let mmap = load_packed_splits(&root).unwrap();
    assert_eq!(mmap.train.store_kind(), "mmap");
    assert_eq!(mem.train.store_kind(), "mem");
    (root, mmap)
}

fn smoke_cell(rt: &Runtime, splits: &Splits, method: Method, seed: u64) -> RunReport {
    let mut cfg = ExperimentConfig::preset("smoke", method, seed).unwrap();
    cfg.epochs_full = 2;
    run_experiment(rt, splits, cfg).unwrap()
}

/// The headline acceptance check: every registered method, run on the
/// smoke grid, reports bitwise-identically from the mem and mmap stores.
#[test]
fn mem_and_mmap_reports_bitwise_identical_for_every_method() {
    let _g = lock();
    let rt = Runtime::native_variant("smoke").unwrap();
    let mem = generate(&SynthSpec::preset("smoke", 3).unwrap());
    let (root, mmap) = packed_copy(&mem, "method_grid", 100);
    for method in MethodRegistry::all() {
        let a = smoke_cell(&rt, &mem, method, 3);
        let b = smoke_cell(&rt, &mmap, method, 3);
        assert_eq!(
            a.deterministic_json().to_string_pretty(),
            b.deterministic_json().to_string_pretty(),
            "{method:?}: mem and mmap stores must produce identical reports"
        );
    }
    std::fs::remove_dir_all(&root).ok();
}

/// The prefetching loader sees the store only through `Dataset::batch`,
/// so its index stream and batch payloads must match across backends.
#[test]
fn loader_streams_identical_batches_from_either_store() {
    let _g = lock();
    let mem = generate(&SynthSpec::preset("smoke", 9).unwrap());
    let (root, mmap) = packed_copy(&mem, "loader", 64);
    let drain = |ds: &Dataset| -> Vec<(Vec<usize>, Vec<f32>, Vec<i32>)> {
        let mut l = Loader::spawn(ds, 32, 20, 17, 4);
        std::iter::from_fn(|| l.next()).map(|b| (b.idx, b.x.data, b.y)).collect()
    };
    let a = drain(&mem.train);
    let b = drain(&mmap.train);
    assert_eq!(a.len(), 20);
    assert_eq!(a, b, "loader batches must not depend on the store backend");
    std::fs::remove_dir_all(&root).ok();
}

/// Enabling the embedding cache (cold or warm) must never change a
/// report: hits return exactly what recomputation would have produced.
#[test]
fn embed_cache_never_changes_reports() {
    let _g = lock();
    let rt = Runtime::native_variant("smoke").unwrap();
    let splits = generate(&SynthSpec::preset("smoke", 5).unwrap());
    let baseline = smoke_cell(&rt, &splits, Method::crest(), 5);

    let dir = tdir("embcache");
    let _ = std::fs::remove_dir_all(&dir);
    std::env::set_var("CREST_EMBED_CACHE", &dir);
    let cold = smoke_cell(&rt, &splits, Method::crest(), 5);
    let n_entries = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
    let warm = smoke_cell(&rt, &splits, Method::crest(), 5);
    std::env::remove_var("CREST_EMBED_CACHE");

    assert!(n_entries > 0, "cold run should have populated the cache");
    let want = baseline.deterministic_json().to_string_pretty();
    assert_eq!(cold.deterministic_json().to_string_pretty(), want, "cold cache changed the run");
    assert_eq!(warm.deterministic_json().to_string_pretty(), want, "warm cache changed the run");
    std::fs::remove_dir_all(&dir).ok();
}
