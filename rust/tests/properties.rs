//! Property-based tests (crest::prop harness) over the pure algorithmic
//! invariants — no XLA required.

use crest::coreset::facility::{
    self, coverage_cost, facility_location, facility_location_metric,
    facility_location_stochastic, EuclidMetric, ProdMetric, SqDistMetric,
};
use crest::exclusion::ExclusionTracker;
use crest::opt::{Budget, LrSchedule};
use crest::prop::{forall, usize_in, vec_f32};
use crest::quadratic::{QuadOptions, QuadraticModel};
use crest::tensor::MatF32;
use crest::util::json::Json;
use crest::util::rng::Rng;
use crest::util::stats;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, vec_f32(rng, rows * cols, scale)).unwrap()
}

#[test]
fn prop_facility_gamma_partitions_ground_set() {
    forall(
        "facility-gamma-partition",
        0xF1,
        40,
        |rng| {
            let r = usize_in(rng, 4, 60);
            let m = usize_in(rng, 1, r.min(20));
            let cols = usize_in(rng, 1, 8);
            (rand_mat(rng, r, cols, 5.0), m)
        },
        |(g, m)| {
            let sel = facility_location(g, *m);
            let sum: f32 = sel.gamma.iter().sum();
            if sum != g.rows as f32 {
                return Err(format!("gamma sums to {sum}, want {}", g.rows));
            }
            let uniq: std::collections::HashSet<_> = sel.idx.iter().collect();
            if uniq.len() != *m {
                return Err("duplicate medoids".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_facility_cost_monotone_in_m() {
    forall(
        "facility-cost-monotone",
        0xF2,
        25,
        |rng| {
            let r = usize_in(rng, 6, 48);
            (rand_mat(rng, r, 4, 3.0), usize_in(rng, 1, r / 2))
        },
        |(g, m)| {
            let c1 = coverage_cost(g, &facility_location(g, *m).idx);
            let c2 = coverage_cost(g, &facility_location(g, m + 1).idx);
            if c2 <= c1 + 1e-6 {
                Ok(())
            } else {
                Err(format!("cost increased: {c1} -> {c2}"))
            }
        },
    );
}

#[test]
fn prop_prod_metric_equals_materialized_outer_product_distance() {
    forall(
        "prod-metric-equivalence",
        0xF3,
        30,
        |rng| {
            let r = usize_in(rng, 2, 12);
            let h = usize_in(rng, 1, 6);
            let c = usize_in(rng, 1, 5);
            (rand_mat(rng, r, h, 2.0), rand_mat(rng, r, c, 2.0))
        },
        |(a, g)| {
            let metric = ProdMetric::new(a, g);
            for i in 0..a.rows {
                for j in 0..a.rows {
                    // materialize outer products explicitly
                    let mut d = 0.0f64;
                    for p in 0..a.cols {
                        for q in 0..g.cols {
                            let x = a.row(i)[p] as f64 * g.row(i)[q] as f64
                                - a.row(j)[p] as f64 * g.row(j)[q] as f64;
                            d += x * x;
                        }
                    }
                    let got = metric.sqdist(i, j) as f64;
                    let tol = 1e-3 * (1.0 + d.abs());
                    if (got - d).abs() > tol {
                        return Err(format!("d({i},{j}) = {got}, want {d}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stochastic_greedy_cost_close_to_lazy() {
    forall(
        "stochastic-vs-lazy",
        0xF4,
        15,
        |rng| {
            let r = usize_in(rng, 30, 80);
            (rand_mat(rng, r, 4, 3.0), usize_in(rng, 4, 12), Rng::new(rng.next_u64()))
        },
        |(g, m, srng)| {
            let lazy = coverage_cost(g, &facility_location(g, *m).idx);
            let metric = EuclidMetric::new(g);
            let mut srng = srng.clone();
            let stoch = coverage_cost(
                g,
                &facility_location_stochastic(&metric, *m, &mut srng).idx,
            );
            // (1 - 1/e - eps) guarantee -> allow generous slack on cost
            if stoch <= lazy * 3.0 + 1e-6 {
                Ok(())
            } else {
                Err(format!("stochastic {stoch} vs lazy {lazy}"))
            }
        },
    );
}

#[test]
fn prop_lazy_greedy_metric_dispatch_consistent() {
    // facility_location(g) == facility_location_metric(Euclid(g))
    forall(
        "metric-dispatch",
        0xF5,
        20,
        |rng| {
            let r = usize_in(rng, 5, 40);
            (rand_mat(rng, r, 3, 4.0), usize_in(rng, 1, 8).min(r))
        },
        |(g, m)| {
            let a = facility_location(g, *m);
            let b = facility_location_metric(&EuclidMetric::new(g), *m);
            if a.idx == b.idx && a.gamma == b.gamma {
                Ok(())
            } else {
                Err("wrapper and metric form disagree".into())
            }
        },
    );
}

#[test]
fn prop_quadratic_ema_bounded_by_observations() {
    forall(
        "ema-bounded",
        0xF6,
        30,
        |rng| {
            let obs: Vec<Vec<f32>> =
                (0..usize_in(rng, 1, 12)).map(|_| vec_f32(rng, 4, 10.0)).collect();
            obs
        },
        |obs| {
            let mut q = QuadraticModel::new(4, 0.9, 0.99, QuadOptions::default());
            for o in obs {
                q.observe_grad(o);
            }
            let g = q.gbar();
            for k in 0..4 {
                let lo = obs.iter().map(|o| o[k]).fold(f32::INFINITY, f32::min);
                let hi = obs.iter().map(|o| o[k]).fold(f32::NEG_INFINITY, f32::max);
                if g[k] < lo - 1e-3 || g[k] > hi + 1e-3 {
                    return Err(format!("ema[{k}]={} outside [{lo},{hi}]", g[k]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quadratic_rho_scale_invariant() {
    // rho(delta, L) is invariant to scaling both F-L difference and L
    forall(
        "rho-definition",
        0xF7,
        30,
        |rng| (vec_f32(rng, 6, 1.0), vec_f32(rng, 6, 0.5), rng.uniform_in(0.1, 5.0)),
        |(g, delta, loss)| {
            let mut q = QuadraticModel::new(6, 0.9, 0.99, QuadOptions::default());
            q.observe_grad(g);
            q.observe_hdiag(&vec![0.0; 6]);
            q.set_anchor(*loss);
            let f = q.f_l(delta);
            let actual = loss * 1.5;
            let want = (f - actual).abs() / actual;
            let got = q.rho(delta, actual);
            if (got - want).abs() < 1e-5 {
                Ok(())
            } else {
                Err(format!("rho {got} vs {want}"))
            }
        },
    );
}

#[test]
fn prop_exclusion_pool_shrinks_monotonically() {
    forall(
        "exclusion-monotone",
        0xF8,
        25,
        |rng| {
            let n = usize_in(rng, 4, 40);
            let windows: Vec<Vec<(usize, f32)>> = (0..usize_in(rng, 1, 6))
                .map(|_| {
                    (0..usize_in(rng, 1, n))
                        .map(|_| (usize_in(rng, 0, n), rng.uniform_in(0.0, 0.3)))
                        .collect()
                })
                .collect();
            (n, windows)
        },
        |(n, windows)| {
            let mut t = ExclusionTracker::new(*n, 0.1, true);
            let mut prev = t.active_pool().len();
            for w in windows {
                for &(i, l) in w {
                    t.observe(i, l);
                }
                t.end_window();
                let now = t.active_pool().len();
                if now > prev {
                    return Err(format!("pool grew {prev} -> {now}"));
                }
                if t.n_excluded() + now != *n {
                    return Err("excluded + active != n".into());
                }
                prev = now;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_values() {
    forall(
        "json-roundtrip",
        0xF9,
        60,
        |rng| {
            fn gen(rng: &mut Rng, depth: usize) -> Json {
                match if depth > 2 { rng.gen_range(4) } else { rng.gen_range(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.next_u32() & 1 == 0),
                    2 => Json::Num((rng.normal() * 1000.0).round() as f64 / 16.0),
                    3 => Json::Str(format!("s{}-\"quote\\{}", rng.gen_range(100), rng.gen_range(10))),
                    4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen(rng, depth + 1)).collect()),
                    _ => Json::Obj(
                        (0..rng.gen_range(4))
                            .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            gen(rng, 0)
        },
        |v| {
            let s = v.to_string_pretty();
            let back = Json::parse(&s).map_err(|e| format!("parse failed: {e}"))?;
            if &back == v {
                Ok(())
            } else {
                Err(format!("roundtrip mismatch: {s}"))
            }
        },
    );
}

#[test]
fn prop_budget_accounts_exactly() {
    forall(
        "budget-exact",
        0xFA,
        40,
        |rng| (usize_in(rng, 1, 1000), usize_in(rng, 1, 64)),
        |(total, m)| {
            let mut b = Budget::exact(*total as u64);
            let mut steps = 0u64;
            while b.charge(*m) {
                steps += 1;
                if steps > *total as u64 + 1 {
                    return Err("budget never exhausts".into());
                }
            }
            let want = (*total as u64).div_ceil(*m as u64);
            if steps == want {
                Ok(())
            } else {
                Err(format!("{steps} steps, want {want}"))
            }
        },
    );
}

#[test]
fn prop_lr_schedule_bounded_and_nonnegative() {
    forall(
        "lr-bounds",
        0xFB,
        40,
        |rng| (rng.uniform_in(0.001, 1.0), usize_in(rng, 10, 5000)),
        |(base, total)| {
            let s = LrSchedule::paper_default(*base);
            for step in 0..*total {
                let lr = s.lr_at(step, *total);
                if !(lr > 0.0 && lr <= *base * 1.0001) {
                    return Err(format!("lr {lr} out of (0, {base}] at {step}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_selection_normalized_gamma_mean_one() {
    forall(
        "gamma-normalization",
        0xFC,
        30,
        |rng| {
            let m = usize_in(rng, 1, 16);
            let gamma: Vec<f32> = (0..m).map(|_| rng.uniform_in(0.0, 20.0)).collect();
            facility::Selection { idx: (0..m).collect(), gamma }
        },
        |sel| {
            let g = sel.normalized_gamma(sel.idx.len());
            let mean = stats::mean(&g);
            if (mean - 1.0).abs() < 1e-4 || sel.gamma.iter().sum::<f32>() == 0.0 {
                Ok(())
            } else {
                Err(format!("mean gamma {mean}"))
            }
        },
    );
}
