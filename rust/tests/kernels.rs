//! Kernel-equivalence properties for the block-at-a-time layer.
//!
//! The register-tiled matmul microkernels and the blocked distance
//! kernels are pure speed: every test here asserts **bitwise** equality
//! against the scalar references (`crest::kernel::reference`, or the
//! `SqDistMetric::sqdist_block` trait default) across odd shapes that
//! exercise every remainder-tile path, empty/singleton ground sets, and
//! pool worker counts 1/2/4/8.

use std::ops::Range;

use crest::coreset::facility::{
    self, facility_location_metric, facility_location_prod, gain_scan, EuclidMetric,
    GramMetric, ProdMetric, SqDistMetric,
};
use crest::kernel::{self, reference, Workspace};
use crest::prop::{forall, usize_in, vec_f32};
use crest::tensor::MatF32;
use crest::util::pool;
use crest::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, vec_f32(rng, rows * cols, scale)).unwrap()
}

/// Random matrix with roughly half its entries zeroed (a post-ReLU
/// activation pattern — exercises the sparsity-skip paths).
fn relu_mat(rng: &mut Rng, rows: usize, cols: usize) -> MatF32 {
    let mut m = rand_mat(rng, rows, cols, 3.0);
    for v in m.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    m
}

fn bits_eq(a: &[f32], b: &[f32]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!(
                "element {k}: {x} ({:#x}) != {y} ({:#x})",
                x.to_bits(),
                y.to_bits()
            ));
        }
    }
    Ok(())
}

/// Forwarder that hides any tiled `sqdist_block` override, so the trait's
/// scalar default is what runs.
struct ScalarMetric<'a, M: SqDistMetric>(&'a M);

impl<M: SqDistMetric> SqDistMetric for ScalarMetric<'_, M> {
    fn len(&self) -> usize {
        self.0.len()
    }
    fn sqdist(&self, i: usize, j: usize) -> f32 {
        self.0.sqdist(i, j)
    }
}

fn block_vs_scalar<M: SqDistMetric>(m: &M, j: usize, range: Range<usize>) -> Result<(), String> {
    let mut tiled = vec![0.0f32; range.len()];
    let mut scalar = vec![0.0f32; range.len()];
    m.sqdist_block(j, range.clone(), &mut tiled);
    ScalarMetric(m).sqdist_block(j, range, &mut scalar);
    bits_eq(&tiled, &scalar)
}

// ------------------------------------------------------- distance kernels

#[test]
fn prop_blocked_sqdist_matches_scalar_default() {
    forall(
        "blocked-sqdist-bitwise",
        0xB10C,
        60,
        |rng| {
            let n = usize_in(rng, 1, 90);
            let c = usize_in(rng, 1, 20);
            let h = usize_in(rng, 1, 20);
            let g = rand_mat(rng, n, c, 4.0);
            let a = rand_mat(rng, n, h, 4.0);
            let j = usize_in(rng, 0, n);
            let lo = usize_in(rng, 0, n);
            let hi = usize_in(rng, lo, n + 1);
            (g, a, j, lo, hi)
        },
        |(g, a, j, lo, hi)| {
            let euclid = EuclidMetric::new(g);
            block_vs_scalar(&euclid, *j, 0..g.rows)?;
            block_vs_scalar(&euclid, *j, *lo..*hi)?;
            let prod = ProdMetric::new(a, g);
            block_vs_scalar(&prod, *j, 0..g.rows)?;
            block_vs_scalar(&prod, *j, *lo..*hi)?;
            let gram = GramMetric::new(&prod);
            block_vs_scalar(&gram, *j, *lo..*hi)
        },
    );
}

#[test]
fn empty_and_singleton_ground_sets() {
    // empty: metrics exist, blocks over empty ranges are no-ops
    let g0 = MatF32::zeros(0, 3);
    let e0 = EuclidMetric::new(&g0);
    assert!(e0.is_empty());
    e0.sqdist_block(0, 0..0, &mut []);
    assert!(gain_scan(&e0, &[]).is_empty());
    assert_eq!(GramMetric::new(&e0).len(), 0);
    // singleton: one medoid, gamma covers the whole (1-element) ground set
    let mut rng = Rng::new(9);
    let g1 = rand_mat(&mut rng, 1, 5, 2.0);
    let sel = facility::facility_location(&g1, 1);
    assert_eq!(sel.idx, vec![0]);
    assert_eq!(sel.gamma, vec![1.0]);
    let e1 = EuclidMetric::new(&g1);
    let mut d = [7.0f32];
    e1.sqdist_block(0, 0..1, &mut d);
    assert_eq!(d[0], 0.0);
}

#[test]
fn gain_scan_identical_across_thread_counts() {
    let mut rng = Rng::new(11);
    // large enough that the candidate-parallel scan engages
    let g = rand_mat(&mut rng, 700, 7, 3.0);
    let a = rand_mat(&mut rng, 700, 33, 3.0);
    let prod = ProdMetric::new(&a, &g);
    let mind: Vec<f32> = (0..700).map(|i| prod.sqdist(3, i)).collect();
    let base = pool::with_threads(1, || gain_scan(&prod, &mind));
    for t in [2, 4, 8] {
        let scan = pool::with_threads(t, || gain_scan(&prod, &mind));
        bits_eq(&base, &scan).unwrap_or_else(|e| panic!("threads={t}: {e}"));
    }
}

#[test]
fn selection_identical_across_thread_counts_and_gram_cache() {
    let mut rng = Rng::new(12);
    let g = rand_mat(&mut rng, 520, 6, 3.0);
    let a = rand_mat(&mut rng, 520, 24, 3.0);
    let base = pool::with_threads(1, || facility_location_prod(&a, &g, 40));
    for t in [2, 4, 8] {
        let sel = pool::with_threads(t, || facility_location_prod(&a, &g, 40));
        assert_eq!(base.idx, sel.idx, "threads={t}");
        assert_eq!(base.gamma, sel.gamma, "threads={t}");
    }
    // the Gram cache changes flops, never the selection — at any count
    let prod = ProdMetric::new(&a, &g);
    let gram = GramMetric::new(&prod);
    for t in [1, 4] {
        let sel = pool::with_threads(t, || facility_location_metric(&gram, 40));
        assert_eq!(base.idx, sel.idx, "gram threads={t}");
        assert_eq!(base.gamma, sel.gamma, "gram threads={t}");
    }
}

// --------------------------------------------------------- tiled matmuls

#[test]
fn prop_tiled_add_matmul_matches_reference() {
    forall(
        "tiled-add-matmul-bitwise",
        0x7117,
        60,
        |rng| {
            let rows = usize_in(rng, 1, 40);
            let d_in = usize_in(rng, 1, 40);
            let d_out = usize_in(rng, 1, 40);
            let x = rand_mat(rng, rows, d_in, 2.0);
            let w = vec_f32(rng, d_in * d_out, 2.0);
            let out = rand_mat(rng, rows, d_out, 1.0);
            (x, w, out)
        },
        |(x, w, out)| {
            let d_out = out.cols;
            let mut tiled = out.clone();
            let mut scalar = out.clone();
            kernel::add_matmul(&mut tiled, x, w, d_out);
            reference::add_matmul(&mut scalar, x, w, d_out);
            bits_eq(&tiled.data, &scalar.data)
        },
    );
}

#[test]
fn prop_tiled_nt_and_masked_match_reference() {
    forall(
        "tiled-nt-bitwise",
        0x7118,
        60,
        |rng| {
            let rows = usize_in(rng, 1, 30);
            let d_in = usize_in(rng, 1, 30);
            let d_out = usize_in(rng, 1, 30);
            let d = rand_mat(rng, rows, d_out, 2.0);
            let w = vec_f32(rng, d_in * d_out, 2.0);
            let out = rand_mat(rng, rows, d_in, 1.0);
            let act = relu_mat(rng, rows, d_in);
            (d, w, out, act)
        },
        |(d, w, out, act)| {
            let d_out = d.cols;
            let mut tiled = out.clone();
            let mut scalar = out.clone();
            kernel::add_matmul_nt(&mut tiled, d, w, d_out);
            reference::add_matmul_nt(&mut scalar, d, w, d_out);
            bits_eq(&tiled.data, &scalar.data)?;
            let mut tiled_m = out.clone();
            let mut scalar_m = out.clone();
            kernel::add_matmul_nt_masked(&mut tiled_m, d, w, d_out, act);
            reference::add_matmul_nt_masked(&mut scalar_m, d, w, d_out, act);
            bits_eq(&tiled_m.data, &scalar_m.data)
        },
    );
}

#[test]
fn prop_tiled_wgrad_and_bgrad_match_reference() {
    forall(
        "tiled-wgrad-bitwise",
        0x7119,
        60,
        |rng| {
            let rows = usize_in(rng, 1, 30);
            let d_in = usize_in(rng, 1, 40);
            let d_out = usize_in(rng, 1, 40);
            let input = relu_mat(rng, rows, d_in);
            let d = rand_mat(rng, rows, d_out, 2.0);
            let gw = vec_f32(rng, d_in * d_out, 1.0);
            let gb = vec_f32(rng, d_out, 1.0);
            (input, d, gw, gb)
        },
        |(input, d, gw, gb)| {
            let d_out = d.cols;
            let mut tiled = gw.clone();
            let mut scalar = gw.clone();
            kernel::accum_wgrad(&mut tiled, input, d, d_out);
            reference::accum_wgrad(&mut scalar, input, d, d_out);
            bits_eq(&tiled, &scalar)?;
            let mut tb = gb.clone();
            let mut sb = gb.clone();
            kernel::accum_bgrad(&mut tb, d);
            reference::accum_bgrad(&mut sb, d);
            bits_eq(&tb, &sb)
        },
    );
}

#[test]
fn matmuls_identical_across_thread_counts() {
    // sized above the parallel gate (64·128·160 ≈ 1.3M MACs) with ragged
    // remainder tiles (rows/cols not multiples of the tile shape)
    let mut rng = Rng::new(13);
    let (rows, d_in, d_out) = (67, 129, 161);
    let x = relu_mat(&mut rng, rows, d_in);
    let w = vec_f32(&mut rng, d_in * d_out, 1.0);
    let d = rand_mat(&mut rng, rows, d_out, 1.0);
    let act = relu_mat(&mut rng, rows, d_in);
    let run = |t: usize| {
        pool::with_threads(t, || {
            let mut mm = MatF32::zeros(rows, d_out);
            kernel::add_matmul(&mut mm, &x, &w, d_out);
            let mut nt = MatF32::zeros(rows, d_in);
            kernel::add_matmul_nt_masked(&mut nt, &d, &w, d_out, &act);
            let mut gw = vec![0.0f32; d_in * d_out];
            kernel::accum_wgrad(&mut gw, &x, &d, d_out);
            let mut gb = vec![0.0f32; d_out];
            kernel::accum_bgrad(&mut gb, &d);
            (mm.data, nt.data, gw, gb)
        })
    };
    let base = run(1);
    for t in [2, 4, 8] {
        assert_eq!(base, run(t), "thread count {t} changed a tiled kernel result");
    }
}

#[test]
fn relu_mask_matches_serial_semantics() {
    let mut rng = Rng::new(14);
    let act = relu_mat(&mut rng, 37, 29);
    let m0 = rand_mat(&mut rng, 37, 29, 2.0);
    let run = |t: usize| {
        pool::with_threads(t, || {
            let mut m = m0.clone();
            kernel::relu_mask(&mut m, &act);
            m.data
        })
    };
    let masked = run(1);
    for (k, (&v, &a)) in masked.iter().zip(&act.data).enumerate() {
        if a <= 0.0 {
            assert_eq!(v, 0.0, "element {k} not masked");
        } else {
            assert_eq!(v.to_bits(), m0.data[k].to_bits(), "element {k} changed");
        }
    }
    for t in [2, 8] {
        assert_eq!(masked, run(t), "threads={t}");
    }
}

// ------------------------------------------------------------- workspace

#[test]
fn workspace_reuses_capacity_and_zeroes_buffers() {
    let mut ws = Workspace::new();
    let mut a = ws.buf(100);
    a.iter_mut().for_each(|v| *v = 7.0);
    let cap = a.capacity();
    ws.recycle(a);
    assert_eq!(ws.pooled(), 1);
    // reuse must hand back zeroed contents on the same allocation
    let b = ws.buf(64);
    assert!(b.capacity() >= 64 && b.capacity() <= cap.max(64));
    assert!(b.iter().all(|&v| v == 0.0));
    ws.recycle(b);
    // copies and broadcast rows
    let src = MatF32::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
    let c = ws.mat_copy(&src);
    assert_eq!(c.data, src.data);
    ws.recycle_mat(c);
    let r = ws.mat_rows(3, &[9.0, 8.0]);
    assert_eq!(r.rows, 3);
    assert_eq!(r.cols, 2);
    assert_eq!(r.data, vec![9.0, 8.0, 9.0, 8.0, 9.0, 8.0]);
}
