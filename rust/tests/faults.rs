//! Chaos suite for the fault-injection harness (ISSUE 10 acceptance):
//!
//! * the facade's injected faults behave as specified — transients are
//!   retried to success, torn writes never touch the destination,
//!   flipped bytes replay bitwise under a fixed schedule
//! * a smoke sweep run under committed fault schedules produces
//!   `deterministic_json` output bitwise-identical to a fault-free run
//!   (faults cost retries and recomputation, never results)
//! * corrupt artifacts (flipped pack shards) are detected and fail loud
//!   with the offending path — never silently loaded
//! * the mmap degradation ladder (mmap → pread → resident) yields
//!   bitwise-identical features at every rung
//! * a panicking sweep cell becomes a failed-cell record while the rest
//!   of the grid completes
//!
//! The fault injector is process-global (armed via the
//! `RuntimeConfig::faults` session knob), so every test here serializes
//! on one mutex and disarms on drop — including on panic.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use anyhow::Result;
use crest::api::{Method, MethodRegistry, MethodSpec, SourceCtx};
use crest::coordinator::sources::BatchSource;
use crest::data::shard::{load_packed_splits, pack_splits};
use crest::data::{generate, StoreFallback, SynthSpec};
use crest::report::aggregate_markdown;
use crest::runtime_config::{set_session, RuntimeConfig};
use crest::sweep::{self, SweepGrid, SweepOutcome, SweepSpec};
use crest::util::artifact_io::{self, FaultKind, READ_STRICT, WRITE_STRICT};
use crest::util::faults::Site;
use crest::util::rng::Rng;

/// Serializes every test in this binary: the fault schedule is
/// process-global session state.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Lock held + session config installed; disarms everything on drop
/// (also when the owning test panics).
struct Armed(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Armed {
    fn with(rc: RuntimeConfig) -> Armed {
        let g = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_session(rc);
        Armed(g)
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        set_session(RuntimeConfig::default());
    }
}

/// Arm a fault schedule (counters reset: the previous drop cleared the
/// injector state, so an identical spec string replays from tick 0).
fn arm(spec: &str) -> Armed {
    Armed::with(RuntimeConfig { faults: Some(spec.to_string()), ..Default::default() })
}

/// Hold the lock with injection off (for fault-free baselines and tests
/// that must not race an armed sibling).
fn arm_none() -> Armed {
    Armed::with(RuntimeConfig::default())
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("crest-faults-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// --------------------------------------------------------- facade behavior

#[test]
fn transient_injection_retries_to_success_and_round_trips() {
    let _a = arm("seed=3,ckpt-write=1.0,ckpt-read=1.0");
    let d = tdir("transient");
    for i in 0..8usize {
        let p = d.join(format!("a{i}.bin"));
        let payload: Vec<u8> = (0..100 + i).map(|v| (v * 7 + i) as u8).collect();
        // probability 1.0 + WRITE_STRICT menu: every publish fails its
        // first attempt with an injected Interrupted and must retry
        artifact_io::publish_with(Site::CkptWrite, &p, &payload, WRITE_STRICT).unwrap();
        // READ_STRICT menu: every read is hit by a transient or a short
        // first chunk; either way the caller sees the full payload
        let back = artifact_io::read_with(Site::CkptRead, &p, READ_STRICT).unwrap();
        assert_eq!(back, payload, "attempt {i}");
    }
    let residue: Vec<_> = artifact_io::read_dir_sorted(&d)
        .unwrap()
        .into_iter()
        .filter(|p| p.to_string_lossy().ends_with(".tmp"))
        .collect();
    assert!(residue.is_empty(), "tmp residue after retried publishes: {residue:?}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn torn_write_fails_loud_and_never_touches_the_destination() {
    let _a = arm("seed=11,ckpt-write=1.0");
    let d = tdir("torn");
    let p = d.join("cell.json");
    let err = artifact_io::publish_with(Site::CkptWrite, &p, b"full payload", &[FaultKind::Torn])
        .unwrap_err();
    assert!(err.to_string().contains("torn"), "{err}");
    assert!(!p.exists(), "a torn publish must leave the destination untouched");
    // the same schedule keeps firing, but WRITE_STRICT only offers the
    // recoverable transient kind: the next publish lands cleanly over
    // the crash debris
    artifact_io::publish_with(Site::CkptWrite, &p, b"second try", WRITE_STRICT).unwrap();
    assert_eq!(std::fs::read(&p).unwrap(), b"second try");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn flip_injection_replays_bitwise_under_a_fixed_schedule() {
    let d = tdir("flip");
    let p = d.join("entry.bin");
    std::fs::write(&p, vec![0u8; 256]).unwrap();
    let run = || -> Vec<Vec<u8>> {
        (0..4)
            .map(|_| artifact_io::read_with(Site::EmbedRead, &p, &[FaultKind::FlipByte]).unwrap())
            .collect()
    };
    let first = {
        let _a = arm("seed=5,embed-read=1.0");
        run()
    };
    let second = {
        let _a = arm("seed=5,embed-read=1.0");
        run()
    };
    assert_eq!(first, second, "identical schedule must replay the same flips bitwise");
    for (i, b) in first.iter().enumerate() {
        assert_eq!(b.len(), 256);
        assert!(b.iter().any(|&x| x != 0), "read {i} was not flipped");
    }
    std::fs::remove_dir_all(&d).ok();
}

// ------------------------------------------------------- sweep under chaos

/// The acceptance grid: smoke × {crest, random} × seeds {1, 2} @ 10%.
fn smoke_spec(dir: Option<PathBuf>, jobs: usize) -> SweepSpec {
    let grid = SweepGrid {
        variants: vec!["smoke".to_string()],
        methods: vec![Method::crest(), Method::random()],
        seeds: vec![1, 2],
        budgets: vec![0.1],
    };
    let mut spec = SweepSpec::new(grid, 2);
    spec.checkpoint_dir = dir;
    spec.jobs = jobs;
    spec
}

/// Bitwise fingerprint of a sweep's deterministic content.
fn fingerprint(outcome: &SweepOutcome) -> Vec<String> {
    let mut out: Vec<String> = outcome
        .cells
        .iter()
        .map(|c| format!("{}\n{}", c.key.label(), c.report.deterministic_json().to_string_pretty()))
        .collect();
    out.push(aggregate_markdown(&outcome.rows));
    out.extend(outcome.rows.iter().map(|r| r.to_json().to_string_pretty()));
    out
}

#[test]
fn checkpoint_chaos_schedule_preserves_sweep_results_bitwise() {
    let baseline = {
        let _a = arm_none();
        sweep::run(&smoke_spec(None, 1)).unwrap()
    };
    let dir = tdir("ckpt-chaos");
    // torn/transient saves, flipped/short/transient loads — every kind
    // the checkpoint path can absorb, at aggressive rates
    let sched = "seed=7,ckpt-write=0.6,ckpt-read=0.6";
    let (fresh, resumed) = {
        let _a = arm(sched);
        let fresh = sweep::run_collect(&smoke_spec(Some(dir.clone()), 1)).unwrap();
        let resumed = sweep::run_collect(&smoke_spec(Some(dir.clone()), 1)).unwrap();
        (fresh, resumed)
    };
    assert!(fresh.failed.is_empty(), "{:?}", fresh.failed);
    assert!(resumed.failed.is_empty(), "{:?}", resumed.failed);
    assert_eq!(fresh.cells.len(), 4);
    assert_eq!(resumed.cells.len(), 4);
    assert_eq!(
        fingerprint(&fresh),
        fingerprint(&baseline),
        "fresh sweep under checkpoint chaos diverged"
    );
    assert_eq!(
        fingerprint(&resumed),
        fingerprint(&baseline),
        "resumed sweep under checkpoint chaos diverged"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn embed_cache_chaos_never_changes_reports() {
    let baseline = {
        let _a = arm_none();
        sweep::run(&smoke_spec(None, 1)).unwrap()
    };
    let cache = tdir("embed-chaos");
    let under = {
        let _a = Armed::with(RuntimeConfig {
            faults: Some("seed=13,embed-write=0.8,embed-read=0.8".to_string()),
            embed_cache: Some(cache.clone()),
            ..Default::default()
        });
        sweep::run(&smoke_spec(None, 1)).unwrap()
    };
    assert_eq!(
        fingerprint(&under),
        fingerprint(&baseline),
        "embed-cache chaos changed a deterministic report"
    );
    std::fs::remove_dir_all(&cache).ok();
}

// -------------------------------------------------- degradation + detection

#[test]
fn mmap_refusal_ladder_yields_identical_features() {
    let base = SynthSpec::preset("smoke", 21).unwrap();
    let spec = SynthSpec { n_train: 96, n_val: 24, n_test: 24, ..base };
    let mem = generate(&spec);
    let root = tdir("mmap-ladder");
    pack_splits(&mem, &root, 40).unwrap();

    let clean = {
        let _a = arm_none();
        load_packed_splits(&root).unwrap()
    };
    assert_eq!(clean.train.store_kind(), "mmap");
    // every map attempt refused -> pread rung
    let pread = {
        let _a = arm("seed=1,mmap-map=1.0");
        load_packed_splits(&root).unwrap()
    };
    // every map attempt refused + CREST_STORE_FALLBACK=mem -> resident rung
    let resident = {
        let _a = Armed::with(RuntimeConfig {
            faults: Some("seed=1,mmap-map=1.0".to_string()),
            store_fallback: Some(StoreFallback::Mem),
            ..Default::default()
        });
        load_packed_splits(&root).unwrap()
    };
    for (name, degraded) in [("pread", &pread), ("resident", &resident)] {
        for (split, a, b) in [
            ("train", &clean.train, &degraded.train),
            ("val", &clean.val, &degraded.val),
            ("test", &clean.test, &degraded.test),
        ] {
            assert_eq!(
                a.to_mat().data,
                b.to_mat().data,
                "{name} rung diverged from mmap on the {split} split"
            );
        }
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn flipped_pack_shard_is_detected_and_names_the_path() {
    let _a = arm_none();
    let base = SynthSpec::preset("smoke", 22).unwrap();
    let spec = SynthSpec { n_train: 64, n_val: 16, n_test: 16, ..base };
    let mem = generate(&spec);
    let root = tdir("pack-flip");
    pack_splits(&mem, &root, 32).unwrap();

    let shard = root.join("train").join("shard_00000.bin");
    let mut bytes = std::fs::read(&shard).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x04; // one flipped bit in the f32 payload
    std::fs::write(&shard, &bytes).unwrap();

    let err = load_packed_splits(&root).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("CRC-32 mismatch"), "flip must be caught by CRC, got: {text}");
    assert!(text.contains("shard_00000.bin"), "error must name the shard, got: {text}");
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------- panicking cell

fn make_panic<'a>(_ctx: SourceCtx<'a>, _rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    panic!("injected panic in batch-source factory")
}

#[test]
fn panicking_cell_is_recorded_while_the_grid_completes() {
    let _a = arm_none();
    let method = MethodRegistry::register(MethodSpec {
        name: "panic-cell".to_string(),
        aliases: vec![],
        help: "test method: panics at construction".to_string(),
        reference: false,
        full_horizon_schedule: false,
        coreset_lr_scale: false,
        factory: Box::new(make_panic),
    })
    .unwrap();
    let grid = SweepGrid {
        variants: vec!["smoke".to_string()],
        methods: vec![method, Method::crest()],
        seeds: vec![1],
        budgets: vec![0.1],
    };
    let mut spec = SweepSpec::new(grid, 2);
    spec.jobs = 1;

    let outcome = sweep::run_collect(&spec).unwrap();
    assert_eq!(outcome.failed.len(), 1, "exactly the panicking cell fails");
    assert!(outcome.failed[0].key.label().contains("panic-cell"));
    assert!(
        outcome.failed[0].error.contains("panicked") && outcome.failed[0].error.contains("factory"),
        "failure record must carry the panic text: {}",
        outcome.failed[0].error
    );
    assert_eq!(outcome.cells.len(), 1, "the sibling cell still completes");
    assert!(outcome.cells[0].executed);
    assert_eq!(outcome.cells[0].key.label(), "smoke/crest/seed=1/budget=0.1");

    // the strict entry point surfaces the same failure as an error
    let err = sweep::run(&spec).unwrap_err();
    assert!(format!("{err:#}").contains("sweep cell(s) failed"), "{err:#}");
}
