//! Integration tests over the native CPU backend.
//!
//! These exercise the full execution path — builtin manifest → native
//! backend → typed runtime wrappers → coordinator — and check numerics
//! against host-side recomputation. No artifacts, Python, or XLA are
//! required; the suite runs end-to-end on every `cargo test`. (The same
//! wrappers drive the optional `pjrt` backend, so these tests double as the
//! contract for that path.)

use crest::api::{Method, MethodRegistry};
use crest::config::ExperimentConfig;
use crest::coordinator::run_experiment;
use crest::coreset::facility;
use crest::data::{generate, SynthSpec};
use crest::model::init_params;
use crest::runtime::Runtime;
use crest::train::{evaluate, TrainState};
use crest::util::rng::Rng;
use crest::util::stats;

const VARIANT: &str = "cifar10-proxy";
/// Tiny variant for whole-experiment cells (fast even in debug builds).
const SMOKE: &str = "smoke";

fn load() -> (Runtime, crest::data::Splits) {
    let rt = Runtime::native_variant(VARIANT).expect("builtin variant");
    let splits = generate(&SynthSpec::preset(VARIANT, 7).unwrap());
    (rt, splits)
}

fn load_smoke() -> (Runtime, crest::data::Splits) {
    let rt = Runtime::native_variant(SMOKE).expect("builtin smoke variant");
    let splits = generate(&SynthSpec::preset(SMOKE, 7).unwrap());
    (rt, splits)
}

#[test]
fn runtime_loads_and_describes_natively() {
    let (rt, _) = load();
    assert_eq!(rt.backend_name(), "native");
    let desc = rt.describe();
    for name in ["train_step", "grad_embed", "eval_chunk", "hess_probe", "select_greedy"] {
        assert!(desc.contains(name), "missing {name} in {desc}");
    }
}

#[test]
fn train_step_decreases_loss_on_fixed_batch() {
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(1);
    let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx: Vec<usize> = (0..rt.man.m).collect();
    let gamma = vec![1.0; rt.man.m];
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..40 {
        let (loss, per_ex) = state.step_batch(&rt, ds, &idx, &gamma, 0.05, 0.0).unwrap();
        assert_eq!(per_ex.len(), rt.man.m);
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(last < 0.5 * first.unwrap(), "{last} vs {first:?}");
}

#[test]
fn zero_gamma_freezes_parameters() {
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(2);
    let init = init_params(&rt.man, &mut rng);
    let mut state = TrainState::new(&rt, &init).unwrap();
    let idx: Vec<usize> = (0..rt.man.m).collect();
    state.step_batch(&rt, ds, &idx, &vec![0.0; rt.man.m], 0.5, 0.0).unwrap();
    let after = state.params_host(&rt).unwrap();
    let drift = stats::norm2(&stats::sub(&after, &init));
    assert!(drift < 1e-5, "drift {drift}");
}

#[test]
fn batch_gradient_matches_finite_difference_of_step() {
    // mom=0, lr=eps step must move params by exactly -eps * grad
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(3);
    let init = init_params(&rt.man, &mut rng);
    let params = rt.params_from_host(&init).unwrap();
    let idx: Vec<usize> = (0..rt.man.m).collect();
    let gamma = vec![1.0; rt.man.m];
    let grad = {
        let (x, y) = ds.batch(&idx);
        rt.batch_gradient(&params, &x, &y, &gamma).unwrap()
    };
    let eps = 0.01f32;
    let (x, y) = ds.batch(&idx);
    let zero = rt.zero_momentum();
    let out = rt.train_step(&params, &zero, &x, &y, &gamma, eps, 0.0).unwrap();
    let stepped = rt.params_to_host(&out.params).unwrap();
    for i in (0..init.len()).step_by(997) {
        let want = init[i] - eps * grad[i];
        assert!((stepped[i] - want).abs() < 1e-5, "param {i}: {} vs {want}", stepped[i]);
    }
}

#[test]
fn grad_embed_losses_match_eval_losses() {
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(4);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx: Vec<usize> = (0..rt.man.r).collect();
    let (x, y) = ds.batch(&idx);
    let (_, _, losses) = rt.grad_embed(&state.params, &x, &y).unwrap();
    // same losses via the eval path
    let sub = ds.subset(&idx);
    let ev = evaluate(&rt, &state.params, &sub).unwrap();
    for i in (0..idx.len()).step_by(37) {
        assert!(
            (losses[i] - ev.per_ex_loss[i]).abs() < 1e-4,
            "loss {i}: {} vs {}",
            losses[i],
            ev.per_ex_loss[i]
        );
    }
}

#[test]
fn grad_embed_rows_sum_to_zero() {
    // softmax gradient rows (p - y) each sum to ~0
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(5);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx: Vec<usize> = (0..rt.man.r).collect();
    let (x, y) = ds.batch(&idx);
    let (gl, _, _) = rt.grad_embed(&state.params, &x, &y).unwrap();
    for i in 0..gl.rows {
        let s: f32 = gl.row(i).iter().sum();
        assert!(s.abs() < 1e-4, "row {i} sums to {s}");
    }
}

#[test]
fn hess_probe_zero_z_matches_batch_gradient_direction() {
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(6);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx: Vec<usize> = (0..rt.man.r).collect();
    let (x, y) = ds.batch(&idx);
    let z = vec![0.0f32; rt.man.p_dim];
    let probe = rt.hess_probe(&state.params, &x, &y, &z).unwrap();
    assert!(stats::norm2(&probe.hz) < 1e-6, "Hz must vanish for z=0");
    assert!(probe.mean_loss > 0.0);
    // probe.grad is the mean grad of these r examples; it must agree with
    // the average of the m-chunked batch gradients
    let mut acc = vec![0.0f64; rt.man.p_dim];
    let chunks: Vec<&[usize]> = idx.chunks(rt.man.m).collect();
    for c in &chunks {
        let (cx, cy) = ds.batch(c);
        let g = rt.batch_gradient(&state.params, &cx, &cy, &vec![1.0; rt.man.m]).unwrap();
        for (a, &v) in acc.iter_mut().zip(&g) {
            *a += v as f64 / chunks.len() as f64;
        }
    }
    let avg: Vec<f32> = acc.iter().map(|&v| v as f32).collect();
    let err = stats::norm2(&stats::sub(&avg, &probe.grad));
    let scale = stats::norm2(&probe.grad).max(1e-9);
    assert!(err / scale < 1e-3, "relative err {}", err / scale);
}

#[test]
fn hutchinson_probe_diag_estimate_is_unbiased_in_sign_flip() {
    // z and -z give identical z .* Hz (the estimator is even)
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(7);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx: Vec<usize> = (0..rt.man.r).collect();
    let (x, y) = ds.batch(&idx);
    let mut z = vec![0.0f32; rt.man.p_dim];
    rng.rademacher_fill(&mut z);
    let p1 = rt.hess_probe(&state.params, &x, &y, &z).unwrap();
    let neg: Vec<f32> = z.iter().map(|&v| -v).collect();
    let p2 = rt.hess_probe(&state.params, &x, &y, &neg).unwrap();
    for i in (0..z.len()).step_by(1009) {
        let d1 = z[i] * p1.hz[i];
        let d2 = neg[i] * p2.hz[i];
        assert!((d1 - d2).abs() < 1e-4, "diag est {i}: {d1} vs {d2}");
    }
}

#[test]
fn backend_greedy_matches_host_greedy_cost() {
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(8);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let idx = rng.sample_indices(ds.n(), rt.man.r);
    let (x, y) = ds.batch(&idx);
    let (gl, al, _) = rt.grad_embed(&state.params, &x, &y).unwrap();
    let (cidx, cw) = rt.select_greedy(&gl, &al).unwrap();
    let host = facility::facility_location_prod(&al, &gl, rt.man.m);
    // weights partition the subset in both
    assert_eq!(cw.iter().sum::<f32>(), rt.man.r as f32);
    assert_eq!(host.gamma.iter().sum::<f32>(), rt.man.r as f32);
    // objective values agree tightly (tie-breaking may differ)
    let metric = facility::ProdMetric::new(&al, &gl);
    let cost = |sel: &[usize]| -> f64 {
        use crest::coreset::facility::SqDistMetric;
        (0..rt.man.r)
            .map(|i| sel.iter().map(|&j| metric.sqdist(j, i)).fold(f32::INFINITY, f32::min) as f64)
            .sum()
    };
    let backend_cost = cost(&cidx);
    let host_cost = cost(&host.idx);
    assert!(
        backend_cost <= host_cost * 1.05 + 1e-6 && host_cost <= backend_cost * 1.05 + 1e-6,
        "backend {backend_cost} vs host {host_cost}"
    );
}

#[test]
fn evaluate_handles_non_chunk_multiple_sizes() {
    let (rt, splits) = load();
    // test set 1024 = 2 chunks exactly; use an odd-sized subset to cover padding
    let idx: Vec<usize> = (0..700).collect();
    let sub = splits.test.subset(&idx);
    let mut rng = Rng::new(9);
    let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
    let ev = evaluate(&rt, &state.params, &sub).unwrap();
    assert_eq!(ev.per_ex_loss.len(), 700);
    assert_eq!(ev.per_ex_correct.len(), 700);
    assert!((0.0..=1.0).contains(&ev.accuracy));
    // untrained accuracy should be near chance
    assert!(ev.accuracy < 0.35, "untrained acc {}", ev.accuracy);
}

#[test]
fn every_method_completes_a_tiny_run() {
    // every *registered* method, so new registry entries (e.g. the
    // loss-topk baseline) are covered automatically
    let (rt, splits) = load_smoke();
    for method in MethodRegistry::all() {
        let mut cfg = ExperimentConfig::preset(SMOKE, method, 11).unwrap();
        cfg.epochs_full = 2; // tiny budget: full = 128 steps, others 12
        cfg.eval_points = 2;
        let rep = run_experiment(&rt, &splits, cfg).unwrap();
        assert!(rep.steps > 0, "{method:?} ran no steps");
        assert!(rep.final_test_acc > 0.05, "{method:?} below chance: {}", rep.final_test_acc);
        assert!(rep.backprops > 0);
        if method == Method::crest() {
            assert!(rep.n_selection_updates > 0);
        }
    }
}

#[test]
fn crest_and_baseline_full_cells_on_paper_proxy() {
    // the acceptance cell: CREST (Algorithm 1) plus the Random baseline run
    // end-to-end on the cifar10 proxy with the native backend
    let (rt, splits) = load();
    for method in [Method::crest(), Method::random()] {
        let mut cfg = ExperimentConfig::preset(VARIANT, method, 21).unwrap();
        cfg.epochs_full = 2;
        cfg.eval_points = 1;
        let rep = run_experiment(&rt, &splits, cfg).unwrap();
        assert!(rep.steps > 0, "{method:?} ran no steps");
        assert!(
            rep.final_test_acc > 0.08,
            "{method:?} below chance on 10 classes: {}",
            rep.final_test_acc
        );
        if method == Method::crest() {
            assert!(rep.n_selection_updates > 0, "CREST never selected");
            assert!(!rep.rho_history.is_empty(), "CREST never ran a rho-check");
        }
    }
}

#[test]
fn crest_report_is_internally_consistent() {
    let (rt, splits) = load_smoke();
    let mut cfg = ExperimentConfig::preset(SMOKE, Method::crest(), 12).unwrap();
    cfg.epochs_full = 5;
    let rep = run_experiment(&rt, &splits, cfg).unwrap();
    assert_eq!(rep.update_steps.len(), rep.n_selection_updates);
    assert!(rep.update_steps.windows(2).all(|w| w[0] < w[1]), "updates sorted");
    assert!(rep.rho_history.iter().all(|&(_, rho)| rho >= 0.0));
    assert_eq!(rep.selection_counts.len(), splits.train.n());
    let total_selected: u64 = rep.selection_counts.iter().map(|&c| c as u64).sum();
    assert_eq!(total_selected, rep.steps as u64 * rt.man.m as u64);
    // serializes
    let j = rep.to_json().to_string_pretty();
    assert!(crest::util::json::Json::parse(&j).is_ok());
}

#[test]
fn deterministic_given_seed() {
    let (rt, splits) = load_smoke();
    let mk = || {
        let mut cfg = ExperimentConfig::preset(SMOKE, Method::crest(), 13).unwrap();
        cfg.epochs_full = 3;
        run_experiment(&rt, &splits, cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.final_test_acc, b.final_test_acc);
    assert_eq!(a.n_selection_updates, b.n_selection_updates);
    assert_eq!(a.update_steps, b.update_steps);
}

#[test]
fn backend_results_bitwise_identical_across_thread_counts() {
    // the parallel execution layer's contract: fixed chunk boundaries make
    // train_step / grad_embed / facility selection reproduce exactly at any
    // worker count (paper-scale shapes, so the parallel paths engage)
    use crest::util::pool;
    let (rt, splits) = load();
    let ds = &splits.train;
    let mut rng = Rng::new(31);
    let params = init_params(&rt.man, &mut rng);
    let mom = rt.zero_momentum();
    let midx: Vec<usize> = (0..rt.man.m).collect();
    let (mx, my) = ds.batch(&midx);
    let gamma = vec![1.0f32; rt.man.m];
    let ridx: Vec<usize> = (0..rt.man.r).collect();
    let (rx, ry) = ds.batch(&ridx);
    let run = |t: usize| {
        pool::with_threads(t, || {
            let s = rt.train_step(&params, &mom, &mx, &my, &gamma, 0.05, 5e-4).unwrap();
            let (g, a, l) = rt.grad_embed(&params, &rx, &ry).unwrap();
            let sel = facility::facility_location_prod(&a, &g, rt.man.m);
            (s.params, s.momentum, g, a, l, sel.idx, sel.gamma)
        })
    };
    let base = run(1);
    for t in [2, 4] {
        assert_eq!(base, run(t), "thread count {t} changed runtime results");
    }
}

#[test]
fn crest_selection_threads_do_not_change_results() {
    // regression for the coordinator's multi-threaded selection path: the
    // per-subset pool fan-out (selection_threads > 1) must reproduce the
    // serial path exactly
    let (rt, splits) = load_smoke();
    let run = |threads: usize| {
        let mut cfg = ExperimentConfig::preset(SMOKE, Method::crest(), 5).unwrap();
        cfg.epochs_full = 3;
        cfg.selection_threads = threads;
        run_experiment(&rt, &splits, cfg).unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.final_test_acc, b.final_test_acc);
    assert_eq!(a.final_test_loss, b.final_test_loss);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.n_selection_updates, b.n_selection_updates);
    assert_eq!(a.update_steps, b.update_steps);
}
