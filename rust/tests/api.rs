//! Public-API integration tests for the registry + builder + observer
//! redesign (ISSUE 4 acceptance):
//!
//! * builtin methods produce bitwise-identical `deterministic_json`
//!   output through the new `Experiment` builder and the low-level
//!   `run_experiment` entry point (the surviving pre-redesign
//!   signature). Both paths share the rewritten coordinator, so this
//!   pins builder-vs-coordinator equivalence and run-to-run
//!   determinism; equivalence with *pre-redesign* numbers is covered by
//!   the untouched `deterministic_json` schema plus the sweep
//!   checkpoint round-trip tests, which restore reports written by any
//!   earlier build of the store format
//! * attaching observers never changes results, and the event stream is
//!   consistent with the final report; `Signal::Stop` ends a run early
//! * a new selection method is added via `MethodRegistry::register`
//!   alone, and is immediately usable in the builder, method parsing,
//!   and sweep grids — zero dispatch-site edits
//! * the registry-registered `loss-topk` baseline trains, sweeps, and
//!   round-trips through sweep checkpoints like any builtin

use std::cell::Cell;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crest::api::{
    EvalEvent, Experiment, Method, MethodRegistry, MethodSpec, RunObserver, SelectionEvent,
    Signal, SourceCtx, StepEvent,
};
use crest::config::ExperimentConfig;
use crest::coordinator::run_experiment;
use crest::coordinator::sources::{BatchSource, SourceStats, SourcedBatch};
use crest::data::{generate, Splits, SynthSpec};
use crest::report::RunReport;
use crest::runtime::Runtime;
use crest::sweep::{self, CellKey, SweepGrid, SweepSpec};
use crest::train::TrainState;
use crest::util::json::Json;
use crest::util::rng::Rng;
use crest::util::timer::PhaseTimers;

const SMOKE: &str = "smoke";

fn load_smoke(seed: u64) -> (Runtime, Arc<Splits>) {
    let rt = Runtime::native_variant(SMOKE).expect("builtin smoke variant");
    let splits = Arc::new(generate(&SynthSpec::preset(SMOKE, seed).unwrap()));
    (rt, splits)
}

#[test]
fn builder_path_matches_low_level_path_bitwise_for_every_method() {
    // the redesign must preserve deterministic output: for every
    // registered method, the new builder path reproduces the pre-redesign
    // coordinator entry point bit for bit
    let (rt, splits) = load_smoke(7);
    for method in MethodRegistry::all() {
        let mut cfg = ExperimentConfig::preset(SMOKE, method, 7).unwrap();
        cfg.epochs_full = 2;
        cfg.eval_points = 2;
        let low = run_experiment(&rt, &splits, cfg).unwrap();
        let built = Experiment::builder()
            .variant(SMOKE)
            .with_method(method)
            .seed(7)
            .budget_frac(0.1)
            .epochs_full(2)
            .configure(|cfg| cfg.eval_points = 2)
            .splits(splits.clone())
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(
            built.deterministic_json().to_string_pretty(),
            low.deterministic_json().to_string_pretty(),
            "builder and low-level paths diverged for {}",
            method.name()
        );
    }
}

#[derive(Clone, Default)]
struct Counts {
    steps: Rc<Cell<usize>>,
    evals: Rc<Cell<usize>>,
    selections: Rc<Cell<usize>>,
    finished: Rc<Cell<bool>>,
}

struct CountingObserver {
    counts: Counts,
}

impl RunObserver for CountingObserver {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Signal {
        assert!(!ev.idx.is_empty(), "step events carry the batch indices");
        self.counts.steps.set(self.counts.steps.get() + 1);
        Signal::Continue
    }

    fn on_eval(&mut self, ev: &EvalEvent<'_>) -> Signal {
        assert!(ev.test_acc.is_finite());
        self.counts.evals.set(self.counts.evals.get() + 1);
        Signal::Continue
    }

    fn on_selection(&mut self, ev: &SelectionEvent<'_>) {
        assert!(!ev.selected.is_empty());
        self.counts.selections.set(self.counts.selections.get() + 1);
    }

    fn on_run_end(&mut self, report: &RunReport) {
        assert!(report.steps > 0);
        self.counts.finished.set(true);
    }
}

#[test]
fn observers_see_a_consistent_stream_and_never_change_results() {
    let (_, splits) = load_smoke(11);
    let run = |observed: bool, counts: &Counts| -> RunReport {
        let mut b = Experiment::builder()
            .variant(SMOKE)
            .method("crest")
            .seed(11)
            .budget_frac(0.1)
            .epochs_full(2)
            .splits(splits.clone());
        if observed {
            b = b.observe(Box::new(CountingObserver { counts: counts.clone() }));
        }
        b.build().unwrap().run().unwrap()
    };
    let counts = Counts::default();
    let plain = run(false, &counts);
    let watched = run(true, &counts);
    // attaching observers changes nothing
    assert_eq!(
        watched.deterministic_json().to_string_pretty(),
        plain.deterministic_json().to_string_pretty()
    );
    // and the stream the observer saw is consistent with the report
    assert_eq!(counts.steps.get(), watched.steps);
    assert_eq!(counts.evals.get(), watched.history.len());
    assert_eq!(counts.selections.get(), watched.n_selection_updates);
    assert!(counts.finished.get(), "on_run_end fired");
}

struct StopAfterFirstEval;

impl RunObserver for StopAfterFirstEval {
    fn on_eval(&mut self, _ev: &EvalEvent<'_>) -> Signal {
        Signal::Stop
    }
}

#[test]
fn early_stopping_observer_ends_the_run_after_the_final_eval() {
    let (_, splits) = load_smoke(13);
    let build = |stop: bool| {
        let mut b = Experiment::builder()
            .variant(SMOKE)
            .method("random")
            .seed(13)
            .budget_frac(0.1)
            .epochs_full(2)
            .splits(splits.clone());
        if stop {
            b = b.observe(Box::new(StopAfterFirstEval));
        }
        b.build().unwrap().run().unwrap()
    };
    let full_run = build(false);
    let stopped = build(true);
    assert!(stopped.steps >= 1, "the stopping step still completes");
    assert!(
        stopped.steps < full_run.steps,
        "stop must end the run early: {} vs {}",
        stopped.steps,
        full_run.steps
    );
    // the final evaluation is always recorded
    assert!(stopped.final_test_acc.is_finite());
}

// A custom method defined entirely outside the crate's dispatch sites:
// the fixed-first-batch source below touches only public API.
struct ConstSource {
    m: usize,
}

impl BatchSource for ConstSource {
    fn next_batch(
        &mut self,
        _step: usize,
        _state: &mut TrainState,
        _timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        Ok(SourcedBatch {
            idx: (0..self.m).collect(),
            gamma: vec![1.0; self.m],
            selection: None,
        })
    }

    fn stats(&self) -> SourceStats {
        SourceStats::default()
    }
}

fn make_const<'a>(ctx: SourceCtx<'a>, _rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(Box::new(ConstSource { m: ctx.rt.man.m }))
}

#[test]
fn a_method_registered_by_a_downstream_crate_runs_everywhere() {
    let method = MethodRegistry::register(MethodSpec {
        name: "const-batch".to_string(),
        aliases: vec!["cb".to_string()],
        help: "test method: always trains on the first m examples".to_string(),
        reference: false,
        full_horizon_schedule: false,
        coreset_lr_scale: false,
        factory: Box::new(make_const),
    })
    .unwrap();

    // visible to parsing, help, and sweep-grid expansion immediately
    assert_eq!(Method::parse("const-batch").unwrap(), method);
    assert_eq!(Method::parse("cb").unwrap(), method);
    assert!(MethodRegistry::help_names().split('|').any(|n| n == "const-batch"));
    let methods = sweep::grid::parse_methods("const-batch,crest").unwrap();
    assert_eq!(methods[0], method);

    // checkpoint keys round-trip through the registry
    let key = CellKey {
        variant: SMOKE.to_string(),
        method,
        seed: 3,
        budget_frac: 0.1,
    };
    let parsed = CellKey::from_json(&Json::parse(&key.to_json().to_string_pretty()).unwrap());
    assert_eq!(parsed.unwrap(), key);

    // and it trains end-to-end through the builder
    let (_, splits) = load_smoke(3);
    let report = Experiment::builder()
        .variant(SMOKE)
        .method("const-batch")
        .seed(3)
        .budget_frac(0.1)
        .epochs_full(2)
        .splits(splits)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.method, "const-batch");
    assert!(report.steps > 0);
}

#[test]
fn loss_topk_baseline_trains_and_sweeps_like_a_builtin() {
    // advertised in help, parses by name and alias
    assert!(MethodRegistry::help_names().split('|').any(|n| n == "loss-topk"));
    assert_eq!(Method::parse("topk").unwrap(), Method::loss_topk());

    // trains on the smoke variant and actually reselects per epoch
    let (_, splits) = load_smoke(5);
    let report = Experiment::builder()
        .variant(SMOKE)
        .method("loss-topk")
        .seed(5)
        .budget_frac(0.1)
        .epochs_full(2)
        .splits(splits)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.method, "loss-topk");
    assert!(report.steps > 0);
    assert!(report.n_selection_updates >= 1, "loss-topk never reselected");

    // sweeps (and checkpoint-resumes) next to a builtin
    let dir = std::env::temp_dir().join(format!("crest-api-topk-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = |ckpt: Option<PathBuf>| {
        let mut s = SweepSpec::new(
            SweepGrid {
                variants: vec![SMOKE.to_string()],
                methods: vec![Method::loss_topk(), Method::crest()],
                seeds: vec![1],
                budgets: vec![0.1],
            },
            2,
        );
        s.checkpoint_dir = ckpt;
        s.jobs = 1;
        s
    };
    let fresh = sweep::run(&spec(Some(dir.clone()))).unwrap();
    assert_eq!(fresh.n_executed(), 2);
    assert!(fresh.rows.iter().any(|r| r.method == "loss-topk"));
    let restored = sweep::run(&spec(Some(dir.clone()))).unwrap();
    assert_eq!(restored.n_executed(), 0, "checkpoints restore loss-topk cells");
    for (a, b) in fresh.cells.iter().zip(&restored.cells) {
        assert_eq!(
            a.report.deterministic_json().to_string_pretty(),
            b.report.deterministic_json().to_string_pretty(),
            "restored cell diverged: {}",
            a.key.label()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
