//! SIMD-vs-scalar differential suite for the kernel ISA dispatch layer.
//!
//! Every vectorized kernel must be **bitwise** identical to the scalar
//! tile path at every shape and thread count: AVX2 lanes map across
//! independent output elements (never within one dot product's
//! accumulation), so the per-element accumulation order — and therefore
//! the bits — are unchanged. The tests pin each member of
//! [`kernel::available_isas`] through the `_isa` kernel variants; on a
//! machine without AVX2 the list collapses to `[Scalar]` and the suite
//! degenerates to self-comparison (still checking the dispatch plumbing).

use crest::kernel::{self, KernelIsa};
use crest::prop::{forall, usize_in, vec_f32};
use crest::runtime_config::RuntimeConfig;
use crest::tensor::MatF32;
use crest::util::pool;
use crest::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, vec_f32(rng, rows * cols, scale)).unwrap()
}

/// Random matrix with roughly half its entries zeroed (post-ReLU pattern —
/// exercises the masked kernel's keep logic and wgrad's zero-skip).
fn relu_mat(rng: &mut Rng, rows: usize, cols: usize) -> MatF32 {
    let mut m = rand_mat(rng, rows, cols, 3.0);
    for v in m.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    m
}

fn bits_eq(a: &[f32], b: &[f32]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!(
                "element {k}: {x} ({:#x}) != {y} ({:#x})",
                x.to_bits(),
                y.to_bits()
            ));
        }
    }
    Ok(())
}

/// The non-scalar ISAs this CPU can run (empty off-AVX2 x86, or on other
/// arches — each test then reduces to checking the scalar path against
/// itself, which still exercises the `_isa` plumbing).
fn simd_isas() -> Vec<KernelIsa> {
    kernel::available_isas().into_iter().filter(|&i| i != KernelIsa::Scalar).collect()
}

// --------------------------------------------------------------- matmuls

#[test]
fn prop_simd_matmuls_match_scalar_bitwise() {
    forall(
        "simd-matmul-bitwise",
        0xA5D2,
        80,
        |rng| {
            // odd shapes around the MR=4/NR=16 tile and the 8-lane ymm
            // width, so every remainder path (0–7 columns, 1–3 rows) runs
            let rows = usize_in(rng, 1, 41);
            let d_in = usize_in(rng, 1, 37);
            let d_out = usize_in(rng, 1, 43);
            let x = rand_mat(rng, rows, d_in, 2.0);
            let w = vec_f32(rng, d_in * d_out, 2.0);
            let out = rand_mat(rng, rows, d_out, 1.0);
            let d = rand_mat(rng, rows, d_out, 2.0);
            let nt_out = rand_mat(rng, rows, d_in, 1.0);
            let act = relu_mat(rng, rows, d_in);
            (x, w, out, d, nt_out, act)
        },
        |(x, w, out, d, nt_out, act)| {
            let d_out = out.cols;
            for isa in simd_isas() {
                let mut s = out.clone();
                let mut v = out.clone();
                kernel::add_matmul_isa(KernelIsa::Scalar, &mut s, x, w, d_out);
                kernel::add_matmul_isa(isa, &mut v, x, w, d_out);
                bits_eq(&s.data, &v.data).map_err(|e| format!("add_matmul {isa}: {e}"))?;

                let mut s = nt_out.clone();
                let mut v = nt_out.clone();
                kernel::add_matmul_nt_isa(KernelIsa::Scalar, &mut s, d, w, d_out);
                kernel::add_matmul_nt_isa(isa, &mut v, d, w, d_out);
                bits_eq(&s.data, &v.data).map_err(|e| format!("add_matmul_nt {isa}: {e}"))?;

                let mut s = nt_out.clone();
                let mut v = nt_out.clone();
                kernel::add_matmul_nt_masked_isa(KernelIsa::Scalar, &mut s, d, w, d_out, act);
                kernel::add_matmul_nt_masked_isa(isa, &mut v, d, w, d_out, act);
                bits_eq(&s.data, &v.data).map_err(|e| format!("nt_masked {isa}: {e}"))?;

                let mut s = vec![0.5f32; x.cols * d_out];
                let mut v = s.clone();
                kernel::accum_wgrad_isa(KernelIsa::Scalar, &mut s, x, d, d_out);
                kernel::accum_wgrad_isa(isa, &mut v, x, d, d_out);
                bits_eq(&s, &v).map_err(|e| format!("accum_wgrad {isa}: {e}"))?;
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------- dot/distance panels

#[test]
fn prop_simd_dot_and_distance_panels_match_scalar_bitwise() {
    forall(
        "simd-dot-bitwise",
        0xA5D3,
        80,
        |rng| {
            let n = usize_in(rng, 1, 70);
            let c = usize_in(rng, 1, 21);
            let h = usize_in(rng, 1, 19);
            let g = rand_mat(rng, n, c, 3.0);
            let a = rand_mat(rng, n, h, 3.0);
            let j = usize_in(rng, 0, n);
            let lo = usize_in(rng, 0, n);
            let hi = usize_in(rng, lo, n + 1);
            (g, a, j, lo, hi)
        },
        |(g, a, j, lo, hi)| {
            let n = g.rows;
            let gsq: Vec<f32> = (0..n).map(|i| kernel::dot4(g.row(i), g.row(i))).collect();
            let asq: Vec<f32> = (0..n)
                .map(|i| kernel::dot4(a.row(i), a.row(i)) * kernel::dot4(g.row(i), g.row(i)))
                .collect();
            for isa in simd_isas() {
                let s = kernel::dot4_isa(KernelIsa::Scalar, g.row(*j), a.row(*j));
                let v = kernel::dot4_isa(isa, g.row(*j), a.row(*j));
                bits_eq(&[s], &[v]).map_err(|e| format!("dot4 {isa}: {e}"))?;

                for range in [0..n, *lo..*hi] {
                    let mut s = vec![0.0f32; range.len()];
                    let mut v = vec![0.0f32; range.len()];
                    kernel::dot4_rows_isa(KernelIsa::Scalar, g.row(*j), g, range.clone(), &mut s);
                    kernel::dot4_rows_isa(isa, g.row(*j), g, range.clone(), &mut v);
                    bits_eq(&s, &v).map_err(|e| format!("dot4_rows {isa}: {e}"))?;

                    kernel::euclid_block_isa(KernelIsa::Scalar, g, &gsq, *j, range.clone(), &mut s);
                    kernel::euclid_block_isa(isa, g, &gsq, *j, range.clone(), &mut v);
                    bits_eq(&s, &v).map_err(|e| format!("euclid_block {isa}: {e}"))?;

                    kernel::prod_block_isa(KernelIsa::Scalar, a, g, &asq, *j, range.clone(), &mut s);
                    kernel::prod_block_isa(isa, a, g, &asq, *j, range, &mut v);
                    bits_eq(&s, &v).map_err(|e| format!("prod_block {isa}: {e}"))?;
                }
            }
            Ok(())
        },
    );
}

// ----------------------------------------------- empty/singleton and tails

#[test]
fn simd_empty_and_singleton_inputs() {
    for isa in kernel::available_isas() {
        // empty: zero rows, zero cols, zero d_out — all no-ops
        let mut out = MatF32::zeros(0, 5);
        kernel::add_matmul_isa(isa, &mut out, &MatF32::zeros(0, 3), &[0.0; 15], 5);
        let mut out = MatF32::zeros(4, 0);
        kernel::add_matmul_isa(isa, &mut out, &MatF32::zeros(4, 3), &[], 0);
        let mut gw: Vec<f32> = vec![];
        kernel::accum_wgrad_isa(isa, &mut gw, &MatF32::zeros(0, 0), &MatF32::zeros(0, 0), 0);
        assert_eq!(kernel::dot4_isa(isa, &[], &[]).to_bits(), 0.0f32.to_bits(), "{isa}");
        kernel::dot4_rows_isa(isa, &[], &MatF32::zeros(0, 0), 0..0, &mut []);

        // singleton: 1×1 everywhere — the smallest remainder tile
        let x = MatF32::from_vec(1, 1, vec![3.0]).unwrap();
        let mut o = MatF32::from_vec(1, 1, vec![1.0]).unwrap();
        kernel::add_matmul_isa(isa, &mut o, &x, &[2.0], 1);
        assert_eq!(o.data[0].to_bits(), 7.0f32.to_bits(), "{isa}: 1 + 3*2");
        let v = kernel::dot4_isa(isa, &[3.0], &[2.0]);
        assert_eq!(v.to_bits(), 6.0f32.to_bits(), "{isa}");
        let mut d1 = [9.0f32];
        kernel::euclid_block_isa(isa, &x, &[9.0], 0, 0..1, &mut d1);
        assert_eq!(d1[0].to_bits(), 0.0f32.to_bits(), "{isa}: self-distance");
    }
}

// ------------------------------------------------------------ thread sweep

#[test]
fn simd_matmuls_identical_across_thread_counts() {
    // sized above the parallel gate with ragged remainder tiles, so the
    // pool actually splits rows and each worker enters the SIMD panels
    let mut rng = Rng::new(21);
    let (rows, d_in, d_out) = (67, 129, 161);
    let x = relu_mat(&mut rng, rows, d_in);
    let w = vec_f32(&mut rng, d_in * d_out, 1.0);
    let d = rand_mat(&mut rng, rows, d_out, 1.0);
    let act = relu_mat(&mut rng, rows, d_in);
    for isa in kernel::available_isas() {
        let run = |t: usize| {
            pool::with_threads(t, || {
                let mut mm = MatF32::zeros(rows, d_out);
                kernel::add_matmul_isa(isa, &mut mm, &x, &w, d_out);
                let mut nt = MatF32::zeros(rows, d_in);
                kernel::add_matmul_nt_masked_isa(isa, &mut nt, &d, &w, d_out, &act);
                let mut gw = vec![0.0f32; d_in * d_out];
                kernel::accum_wgrad_isa(isa, &mut gw, &x, &d, d_out);
                (mm.data, nt.data, gw)
            })
        };
        let base = run(1);
        for t in [2, 4, 8] {
            assert_eq!(base, run(t), "{isa}: thread count {t} changed a kernel result");
        }
    }
    // and across ISAs at the same thread count
    let outs: Vec<_> = kernel::available_isas()
        .into_iter()
        .map(|isa| {
            pool::with_threads(4, || {
                let mut mm = MatF32::zeros(rows, d_out);
                kernel::add_matmul_isa(isa, &mut mm, &x, &w, d_out);
                mm.data
            })
        })
        .collect();
    for o in &outs[1..] {
        assert_eq!(&outs[0], o, "ISAs disagree under the 4-worker pool");
    }
}

// --------------------------------------------------------------- dispatch

#[test]
fn resolve_isa_honors_force_scalar() {
    assert_eq!(kernel::resolve_isa(true), KernelIsa::Scalar);
    // without the override, resolution picks a member of the available set
    assert!(kernel::available_isas().contains(&kernel::resolve_isa(false)));
    // scalar is always available and always listed first
    assert_eq!(kernel::available_isas()[0], KernelIsa::Scalar);
}

#[test]
fn session_force_scalar_pins_the_active_isa() {
    // the one test that touches global dispatch state: set a session-level
    // force_scalar, check active_isa() follows, then restore. Runs in its
    // own process-wide critical section via the session config itself —
    // other tests here only use the pure resolve/_isa paths.
    let prev = RuntimeConfig::current();
    let mut forced = prev.clone();
    forced.force_scalar = Some(true);
    crest::runtime_config::set_session(forced);
    assert_eq!(kernel::active_isa(), KernelIsa::Scalar);

    let mut unforced = prev.clone();
    unforced.force_scalar = Some(false);
    crest::runtime_config::set_session(unforced);
    assert_eq!(kernel::active_isa(), kernel::resolve_isa(false));

    crest::runtime_config::set_session(prev);
}
