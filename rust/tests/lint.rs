//! Golden-fixture suite for the contract checker (`crest lint`), plus
//! the end-to-end run over the real tree.
//!
//! Each fixture under `tests/lint_fixtures/` seeds one rule's violation
//! (or its justified/clean counterpart) and is linted under a *virtual*
//! repo path, so the module-scoping logic is exercised without the
//! fixture living in the real source tree. The fixtures directory is
//! excluded from the tree walk — `repo_tree_is_clean` below would fail
//! otherwise, and doubles as the CI gate's in-process twin.

use std::path::Path;

use crest::lint::{lint_tree, Linter, RULES};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// Lint one fixture under a virtual repo path with an empty README and
/// return the (line, rule) pairs.
fn findings(rel: &str, name: &str) -> Vec<(usize, &'static str)> {
    Linter::with_readme("")
        .lint_file(rel, &fixture(name))
        .into_iter()
        .map(|d| (d.line, d.rule))
        .collect()
}

#[test]
fn det_hash_fires_on_selection_code() {
    assert_eq!(findings("rust/src/coreset/fixture.rs", "det_hash_bad.rs"), [(4, "DET-HASH")]);
}

#[test]
fn det_hash_outside_det_modules_is_quiet() {
    assert!(findings("rust/src/util/fixture.rs", "det_hash_bad.rs").is_empty());
}

#[test]
fn det_hash_allow_suppresses_both_directive_forms() {
    assert!(findings("rust/src/coreset/fixture.rs", "det_hash_allowed.rs").is_empty());
}

#[test]
fn det_clock_fires_on_call_site_not_use_line() {
    assert_eq!(findings("rust/src/sweep/fixture.rs", "det_clock_bad.rs"), [(7, "DET-CLOCK")]);
}

#[test]
fn det_fma_fires_on_method_and_intrinsic() {
    assert_eq!(findings("rust/src/kernel.rs", "det_fma_bad.rs"), [(5, "DET-FMA"), (9, "DET-FMA")]);
}

#[test]
fn unsafe_outside_registered_scopes_fires() {
    assert_eq!(findings("rust/src/coreset/fixture.rs", "unsafe_bad.rs"), [(4, "UNSAFE-SCOPE")]);
}

#[test]
fn unsafe_without_safety_comment_fires() {
    // the justified block in the same registered module stays quiet
    assert_eq!(findings("rust/src/data/store.rs", "unsafe_nosafety.rs"), [(13, "UNSAFE-SCOPE")]);
}

#[test]
fn env_hygiene_fires_on_read_and_undocumented_name() {
    let d = findings("rust/src/coordinator/fixture.rs", "env_bad.rs");
    assert_eq!(d, [(6, "ENV-HYGIENE"), (6, "ENV-HYGIENE")]);
}

#[test]
fn env_hygiene_documented_name_in_registered_reader_is_quiet() {
    // same fixture, but linted as a registered reader with the name in
    // the README table: both findings disappear
    let src = fixture("env_bad.rs");
    let readme = "| `CREST_BOGUS_KNOB` | documented |";
    let d = Linter::with_readme(readme).lint_file("rust/src/bench_util/mod.rs", &src);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn io_facade_fires_once_per_offending_line() {
    // line 6 (`std::fs::File::open`) matches two tokens but dedupes;
    // line 15's metadata probe carries a justified trailing allow
    assert_eq!(
        findings("rust/src/sweep/store.rs", "io_facade_bad.rs"),
        [(6, "IO-FACADE"), (11, "IO-FACADE")]
    );
}

#[test]
fn io_facade_outside_artifact_modules_is_quiet() {
    // the facade itself, and files not in the exact artifact list, may
    // use raw std::fs freely
    assert!(findings("rust/src/util/artifact_io.rs", "io_facade_bad.rs").is_empty());
    assert!(findings("rust/src/data/synth.rs", "io_facade_bad.rs").is_empty());
}

#[test]
fn isa_dispatch_fires_outside_kernel() {
    let d = findings("rust/src/util/fixture.rs", "isa_bad.rs");
    assert_eq!(d, [(4, "ISA-DISPATCH"), (10, "ISA-DISPATCH")]);
}

#[test]
fn lint_allow_meta_rule_fires_on_broken_directives() {
    let d = findings("rust/src/coreset/fixture.rs", "allow_bad.rs");
    assert_eq!(d, [(4, "LINT-ALLOW"), (7, "LINT-ALLOW")]);
}

#[test]
fn lint_allow_cannot_suppress_itself() {
    let src = "// lint:allow(LINT-ALLOW) nice try\nfn x() {}\n";
    let d = Linter::with_readme("").lint_file("rust/src/coreset/fixture.rs", src);
    assert_eq!(d.len(), 1);
    assert_eq!(d[0].rule, "LINT-ALLOW");
}

#[test]
fn clean_fixture_is_quiet() {
    assert!(findings("rust/src/coreset/fixture.rs", "clean.rs").is_empty());
}

#[test]
fn diagnostics_render_with_rule_id() {
    let d = Linter::with_readme("").lint_file("rust/src/kernel.rs", &fixture("det_fma_bad.rs"));
    let line = d[0].to_string();
    assert!(line.starts_with("rust/src/kernel.rs:5: [DET-FMA]"), "{line}");
}

// ------------------------------------------------------------ real tree

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap()
}

/// The CI gate's in-process twin: the real tree must lint clean. A
/// failure message lists the findings verbatim.
#[test]
fn repo_tree_is_clean() {
    let diags = lint_tree(repo_root()).unwrap();
    let rendered: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(diags.is_empty(), "crest lint found:\n{}", rendered.join("\n"));
}

/// Every rule ID must be documented in CONTRACTS.md (the same pattern
/// as the README env-table coverage test in `runtime_config`).
#[test]
fn contracts_documents_every_rule() {
    let text = std::fs::read_to_string(repo_root().join("CONTRACTS.md")).unwrap();
    for r in RULES {
        assert!(text.contains(r.id), "CONTRACTS.md is missing rule {}", r.id);
    }
    assert!(text.contains("lint:allow"), "CONTRACTS.md must document the allow syntax");
}

/// README's CLI table must carry the `lint` subcommand row and link the
/// contracts document.
#[test]
fn readme_documents_lint_command() {
    let text = std::fs::read_to_string(repo_root().join("README.md")).unwrap();
    assert!(text.contains("| `lint` |"));
    assert!(text.contains("CONTRACTS.md"));
}
