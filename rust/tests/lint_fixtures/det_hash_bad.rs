// Golden fixture: a hash container in selection code. Linted under the
// virtual path `rust/src/coreset/fixture.rs`; must trip DET-HASH once.
fn fold_gains(idx: &[usize]) -> f32 {
    let mut gains = std::collections::HashMap::new();
    for &i in idx {
        gains.insert(i, i as f32);
    }
    gains.values().sum()
}
