// Golden fixture: a wall-clock read in a module feeding
// deterministic_json. Linted under `rust/src/sweep/fixture.rs`; must
// trip DET-CLOCK once (the `use` line is exempt, the call site is not).
use std::time::Instant;

fn cell_secs() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
