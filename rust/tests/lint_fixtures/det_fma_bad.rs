// Golden fixture: fused multiply-adds in the kernel layer. Linted under
// `rust/src/kernel.rs`; must trip DET-FMA twice (the method and the
// intrinsic), while the mention in this comment — mul_add — stays quiet.
fn axpy(a: f32, x: f32, y: f32) -> f32 {
    a.mul_add(x, y)
}

fn tile(acc: F, a: F, b: F) -> F {
    _mm256_fmadd_ps(a, b, acc)
}
