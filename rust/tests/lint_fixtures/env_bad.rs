// Golden fixture: an environment read outside the registered readers,
// naming a variable the README does not document. Linted under
// `rust/src/coordinator/fixture.rs`; must trip ENV-HYGIENE twice — once
// for the read location, once for the undocumented name.
pub fn knob() -> bool {
    std::env::var("CREST_BOGUS_KNOB").is_ok()
}
