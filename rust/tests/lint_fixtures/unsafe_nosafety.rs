// Golden fixture: a registered unsafe scope where one block carries a
// SAFETY comment and one does not. Linted under the registered path
// `rust/src/data/store.rs`; must trip UNSAFE-SCOPE exactly once, on the
// unjustified block.
#[allow(unsafe_code)]
mod mm {
    pub fn justified(v: &[f32]) -> f32 {
        // SAFETY: the caller guarantees v is non-empty
        unsafe { *v.get_unchecked(0) }
    }

    pub fn unjustified(v: &[f32]) -> f32 {
        unsafe { *v.get_unchecked(0) }
    }
}
