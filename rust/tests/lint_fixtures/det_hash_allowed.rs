// Golden fixture: the same hash container, justified. Both directive
// forms appear — trailing and standalone-above — and must suppress the
// findings without tripping LINT-ALLOW.
fn lookup_cache() {
    let m = std::collections::HashMap::<u64, u64>::new(); // lint:allow(DET-HASH) keyed get/insert only, never iterated
    drop(m);
}

fn membership() {
    // lint:allow(DET-HASH) membership-only set, iteration order unreachable
    let s = std::collections::HashSet::<u64>::new();
    drop(s);
}
