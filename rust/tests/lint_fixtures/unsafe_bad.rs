// Golden fixture: `unsafe` in a file with no registered scope. Linted
// under `rust/src/coreset/fixture.rs`; must trip UNSAFE-SCOPE once.
fn peek(v: &[f32]) -> f32 {
    unsafe { *v.get_unchecked(0) }
}
