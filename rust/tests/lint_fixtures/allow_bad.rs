// Golden fixture: broken allow directives. Linted under
// `rust/src/coreset/fixture.rs`; must trip LINT-ALLOW twice — an
// unknown rule ID, and a directive with no written reason.
// lint:allow(NO-SUCH-RULE) the id does not exist
fn a() {}

// lint:allow(DET-HASH)
fn b() {}
