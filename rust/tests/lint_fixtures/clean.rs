// Golden fixture: a representative clean file. Linted under
// `rust/src/coreset/fixture.rs`; must produce zero findings — the hash
// import sits in a `use` declaration, the ordered map is fine, and the
// hash set plus timer live in test code.
use std::collections::HashMap;

fn ordered(n: usize) -> Vec<usize> {
    let mut m = std::collections::BTreeMap::new();
    for i in 0..n {
        m.insert(i, i * 2);
    }
    m.into_values().collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn uniqueness() {
        let t0 = std::time::Instant::now();
        let s: std::collections::HashSet<usize> = super::ordered(8).into_iter().collect();
        assert_eq!(s.len(), 8);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
