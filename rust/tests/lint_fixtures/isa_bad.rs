// Golden fixture: ISA-specific code outside kernel.rs. Linted under
// `rust/src/util/fixture.rs`; must trip ISA-DISPATCH twice — the
// #[target_feature] body and the stray feature probe.
#[target_feature(enable = "avx2")]
fn fast_path(a: &[f32]) -> f32 {
    a[0]
}

pub fn caller() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}
