// Golden fixture: raw `std::fs` call-sites in an artifact module.
// Linted under the virtual path `rust/src/sweep/store.rs`; must trip
// IO-FACADE once per offending line — `std::fs::File::open` on line 6
// matches both `fs::` and `File::` but dedupes to a single finding.
fn read_raw(path: &std::path::Path) -> std::io::Result<Vec<u8>> {
    let _f = std::fs::File::open(path)?;
    Ok(Vec::new())
}

fn publish_raw(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)
}

fn probe(path: &std::path::Path) -> bool {
    std::fs::metadata(path).is_ok() // lint:allow(IO-FACADE) metadata probe: no payload bytes move
}
