//! Sweep-orchestrator semantics on the tiny `smoke` variant:
//!
//! * an interrupted-then-resumed sweep produces bitwise-identical
//!   per-cell deterministic reports and aggregates vs an uninterrupted run
//! * only the missing cells re-execute on resume
//! * scheduling cells across pool workers does not perturb results

use std::path::PathBuf;

use crest::api::Method;
use crest::report::aggregate_markdown;
use crest::sweep::{self, CheckpointStore, SweepGrid, SweepOutcome, SweepSpec};

/// The acceptance grid: smoke × {crest, random} × seeds {1, 2} @ 10%.
fn smoke_grid(seeds: Vec<u64>) -> SweepGrid {
    SweepGrid {
        variants: vec!["smoke".to_string()],
        methods: vec![Method::crest(), Method::random()],
        seeds,
        budgets: vec![0.1],
    }
}

fn smoke_spec(seeds: Vec<u64>, dir: Option<PathBuf>, jobs: usize) -> SweepSpec {
    let mut spec = SweepSpec::new(smoke_grid(seeds), 2);
    spec.checkpoint_dir = dir;
    spec.jobs = jobs;
    spec
}

/// Fresh (absent) temp checkpoint dir, unique per test.
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("crest-sweep-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Bitwise fingerprint of a sweep's deterministic content: every cell's
/// deterministic report core plus the rendered aggregates.
fn fingerprint(outcome: &SweepOutcome) -> Vec<String> {
    let mut out: Vec<String> = outcome
        .cells
        .iter()
        .map(|c| format!("{}\n{}", c.key.label(), c.report.deterministic_json().to_string_pretty()))
        .collect();
    out.push(aggregate_markdown(&outcome.rows));
    out.extend(outcome.rows.iter().map(|r| r.to_json().to_string_pretty()));
    out
}

#[test]
fn interrupted_then_resumed_sweep_matches_uninterrupted_bitwise() {
    let dir = tmp_dir("resume");

    // reference: uninterrupted, no checkpoints, serial
    let reference = sweep::run(&smoke_spec(vec![1, 2], None, 1)).unwrap();
    assert_eq!(reference.cells.len(), 4);
    assert_eq!(reference.n_executed(), 4);

    // "interrupted" sweep: only the seed-1 half of the grid completed
    // before the kill — its cells are checkpointed
    let partial = sweep::run(&smoke_spec(vec![1], Some(dir.clone()), 2)).unwrap();
    assert_eq!(partial.n_executed(), 2);

    // resume the full grid: only the missing seed-2 cells execute
    let resumed = sweep::run(&smoke_spec(vec![1, 2], Some(dir.clone()), 2)).unwrap();
    assert_eq!(resumed.cells.len(), 4);
    assert_eq!(resumed.n_executed(), 2, "only missing cells re-execute");
    assert_eq!(resumed.n_restored(), 2);
    for c in &resumed.cells {
        assert_eq!(c.executed, c.key.seed == 2, "exactly the seed-2 cells ran: {}", c.key.label());
    }

    // per-cell reports and aggregates are bitwise-identical to the
    // uninterrupted run (deterministic core; wall-clock excluded)
    assert_eq!(fingerprint(&resumed), fingerprint(&reference));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deleting_one_checkpoint_reexecutes_only_that_cell_and_reproduces_aggregate() {
    let dir = tmp_dir("delete-one");

    let first = sweep::run(&smoke_spec(vec![1, 2], Some(dir.clone()), 2)).unwrap();
    assert_eq!(first.n_executed(), 4);

    // a second invocation restores everything
    let warm = sweep::run(&smoke_spec(vec![1, 2], Some(dir.clone()), 2)).unwrap();
    assert_eq!(warm.n_executed(), 0);
    assert_eq!(fingerprint(&warm), fingerprint(&first));

    // delete one cell's checkpoint -> exactly that cell re-executes
    let victim = first.cells[1].key.clone();
    let store = CheckpointStore::open(&dir).unwrap();
    assert!(store.remove(&victim), "victim checkpoint existed");
    let repaired = sweep::run(&smoke_spec(vec![1, 2], Some(dir.clone()), 2)).unwrap();
    assert_eq!(repaired.n_executed(), 1);
    for c in &repaired.cells {
        assert_eq!(c.executed, c.key == victim, "re-executed wrong cell: {}", c.key.label());
    }

    // ... and the re-executed cell reproduces the aggregate bitwise
    assert_eq!(fingerprint(&repaired), fingerprint(&first));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_and_serial_scheduling_agree_bitwise() {
    let serial = sweep::run(&smoke_spec(vec![1, 2], None, 1)).unwrap();
    let parallel = sweep::run(&smoke_spec(vec![1, 2], None, 4)).unwrap();
    assert_eq!(fingerprint(&parallel), fingerprint(&serial));
    // grid order is preserved regardless of completion order
    let labels: Vec<String> = parallel.cells.iter().map(|c| c.key.label()).collect();
    assert_eq!(
        labels,
        vec![
            "smoke/crest/seed=1/budget=0.1",
            "smoke/crest/seed=2/budget=0.1",
            "smoke/random/seed=1/budget=0.1",
            "smoke/random/seed=2/budget=0.1",
        ]
    );
}
