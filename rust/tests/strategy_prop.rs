//! Property suite for the selection-strategy layer's arithmetic edges.
//!
//! Driven by the crate's dependency-free seeded generator
//! ([`crest::prop::forall`], a splitmix-seeded LCG draw per case):
//!
//! * largest-remainder budget apportionment — sums to `min(k, Σ sizes)`,
//!   never exceeds a piece's size, ignores zero-size pieces, and is stable
//!   under permutation when every remainder is equal;
//! * [`SparseKnnMetric`] — every finite (non-`far`) pair lies inside the
//!   candidate window of the projection ordering the build used, rows keep
//!   at most `neighbors` entries, and the `far` sentinel strictly
//!   dominates every kept distance.

use crest::coreset::facility::{
    projection_order, EuclidMetric, SparseKnnMetric, SqDistMetric, KNN_PROJ_SEED,
};
use crest::coreset::strategy::apportion;
use crest::prop::{forall, usize_in, vec_f32};
use crest::tensor::MatF32;
use crest::util::rng::Rng;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, vec_f32(rng, rows * cols, scale)).unwrap()
}

// ------------------------------------------------------------- apportion

#[test]
fn prop_apportion_sums_and_caps() {
    forall(
        "apportion-sums-caps",
        0xA110,
        200,
        |rng| {
            let pieces = usize_in(rng, 0, 12);
            let sizes: Vec<usize> = (0..pieces).map(|_| usize_in(rng, 0, 40)).collect();
            let k = usize_in(rng, 0, 80);
            (sizes, k)
        },
        |(sizes, k)| {
            let out = apportion(*k, sizes);
            if out.len() != sizes.len() {
                return Err(format!("length {} != {}", out.len(), sizes.len()));
            }
            let n: usize = sizes.iter().sum();
            let total: usize = out.iter().sum();
            if total != (*k).min(n) {
                return Err(format!("sum {total} != min(k={k}, n={n})"));
            }
            for (i, (&q, &sz)) in out.iter().zip(sizes).enumerate() {
                if q > sz {
                    return Err(format!("piece {i}: budget {q} exceeds size {sz}"));
                }
            }
            // determinism: a second call reproduces the split exactly
            if apportion(*k, sizes) != out {
                return Err("apportion is not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_apportion_stable_under_permutation_of_equal_remainders() {
    // all pieces the same size → every fractional remainder is equal, so
    // any permutation must yield the same multiset of budgets (the extras
    // just land on different indices)
    forall(
        "apportion-equal-remainders",
        0xA111,
        200,
        |rng| {
            let pieces = usize_in(rng, 1, 10);
            let size = usize_in(rng, 1, 20);
            let k = usize_in(rng, 0, pieces * size + 5);
            // a random permutation via Fisher–Yates on the index array
            let mut perm: Vec<usize> = (0..pieces).collect();
            for i in (1..pieces).rev() {
                perm.swap(i, usize_in(rng, 0, i + 1));
            }
            (pieces, size, k, perm)
        },
        |(pieces, size, k, perm)| {
            let sizes = vec![*size; *pieces];
            let base = apportion(*k, &sizes);
            let permuted = apportion(*k, &perm.iter().map(|&i| sizes[i]).collect::<Vec<_>>());
            let mut a = base.clone();
            let mut b = permuted.clone();
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                return Err(format!("budget multiset changed: {base:?} vs {permuted:?}"));
            }
            // equal remainders also means budgets differ by at most 1
            if let (Some(&hi), Some(&lo)) = (a.last(), a.first()) {
                if hi - lo > 1 {
                    return Err(format!("equal-size budgets spread beyond 1: {a:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_apportion_ignores_zero_size_pieces() {
    // inserting zero-size pieces anywhere must not change any other
    // piece's budget: zeros take no quota, no remainder, no overflow
    forall(
        "apportion-zero-pieces",
        0xA112,
        150,
        |rng| {
            let pieces = usize_in(rng, 1, 8);
            let sizes: Vec<usize> = (0..pieces).map(|_| usize_in(rng, 1, 30)).collect();
            let k = usize_in(rng, 0, 60);
            let insert_at = usize_in(rng, 0, pieces + 1);
            (sizes, k, insert_at)
        },
        |(sizes, k, insert_at)| {
            let base = apportion(*k, sizes);
            let mut padded = sizes.clone();
            padded.insert(*insert_at, 0);
            let got = apportion(*k, &padded);
            if got[*insert_at] != 0 {
                return Err(format!("zero-size piece received budget {}", got[*insert_at]));
            }
            let mut stripped = got.clone();
            stripped.remove(*insert_at);
            if stripped != base {
                return Err(format!("zero piece changed neighbors: {base:?} vs {stripped:?}"));
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------- sparse knn

#[test]
fn prop_sparse_knn_candidate_window_bounds() {
    forall(
        "sparse-knn-window",
        0x5EED,
        40,
        |rng| {
            let n = usize_in(rng, 1, 60);
            let c = usize_in(rng, 1, 8);
            let g = rand_mat(rng, n, c, 3.0);
            let k = usize_in(rng, 1, n + 3);
            (g, k)
        },
        |(g, k)| {
            let n = g.rows;
            let euclid = EuclidMetric::new(g);
            let knn = SparseKnnMetric::build(&euclid, g, *k);
            let kc = (*k).clamp(1, n);
            if knn.neighbors() != kc {
                return Err(format!("neighbors {} != clamped {kc}", knn.neighbors()));
            }
            if knn.far() <= 0.0 || !knn.far().is_finite() {
                return Err(format!("far sentinel {} not positive/finite", knn.far()));
            }
            // rank of every element in the projection ordering the build used
            let order = projection_order(g, KNN_PROJ_SEED);
            let mut rank = vec![0usize; n];
            for (p, &i) in order.iter().enumerate() {
                rank[i] = p;
            }
            for i in 0..n {
                if knn.sqdist(i, i) != 0.0 {
                    return Err(format!("sqdist({i},{i}) = {}", knn.sqdist(i, i)));
                }
                let mut kept = 0usize;
                for j in 0..n {
                    if i == j {
                        continue;
                    }
                    let dij = knn.sqdist(i, j);
                    if dij == knn.far() {
                        continue;
                    }
                    kept += 1;
                    // every finite pair must be inside the candidate window:
                    // k projection-ranks either side of row i's own rank
                    let dr = rank[i].abs_diff(rank[j]);
                    if dr > kc {
                        return Err(format!(
                            "finite pair ({i},{j}) is {dr} ranks apart, window is {kc}"
                        ));
                    }
                    // kept distances match the inner metric and stay below far
                    let exact = euclid.sqdist(i, j);
                    if dij.to_bits() != exact.to_bits() {
                        return Err(format!("kept dist ({i},{j}) {dij} != inner {exact}"));
                    }
                    if dij >= knn.far() {
                        return Err(format!("kept dist {dij} not below far {}", knn.far()));
                    }
                }
                // each row stores exactly kc entries (usually including the
                // element itself), so at most kc other elements are finite
                if kept > kc {
                    return Err(format!("row {i} keeps {kept} finite pairs, cap is {kc}"));
                }
            }
            Ok(())
        },
    );
}
