//! Table 5 — Relative error at a 20% training budget (vs 10% in Table 1):
//! CREST vs Random vs SGD† on the three vision proxies.
//!
//! Expected shape (paper): with a larger budget both CREST and Random get
//! close to full training (2-4% rel. error) while SGD† still lags badly;
//! CREST's edge over Random shrinks.

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    println!("# Table 5 — relative error (%) @ 20% budget ({} seeds)", sc::seeds().len());
    let methods = [Method::crest(), Method::random(), Method::sgd_truncated()];
    let mut table = Table::new(&["variant", "crest", "random", "sgd†"]);
    let variants: Vec<String> = sc::variants()
        .into_iter()
        .filter(|v| v != "snli-proxy") // paper Table 5 is vision-only
        .collect();
    for variant in variants {
        let mut rel = vec![Vec::new(); methods.len()];
        for seed in sc::seeds() {
            let Some((rt, splits)) = sc::load(&variant, seed) else { return Ok(()) };
            let full = sc::cell(&rt, &splits, &variant, Method::full(), seed, |_| {})?;
            for (mi, &m) in methods.iter().enumerate() {
                let rep = sc::cell(&rt, &splits, &variant, m, seed, |c| c.budget_frac = 0.20)?;
                rel[mi].push(sc::rel_err(rep.final_test_acc, full.final_test_acc));
            }
        }
        table.row(&[
            variant.clone(),
            sc::fmt_mean_std(&rel[0]),
            sc::fmt_mean_std(&rel[1]),
            sc::fmt_mean_std(&rel[2]),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
