//! Figure 9 — gradient variance of CREST mini-batch coresets (size m from
//! size-r subsets) vs random batches of size m vs random subsets of size r,
//! at several checkpoints along training.
//!
//! Expected shape (paper): Var(crest-mb) ≈ Var(random-r) ≪ Var(random-m).

use anyhow::Result;
use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::coreset::{facility, MiniBatchCoreset};
use crest::metrics::gradprobe;
use crest::model::init_params;
use crest::opt::LrSchedule;
use crest::train::TrainState;
use crest::util::rng::Rng;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };
    let ds = &splits.train;
    let (m, r, p_dim) = (rt.man.m, rt.man.r, rt.man.p_dim);
    let cfg = crest::config::ExperimentConfig::preset(variant, Method::random(), seed)?;
    let sched = LrSchedule::paper_default(cfg.base_lr);
    let mut rng = Rng::new(seed ^ 0x99);
    let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;

    println!("# Fig 9 — gradient variance of three estimators ({variant}, m={m}, r={r})");
    println!("{:>6} {:>14} {:>14} {:>14}", "step", "random-m", "crest-mb", "random-r");
    let total = 400usize;
    let checkpoints = [0usize, 50, 150, 399];
    let k_samples = 16;
    let mut cp = 0;
    for step in 0..total {
        if cp < checkpoints.len() && step == checkpoints[cp] {
            cp += 1;
            let full = gradprobe::full_gradient(&rt, &state.params, ds)?;
            let mut rng_a = rng.split();
            let rand_m = gradprobe::bias_variance(&rt, &state.params, ds, &full, k_samples,
                || (rng_a.sample_indices(ds.n(), m), vec![1.0; m]))?;
            let mut rng_b = rng.split();
            let crest_mb = gradprobe::bias_variance(&rt, &state.params, ds, &full, k_samples,
                || {
                    let pool = rng_b.sample_indices(ds.n(), r);
                    let (x, y) = ds.batch(&pool);
                    let (gl, al, _) = rt.grad_embed(&state.params, &x, &y).unwrap();
                    let sel = facility::facility_location_prod(&al, &gl, m);
                    let mb = MiniBatchCoreset::from_selection(&sel, &pool, m);
                    (mb.idx, mb.gamma)
                })?;
            // random-r: exact mean of r/m chunked batch gradients per draw
            let mut rng_c = rng.split();
            let mut var_acc = 0.0f64;
            for _ in 0..k_samples {
                let pool = rng_c.sample_indices(ds.n(), r);
                let mut g = vec![0.0f64; p_dim];
                for chunk in pool.chunks(m) {
                    let gi = gradprobe::batch_gradient(&rt, &state.params, ds, chunk,
                                                       &vec![1.0; m])?;
                    for (a, &v) in g.iter_mut().zip(&gi) {
                        *a += v as f64 / (r / m) as f64;
                    }
                }
                let mut dev2 = 0.0f64;
                for (a, &f) in g.iter().zip(&full) {
                    dev2 += (a - f as f64) * (a - f as f64);
                }
                var_acc += dev2 / k_samples as f64;
            }
            println!("{:>6} {:>14.4} {:>14.4} {:>14.4}",
                     step, rand_m.variance, crest_mb.variance, var_acc);
        }
        let idx = rng.sample_indices(ds.n(), m);
        let lr = sched.lr_at(step, total);
        state.step_batch(&rt, ds, &idx, &vec![1.0; m], lr, cfg.weight_decay)?;
    }
    println!("\nexpected shape: crest-mb ≈ random-r ≪ random-m");
    Ok(())
}
