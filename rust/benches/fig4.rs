//! Figure 4 — (left) cumulative coreset updates over training for CREST:
//! updates concentrate early and flatten as the quadratic regions grow;
//! (right) final accuracy vs total update count for the quadratic,
//! first-order, and unsmoothed variants.

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };

    println!("# Fig 4 (left) — cumulative coreset updates vs iteration (CREST, {variant})");
    let rep = sc::cell(&rt, &splits, variant, Method::crest(), seed, |_| {})?;
    let total_steps = rep.steps.max(1);
    println!("{:>10} {:>10}", "iteration", "updates");
    let buckets = 10;
    for b in 1..=buckets {
        let cutoff = total_steps * b / buckets;
        let count = rep.update_steps.iter().filter(|&&s| s < cutoff).count();
        println!("{:>10} {:>10}", cutoff, count);
    }
    // T1 growth across the run
    if !rep.t1_history.is_empty() {
        println!("\nT1 adaptations (step, T1): {:?}", &rep.t1_history
            [..rep.t1_history.len().min(12)]);
    }

    println!("\n# Fig 4 (right) — accuracy vs total updates, model-variant ablation");
    let mut table = Table::new(&["variant", "test acc", "# updates"]);
    let cells: [(&str, Box<dyn Fn(&mut crest::config::ExperimentConfig)>); 3] = [
        ("quadratic (CREST)", Box::new(|_| {})),
        ("first-order", Box::new(|c| c.crest.second_order = false)),
        ("no smoothing", Box::new(|c| c.crest.smooth = false)),
    ];
    for (name, patch) in cells {
        let rep = sc::cell(&rt, &splits, variant, Method::crest(), seed, patch)?;
        table.row(&[
            name.to_string(),
            format!("{:.4}", rep.final_test_acc),
            format!("{}", rep.n_selection_updates),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
