//! §Scaling — thread-count sweep over the parallel hot paths.
//!
//! Measures train_step, grad_embed and facility-location selection at
//! 1/2/4/8 pool workers on a model sized so the batch-row loops dominate
//! thread-spawn overhead, printing per-count speedups vs the 1-thread
//! baseline and a bitwise-determinism spot check. It continues with the
//! out-of-core scenario: stream-pack a ≥10^6-example corpus into the
//! sharded format, reopen it through the mmap store, and train a
//! budgeted CREST cell on it end to end. It closes with the selection
//! crossover: every [`SelectionStrategy`] from the scenario table runs
//! over a 10^5-scale ground set fed from the same mmap pack, recording
//! wall-clock per strategy and the coverage-cost rel-err vs exact — the
//! sub-quadratic strategies must beat exact wall-clock at that scale
//! while the sweep-aggregate rel-err stays ≤ 5%. With
//! `CREST_BENCH_JSON=<path>` the records seed the perf trajectory;
//! `CREST_BENCH_QUICK=1` shrinks the model and corpus for the CI
//! perf-smoke and scaling-smoke jobs.
//!
//! Run with `cargo bench --bench scaling`.

use crest::bench_util::scenario as sc;
use crest::bench_util::{self, bench_recorded, format_secs, section};
use crest::config::Method;
use crest::coreset::facility;
use crest::coreset::strategy::{self, SelectionStrategy};
use crest::kernel;
use crest::model::init_params;
use crest::runtime::manifest::{ModelSpec, VariantManifest};
use crest::runtime::Runtime;
use crest::tensor::MatF32;
use crest::util::pool;
use crest::util::rng::Rng;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> MatF32 {
    let mut m = MatF32::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

/// Run `f` at every thread count, printing speedup vs the 1-thread p50.
fn sweep<T>(label: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) {
    let mut base_p50 = None;
    for &t in &THREAD_COUNTS {
        pool::set_threads(t);
        let r = bench_recorded(&format!("{label} t={t}"), warmup, reps, &mut f);
        let base = *base_p50.get_or_insert(r.p50_secs);
        println!(
            "    -> speedup vs t=1: {:.2}x (p50 {})",
            base / r.p50_secs.max(1e-12),
            format_secs(r.p50_secs)
        );
    }
}

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let quick = bench_util::quick();
    let initial_threads = pool::threads();

    // batch/hidden sizes chosen so one step is tens of milliseconds of
    // dense-kernel work — the regime the parallel layer targets
    let (hidden, m, r) = if quick {
        (vec![256, 128], 128, 256)
    } else {
        (vec![512, 256], 256, 512)
    };
    let spec = ModelSpec {
        name: "scaling-bench",
        d_in: 256,
        hidden,
        classes: 10,
        m,
        r,
        eval_chunk: r,
        momentum: 0.9,
    };
    let rt = Runtime::native(VariantManifest::from_spec(&spec)?);
    let mut rng = Rng::new(42);
    let params = init_params(&rt.man, &mut rng);
    let mom = rt.zero_momentum();
    let mx = random_mat(&mut rng, m, spec.d_in);
    let my: Vec<i32> = (0..m).map(|_| rng.gen_range(spec.classes) as i32).collect();
    let gamma = vec![1.0f32; m];
    let rx = random_mat(&mut rng, r, spec.d_in);
    let ry: Vec<i32> = (0..r).map(|_| rng.gen_range(spec.classes) as i32).collect();
    let reps = if quick { 5 } else { 10 };

    section("scaling: train_step (batch-row parallel kernels)");
    sweep(&format!("train_step m={m}"), 2, reps, || {
        rt.train_step(&params, &mom, &mx, &my, &gamma, 0.01, 5e-4).unwrap()
    });

    section("scaling: grad_embed");
    sweep(&format!("grad_embed r={r}"), 2, reps, || {
        rt.grad_embed(&params, &rx, &ry).unwrap()
    });

    section("scaling: facility location (lazy greedy, prod metric)");
    let n = if quick { 1024 } else { 2048 };
    let gl = random_mat(&mut rng, n, 10);
    let al = random_mat(&mut rng, n, 64);
    let msel = n / 16;
    sweep(&format!("facility-location n={n} m={msel}"), 1, if quick { 3 } else { 5 }, || {
        facility::facility_location_prod(&al, &gl, msel)
    });

    section("scaling: facility location (stochastic greedy)");
    let ns = if quick { 2048 } else { 8192 };
    let gs = random_mat(&mut rng, ns, 10);
    let acts = random_mat(&mut rng, ns, 64);
    let metric = facility::ProdMetric::new(&acts, &gs);
    let msel_s = ns / 16;
    sweep(&format!("stochastic greedy n={ns} m={msel_s}"), 1, 3, || {
        let mut srng = Rng::new(7);
        facility::facility_location_stochastic(&metric, msel_s, &mut srng)
    });

    section("scaling: SIMD matmul kernel (dispatched ISA across thread counts)");
    {
        // one thread-sweep row per available ISA over the same matmul, so
        // the trajectory records how the SIMD win composes with threading
        let (km, kk, kn) = (512usize, 256usize, 256usize);
        let kx = random_mat(&mut rng, km, kk);
        let kw: Vec<f32> = (0..kk * kn).map(|_| rng.normal()).collect();
        for isa in kernel::available_isas() {
            let mut kout = MatF32::zeros(km, kn);
            sweep(&format!("add_matmul m={km} k={kk} n={kn} isa={isa}"), 2, reps, || {
                kernel::add_matmul_isa(isa, &mut kout, &kx, &kw, kn)
            });
        }
        // SIMD-vs-scalar determinism: the dispatched ISA must reproduce the
        // scalar path bitwise (lanes map across output elements, never
        // within one dot product's accumulation)
        let mut o_scalar = MatF32::zeros(km, kn);
        let mut o_active = MatF32::zeros(km, kn);
        kernel::add_matmul_isa(crest::kernel::KernelIsa::Scalar, &mut o_scalar, &kx, &kw, kn);
        kernel::add_matmul_isa(kernel::active_isa(), &mut o_active, &kx, &kw, kn);
        assert_eq!(
            o_scalar.data, o_active.data,
            "dispatched ISA must be bitwise-identical to scalar"
        );
        println!(
            "\ndeterminism: {} and scalar matmul outputs are bitwise-identical",
            kernel::active_isa()
        );
    }

    // determinism spot check across the sweep's thread counts
    let d1 = pool::with_threads(1, || facility::facility_location_prod(&al, &gl, msel));
    let d4 = pool::with_threads(4, || facility::facility_location_prod(&al, &gl, msel));
    assert_eq!(d1.idx, d4.idx, "facility selection must not depend on thread count");
    assert_eq!(d1.gamma, d4.gamma, "facility gammas must not depend on thread count");
    let s1 = pool::with_threads(1, || {
        rt.train_step(&params, &mom, &mx, &my, &gamma, 0.01, 5e-4).unwrap()
    });
    let s4 = pool::with_threads(4, || {
        rt.train_step(&params, &mom, &mx, &my, &gamma, 0.01, 5e-4).unwrap()
    });
    assert_eq!(s1.params, s4.params, "train_step must not depend on thread count");
    println!("\ndeterminism: threads=1 and threads=4 outputs are bitwise-identical");

    pool::set_threads(initial_threads);

    // ---------------------------------------------------- out-of-core
    section("scaling: out-of-core mmap store (pack + train ≥10^6 examples)");
    // 2^20 = 1,048,576 training examples at d=16: a 64 MB feature payload
    // streamed to shards and trained through the mmap store without ever
    // being resident. Quick mode keeps the same code path at 2^16.
    let n_train = if quick { 1 << 16 } else { 1 << 20 };
    let oospec = sc::oocore_spec(n_train, 1);
    let root = std::env::temp_dir()
        .join(format!("crest-scaling-oocore-{}", std::process::id()))
        .join(format!("{}-s{}", oospec.name, oospec.seed));
    let _ = std::fs::remove_dir_all(root.parent().unwrap());
    bench_recorded(&format!("oocore pack n={n_train}"), 0, 1, || {
        crest::data::generate_packed(&oospec, &root, crest::data::shard::DEFAULT_SHARD_ROWS)
            .unwrap()
    });
    let mut loaded = None;
    bench_recorded(&format!("oocore load n={n_train}"), 0, 1, || {
        loaded = Some(crest::data::shard::load_packed_splits(&root).unwrap());
    });
    let splits = loaded.expect("load bench ran at least once");
    assert_eq!(splits.train.store_kind(), "mmap");
    assert_eq!(splits.train.n(), n_train);
    let smoke_rt = Runtime::native_variant("smoke")?;
    let mut trained = None;
    bench_recorded(&format!("oocore crest train n={n_train}"), 0, 1, || {
        let rep = sc::cell(&smoke_rt, &splits, "smoke", Method::crest(), 1, |cfg| {
            // ~1% of one epoch: hundreds of steps, every batch gathered
            // through the mmap shards
            cfg.epochs_full = 1;
            cfg.budget_frac = 0.01;
        })
        .unwrap();
        trained = Some(rep);
    });
    let rep = trained.expect("train bench ran at least once");
    println!(
        "    -> trained on {} packed examples via {} store: final test acc {:.4}",
        n_train,
        splits.train.store_kind(),
        rep.final_test_acc
    );

    // ------------------------------------------- selection crossover
    section("scaling: exact vs approximate selection (mmap-fed ground set)");
    // The ground set is the head of the packed train split, read
    // block-at-a-time out of the mmap shards (never a resident Dataset
    // copy), with the resident label vector alongside. 2^17 examples in
    // full mode — past the 10^5 mark where exact greedy's super-linear
    // scan cost dominates; quick mode keeps the code path at 2^13.
    let n_sel = if quick { 1 << 13 } else { 1 << 17 };
    assert!(n_sel <= splits.train.n(), "ground set drawn from the packed corpus");
    let d = splits.train.d();
    let mut ground = MatF32::zeros(n_sel, d);
    splits.train.read_block(0, n_sel, &mut ground.data);
    let ylab: Vec<i32> = splits.train.y[..n_sel].to_vec();
    let g = strategy::Ground { gl: &ground, al: None, labels: Some(&ylab) };
    let m_sel = 256;
    let reps_sel = if quick { 1 } else { 3 };
    let mut exact_p50 = None;
    let mut exact_cost = None;
    let mut approx: Vec<(&str, f64, f64)> = Vec::new(); // (name, p50, rel-err %)
    for (name, strat) in sc::selection_strategies() {
        let mut picked = None;
        let r = bench_recorded(
            &format!("selection {name} n={n_sel} m={m_sel}"),
            0,
            reps_sel,
            || picked = Some(strat.select(&g, m_sel, &mut Rng::new(11), &strategy::CraigSelector)),
        );
        let sel = picked.expect("selection ran at least once");
        let cost = facility::coverage_cost(&ground, &sel.idx);
        match strat {
            SelectionStrategy::Exact => {
                exact_p50 = Some(r.p50_secs);
                exact_cost = Some(cost);
            }
            _ => {
                let base = exact_cost.expect("exact strategy measured first");
                // coverage cost: lower is better; a strategy that beats
                // the (stochastic) exact baseline counts as zero error
                let rel = ((cost - base) / base.max(1e-12) * 100.0).max(0.0);
                println!(
                    "    -> {name}: coverage rel-err {rel:.2}% vs exact, speedup {:.2}x",
                    exact_p50.expect("exact strategy measured first") / r.p50_secs.max(1e-12)
                );
                approx.push((name, r.p50_secs, rel));
            }
        }
    }
    let exact_p50 = exact_p50.expect("strategy table contains exact");
    let mean_rel = approx.iter().map(|&(_, _, e)| e).sum::<f64>() / approx.len() as f64;
    let best = approx.iter().map(|&(_, p50, _)| p50).fold(f64::INFINITY, f64::min);
    println!(
        "    -> sweep aggregate: rel-err {mean_rel:.2}% (bound 5%), best approx p50 {} vs exact {}",
        format_secs(best),
        format_secs(exact_p50)
    );
    assert!(
        mean_rel <= 5.0,
        "approximate selection sweep aggregate rel-err {mean_rel:.2}% exceeds 5%"
    );
    if !quick {
        assert!(
            best < exact_p50,
            "at n={n_sel} (>=10^5) an approximate strategy must beat exact wall-clock"
        );
    }
    std::fs::remove_dir_all(root.parent().unwrap()).ok();

    bench_util::flush_json()?;
    Ok(())
}
