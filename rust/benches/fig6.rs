//! Figure 6 — (a) the union of the P mini-batch coresets captures the full
//! gradient better than individual coresets (errors cancel); (b) the
//! normalized bias ε = ‖E[ξ]‖/‖∇L‖ stays < 1 for CREST across training but
//! blows up for stale CRAIG coresets (the convergence condition of
//! Theorem 4.1 Case 1 vs Case 2).

use anyhow::Result;
use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::coordinator::sources::full_embeddings;
use crest::coreset::{craig, facility, MiniBatchCoreset};
use crest::metrics::gradprobe;
use crest::model::init_params;
use crest::opt::LrSchedule;
use crest::train::TrainState;
use crest::util::rng::Rng;
use crest::util::stats;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };
    let ds = &splits.train;
    let (m, r, p_dim) = (rt.man.m, rt.man.r, rt.man.p_dim);
    let p_count = 5usize;

    let cfg = crest::config::ExperimentConfig::preset(variant, Method::random(), seed)?;
    let sched = LrSchedule::paper_default(cfg.base_lr);
    let mut rng = Rng::new(seed ^ 0x66);
    let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;

    // stale CRAIG coreset selected at step 0 (for panel b)
    let (gl0, al0, _) = full_embeddings(&rt, &state.params, ds)?;
    let stale = craig::craig_select(&al0, &gl0, ds.n() / 10, &mut rng);
    let stale_gamma = craig::craig_batch_gamma(&stale);

    println!("# Fig 6a/6b — coreset-union error and normalized bias ε ({variant})");
    println!("{:>6} {:>14} {:>14} {:>12} {:>12} {:>10}", "step",
             "mean indiv err", "union err", "ε crest", "ε craig", "|∇L|");
    let total = 400usize;
    let checkpoints = [0usize, 20, 60, 150, 399];
    let mut cp = 0;
    for step in 0..total {
        if cp < checkpoints.len() && step == checkpoints[cp] {
            cp += 1;
            let full = gradprobe::full_gradient(&rt, &state.params, ds)?;
            let full_norm = stats::norm2(&full);
            // P mini-batch coresets: individual + union errors
            let mut union = vec![0.0f64; p_dim];
            let mut indiv_errs = Vec::new();
            for _ in 0..p_count {
                let pool = rng.sample_indices(ds.n(), r);
                let (x, y) = ds.batch(&pool);
                let (gl, al, _) = rt.grad_embed(&state.params, &x, &y)?;
                let sel = facility::facility_location_prod(&al, &gl, m);
                let mb = MiniBatchCoreset::from_selection(&sel, &pool, m);
                let g = gradprobe::batch_gradient(&rt, &state.params, ds, &mb.idx, &mb.gamma)?;
                indiv_errs.push(gradprobe::gradient_error(&g, &full) as f32);
                for (u, &v) in union.iter_mut().zip(&g) {
                    *u += v as f64 / p_count as f64;
                }
            }
            let union_f: Vec<f32> = union.iter().map(|&v| v as f32).collect();
            let union_err = gradprobe::gradient_error(&union_f, &full);
            // normalized bias ε for crest (union) and the stale craig coreset
            let eps_crest = union_err / full_norm.max(1e-9);
            let mut craig_acc = vec![0.0f64; p_dim];
            let chunks = stale.idx.len() / m;
            for c in 0..chunks {
                let idx: Vec<usize> = stale.idx[c * m..(c + 1) * m].to_vec();
                let gam: Vec<f32> = stale_gamma[c * m..(c + 1) * m].to_vec();
                let g = gradprobe::batch_gradient(&rt, &state.params, ds, &idx, &gam)?;
                for (a, &v) in craig_acc.iter_mut().zip(&g) {
                    *a += v as f64 / chunks as f64;
                }
            }
            let craig_f: Vec<f32> = craig_acc.iter().map(|&v| v as f32).collect();
            let eps_craig = gradprobe::gradient_error(&craig_f, &full) / full_norm.max(1e-9);
            println!("{:>6} {:>14.4} {:>14.4} {:>12.3} {:>12.3} {:>10.4}",
                     step, stats::mean(&indiv_errs), union_err, eps_crest, eps_craig, full_norm);
        }
        let idx = rng.sample_indices(ds.n(), m);
        let lr = sched.lr_at(step, total);
        state.step_batch(&rt, ds, &idx, &vec![1.0; m], lr, cfg.weight_decay)?;
    }
    Ok(())
}
