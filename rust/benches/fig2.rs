//! Figure 2 — normalized wall-clock run time vs test accuracy for CREST,
//! Random and the baselines, per variant (the speedup headline).
//!
//! Two cost axes are reported: wall-clock on this substrate, and the
//! hardware-independent backprop count (on the paper's GPU
//! testbed training dominates; on a tiny-MLP CPU substrate selection
//! overhead weighs more, so backprops are the primary speedup metric).

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    println!("# Fig 2 — accuracy and cost, normalized to full-data training");
    let methods = [
        Method::full(),
        Method::random(),
        Method::crest(),
        Method::craig(),
    ];
    for variant in sc::variants() {
        let seed = 1;
        let Some((rt, splits)) = sc::load(&variant, seed) else { return Ok(()) };
        let mut table = Table::new(&[
            "method", "test acc", "norm acc", "norm wall", "norm backprops", "backprop speedup",
        ]);
        let mut full: Option<(f32, f64, u64)> = None;
        for &method in &methods {
            // CRAIG's full-data selection is prohibitively slow on the two
            // larger corpora — the paper makes the same scaling argument
            // (it cannot run on SNLI at all).
            if method == Method::craig() && splits.train.n() > 10_000 {
                table.row(&["craig".into(), "-".into(), "(does not scale)".into(),
                            "-".into(), "-".into(), "-".into()]);
                continue;
            }
            let rep = sc::cell(&rt, &splits, &variant, method, seed, |_| {})?;
            if method == Method::full() {
                full = Some((rep.final_test_acc, rep.total_secs, rep.backprops));
            }
            let (fa, fs, fb) = full.expect("full runs first");
            table.row(&[
                rep.method.clone(),
                format!("{:.4}", rep.final_test_acc),
                format!("{:.3}", rep.final_test_acc / fa),
                format!("{:.3}", rep.total_secs / fs),
                format!("{:.3}", rep.backprops as f64 / fb as f64),
                format!("{:.1}x", fb as f64 / rep.backprops as f64),
            ]);
        }
        println!("\n## {variant}");
        print!("{}", table.render());
    }
    Ok(())
}
