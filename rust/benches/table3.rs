//! Table 3 — Ablation of CREST's components on the cifar10 proxy:
//! CREST-FIRST (first-order model), w/o smoothing, w/o exclusion, full.
//!
//! Expected shape (paper): full CREST has the lowest relative error with
//! the fewest coreset updates; first-order and unsmoothed variants update
//! more and do worse.

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;
use crest::util::stats;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    println!("# Table 3 — CREST component ablations, {variant} ({} seeds)", sc::seeds().len());
    let rows: [(&str, Box<dyn Fn(&mut crest::config::ExperimentConfig)>); 4] = [
        ("CREST-FIRST", Box::new(|c| c.crest.second_order = false)),
        ("CREST w/o smooth", Box::new(|c| c.crest.smooth = false)),
        ("CREST w/o excluding", Box::new(|c| c.crest.exclude = false)),
        ("CREST", Box::new(|_| {})),
    ];
    let mut table = Table::new(&["algorithm", "rel. error %", "# updates"]);
    let mut per_row: Vec<(Vec<f32>, Vec<f32>)> = vec![(Vec::new(), Vec::new()); rows.len()];
    for seed in sc::seeds() {
        let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };
        let full = sc::cell(&rt, &splits, variant, Method::full(), seed, |_| {})?;
        for (ri, (_, patch)) in rows.iter().enumerate() {
            let rep = sc::cell(&rt, &splits, variant, Method::crest(), seed, |c| patch(c))?;
            per_row[ri].0.push(sc::rel_err(rep.final_test_acc, full.final_test_acc));
            per_row[ri].1.push(rep.n_selection_updates as f32);
        }
    }
    for (ri, (name, _)) in rows.iter().enumerate() {
        table.row(&[
            name.to_string(),
            sc::fmt_mean_std(&per_row[ri].0),
            format!("{:.0}", stats::mean(&per_row[ri].1)),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
