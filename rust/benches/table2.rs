//! Table 2 — Average wall-clock time of CREST's components on the
//! cifar100 proxy: per-mini-batch selection (CREST vs CRAIG-style
//! full-data selection), quadratic loss approximation, and ρ-check.
//!
//! The CREST cell runs through the sweep orchestrator, so it can be
//! restored from a checkpoint (`CREST_SWEEP_CKPT=<dir>`) instead of
//! re-training; the micro selection timings always run live.
//!
//! Expected shape (paper): CREST selection ≫ faster than CRAIG selection;
//! the ρ-check is the most expensive CREST component.

use std::time::Instant;

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::coordinator::sources::full_embeddings;
use crest::coreset::facility;
use crest::coreset::MiniBatchCoreset;
use crest::model::init_params;
use crest::report::Table;
use crest::runtime::Runtime;
use crest::sweep::{self, SweepGrid, SweepSpec};
use crest::train::TrainState;
use crest::util::rng::Rng;

fn crest_selection_time(rt: &Runtime, splits: &crest::data::Splits) -> anyhow::Result<(f64, f64)> {
    // time one mini-batch coreset selection (embedding + greedy) and one
    // CRAIG-style full-data selection, at matched model state
    let mut rng = Rng::new(7);
    let state = TrainState::new(rt, &init_params(&rt.man, &mut rng))?;
    let ds = &splits.train;
    let (r, m) = (rt.man.r, rt.man.m);
    // CREST: selection of ONE mini-batch coreset from one random subset
    let reps = 8;
    let t0 = Instant::now();
    for _ in 0..reps {
        let pool = rng.sample_indices(ds.n(), r);
        let (x, y) = ds.batch(&pool);
        let (gl, al, _) = rt.grad_embed(&state.params, &x, &y)?;
        let sel = facility::facility_location_prod(&al, &gl, m);
        let _ = MiniBatchCoreset::from_selection(&sel, &pool, m);
    }
    let crest_sel = t0.elapsed().as_secs_f64() / reps as f64;
    // CRAIG: full-data embedding + stochastic greedy for k = 10% of n,
    // amortized per mini-batch drawn from it (k/m batches per epoch)
    let k = ds.n() / 10;
    let t0 = Instant::now();
    let (gl, al, _) = full_embeddings(rt, &state.params, ds)?;
    let _sel = crest::coreset::craig::craig_select(&al, &gl, k, &mut rng);
    let craig_total = t0.elapsed().as_secs_f64();
    let craig_per_batch = craig_total / (k as f64 / m as f64);
    Ok((crest_sel, craig_per_batch))
}

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let variant = "cifar100-proxy";
    println!("# Table 2 — mean component times, {variant} (batch size = m)");
    let Some((rt, splits)) = sc::load(variant, 1) else { return Ok(()) };
    let (crest_sel, craig_sel) = crest_selection_time(&rt, &splits)?;

    // loss approximation + checking threshold measured inside a real run,
    // scheduled (and optionally checkpointed) through the sweep orchestrator
    let mut spec = SweepSpec::new(
        SweepGrid {
            variants: vec![variant.to_string()],
            methods: vec![Method::crest()],
            seeds: vec![1],
            budgets: vec![0.1],
        },
        sc::epochs_full(),
    );
    spec.artifact_root = sc::artifact_root();
    spec.checkpoint_dir = sc::checkpoint_dir();
    let outcome = sweep::run(&spec)?;
    let rep = &outcome.cells[0].report;
    let n_up = rep.n_selection_updates.max(1) as f64;
    let n_checks = rep.rho_history.len().max(1) as f64;

    let mut table = Table::new(&["step", "time (seconds)"]);
    table.row(&["selection (CREST, per mini-batch)".into(), format!("{crest_sel:.4}")]);
    table.row(&["selection (CRAIG, per mini-batch equiv)".into(), format!("{craig_sel:.4}")]);
    table.row(&["loss approximation (per update)".into(),
                format!("{:.4}", rep.approx_secs / n_up)]);
    table.row(&["checking threshold (per ρ-check)".into(),
                format!("{:.4}", rep.check_secs / n_checks)]);
    print!("{}", table.render());
    println!("\n(CREST updates: {}, ρ-checks: {})", rep.n_selection_updates, rep.rho_history.len());
    Ok(())
}
