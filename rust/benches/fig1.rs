//! Figure 1 — the CRAIG pathology that motivates CREST.
//!
//! (a) test-accuracy curves: CRAIG's per-epoch 10% coresets vs Random vs
//!     Full (CRAIG fluctuates well below Random);
//! (b) gradient error of a stale coreset: ‖g_{t,S} − ∇L(w_t)‖ grows within
//!     a few iterations of selection;
//! (c,d) bias and variance of weighted mini-batches from the stale coreset
//!     vs CREST mini-batch coresets vs random mini-batches.

use anyhow::Result;
use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::coordinator::sources::full_embeddings;
use crest::coreset::{craig, facility, MiniBatchCoreset};
use crest::metrics::gradprobe;
use crest::model::init_params;
use crest::opt::LrSchedule;
use crest::runtime::Runtime;
use crest::train::TrainState;
use crest::util::rng::Rng;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };
    let ds = &splits.train;

    // ---------------- (a) accuracy curves ----------------
    println!("# Fig 1a — test accuracy vs step (10% budget)");
    println!("{:>8} {:>10} {:>10} {:>10}", "step", "craig", "random", "full");
    let craig_rep = sc::cell(&rt, &splits, variant, Method::craig(), seed, |_| {})?;
    let rand_rep = sc::cell(&rt, &splits, variant, Method::random(), seed, |_| {})?;
    let full_rep = sc::cell(&rt, &splits, variant, Method::full(), seed, |_| {})?;
    for i in 0..craig_rep.history.len().min(rand_rep.history.len()) {
        let c = &craig_rep.history[i];
        let r = &rand_rep.history[i];
        // full has 10x more steps; show its value at the same eval index
        let f = full_rep.history.get(i).map(|p| p.test_acc).unwrap_or(f32::NAN);
        println!("{:>8} {:>10.4} {:>10.4} {:>10.4}", c.step, c.test_acc, r.test_acc, f);
    }

    // ------------- (b,c,d) stale-coreset gradient quality -------------
    println!("\n# Fig 1b/1c/1d — stale CRAIG coreset vs CREST mini-batch coresets");
    let mut rng = Rng::new(seed ^ 0x51);
    let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;
    let (m, r) = (rt.man.m, rt.man.r);
    let cfg = crest::config::ExperimentConfig::preset(variant, Method::random(), seed)?;
    let sched = LrSchedule::paper_default(cfg.base_lr);
    let total = 400usize;
    // select a CRAIG coreset ONCE at step 0 (the stale coreset of Fig. 1b)
    let (gl0, al0, _) = full_embeddings(&rt, &state.params, ds)?;
    let k = ds.n() / 10;
    let stale = craig::craig_select(&al0, &gl0, k, &mut rng);
    let stale_gamma = craig::craig_batch_gamma(&stale);

    let stale_coreset_grad = |rt: &Runtime, state: &TrainState| -> Result<Vec<f32>> {
        // weighted coreset mean gradient, chunked over m-batches
        let mut acc = vec![0.0f64; rt.man.p_dim];
        let chunks = stale.idx.len() / m;
        for c in 0..chunks {
            let idx: Vec<usize> = stale.idx[c * m..(c + 1) * m].to_vec();
            let gam: Vec<f32> = stale_gamma[c * m..(c + 1) * m].to_vec();
            let g = gradprobe::batch_gradient(rt, &state.params, ds, &idx, &gam)?;
            for (a, &v) in acc.iter_mut().zip(&g) {
                *a += v as f64 / chunks as f64;
            }
        }
        Ok(acc.into_iter().map(|v| v as f32).collect())
    };

    println!("{:>6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}", "step",
             "stale err", "craig bias", "craig var", "crest bias", "crest var", "|∇L|");
    let checkpoints = [0usize, 20, 60, 150, 399];
    let k_samples = 16;
    let mut cp = 0;
    for step in 0..total {
        if cp < checkpoints.len() && step == checkpoints[cp] {
            cp += 1;
            let full = gradprobe::full_gradient(&rt, &state.params, ds)?;
            let stale_err = gradprobe::gradient_error(&stale_coreset_grad(&rt, &state)?, &full);
            let mut rng_a = rng.split();
            let craig_stats = gradprobe::bias_variance(&rt, &state.params, ds, &full,
                k_samples, || {
                    // weighted mini-batch sampled from the stale coreset
                    let picks = rng_a.sample_indices(stale.idx.len(), m);
                    let idx: Vec<usize> = picks.iter().map(|&p| stale.idx[p]).collect();
                    let gam: Vec<f32> = picks.iter().map(|&p| stale_gamma[p]).collect();
                    (idx, gam)
                })?;
            let mut rng_b = rng.split();
            let crest_stats = gradprobe::bias_variance(&rt, &state.params, ds, &full,
                k_samples, || {
                    let pool = rng_b.sample_indices(ds.n(), r);
                    let (x, y) = ds.batch(&pool);
                    let (gl, al, _) = rt.grad_embed(&state.params, &x, &y).unwrap();
                    let sel = facility::facility_location_prod(&al, &gl, m);
                    let mb = MiniBatchCoreset::from_selection(&sel, &pool, m);
                    (mb.idx, mb.gamma)
                })?;
            println!("{:>6} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
                     step, stale_err, craig_stats.bias, craig_stats.variance,
                     crest_stats.bias, crest_stats.variance, craig_stats.full_norm);
        }
        let idx = rng.sample_indices(ds.n(), m);
        let lr = sched.lr_at(step, total);
        state.step_batch(&rt, ds, &idx, &vec![1.0; m], lr, cfg.weight_decay)?;
    }
    Ok(())
}
