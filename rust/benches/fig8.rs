//! Figure 8 — CREST mini-batch coresets of size m selected from random
//! subsets of size r behave like *large* random batches of size r:
//! relative error of (i) random batches of size m, (ii) CREST coresets of
//! size m (from size-r subsets), (iii) emulated random batches of size r,
//! all under the same backprop budget.
//!
//! The size-r random run is emulated host-side: its gradient is the exact
//! average of r/m compiled batch gradients, applied with a host SGD+momentum
//! mirror (same math as the train_step artifact), consuming r backprops per
//! step.

use anyhow::Result;
use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::metrics::gradprobe;
use crest::model::init_params;
use crest::opt::{Budget, LrSchedule};
use crest::report::Table;
use crest::train::evaluate;
use crest::util::rng::Rng;
use crest::util::stats;

fn main() -> Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };
    let ds = &splits.train;
    let (m, r) = (rt.man.m, rt.man.r);
    let cfg = crest::config::ExperimentConfig::preset(variant, Method::random(), seed)?;

    // (i) random-m and (ii) crest via the coordinator
    let full = sc::cell(&rt, &splits, variant, Method::full(), seed, |_| {})?;
    let rand_m = sc::cell(&rt, &splits, variant, Method::random(), seed, |_| {})?;
    let crest_rep = sc::cell(&rt, &splits, variant, Method::crest(), seed, |_| {})?;

    // (iii) emulated random-r: host-side SGD with exact size-r gradients
    let mut rng = Rng::new(seed ^ 0x88);
    let mut params = init_params(&rt.man, &mut rng);
    let mut mom = vec![0.0f32; rt.man.p_dim];
    let mut budget = Budget::fraction_of_full(ds.n(), sc::epochs_full(), cfg.budget_frac);
    let steps = budget.steps(r).max(1);
    let sched = LrSchedule::paper_default(cfg.base_lr);
    // large batches get the same √(r/m) step-size scaling CREST uses
    let lr_mult = ((r as f32) / (m as f32)).sqrt();
    let mut step = 0usize;
    while budget.charge(r) {
        let lr = sched.lr_at(step, steps) * lr_mult;
        let pool = rng.sample_indices(ds.n(), r);
        let mut grad = vec![0.0f64; rt.man.p_dim];
        let plit = rt.params_from_host(&params)?;
        for chunk in pool.chunks(m) {
            let g = gradprobe::batch_gradient(&rt, &plit, ds, chunk, &vec![1.0; m])?;
            for (a, &v) in grad.iter_mut().zip(&g) {
                *a += v as f64 / (r / m) as f64;
            }
        }
        // host mirror of the train_step update (momentum 0.9 + wd)
        for i in 0..params.len() {
            let g = grad[i] as f32 + cfg.weight_decay * params[i];
            mom[i] = rt.man.momentum * mom[i] + g;
            params[i] -= lr * mom[i];
        }
        step += 1;
    }
    let plit = rt.params_from_host(&params)?;
    let big = evaluate(&rt, &plit, &splits.test)?;

    println!("# Fig 8 — relative error (%) @ 10% budget, {variant}");
    let mut table = Table::new(&["estimator", "test acc", "rel err %"]);
    for (name, acc) in [
        (format!("random m={m}"), rand_m.final_test_acc),
        (format!("crest m={m} (r={r})"), crest_rep.final_test_acc),
        (format!("random r={r} (emulated, {} steps)", step), big.accuracy),
    ] {
        table.row(&[
            name,
            format!("{acc:.4}"),
            format!("{:.2}", sc::rel_err(acc, full.final_test_acc)),
        ]);
    }
    print!("{}", table.render());
    println!("full acc {:.4}; expected shape: crest ≈ random-r < random-m rel err",
             full.final_test_acc);
    let _ = stats::mean(&[0.0]); // keep stats linked for doc parity
    Ok(())
}
