//! Table 1 — Relative error (%) of each method vs full-data training at a
//! 10% budget, per variant, plus the tuned (τ, h) pairs (Table 6).
//!
//! Runs as one sweep through the orchestrator (`crest::sweep`): the full
//! (variant × method × seed) grid is scheduled over the thread pool, can
//! resume from per-cell checkpoints (`CREST_SWEEP_CKPT=<dir>`), and the
//! mean±std rel-err cells come from the sweep aggregator.
//!
//! Expected shape (paper): CREST ≤ Random < GRADMATCH < CRAIG, GLISTER
//! worst; SGD† well above Random.

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;
use crest::sweep::{self, SweepGrid, SweepSpec};
use crest::util::stats;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    // column order of the paper's Table 1
    let methods = [
        Method::sgd_truncated(),
        Method::random(),
        Method::craig(),
        Method::gradmatch(),
        Method::glister(),
        Method::crest(),
    ];
    let variants: Vec<String> = sc::variants().into_iter().filter(|v| sc::known(v)).collect();
    if variants.is_empty() {
        return Ok(());
    }

    // one grid: the full reference plus every method, all seeds
    let mut grid_methods = vec![Method::full()];
    grid_methods.extend(methods);
    let mut spec = SweepSpec::new(
        SweepGrid {
            variants: variants.clone(),
            methods: grid_methods,
            seeds: sc::seeds(),
            budgets: vec![0.1],
        },
        sc::epochs_full(),
    );
    spec.artifact_root = sc::artifact_root();
    spec.checkpoint_dir = sc::checkpoint_dir();
    let outcome = sweep::run(&spec)?;

    println!(
        "# Table 1 — relative error (%) @ 10% budget (mean±std over {} seeds)",
        sc::seeds().len()
    );
    let mut table = Table::new(&[
        "variant", "sgd†", "random", "craig", "gradmatch", "glister", "crest", "full acc",
    ]);
    for variant in &variants {
        let mut row = vec![variant.clone()];
        for method in &methods {
            let cell = outcome
                .rows
                .iter()
                .find(|r| r.variant == *variant && r.method == method.name());
            row.push(cell.map(|r| r.fmt_rel_err()).unwrap_or_else(|| "-".to_string()));
        }
        let full_accs: Vec<f32> = outcome
            .cells
            .iter()
            .filter(|c| c.key.variant == *variant && c.key.method == Method::full())
            .map(|c| c.report.final_test_acc * 100.0)
            .collect();
        row.push(format!("{:.2}", stats::mean(&full_accs)));
        table.row(&row);
    }
    print!("{}", table.render());

    println!("\n# Table 6 — tuned hyperparameters per variant");
    let mut t6 = Table::new(&["variant", "tau", "h"]);
    for variant in &variants {
        let cfg = crest::config::ExperimentConfig::preset(variant, Method::crest(), 0)?;
        t6.row(&[variant.clone(), format!("{}", cfg.tau), format!("{}", cfg.h_mult)]);
    }
    print!("{}", t6.render());
    Ok(())
}
