//! Table 1 — Relative error (%) of each method vs full-data training at a
//! 10% budget, per variant, plus the tuned (τ, h) pairs (Table 6).
//!
//! Expected shape (paper): CREST ≤ Random < GRADMATCH < CRAIG, GLISTER
//! worst; SGD† well above Random.

use crest::bench_util::scenario as sc;
use crest::config::MethodKind;
use crest::report::Table;
use crest::util::stats;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let methods = [
        MethodKind::SgdTruncated,
        MethodKind::Random,
        MethodKind::Craig,
        MethodKind::GradMatch,
        MethodKind::Glister,
        MethodKind::Crest,
    ];
    println!("# Table 1 — relative error (%) @ 10% budget (mean±std over {} seeds)",
             sc::seeds().len());
    let mut table = Table::new(&[
        "variant", "sgd†", "random", "craig", "gradmatch", "glister", "crest", "full acc",
    ]);
    for variant in sc::variants() {
        let mut rel: Vec<Vec<f32>> = vec![Vec::new(); methods.len()];
        let mut full_accs = Vec::new();
        for seed in sc::seeds() {
            let Some((rt, splits)) = sc::load(&variant, seed) else { return Ok(()) };
            let full = sc::cell(&rt, &splits, &variant, MethodKind::Full, seed, |_| {})?;
            full_accs.push(full.final_test_acc * 100.0);
            for (mi, &method) in methods.iter().enumerate() {
                let rep = sc::cell(&rt, &splits, &variant, method, seed, |_| {})?;
                rel[mi].push(sc::rel_err(rep.final_test_acc, full.final_test_acc));
            }
        }
        let mut row = vec![variant.clone()];
        row.extend(rel.iter().map(|v| sc::fmt_mean_std(v)));
        row.push(format!("{:.2}", stats::mean(&full_accs)));
        table.row(&row);
    }
    print!("{}", table.render());

    println!("\n# Table 6 — tuned hyperparameters per variant");
    let mut t6 = Table::new(&["variant", "tau", "h"]);
    for variant in sc::variants() {
        let cfg = crest::config::ExperimentConfig::preset(&variant, MethodKind::Crest, 0)?;
        t6.row(&[variant.clone(), format!("{}", cfg.tau), format!("{}", cfg.h_mult)]);
    }
    print!("{}", t6.render());
    Ok(())
}
