//! Figure 3 — CREST vs greedy mini-batch selection: how much of the
//! per-step-greedy accuracy does CREST keep, with what fraction of its
//! selection updates?
//!
//! Expected shape (paper): CREST preserves ~95-99% of greedy's accuracy
//! with a few % of its update count.

use crest::api::Method;
use crest::bench_util::scenario as sc;
use crest::report::Table;
use crest::util::stats;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    println!("# Fig 3 — normalized accuracy and update count vs greedy-per-batch ({} seeds)",
             sc::seeds().len());
    let mut table = Table::new(&[
        "variant", "acc ratio (crest/greedy)", "update ratio", "crest updates", "greedy updates",
    ]);
    for variant in sc::variants() {
        let (mut accs, mut upds) = (Vec::new(), Vec::new());
        let (mut cu, mut gu) = (Vec::new(), Vec::new());
        for seed in sc::seeds() {
            let Some((rt, splits)) = sc::load(&variant, seed) else { return Ok(()) };
            let crest_rep = sc::cell(&rt, &splits, &variant, Method::crest(), seed, |_| {})?;
            let greedy_rep =
                sc::cell(&rt, &splits, &variant, Method::greedy_per_batch(), seed, |_| {})?;
            accs.push(crest_rep.final_test_acc / greedy_rep.final_test_acc.max(1e-6));
            upds.push(crest_rep.n_selection_updates as f32
                / greedy_rep.n_selection_updates.max(1) as f32);
            cu.push(crest_rep.n_selection_updates as f32);
            gu.push(greedy_rep.n_selection_updates as f32);
        }
        table.row(&[
            variant.clone(),
            format!("{:.3}", stats::mean(&accs)),
            format!("{:.3}", stats::mean(&upds)),
            format!("{:.0}", stats::mean(&cu)),
            format!("{:.0}", stats::mean(&gu)),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
