//! Figure 5 — average forgettability score of the examples CREST selects,
//! over the course of training, with and without learned-example exclusion,
//! against the Random baseline.
//!
//! Expected shape (paper): CREST's selected examples get *harder* over
//! training (score rises); exclusion amplifies the effect; Random stays
//! flat at the dataset mean.

use crest::api::Method;
use crest::bench_util::scenario as sc;

fn series(rep: &crest::report::RunReport, buckets: usize) -> Vec<f32> {
    // bucket the (step, score) series into equal step ranges
    let total = rep.steps.max(1);
    let mut sums = vec![0.0f64; buckets];
    let mut counts = vec![0usize; buckets];
    for &(step, score) in &rep.forget_of_selected {
        let b = (step * buckets / total).min(buckets - 1);
        sums[b] += score as f64;
        counts[b] += 1;
    }
    (0..buckets)
        .map(|b| if counts[b] > 0 { (sums[b] / counts[b] as f64) as f32 } else { f32::NAN })
        .collect()
}

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };

    let crest_ex = sc::cell(&rt, &splits, variant, Method::crest(), seed, |_| {})?;
    let crest_no =
        sc::cell(&rt, &splits, variant, Method::crest(), seed, |c| c.crest.exclude = false)?;
    let random = sc::cell(&rt, &splits, variant, Method::random(), seed, |_| {})?;

    println!("# Fig 5 — mean final forgettability of selected examples ({variant})");
    println!("{:>12} {:>16} {:>16} {:>12}", "train frac", "crest+exclude", "crest no-excl", "random");
    let buckets = 8;
    let (a, b, c) = (series(&crest_ex, buckets), series(&crest_no, buckets), series(&random, buckets));
    for i in 0..buckets {
        println!(
            "{:>12.2} {:>16.3} {:>16.3} {:>12.3}",
            (i as f32 + 0.5) / buckets as f32,
            a[i],
            b[i],
            c[i]
        );
    }
    println!("\n(excluded by end: with-exclusion {} / {} examples)",
             crest_ex.n_excluded, splits.train.n());
    Ok(())
}
