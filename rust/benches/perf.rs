//! §Perf microbenches — the per-layer hot paths:
//!
//!   L3: facility-location greedy (host lazy vs stochastic), batch assembly
//!   L2/runtime: train_step, grad_embed, eval_chunk, hess_probe executions
//!   L1 (compiled): in-graph select_greedy vs host greedy
//!
//! Run with `cargo bench --bench perf`. Quick CI mode: `CREST_BENCH_QUICK=1`
//! (reduced sizes + capped reps); machine-readable trajectory:
//! `CREST_BENCH_JSON=<path>`. Ops with a known arithmetic cost report
//! GFLOP/s alongside p50/p95 (approximate op counts — matmul passes and
//! dot panels only); `crest bench-diff` gates fresh records against the
//! committed `BENCH_perf.json` baseline.

use crest::bench_util::scenario as sc;
use crest::bench_util::{self, bench_recorded, bench_recorded_flops, section};
use crest::coreset::facility;
use crest::kernel;
use crest::model::init_params;
use crest::runtime::manifest::VariantManifest;
use crest::tensor::MatF32;
use crest::train::TrainState;
use crest::util::pool;
use crest::util::rng::Rng;

fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> MatF32 {
    let mut m = MatF32::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

/// Approximate flop count of `passes` matmul-equivalent passes through the
/// manifest's MLP at the given batch size (2 flops per MAC).
fn mlp_flops(man: &VariantManifest, batch: usize, passes: u64) -> u64 {
    let mut dims = vec![man.d_in];
    dims.extend(man.hidden.iter().copied());
    dims.push(man.classes);
    let macs: u64 = dims.windows(2).map(|w| (w[0] * w[1]) as u64).sum();
    passes * 2 * macs * batch as u64
}

/// L0 kernel microbenches, one record per `(kernel, ISA)` pair — the
/// SIMD-vs-scalar comparison the dispatch layer is judged by. Shapes are
/// fixed (independent of quick mode, odd to exercise remainder tiles) and
/// the pool is pinned to one worker so records are comparable across
/// machines with different core counts.
fn kernel_benches(rng: &mut Rng) {
    section("L0 kernels: scalar vs SIMD microbenches (threads pinned to 1)");
    let (m, k, n) = (96usize, 67usize, 130usize);
    let x = random_mat(rng, m, k);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let d = random_mat(rng, m, n);
    let wt: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut act = random_mat(rng, m, k);
    for v in act.data.iter_mut() {
        *v = v.max(0.0); // half-zero ReLU activation pattern for the masked kernel
    }
    let (bn, bc, bh) = (768usize, 10usize, 66usize);
    let g = random_mat(rng, bn, bc);
    let a = random_mat(rng, bn, bh);
    let gsq: Vec<f32> = (0..bn).map(|i| kernel::dot4(g.row(i), g.row(i))).collect();
    let asq: Vec<f32> = (0..bn).map(|i| kernel::dot4(a.row(i), a.row(i))).collect();
    pool::with_threads(1, || {
        for isa in kernel::available_isas() {
            let mm_flops = 2 * (m * k * n) as u64;
            let mut out = MatF32::zeros(m, n);
            bench_recorded_flops(
                &format!("kernel add_matmul m={m} k={k} n={n} isa={isa}"),
                3,
                20,
                mm_flops,
                || kernel::add_matmul_isa(isa, &mut out, &x, &w, n),
            );
            let mut outk = MatF32::zeros(m, k);
            bench_recorded_flops(
                &format!("kernel add_matmul_nt m={m} k={k} n={n} isa={isa}"),
                3,
                20,
                mm_flops,
                || kernel::add_matmul_nt_isa(isa, &mut outk, &d, &wt, n),
            );
            let mut outm = MatF32::zeros(m, k);
            bench_recorded_flops(
                &format!("kernel add_matmul_nt_masked m={m} k={k} n={n} isa={isa}"),
                3,
                20,
                mm_flops,
                || kernel::add_matmul_nt_masked_isa(isa, &mut outm, &d, &wt, n, &act),
            );
            let mut gw = vec![0.0f32; k * n];
            bench_recorded_flops(
                &format!("kernel accum_wgrad m={m} k={k} n={n} isa={isa}"),
                3,
                20,
                mm_flops,
                || kernel::accum_wgrad_isa(isa, &mut gw, &x, &d, n),
            );
            let mut db = vec![0.0f32; bn];
            bench_recorded_flops(
                &format!("kernel dot4_rows n={bn} d={bh} isa={isa}"),
                3,
                20,
                2 * (bn * bh) as u64,
                || kernel::dot4_rows_isa(isa, a.row(0), &a, 0..bn, &mut db),
            );
            bench_recorded_flops(
                &format!("kernel euclid_block n={bn} c={bc} isa={isa}"),
                3,
                20,
                (bn * (2 * bc + 4)) as u64,
                || kernel::euclid_block_isa(isa, &g, &gsq, 0, 0..bn, &mut db),
            );
            bench_recorded_flops(
                &format!("kernel prod_block n={bn} c={bc} h={bh} isa={isa}"),
                3,
                20,
                (bn * (2 * (bc + bh) + 6)) as u64,
                || kernel::prod_block_isa(isa, &a, &g, &asq, 0, 0..bn, &mut db),
            );
        }
    });
}

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let quick = bench_util::quick();
    let mut rng = Rng::new(42);

    kernel_benches(&mut rng);

    section("L3 host: facility-location greedy");
    let grid: &[(usize, usize, usize)] = if quick {
        &[(256, 10, 32)]
    } else {
        &[(256, 10, 32), (320, 40, 32), (512, 10, 64)]
    };
    for &(r, c, m) in grid {
        let g = random_mat(&mut rng, r, c);
        let a = random_mat(&mut rng, r, 64);
        bench_recorded(&format!("lazy greedy r={r} c={c} m={m}"), 2, 10, || {
            facility::facility_location(&g, m)
        });
        bench_recorded(&format!("lazy greedy prod r={r} h=64 m={m}"), 2, 10, || {
            facility::facility_location_prod(&a, &g, m)
        });
    }
    {
        let (r, c, m) = if quick { (1536, 10, 128) } else { (5120, 10, 512) };
        let g = random_mat(&mut rng, r, c);
        let a = random_mat(&mut rng, r, 64);
        let metric = facility::ProdMetric::new(&a, &g);
        let mut srng = Rng::new(7);
        bench_recorded(&format!("stochastic greedy n={r} m={m}"), 1, 3, || {
            facility::facility_location_stochastic(&metric, m, &mut srng)
        });
    }

    section("L3 host: facility gain scans (blocked distance kernels)");
    {
        // the dense O(n²·d) seeding scan — the kernel the block layer
        // accelerates; GFLOP/s counts both dot panels of the prod metric
        let (n, c, h) = if quick { (1024usize, 10usize, 64usize) } else { (2048, 10, 64) };
        let g = random_mat(&mut rng, n, c);
        let a = random_mat(&mut rng, n, h);
        let euclid = facility::EuclidMetric::new(&g);
        let prod = facility::ProdMetric::new(&a, &g);
        let mind: Vec<f32> = (0..n).map(|i| euclid.sqdist(0, i)).collect();
        let mind_prod: Vec<f32> = (0..n).map(|i| prod.sqdist(0, i)).collect();
        let nn = (n * n) as u64;
        bench_recorded_flops(
            &format!("gain scan euclid n={n} c={c}"),
            1,
            8,
            nn * (2 * c as u64 + 4),
            || facility::gain_scan(&euclid, &mind),
        );
        bench_recorded_flops(
            &format!("gain scan prod n={n} h={h} c={c}"),
            1,
            8,
            nn * (2 * (c + h) as u64 + 6),
            || facility::gain_scan(&prod, &mind_prod),
        );
        // opt-in Gram cache: one blocked precompute pass, then lookups
        bench_recorded_flops(
            &format!("gram precompute n={n} (prod metric)"),
            1,
            8,
            nn * (2 * (c + h) as u64 + 6),
            || facility::GramMetric::new(&prod),
        );
        let gram = facility::GramMetric::new(&prod);
        bench_recorded(&format!("gain scan gram-cached n={n}"), 1, 8, || {
            facility::gain_scan(&gram, &mind_prod)
        });
    }

    section("L3 host: batch assembly");
    if let Some((_, splits)) = sc::load("cifar10-proxy", 1) {
        let ds = splits.train;
        let idx: Vec<usize> = (0..32).map(|i| i * 37 % ds.n()).collect();
        bench_recorded("dataset.batch gather m=32", 10, 200, || ds.batch(&idx));
    }

    section("runtime: native backend executions");
    let variants: &[&str] =
        if quick { &["cifar10-proxy"] } else { &["cifar10-proxy", "cifar100-proxy"] };
    for &variant in variants {
        let Some((rt, splits)) = sc::load(variant, 1) else { continue };
        let ds = &splits.train;
        let mut rng = Rng::new(1);
        let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;
        let (m, r) = (rt.man.m, rt.man.r);
        let midx: Vec<usize> = (0..m).collect();
        let (mx, my) = ds.batch(&midx);
        let gamma = vec![1.0f32; m];
        let mom = rt.zero_momentum();
        bench_recorded_flops(
            &format!("{variant}: train_step"),
            3,
            30,
            mlp_flops(&rt.man, m, 3),
            || rt.train_step(&state.params, &mom, &mx, &my, &gamma, 0.01, 5e-4).unwrap(),
        );
        let ridx: Vec<usize> = (0..r).collect();
        let (rx, ry) = ds.batch(&ridx);
        bench_recorded_flops(
            &format!("{variant}: grad_embed r={r}"),
            3,
            20,
            mlp_flops(&rt.man, r, 1),
            || rt.grad_embed(&state.params, &rx, &ry).unwrap(),
        );
        let eidx: Vec<usize> = (0..rt.man.eval_chunk).map(|i| i % ds.n()).collect();
        let (ex, ey) = ds.batch(&eidx);
        bench_recorded_flops(
            &format!("{variant}: eval_chunk e={}", rt.man.eval_chunk),
            3,
            20,
            mlp_flops(&rt.man, rt.man.eval_chunk, 1),
            || rt.eval_chunk(&state.params, &ex, &ey).unwrap(),
        );
        let z = vec![1.0f32; rt.man.p_dim];
        bench_recorded_flops(
            &format!("{variant}: hess_probe"),
            2,
            10,
            mlp_flops(&rt.man, r, 7),
            || rt.hess_probe(&state.params, &rx, &ry, &z).unwrap(),
        );

        // L1 compiled greedy vs host greedy at identical inputs
        let (gl, al, _) = rt.grad_embed(&state.params, &rx, &ry)?;
        bench_recorded(&format!("{variant}: select_greedy (compiled)"), 2, 8, || {
            rt.select_greedy(&gl, &al).unwrap()
        });
        bench_recorded(&format!("{variant}: select greedy (host)"), 2, 8, || {
            facility::facility_location_prod(&al, &gl, m)
        });
    }

    bench_util::flush_json()?;
    Ok(())
}
