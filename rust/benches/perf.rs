//! §Perf microbenches — the per-layer hot paths:
//!
//!   L3: facility-location greedy (host lazy vs stochastic), batch assembly
//!   L2/runtime: train_step, grad_embed, eval_chunk, hess_probe executions
//!   L1 (compiled): in-graph select_greedy vs host greedy
//!
//! Run with `cargo bench --bench perf`.

use crest::bench_util::{bench, section};
use crest::bench_util::scenario as sc;
use crest::coreset::facility;
use crest::model::init_params;
use crest::tensor::MatF32;
use crest::train::TrainState;
use crest::util::rng::Rng;

fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> MatF32 {
    let mut m = MatF32::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let mut rng = Rng::new(42);

    section("L3 host: facility-location greedy");
    for &(r, c, m) in &[(256usize, 10usize, 32usize), (320, 40, 32), (512, 10, 64)] {
        let g = random_mat(&mut rng, r, c);
        let a = random_mat(&mut rng, r, 64);
        let res = bench(&format!("lazy greedy      r={r} c={c} m={m}"), 2, 10,
                        || facility::facility_location(&g, m));
        println!("{}", res.report());
        let res = bench(&format!("lazy greedy prod r={r} h=64 m={m}"), 2, 10,
                        || facility::facility_location_prod(&a, &g, m));
        println!("{}", res.report());
    }
    {
        let (r, c, m) = (5120usize, 10usize, 512usize);
        let g = random_mat(&mut rng, r, c);
        let a = random_mat(&mut rng, r, 64);
        let metric = facility::ProdMetric::new(&a, &g);
        let mut srng = Rng::new(7);
        let res = bench(&format!("stochastic greedy n={r} m={m}"), 1, 3,
                        || facility::facility_location_stochastic(&metric, m, &mut srng));
        println!("{}", res.report());
    }

    section("L3 host: batch assembly");
    {
        let variant = "cifar10-proxy";
        if let Some((_, splits)) = sc::load(variant, 1) {
            let ds = splits.train;
            let idx: Vec<usize> = (0..32).map(|i| i * 37 % ds.n()).collect();
            let res = bench("dataset.batch gather m=32", 10, 200, || ds.batch(&idx));
            println!("{}", res.report());
        }
    }

    section("runtime: compiled executions (PJRT CPU)");
    for variant in ["cifar10-proxy", "cifar100-proxy"] {
        let Some((rt, splits)) = sc::load(variant, 1) else { continue };
        let ds = &splits.train;
        let mut rng = Rng::new(1);
        let state = TrainState::new(&rt, &init_params(&rt.man, &mut rng))?;
        let (m, r) = (rt.man.m, rt.man.r);
        let midx: Vec<usize> = (0..m).collect();
        let (mx, my) = ds.batch(&midx);
        let gamma = vec![1.0f32; m];
        let mom = rt.zero_momentum();
        let res = bench(&format!("{variant}: train_step"), 3, 30,
                        || rt.train_step(&state.params, &mom, &mx, &my, &gamma, 0.01, 5e-4)
                            .unwrap());
        println!("{}", res.report());
        let ridx: Vec<usize> = (0..r).collect();
        let (rx, ry) = ds.batch(&ridx);
        let res = bench(&format!("{variant}: grad_embed r={r}"), 3, 20,
                        || rt.grad_embed(&state.params, &rx, &ry).unwrap());
        println!("{}", res.report());
        let eidx: Vec<usize> = (0..rt.man.eval_chunk).map(|i| i % ds.n()).collect();
        let (ex, ey) = ds.batch(&eidx);
        let res = bench(&format!("{variant}: eval_chunk e={}", rt.man.eval_chunk), 3, 20,
                        || rt.eval_chunk(&state.params, &ex, &ey).unwrap());
        println!("{}", res.report());
        let z = vec![1.0f32; rt.man.p_dim];
        let res = bench(&format!("{variant}: hess_probe"), 2, 10,
                        || rt.hess_probe(&state.params, &rx, &ry, &z).unwrap());
        println!("{}", res.report());

        // L1 compiled greedy vs host greedy at identical inputs
        let (gl, al, _) = rt.grad_embed(&state.params, &rx, &ry)?;
        let res = bench(&format!("{variant}: select_greedy (compiled)"), 2, 8,
                        || rt.select_greedy(&gl, &al).unwrap());
        println!("{}", res.report());
        let res = bench(&format!("{variant}: select greedy (host)"), 2, 8,
                        || facility::facility_location_prod(&al, &gl, m));
        println!("{}", res.report());
    }
    Ok(())
}
