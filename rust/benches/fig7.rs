//! Figure 7 — (a) accuracy of the examples CREST dropped as "learned",
//! tracked after they stop being trained on; (b) distribution of how often
//! each example appears in a training batch (long-tailed: not all examples
//! matter equally).

use crest::api::Method;
use crest::bench_util::scenario as sc;

fn main() -> anyhow::Result<()> {
    crest::util::logging::init();
    let variant = "cifar10-proxy";
    let seed = 1;
    let Some((rt, splits)) = sc::load(variant, seed) else { return Ok(()) };

    let rep = sc::cell(&rt, &splits, variant, Method::crest(), seed, |_| {})?;

    println!("# Fig 7a — accuracy of dropped examples over training ({variant})");
    if rep.dropped_acc_history.is_empty() {
        println!("(no examples were excluded in this run)");
    } else {
        println!("{:>8} {:>14}", "step", "dropped acc");
        for &(step, acc) in &rep.dropped_acc_history {
            println!("{:>8} {:>14.4}", step, acc);
        }
    }
    println!("excluded by end: {} / {}", rep.n_excluded, splits.train.n());

    println!("\n# Fig 7b — selection-count distribution (times in a training batch)");
    let counts = &rep.selection_counts;
    let max = counts.iter().copied().max().unwrap_or(0) as usize;
    // histogram over count buckets
    let buckets = [0usize, 1, 2, 4, 8, 16, 32, 64, usize::MAX];
    println!("{:>12} {:>10}", "times", "examples");
    for w in buckets.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let n = counts.iter().filter(|&&c| (c as usize) >= lo && (c as usize) < hi).count();
        let label = if hi == usize::MAX { format!("{lo}+") } else { format!("{lo}-{}", hi - 1) };
        println!("{:>12} {:>10}", label, n);
    }
    println!("max selections of one example: {max}");
    let never = counts.iter().filter(|&&c| c == 0).count();
    println!("never selected: {} / {} (the redundant mass)", never, counts.len());
    Ok(())
}
