//! Optimizer schedule + training-budget accounting.
//!
//! The paper's pipeline (§5 Training Setup): warm-start the learning rate
//! over the first 10% of training, then decay by 0.1× at 60% and 85%.
//! Budgets are counted in *backprops* (examples × steps), the
//! hardware-independent cost unit used for the 10%/20% budget comparisons.

/// Learning-rate schedule over a fixed horizon of steps.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    /// Constant learning rate.
    Const(f32),
    /// Linear warmup to `base` over `warmup_frac`, step decays afterwards:
    /// `decays` holds (progress_fraction, multiplier) pairs.
    WarmupStep { base: f32, warmup_frac: f32, decays: Vec<(f32, f32)> },
}

impl LrSchedule {
    /// The paper's vision-benchmark schedule.
    pub fn paper_default(base: f32) -> LrSchedule {
        LrSchedule::WarmupStep {
            base,
            warmup_frac: 0.10,
            decays: vec![(0.60, 0.1), (0.85, 0.1)],
        }
    }

    /// LR at `step` of `total` steps.
    pub fn lr_at(&self, step: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::WarmupStep { base, warmup_frac, decays } => {
                let total = total.max(1);
                let prog = step as f32 / total as f32;
                if *warmup_frac > 0.0 && prog < *warmup_frac {
                    // linear ramp, never exactly 0
                    return base * ((step + 1) as f32 / (*warmup_frac * total as f32)).min(1.0);
                }
                let mut lr = *base;
                for &(frac, mult) in decays {
                    if prog >= frac {
                        lr *= mult;
                    }
                }
                lr
            }
        }
    }
}

/// Backprop budget: `full_budget` is the cost of the full-data reference
/// run; methods stop when they have consumed `budget_frac` of it.
#[derive(Debug, Clone)]
pub struct Budget {
    /// examples × steps available to this run.
    pub total_backprops: u64,
    used: u64,
}

impl Budget {
    /// Budget for training `epochs_full` epochs over `n` examples with the
    /// given fraction (paper: 10% or 20%).
    pub fn fraction_of_full(n: usize, epochs_full: usize, frac: f32) -> Budget {
        let full = n as u64 * epochs_full as u64;
        Budget { total_backprops: (full as f64 * frac as f64) as u64, used: 0 }
    }

    /// Budget of exactly `total_backprops` backprops.
    pub fn exact(total_backprops: u64) -> Budget {
        Budget { total_backprops, used: 0 }
    }

    /// Charge a batch of `m` backprops. Returns false when the budget was
    /// already exhausted (the step should not run).
    pub fn charge(&mut self, m: usize) -> bool {
        if self.used >= self.total_backprops {
            return false;
        }
        self.used += m as u64;
        true
    }

    /// Backprops charged so far.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// True once the budget is spent.
    pub fn exhausted(&self) -> bool {
        self.used >= self.total_backprops
    }

    /// Number of size-m steps this budget affords in total.
    pub fn steps(&self, m: usize) -> usize {
        (self.total_backprops / m as u64) as usize
    }

    /// Fraction of the budget spent, in [0, 1].
    pub fn progress(&self) -> f32 {
        if self.total_backprops == 0 {
            1.0
        } else {
            (self.used as f64 / self.total_backprops as f64) as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const(0.05);
        assert_eq!(s.lr_at(0, 100), 0.05);
        assert_eq!(s.lr_at(99, 100), 0.05);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = LrSchedule::paper_default(0.1);
        let total = 1000;
        // early warmup below base, monotone
        let lr5 = s.lr_at(5, total);
        let lr50 = s.lr_at(50, total);
        assert!(lr5 < lr50 && lr50 <= 0.1);
        // after warmup: base
        assert_eq!(s.lr_at(200, total), 0.1);
        // after 60%: 0.01
        assert!((s.lr_at(700, total) - 0.01).abs() < 1e-6);
        // after 85%: 0.001
        assert!((s.lr_at(900, total) - 0.001).abs() < 1e-6);
    }

    #[test]
    fn warmup_never_zero() {
        let s = LrSchedule::paper_default(0.1);
        assert!(s.lr_at(0, 10_000) > 0.0);
    }

    #[test]
    fn budget_counts_and_exhausts() {
        let mut b = Budget::fraction_of_full(1000, 10, 0.1);
        assert_eq!(b.total_backprops, 1000);
        assert_eq!(b.steps(100), 10);
        let mut steps = 0;
        while b.charge(100) {
            steps += 1;
        }
        assert_eq!(steps, 10);
        assert!(b.exhausted());
        assert_eq!(b.progress(), 1.0);
    }

    #[test]
    fn zero_budget_runs_nothing() {
        let mut b = Budget::exact(0);
        assert!(!b.charge(1));
        assert!(b.exhausted());
    }
}
