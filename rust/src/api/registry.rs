//! The pluggable selection-method registry.
//!
//! Every training method — the builtins (full / random / SGD† / CREST /
//! CRAIG / GRADMATCH / GLISTER / greedy-per-batch / loss-topk) and any
//! method a downstream crate adds — is described by one [`MethodSpec`]:
//! its canonical name, CLI aliases, help text, the three behavior flags
//! the coordinator consults, and a factory producing the method's
//! [`BatchSource`]. The global [`MethodRegistry`] is the single table all
//! dispatch derives from: `--method` parsing and help, sweep-grid
//! expansion, `compare` rows, and report labels. Registering a new method
//! makes it usable in `train`, `compare`, and `sweep` with no edits to
//! any dispatch site.
//!
//! [`Method`] is the cheap `Copy` handle the rest of the crate passes
//! around where the old `MethodKind` enum used to go; it compares by
//! canonical name, which the registry guarantees unique.

use std::sync::{OnceLock, RwLock};

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::sources::BatchSource;
use crate::data::Dataset;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Borrowed per-run context handed to a [`MethodFactory`] when the
/// coordinator instantiates the method's [`BatchSource`].
#[derive(Clone, Copy)]
pub struct SourceCtx<'a> {
    /// Full cell configuration (budget, CREST knobs, thread counts).
    pub cfg: &'a ExperimentConfig,
    /// Execution runtime of the variant.
    pub rt: &'a Runtime,
    /// Training split the source draws batches from.
    pub train: &'a Dataset,
    /// Validation split (GLISTER's greedy objective needs it).
    pub val: &'a Dataset,
    /// Total training steps the run's budget affords.
    pub steps_total: usize,
}

/// Factory producing one run's [`BatchSource`] for a method. The `Rng` is
/// an independent stream split off the experiment seed; the returned
/// source may borrow from the [`SourceCtx`] for the life of the run.
pub type MethodFactory =
    Box<dyn for<'a> Fn(SourceCtx<'a>, Rng) -> Result<Box<dyn BatchSource + 'a>> + Send + Sync>;

/// Everything the framework needs to know about one selection method.
pub struct MethodSpec {
    /// Canonical CLI/report name (unique across the registry).
    pub name: String,
    /// Extra names [`Method::parse`] accepts (also kept unique).
    pub aliases: Vec<String>,
    /// One-line description shown in CLI help.
    pub help: String,
    /// Trains on the full data: the budget is pinned to 1.0 and the
    /// method serves as the relative-error reference in aggregates.
    pub reference: bool,
    /// Lay the LR schedule out over the *full* training horizon instead
    /// of compressing it into the budget (the paper's SGD†).
    pub full_horizon_schedule: bool,
    /// Train on variance-reduced mini-batch coresets, so the Theorem 4.1
    /// step-size scaling √(r/m) applies (CREST / greedy-per-batch).
    pub coreset_lr_scale: bool,
    /// Builds the method's batch source for one run.
    pub factory: MethodFactory,
}

/// A cheap `Copy` handle to a registered method.
///
/// Obtained from [`Method::parse`], the builtin constructors
/// ([`Method::crest`], …), or as the return value of
/// [`MethodRegistry::register`]. Compares by canonical name.
#[derive(Clone, Copy)]
pub struct Method {
    spec: &'static MethodSpec,
}

impl PartialEq for Method {
    fn eq(&self, other: &Method) -> bool {
        self.spec.name == other.spec.name
    }
}

impl Eq for Method {}

impl std::fmt::Debug for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Method({})", self.spec.name)
    }
}

impl Method {
    /// Look a method up by canonical name or alias (registry-backed; the
    /// replacement for the old `MethodKind::parse`).
    pub fn parse(name: &str) -> Result<Method> {
        MethodRegistry::get(name)
    }

    /// Canonical CLI/report name of the method.
    pub fn name(&self) -> &'static str {
        self.spec.name.as_str()
    }

    /// One-line help text of the method.
    pub fn help(&self) -> &'static str {
        self.spec.help.as_str()
    }

    /// True for the full-data reference method (budget pinned to 1.0;
    /// the rel-err baseline in sweep aggregates).
    pub fn is_reference(&self) -> bool {
        self.spec.reference
    }

    /// True when the LR schedule spans the full horizon (SGD†).
    pub fn full_horizon_schedule(&self) -> bool {
        self.spec.full_horizon_schedule
    }

    /// True when the Theorem 4.1 √(r/m) step-size scaling applies.
    pub fn coreset_lr_scale(&self) -> bool {
        self.spec.coreset_lr_scale
    }

    /// Instantiate the method's batch source for one run. Splits one
    /// child stream off `rng` and hands it to the factory, exactly like
    /// the pre-registry dispatch did — bitwise-identical RNG sequencing.
    pub fn make_source<'a>(
        &self,
        ctx: SourceCtx<'a>,
        rng: &mut Rng,
    ) -> Result<Box<dyn BatchSource + 'a>> {
        let src_rng = rng.split();
        (self.spec.factory)(ctx, src_rng)
    }

    fn builtin(name: &str) -> Method {
        MethodRegistry::get(name).expect("builtin method is always registered")
    }

    /// Full-data mini-batch SGD (the accuracy reference).
    pub fn full() -> Method {
        Method::builtin("full")
    }

    /// Random mini-batches under the budget (paper's Random baseline).
    pub fn random() -> Method {
        Method::builtin("random")
    }

    /// Standard pipeline truncated at the budget (paper's SGD†).
    pub fn sgd_truncated() -> Method {
        Method::builtin("sgd-truncated")
    }

    /// This paper (Algorithm 1).
    pub fn crest() -> Method {
        Method::builtin("crest")
    }

    /// CRAIG: per-epoch full-data coreset (Mirzasoleiman et al. 2020).
    pub fn craig() -> Method {
        Method::builtin("craig")
    }

    /// GRADMATCH: OMP gradient matching per epoch (Killamsetty 2021a).
    pub fn gradmatch() -> Method {
        Method::builtin("gradmatch")
    }

    /// GLISTER: validation-gradient greedy per epoch (Killamsetty 2021b).
    pub fn glister() -> Method {
        Method::builtin("glister")
    }

    /// Fig. 3 ablation: fresh greedy mini-batch at every step.
    pub fn greedy_per_batch() -> Method {
        Method::builtin("greedy-per-batch")
    }

    /// Hard-example mining baseline (per-epoch top-k by loss), registered
    /// purely through the registry (`coreset::loss_topk`).
    pub fn loss_topk() -> Method {
        Method::builtin("loss-topk")
    }
}

/// The global method table; see the module docs.
pub struct MethodRegistry;

fn leak(spec: MethodSpec) -> &'static MethodSpec {
    Box::leak(Box::new(spec))
}

fn table() -> &'static RwLock<Vec<&'static MethodSpec>> {
    static TABLE: OnceLock<RwLock<Vec<&'static MethodSpec>>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut specs = crate::coordinator::sources::builtin_specs();
        specs.push(crate::coreset::loss_topk::spec());
        RwLock::new(specs.into_iter().map(leak).collect())
    })
}

impl MethodRegistry {
    /// Register a new selection method. Fails loudly when the name (or
    /// any alias) collides with an already-registered method, or when the
    /// name would not survive the CLI comma/pipe list syntax. On success
    /// the method is immediately usable everywhere a builtin is: CLI
    /// `--method` parsing and help, `compare`, sweep grids, checkpoints.
    pub fn register(spec: MethodSpec) -> Result<Method> {
        let own: Vec<&String> = std::iter::once(&spec.name).chain(spec.aliases.iter()).collect();
        for (i, name) in own.iter().enumerate() {
            if name.is_empty()
                || name.contains(|c: char| c.is_whitespace() || c == ',' || c == '|')
            {
                bail!("invalid method name {name:?} (empty or contains whitespace/','/'|')");
            }
            if own[..i].contains(name) {
                bail!("method spec {:?} lists the name {name:?} twice", spec.name);
            }
        }
        let mut t = table().write().unwrap();
        for existing in t.iter() {
            for name in std::iter::once(&spec.name).chain(spec.aliases.iter()) {
                if existing.name == *name || existing.aliases.iter().any(|a| a == name) {
                    bail!(
                        "method name {name:?} is already registered (by method {:?})",
                        existing.name
                    );
                }
            }
        }
        let leaked = leak(spec);
        t.push(leaked);
        Ok(Method { spec: leaked })
    }

    /// Look a method up by canonical name or alias.
    pub fn get(name: &str) -> Result<Method> {
        let t = table().read().unwrap();
        for &spec in t.iter() {
            if spec.name == name || spec.aliases.iter().any(|a| a == name) {
                return Ok(Method { spec });
            }
        }
        let known: Vec<&str> = t.iter().map(|s| s.name.as_str()).collect();
        bail!("unknown method {name:?} (available: {})", known.join("|"))
    }

    /// Every registered method: builtins in paper Table-1 presentation
    /// order, then custom registrations in registration order.
    pub fn all() -> Vec<Method> {
        table().read().unwrap().iter().map(|&spec| Method { spec }).collect()
    }

    /// Canonical method names joined with `|` for CLI help text.
    /// Generated from the registry, so the help string can never drift
    /// from what [`Method::parse`] accepts.
    pub fn help_names() -> String {
        MethodRegistry::all().iter().map(|m| m.name()).collect::<Vec<_>>().join("|")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sources::{SourceStats, SourcedBatch};
    use crate::train::TrainState;
    use crate::util::timer::PhaseTimers;

    struct NullSource;

    impl BatchSource for NullSource {
        fn next_batch(
            &mut self,
            _step: usize,
            _state: &mut TrainState,
            _timers: &mut PhaseTimers,
        ) -> Result<SourcedBatch> {
            bail!("test source never produces batches")
        }

        fn stats(&self) -> SourceStats {
            SourceStats::default()
        }
    }

    fn make_null<'a>(_ctx: SourceCtx<'a>, _rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
        Ok(Box::new(NullSource))
    }

    fn null_spec(name: &str, aliases: &[&str]) -> MethodSpec {
        MethodSpec {
            name: name.to_string(),
            aliases: aliases.iter().map(|s| s.to_string()).collect(),
            help: "test method".to_string(),
            reference: false,
            full_horizon_schedule: false,
            coreset_lr_scale: false,
            factory: Box::new(make_null),
        }
    }

    #[test]
    fn builtins_parse_by_name_and_alias() {
        for m in MethodRegistry::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert_eq!(Method::parse("sgd").unwrap(), Method::sgd_truncated());
        assert_eq!(Method::parse("greedy").unwrap(), Method::greedy_per_batch());
        assert_eq!(Method::parse("topk").unwrap(), Method::loss_topk());
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn help_names_roundtrip_through_parse() {
        // every name the CLI help advertises must parse back to the
        // method whose canonical name it is — the help string cannot
        // drift from the parser. (Registration is append-only, so names
        // from this snapshot always still parse even while sibling tests
        // register methods concurrently.)
        let help = MethodRegistry::help_names();
        for name in help.split('|') {
            let parsed = Method::parse(name).unwrap_or_else(|e| {
                panic!("help lists {name:?} but parse rejects it: {e:#}")
            });
            assert_eq!(parsed.name(), name);
        }
        // coverage is asserted over the fixed builtin set, not all(),
        // so concurrent test registrations cannot race this check
        for m in [
            Method::full(),
            Method::random(),
            Method::sgd_truncated(),
            Method::crest(),
            Method::craig(),
            Method::gradmatch(),
            Method::glister(),
            Method::greedy_per_batch(),
            Method::loss_topk(),
        ] {
            assert!(help.split('|').any(|n| n == m.name()), "help misses {}", m.name());
        }
    }

    #[test]
    fn behavior_flags_match_the_paper_setup() {
        assert!(Method::full().is_reference());
        assert!(!Method::crest().is_reference());
        assert!(Method::sgd_truncated().full_horizon_schedule());
        assert!(!Method::random().full_horizon_schedule());
        assert!(Method::crest().coreset_lr_scale());
        assert!(Method::greedy_per_batch().coreset_lr_scale());
        assert!(!Method::craig().coreset_lr_scale());
    }

    #[test]
    fn duplicate_method_name_registration_fails_loudly() {
        // fresh name registers once ...
        let m = MethodRegistry::register(null_spec("dup-probe", &["dup-alias"])).unwrap();
        assert_eq!(m.name(), "dup-probe");
        assert_eq!(Method::parse("dup-alias").unwrap(), m);
        // ... and any collision (name vs name, alias vs name, name vs
        // alias) is rejected with the offending name in the error
        for (name, aliases) in [
            ("dup-probe", vec![]),
            ("crest", vec![]),
            ("dup-alias", vec![]),
            ("dup-other", vec!["dup-probe"]),
            ("dup-other", vec!["crest"]),
        ] {
            let err = MethodRegistry::register(null_spec(name, &aliases)).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("already registered"), "unexpected error: {msg}");
        }
        // a spec colliding with itself is rejected before touching the
        // table (its own aliases are part of the uniqueness contract)
        for (name, aliases) in [("dup-self", vec!["dup-self"]), ("dup-self2", vec!["a", "a"])] {
            let err = MethodRegistry::register(null_spec(name, &aliases)).unwrap_err();
            assert!(format!("{err:#}").contains("twice"), "self-collision not caught");
        }
        assert!(Method::parse("dup-self").is_err(), "rejected spec must not register");
    }

    #[test]
    fn invalid_method_names_rejected() {
        for bad in ["", "has space", "a,b", "a|b"] {
            assert!(MethodRegistry::register(null_spec(bad, &[])).is_err(), "{bad:?}");
        }
    }
}
