//! Run observers: a streaming event interface over one training run.
//!
//! [`Coordinator::run_observed`](crate::coordinator::Coordinator::run_observed)
//! emits typed events at every training step, evaluation point, selection
//! round, and exclusion update, and any number of [`RunObserver`]s can
//! subscribe — streaming progress bars, external metric sinks, early
//! stopping (return [`Signal::Stop`] from a step/eval hook). The run
//! report itself is built by one such observer: [`ReportObserver`]
//! accumulates the event stream and folds it, together with the
//! end-of-run [`RunEnd`] summary, into the final
//! [`RunReport`](crate::report::RunReport) — there are no ad-hoc history
//! vectors in the coordinator loop.
//!
//! Attaching observers never changes training results: events are
//! emitted after the deterministic work of each step, and the default
//! hooks are no-ops.

use crate::config::ExperimentConfig;
use crate::coordinator::sources::SourceStats;
use crate::metrics::forget::ForgetTracker;
use crate::report::{EvalPoint, RunReport};

/// Flow-control verdict returned by the step/eval hooks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Signal {
    /// Keep training.
    #[default]
    Continue,
    /// Finish the current step (and its evaluation, when due), run the
    /// final evaluation, and end the run early.
    Stop,
}

/// One completed training step.
#[derive(Debug, Clone, Copy)]
pub struct StepEvent<'e> {
    /// Step index (0-based).
    pub step: usize,
    /// Total steps the budget affords.
    pub steps_total: usize,
    /// Learning rate applied at this step (schedule × method scaling).
    pub lr: f32,
    /// Weighted mean loss of the training batch.
    pub mean_loss: f32,
    /// Global example indices of the training batch.
    pub idx: &'e [usize],
    /// Cumulative backprops charged to the budget (including this step).
    pub backprops: u64,
}

/// One evaluation point along training.
#[derive(Debug, Clone, Copy)]
pub struct EvalEvent<'e> {
    /// Step the evaluation ran at.
    pub step: usize,
    /// Cumulative backprops charged to the budget.
    pub backprops: u64,
    /// Test-set accuracy.
    pub test_acc: f32,
    /// Mean test-set loss.
    pub test_loss: f32,
    /// Training-set accuracy.
    pub train_acc: f32,
    /// Wall-clock seconds since the run started.
    pub wall_secs: f64,
    /// Per-example 0/1 correctness over the training set (index-aligned
    /// with the dataset; feeds forgettability tracking).
    pub train_per_ex_correct: &'e [f32],
}

/// One selection round (a method refreshed its coreset pool).
#[derive(Debug, Clone, Copy)]
pub struct SelectionEvent<'e> {
    /// Step the selection happened at.
    pub step: usize,
    /// Global indices the round selected.
    pub selected: &'e [usize],
}

/// The learned-example exclusion state at an evaluation point (emitted
/// only while the excluded set is non-empty — paper Fig. 7a).
#[derive(Debug, Clone, Copy)]
pub struct ExclusionEvent {
    /// Step of the evaluation point.
    pub step: usize,
    /// Examples currently excluded as learned.
    pub n_excluded: usize,
    /// Training accuracy over the currently-excluded examples.
    pub dropped_acc: f32,
}

/// End-of-run summary the coordinator assembles after the final
/// evaluation: final metrics, the source's aggregate statistics, and the
/// phase wall-clock totals (paper Table 2 accounting).
#[derive(Debug, Clone)]
pub struct RunEnd {
    /// Test accuracy at budget exhaustion.
    pub final_test_acc: f32,
    /// Mean test loss at budget exhaustion.
    pub final_test_loss: f32,
    /// Training steps taken.
    pub steps: usize,
    /// Backprops actually charged to the budget.
    pub backprops: u64,
    /// Aggregate statistics reported by the method's batch source
    /// (owned, so [`ReportObserver::finish`] moves its history vectors
    /// into the report instead of cloning them).
    pub stats: SourceStats,
    /// Total wall-clock spent selecting coresets.
    pub selection_secs: f64,
    /// Total wall-clock spent in training steps.
    pub train_secs: f64,
    /// Total wall-clock spent evaluating.
    pub eval_secs: f64,
    /// ρ-check time (Table 2 "checking threshold").
    pub check_secs: f64,
    /// Quadratic-model construction time (Table 2 "loss approximation").
    pub approx_secs: f64,
    /// End-to-end wall-clock of the run.
    pub total_secs: f64,
    /// Mean per-step wall time of the training phase.
    pub mean_step_secs: f64,
}

/// A subscriber to one run's event stream. Every hook has a no-op
/// default, so observers implement only what they need.
pub trait RunObserver {
    /// Called after every completed training step.
    fn on_step(&mut self, _ev: &StepEvent<'_>) -> Signal {
        Signal::Continue
    }

    /// Called at every evaluation point.
    fn on_eval(&mut self, _ev: &EvalEvent<'_>) -> Signal {
        Signal::Continue
    }

    /// Called when a selection round ran while producing a batch.
    fn on_selection(&mut self, _ev: &SelectionEvent<'_>) {}

    /// Called at evaluation points while examples are excluded as
    /// learned.
    fn on_exclusion(&mut self, _ev: &ExclusionEvent) {}

    /// Called once after the final evaluation with the completed report.
    fn on_run_end(&mut self, _report: &RunReport) {}
}

/// The built-in observer that assembles the [`RunReport`]: it subscribes
/// to the same event stream as user observers and folds it — history
/// curve, best accuracy, selection records, forgettability bookkeeping,
/// dropped-example accuracy — into the report via
/// [`ReportObserver::finish`].
pub struct ReportObserver {
    method: String,
    variant: String,
    seed: u64,
    budget_frac: f32,
    n_train: usize,
    forget: ForgetTracker,
    history: Vec<EvalPoint>,
    best_acc: f32,
    selections: Vec<(usize, Vec<usize>)>,
    dropped_acc_history: Vec<(usize, f32)>,
}

impl ReportObserver {
    /// Observer for one cell. `budget_frac` is the *effective* budget
    /// (1.0 for the full-data reference), `n_train` the training-set
    /// size.
    pub fn new(cfg: &ExperimentConfig, budget_frac: f32, n_train: usize) -> ReportObserver {
        ReportObserver {
            method: cfg.method.name().to_string(),
            variant: cfg.variant.clone(),
            seed: cfg.seed,
            budget_frac,
            n_train,
            forget: ForgetTracker::new(n_train),
            history: Vec::new(),
            best_acc: 0.0,
            selections: Vec::new(),
            dropped_acc_history: Vec::new(),
        }
    }

    /// Fold the streamed events plus the end-of-run summary into the
    /// final report (consumes the observer and the summary).
    pub fn finish(self, end: RunEnd) -> RunReport {
        // post-hoc Fig. 5 series: mean *final* forgettability of the
        // examples each selection round picked
        let max_score = self.forget.max_observed_score().max(1);
        let forget_of_selected: Vec<(usize, f32)> = self
            .selections
            .iter()
            .map(|(step, sel)| (*step, self.forget.mean_score(sel, max_score)))
            .collect();
        let stats = end.stats;
        RunReport {
            method: self.method,
            variant: self.variant,
            seed: self.seed,
            budget_frac: self.budget_frac,
            final_test_acc: end.final_test_acc,
            final_test_loss: end.final_test_loss,
            best_test_acc: self.best_acc.max(end.final_test_acc),
            steps: end.steps,
            backprops: end.backprops,
            n_selection_updates: stats.n_updates,
            selection_secs: end.selection_secs,
            train_secs: end.train_secs,
            eval_secs: end.eval_secs,
            check_secs: end.check_secs,
            approx_secs: end.approx_secs,
            total_secs: end.total_secs,
            n_excluded: stats.n_excluded,
            history: self.history,
            rho_history: stats.rho_history,
            t1_history: stats.t1_history,
            update_steps: stats.update_steps,
            forget_of_selected,
            selection_counts: self.forget.selection_counts().to_vec(),
            dropped_acc_history: self.dropped_acc_history,
            excluded_indices: stats.excluded_indices,
            mean_step_secs: end.mean_step_secs,
            mean_selection_secs: if stats.n_updates > 0 {
                end.selection_secs / stats.n_updates as f64
            } else {
                0.0
            },
        }
    }
}

impl RunObserver for ReportObserver {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Signal {
        self.forget.count_selection(ev.idx);
        Signal::Continue
    }

    fn on_eval(&mut self, ev: &EvalEvent<'_>) -> Signal {
        let all: Vec<usize> = (0..self.n_train).collect();
        self.forget.observe_batch(&all, ev.train_per_ex_correct);
        self.best_acc = self.best_acc.max(ev.test_acc);
        self.history.push(EvalPoint {
            step: ev.step,
            backprops: ev.backprops,
            test_acc: ev.test_acc,
            test_loss: ev.test_loss,
            train_acc: ev.train_acc,
            wall_secs: ev.wall_secs,
        });
        Signal::Continue
    }

    fn on_selection(&mut self, ev: &SelectionEvent<'_>) {
        self.selections.push((ev.step, ev.selected.to_vec()));
    }

    fn on_exclusion(&mut self, ev: &ExclusionEvent) {
        self.dropped_acc_history.push((ev.step, ev.dropped_acc));
    }
}
