//! Public embedding API: method registry, experiment builder, and run
//! observers.
//!
//! This is the layer downstream crates program against when embedding the
//! CREST engine instead of shelling out to the `crest` binary:
//!
//! * [`MethodRegistry`] / [`Method`] — the single table every dispatch
//!   site derives from (CLI `--method` parsing and help, sweep-grid
//!   expansion, `compare` rows, report labels). Register a
//!   [`MethodSpec`] to add a selection method with zero edits to this
//!   crate.
//! * [`Experiment`] / [`ExperimentBuilder`] — build-time-validated
//!   experiment construction replacing the old preset + field-mutation
//!   flow.
//! * [`RunObserver`] — a streaming event interface over a run (steps,
//!   evaluations, selections, exclusions) enabling progress streaming,
//!   early stopping, and external metric sinks; the run report itself is
//!   assembled by the built-in [`ReportObserver`].
//!
//! ## Library usage
//!
//! The README's "library usage" snippet, kept honest by running as a
//! doctest:
//!
//! ```
//! use crest::api::Experiment;
//!
//! fn main() -> anyhow::Result<()> {
//!     // Train CREST on the tiny smoke variant at a 10% budget.
//!     let report = Experiment::builder()
//!         .variant("smoke")
//!         .method("crest")
//!         .seed(1)
//!         .budget_frac(0.1)
//!         .epochs_full(2)
//!         .build()?
//!         .run()?;
//!     println!("acc {:.4} in {} steps", report.final_test_acc, report.steps);
//!     assert!(report.steps > 0);
//!     Ok(())
//! }
//! ```

pub mod experiment;
pub mod observer;
pub mod registry;

pub use experiment::{Experiment, ExperimentBuilder, RuntimeConfig, SelectionStrategy};
pub use observer::{
    EvalEvent, ExclusionEvent, ReportObserver, RunEnd, RunObserver, SelectionEvent, Signal,
    StepEvent,
};
pub use registry::{Method, MethodFactory, MethodRegistry, MethodSpec, SourceCtx};
