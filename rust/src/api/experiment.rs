//! The `Experiment` builder: the high-level library entry point.
//!
//! Replaces the old `preset` + field-mutation + free-function flow with a
//! validating builder:
//!
//! ```
//! use crest::api::Experiment;
//!
//! # fn main() -> anyhow::Result<()> {
//! let report = Experiment::builder()
//!     .variant("smoke")
//!     .method("crest")
//!     .seed(1)
//!     .budget_frac(0.1)
//!     .epochs_full(2)
//!     .build()?
//!     .run()?;
//! assert_eq!(report.method, "crest");
//! # Ok(())
//! # }
//! ```
//!
//! Everything is validated at [`ExperimentBuilder::build`]: unknown
//! variants and methods, out-of-range budgets, zero epochs. `build` also
//! loads the variant's runtime and (unless a corpus is injected with
//! [`ExperimentBuilder::splits`]) generates the proxy corpus, so
//! [`Experiment::run`] itself cannot fail on configuration.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::Coordinator;
use crate::data::{prepare_spec_splits, Splits, SynthSpec};
use crate::report::RunReport;
use crate::runtime::Runtime;
use crate::util::json::Json;

use super::observer::RunObserver;
use super::registry::Method;

pub use crate::coreset::strategy::SelectionStrategy;
pub use crate::runtime_config::RuntimeConfig;

enum MethodSel {
    Name(String),
    Handle(Method),
}

/// A fully validated, ready-to-run experiment: configuration, runtime,
/// corpus, and attached observers. Built by [`Experiment::builder`].
pub struct Experiment {
    cfg: ExperimentConfig,
    rt: Runtime,
    splits: Arc<Splits>,
    observers: Vec<Box<dyn RunObserver>>,
}

impl Experiment {
    /// Start building an experiment. Defaults: `cifar10-proxy` variant,
    /// `crest` method, seed 1, the preset budget (10%) and reference
    /// epochs, artifact root `artifacts`.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder {
            variant: "cifar10-proxy".to_string(),
            method: None,
            seed: 1,
            budget_frac: None,
            epochs_full: None,
            artifact_root: PathBuf::from("artifacts"),
            splits: None,
            selection: None,
            runtime_config: None,
            overrides: Vec::new(),
            tweaks: Vec::new(),
            observers: Vec::new(),
        }
    }

    /// The validated configuration this experiment will run.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The execution runtime the experiment runs on.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// The train/val/test corpus of the experiment.
    pub fn splits(&self) -> &Splits {
        &self.splits
    }

    /// A shared handle to the corpus, for injecting into another
    /// builder via [`ExperimentBuilder::splits`] (avoids regenerating
    /// the identical (variant, seed) corpus per method).
    pub fn splits_arc(&self) -> Arc<Splits> {
        self.splits.clone()
    }

    /// Execute the experiment: drives the coordinator with the attached
    /// observers and returns the run report. Re-running produces a
    /// bitwise-identical deterministic report core (everything derives
    /// from the seed).
    pub fn run(&mut self) -> Result<RunReport> {
        Coordinator::new(&self.rt, &self.splits, self.cfg.clone())
            .run_observed(&mut self.observers)
    }
}

/// Builder for [`Experiment`]; see the module docs for the shape.
pub struct ExperimentBuilder {
    variant: String,
    method: Option<MethodSel>,
    seed: u64,
    budget_frac: Option<f32>,
    epochs_full: Option<usize>,
    artifact_root: PathBuf,
    splits: Option<Arc<Splits>>,
    selection: Option<SelectionStrategy>,
    runtime_config: Option<RuntimeConfig>,
    overrides: Vec<Json>,
    tweaks: Vec<Box<dyn FnOnce(&mut ExperimentConfig)>>,
    observers: Vec<Box<dyn RunObserver>>,
}

impl ExperimentBuilder {
    /// Model/dataset variant name (validated at build).
    pub fn variant(mut self, variant: impl Into<String>) -> Self {
        self.variant = variant.into();
        self
    }

    /// Selection method by registry name or alias (validated at build).
    pub fn method(mut self, name: impl Into<String>) -> Self {
        self.method = Some(MethodSel::Name(name.into()));
        self
    }

    /// Selection method by handle (e.g. the return value of
    /// [`MethodRegistry::register`](super::MethodRegistry::register)).
    pub fn with_method(mut self, method: Method) -> Self {
        self.method = Some(MethodSel::Handle(method));
        self
    }

    /// Experiment seed; data, init, subsets and probes all derive from
    /// it.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Training budget as a fraction of the full run's backprops
    /// (must be in (0, 1]).
    pub fn budget_frac(mut self, frac: f32) -> Self {
        self.budget_frac = Some(frac);
        self
    }

    /// Epochs of the full-data reference run (the budget denominator;
    /// must be at least 1).
    pub fn epochs_full(mut self, epochs: usize) -> Self {
        self.epochs_full = Some(epochs);
        self
    }

    /// Artifact root consulted for manifest overrides (the native
    /// backend falls back to builtin manifests when absent).
    pub fn artifact_root(mut self, root: impl AsRef<Path>) -> Self {
        self.artifact_root = root.as_ref().to_path_buf();
        self
    }

    /// Inject a prepared corpus instead of regenerating it from the
    /// (variant, seed) synthetic preset — how the sweep shares one corpus
    /// across every cell of a (variant, seed) pair.
    pub fn splits(mut self, splits: Arc<Splits>) -> Self {
        self.splits = Some(splits);
        self
    }

    /// Selection strategy applied uniformly to every method's ground-set
    /// traversal (default [`SelectionStrategy::Exact`]; also settable
    /// through the `selection` JSON key or `--selection` CLI flag).
    pub fn selection(mut self, strategy: SelectionStrategy) -> Self {
        self.selection = Some(strategy);
        self
    }

    /// Install session-level runtime overrides (threads, caches, data
    /// store, pack dir) before the corpus is prepared — the typed
    /// equivalent of exporting the `CREST_*` env vars. The overrides are
    /// process-wide (see [`crate::runtime_config::set_session`]).
    pub fn runtime_config(mut self, rc: RuntimeConfig) -> Self {
        self.runtime_config = Some(rc);
        self
    }

    /// Apply a partial JSON config override at build time (same schema as
    /// [`ExperimentConfig::apply_json`]; unknown keys fail the build).
    pub fn override_json(mut self, overrides: &Json) -> Self {
        self.overrides.push(overrides.clone());
        self
    }

    /// Escape hatch for knobs without a dedicated builder method: the
    /// closure runs against the preset-derived config at build time,
    /// after JSON overrides.
    pub fn configure(mut self, f: impl FnOnce(&mut ExperimentConfig) + 'static) -> Self {
        self.tweaks.push(Box::new(f));
        self
    }

    /// Attach a run observer; observers receive the run's event stream
    /// in attachment order and never change training results.
    pub fn observe(mut self, observer: Box<dyn RunObserver>) -> Self {
        self.observers.push(observer);
        self
    }

    /// Validate and assemble the experiment: resolve the method against
    /// the registry, derive the variant preset, apply overrides, check
    /// ranges, load the runtime, and prepare the corpus.
    pub fn build(self) -> Result<Experiment> {
        let method = match self.method {
            Some(MethodSel::Handle(m)) => m,
            Some(MethodSel::Name(name)) => Method::parse(&name)?,
            None => Method::crest(),
        };
        if let Some(rc) = self.runtime_config {
            crate::runtime_config::set_session(rc);
        }
        let mut cfg = ExperimentConfig::preset(&self.variant, method, self.seed)?;
        if let Some(b) = self.budget_frac {
            cfg.budget_frac = b;
        }
        if let Some(e) = self.epochs_full {
            cfg.epochs_full = e;
        }
        if let Some(s) = self.selection {
            cfg.selection = s;
        }
        for overrides in &self.overrides {
            cfg.apply_json(overrides)?;
        }
        for tweak in self.tweaks {
            tweak(&mut cfg);
        }
        if !(cfg.budget_frac > 0.0 && cfg.budget_frac <= 1.0) {
            bail!("budget_frac {} out of (0, 1]", cfg.budget_frac);
        }
        if cfg.epochs_full == 0 {
            bail!("epochs_full must be at least 1");
        }
        let rt = Runtime::load(&self.artifact_root, &cfg.variant)?;
        let splits = match self.splits {
            Some(s) => s,
            None => {
                // honors the session store selection: resident under mem,
                // lazily packed + mmap-backed under mmap
                let spec = SynthSpec::preset(&cfg.variant, cfg.seed).with_context(|| {
                    format!("no synthetic preset for variant {:?}", cfg.variant)
                })?;
                prepare_spec_splits(&spec)?
            }
        };
        Ok(Experiment { cfg, rt, splits, observers: self.observers })
    }
}
