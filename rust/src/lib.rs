//! # CREST — Coresets for Data-efficient Deep Learning (ICML 2023)
//!
//! From-scratch reproduction of Yang, Kang & Mirzasoleiman's CREST: the
//! coordinator (Algorithm 1 of the paper), the baseline coreset methods,
//! the data pipeline, and the benchmark harness that regenerates the
//! evaluation's tables and figures.
//!
//! Execution is abstracted behind [`runtime::Backend`], with two engines:
//!
//! * **native** (default): a pure-Rust CPU implementation of the five model
//!   computations (`train_step`, `grad_embed`, `eval_chunk`, `hess_probe`,
//!   `select_greedy`), derived directly from the
//!   [`runtime::manifest::VariantManifest`] shape contract. A clean
//!   checkout builds and trains with no Python, no XLA, and no artifact
//!   files.
//! * **pjrt** (`--features pjrt`, opt-in): executes the AOT HLO artifacts
//!   produced by `python/compile/` (JAX graph + Pallas selection kernels)
//!   through XLA/PJRT. Requires an `xla` crate dependency and the built
//!   artifacts; Python still never runs on the training path.
//!
//! The public embedding surface lives in [`api`]: a pluggable
//! [`api::MethodRegistry`] (every selection method — builtin or
//! downstream-registered — is one registry entry all dispatch derives
//! from), the validating [`api::Experiment`] builder, and the
//! [`api::RunObserver`] event stream over a run.
//!
//! See the top-level `README.md` for build and test instructions, and
//! `ARCHITECTURE.md` for the layer map (runtime backends → selection
//! algorithms → coordinator/sweep orchestration → API/CLI/report).

#![warn(missing_docs)]

pub mod api;
pub mod bench_util;
pub mod config;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod exclusion;
pub mod kernel;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod opt;
pub mod prop;
pub mod quadratic;
pub mod report;
pub mod runtime;
pub mod runtime_config;
pub mod sweep;
pub mod tensor;
pub mod train;
pub mod util;
