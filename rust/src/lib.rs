//! # CREST — Coresets for Data-efficient Deep Learning (ICML 2023)
//!
//! From-scratch reproduction of Yang, Kang & Mirzasoleiman's CREST as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the selection
//!   hot-spots (pairwise gradient distances, fused last-layer gradients,
//!   facility-location gains), validated against pure-jnp oracles.
//! * **L2** (`python/compile/model.py`): the JAX training graph (fwd/bwd,
//!   Hutchinson Hessian probes, in-graph greedy selection), AOT-lowered to
//!   HLO text once by `make artifacts`.
//! * **L3** (this crate): the coordinator — Algorithm 1 of the paper, the
//!   baseline coreset methods, the data pipeline, and the benchmark
//!   harness that regenerates every table and figure of the evaluation.
//!
//! Python never runs on the training path: the `crest` binary loads the
//! HLO artifacts through PJRT (`runtime`) and is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench_util;
pub mod config;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod exclusion;
pub mod metrics;
pub mod model;
pub mod opt;
pub mod prop;
pub mod quadratic;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
