//! Piece-wise quadratic loss modeling (paper §4.1, Eq. 6–10).
//!
//! At every selection step the coordinator anchors a quadratic
//! `F^l(δ) = ½ δᵀ diag(H̄) δ + ḡᵀδ + L(w_{t_l})` built from smoothed
//! gradient/curvature estimates:
//!
//! * ḡ  — bias-corrected EMA of the coreset gradient (Eq. 8),
//! * H̄  — bias-corrected RMS-EMA of Hutchinson Hessian-diagonal probes
//!         `z ⊙ Hz` (Eq. 7 + Eq. 9).
//!
//! Training continues on the current coresets while
//! `ρ = |F^l(δ) − L^r(w+δ)| / L^r(w+δ) ≤ τ`; a violation triggers
//! reselection with the adaptive horizon `T₁ = h·‖H̄₀‖/‖H̄_t‖` and
//! `P = b·T₁` (paper §4.1/§4.2 remarks).

use crate::util::stats;

/// Ablation switches (paper Table 3 rows).
#[derive(Debug, Clone, Copy)]
pub struct QuadOptions {
    /// `false` = CREST-FIRST: drop the curvature term from F^l.
    pub second_order: bool,
    /// `false` = no EMA smoothing: use raw last observations.
    pub smooth: bool,
}

impl Default for QuadOptions {
    fn default() -> Self {
        QuadOptions { second_order: true, smooth: true }
    }
}

/// Smoothed quadratic model of the coreset loss around an anchor point.
#[derive(Debug, Clone)]
pub struct QuadraticModel {
    beta1: f32,
    beta2: f32,
    opts: QuadOptions,
    /// raw EMA accumulators (before bias correction)
    g_ema: Vec<f64>,
    h2_ema: Vec<f64>,
    /// observation counters for bias correction
    t1_count: u32,
    t2_count: u32,
    /// ‖H̄‖ at the first anchor — reference scale for T₁ adaptation
    h0_norm: Option<f64>,
    /// anchor state (set at each selection step l)
    anchor_loss: f32,
    anchored: bool,
}

impl QuadraticModel {
    /// Model over a `p_dim`-dimensional parameter space with EMA decay
    /// rates `beta1` (gradient) and `beta2` (curvature).
    pub fn new(p_dim: usize, beta1: f32, beta2: f32, opts: QuadOptions) -> Self {
        assert!((0.0..1.0).contains(&beta1) && (0.0..1.0).contains(&beta2));
        QuadraticModel {
            beta1,
            beta2,
            opts,
            g_ema: vec![0.0; p_dim],
            h2_ema: vec![0.0; p_dim],
            t1_count: 0,
            t2_count: 0,
            h0_norm: None,
            anchor_loss: 0.0,
            anchored: false,
        }
    }

    /// Feed one gradient observation (Eq. 8).
    pub fn observe_grad(&mut self, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.g_ema.len());
        self.t1_count += 1;
        let b1 = if self.opts.smooth { self.beta1 as f64 } else { 0.0 };
        for (e, &g) in self.g_ema.iter_mut().zip(grad) {
            *e = b1 * *e + (1.0 - b1) * g as f64;
        }
    }

    /// Feed one Hessian-diagonal estimate `z ⊙ Hz` (Eq. 7 → Eq. 9).
    pub fn observe_hdiag(&mut self, hdiag: &[f32]) {
        debug_assert_eq!(hdiag.len(), self.h2_ema.len());
        self.t2_count += 1;
        let b2 = if self.opts.smooth { self.beta2 as f64 } else { 0.0 };
        for (e, &h) in self.h2_ema.iter_mut().zip(hdiag) {
            *e = b2 * *e + (1.0 - b2) * (h as f64) * (h as f64);
        }
    }

    /// Bias-corrected smoothed gradient ḡ.
    pub fn gbar(&self) -> Vec<f32> {
        let b1 = if self.opts.smooth { self.beta1 as f64 } else { 0.0 };
        let corr = 1.0 - b1.powi(self.t1_count.max(1) as i32);
        self.g_ema.iter().map(|&e| (e / corr) as f32).collect()
    }

    /// Bias-corrected smoothed |Hessian diagonal| H̄ (RMS form of Eq. 9).
    pub fn hbar(&self) -> Vec<f32> {
        if !self.opts.second_order {
            return vec![0.0; self.h2_ema.len()];
        }
        let b2 = if self.opts.smooth { self.beta2 as f64 } else { 0.0 };
        let corr = 1.0 - b2.powi(self.t2_count.max(1) as i32);
        self.h2_ema.iter().map(|&e| (e / corr).sqrt() as f32).collect()
    }

    /// ‖H̄‖₂ (used by the T₁ adaptation rule).
    pub fn hbar_norm(&self) -> f64 {
        stats::norm2(&self.hbar())
    }

    /// Anchor F^l at the current point: record L(w_{t_l}) and, on the first
    /// anchor, the reference curvature norm ‖H̄₀‖.
    pub fn set_anchor(&mut self, loss: f32) {
        self.anchor_loss = loss;
        self.anchored = true;
        if self.h0_norm.is_none() {
            let n = self.hbar_norm();
            if n > 0.0 {
                self.h0_norm = Some(n);
            }
        }
    }

    /// True once an anchor (selection step l) has been set.
    pub fn anchored(&self) -> bool {
        self.anchored
    }

    /// Evaluate F^l(δ) (Eq. 6 with the diagonal Hessian surrogate).
    pub fn f_l(&self, delta: &[f32]) -> f32 {
        debug_assert!(self.anchored, "f_l before set_anchor");
        let g = self.gbar();
        let lin = stats::dot(&g, delta);
        let quad = if self.opts.second_order {
            let h = self.hbar();
            delta
                .iter()
                .zip(&h)
                .map(|(&d, &hh)| 0.5 * (d as f64) * (hh as f64) * (d as f64))
                .sum::<f64>()
        } else {
            0.0
        };
        (self.anchor_loss as f64 + lin + quad) as f32
    }

    /// ρ-check (Eq. 10) against an unbiased loss estimate at w_{t_l}+δ.
    pub fn rho(&self, delta: &[f32], actual_loss: f32) -> f32 {
        let f = self.f_l(delta);
        (f - actual_loss).abs() / actual_loss.max(1e-8)
    }

    /// Adaptive reselection horizon T₁ = h·‖H̄₀‖/‖H̄_t‖, clamped to
    /// [1, max_t1]. Grows as curvature flattens late in training (paper
    /// §4.1 Remark).
    pub fn adapt_t1(&self, h_mult: f32, max_t1: usize) -> usize {
        let h0 = match self.h0_norm {
            Some(v) => v,
            None => return 1,
        };
        let ht = self.hbar_norm().max(1e-12);
        let t1 = (h_mult as f64 * h0 / ht).floor();
        (t1 as usize).clamp(1, max_t1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(opts: QuadOptions) -> QuadraticModel {
        QuadraticModel::new(4, 0.9, 0.99, opts)
    }

    #[test]
    fn ema_bias_correction_exact_for_constant_signal() {
        let mut q = model(QuadOptions::default());
        for _ in 0..3 {
            q.observe_grad(&[2.0, -1.0, 0.0, 4.0]);
        }
        let g = q.gbar();
        for (got, want) in g.iter().zip([2.0, -1.0, 0.0, 4.0]) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn hbar_is_rms_of_probes() {
        let mut q = model(QuadOptions::default());
        q.observe_hdiag(&[3.0, -3.0, 0.0, 1.0]);
        let h = q.hbar();
        assert!((h[0] - 3.0).abs() < 1e-5);
        assert!((h[1] - 3.0).abs() < 1e-5, "sign dropped by RMS");
        assert!(h[2].abs() < 1e-6);
    }

    #[test]
    fn f_l_quadratic_in_delta() {
        let mut q = model(QuadOptions::default());
        q.observe_grad(&[1.0, 0.0, 0.0, 0.0]);
        q.observe_hdiag(&[2.0, 0.0, 0.0, 0.0]);
        q.set_anchor(5.0);
        // F(δ) = 5 + δ0 + 0.5·2·δ0²
        let f = q.f_l(&[0.5, 0.0, 0.0, 0.0]);
        assert!((f - (5.0 + 0.5 + 0.25)).abs() < 1e-4, "{f}");
    }

    #[test]
    fn first_order_drops_curvature() {
        let mut q = model(QuadOptions { second_order: false, smooth: true });
        q.observe_grad(&[1.0, 0.0, 0.0, 0.0]);
        q.observe_hdiag(&[100.0, 100.0, 100.0, 100.0]);
        q.set_anchor(5.0);
        let f = q.f_l(&[1.0, 0.0, 0.0, 0.0]);
        assert!((f - 6.0).abs() < 1e-5, "{f}");
    }

    #[test]
    fn no_smooth_uses_last_observation_only() {
        let mut q = model(QuadOptions { second_order: true, smooth: false });
        q.observe_grad(&[10.0, 0.0, 0.0, 0.0]);
        q.observe_grad(&[-2.0, 0.0, 0.0, 0.0]);
        assert!((q.gbar()[0] + 2.0).abs() < 1e-5);
    }

    #[test]
    fn rho_zero_when_model_exact() {
        let mut q = model(QuadOptions::default());
        q.observe_grad(&[1.0, 1.0, 1.0, 1.0]);
        q.observe_hdiag(&[0.0; 4]);
        q.set_anchor(2.0);
        let delta = [0.1, 0.1, 0.1, 0.1];
        let actual = q.f_l(&delta);
        assert!(q.rho(&delta, actual) < 1e-6);
    }

    #[test]
    fn rho_measures_relative_error() {
        let mut q = model(QuadOptions::default());
        q.observe_grad(&[0.0; 4]);
        q.observe_hdiag(&[0.0; 4]);
        q.set_anchor(1.0);
        // F == 1.0 everywhere; actual 2.0 -> rho = 0.5
        assert!((q.rho(&[0.0; 4], 2.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn t1_grows_as_curvature_decays() {
        let mut q = model(QuadOptions::default());
        q.observe_hdiag(&[8.0, 8.0, 8.0, 8.0]);
        q.set_anchor(1.0); // h0 recorded
        let t1_early = q.adapt_t1(1.0, 100);
        assert_eq!(t1_early, 1);
        // curvature decays by 4x (push the RMS-EMA down over many steps)
        for _ in 0..500 {
            q.observe_hdiag(&[2.0, 2.0, 2.0, 2.0]);
        }
        let t1_late = q.adapt_t1(1.0, 100);
        assert!(t1_late >= 3, "t1_late={t1_late}");
        // h multiplier scales
        assert!(q.adapt_t1(10.0, 1000) >= 30);
        // clamp respected
        assert_eq!(q.adapt_t1(10.0, 8), 8);
    }

    #[test]
    fn t1_is_one_before_first_anchor() {
        let q = model(QuadOptions::default());
        assert_eq!(q.adapt_t1(5.0, 100), 1);
    }
}
