//! The selection-strategy layer: exact vs. sub-quadratic approximate
//! selection, composable with every registered method.
//!
//! Greedy facility location is O(n·|candidates|·d) per step — fine for
//! per-batch pools, super-linear for epoch-level selection over 10⁵–10⁶
//! example ground sets (the scaling wall AdaCore documents; CRAIG's
//! reference implementation ships `dense | sparse | clustered` escape
//! hatches for the same reason). A [`SelectionStrategy`] decides *how* a
//! ground set is traversed; a [`GroundSelector`] decides *what* exact
//! selection runs on each piece. Methods supply the selector, experiments
//! supply the strategy, and the two compose without any per-method
//! dispatch edits:
//!
//! * [`SelectionStrategy::Exact`] — hand the whole ground set to the
//!   selector. Bit-for-bit the pre-strategy behavior.
//! * [`SelectionStrategy::ClassSharded`] — partition by label into
//!   contiguous class shards (CRAIG's per-class mode), select per shard
//!   with a size-proportional budget, remap and concatenate.
//! * [`SelectionStrategy::Clustered`] — random-projection bucketing of the
//!   gradient embeddings; the selector sees one representative per bucket,
//!   winning buckets expand back to their members under an apportioned
//!   budget.
//! * [`SelectionStrategy::Knn`] — run the selector against a sparse
//!   [`SparseKnnMetric`] that scores gains only on precomputed neighbor
//!   lists (metric-driven selectors only; others keep their exact path).
//!
//! Determinism contract (same as the kernel layer): partition boundaries
//! are functions of shapes and labels only, per-piece work folds in a
//! fixed order, and child RNG streams split from the caller's stream in
//! piece order — so every strategy is bitwise-identical at any thread
//! count, and the degenerate parameters (`ClassSharded` with one shard,
//! `Clustered` with `k ≥ n`, `Knn` with `neighbors ≥ n`) short-circuit to
//! `Exact` *before* touching the RNG, making the equivalence exact.

use std::fmt;

use anyhow::{bail, Context, Result};

use crate::coreset::craig;
use crate::coreset::facility::{
    self, facility_location_metric, facility_location_stochastic, EuclidMetric, ProdMetric,
    Selection, SparseKnnMetric, SqDistMetric,
};
use crate::coreset::{glister, gradmatch};
use crate::tensor::MatF32;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Fixed seed of the clustered-selection bucketing projection (shape-only;
/// independent of [`facility`]'s k-NN window seed so the two layers don't
/// alias).
const CLUSTER_PROJ_SEED: u64 = 0xc1a5_7e4e_d00d_5eed;

/// Neighbors kept by `knn` when the parameter is elided (`knn` == `knn:0`).
const DEFAULT_KNN_NEIGHBORS: usize = 32;

/// Fixed RNG stream for strategy entry points whose base selector never
/// draws randomness (the facility-location pool paths) — keeps those call
/// sites free of the caller's RNG stream, so `Exact` consumes nothing.
const FACILITY_STREAM_SEED: u64 = 0x5e1e_c7ed_0000_0001;

// ---------------------------------------------------------------- strategy

/// How a selection traverses its ground set: exactly, or through one of
/// three sub-quadratic approximations. A parameter of `0` means "auto"
/// (one shard per class / `4·⌈√n⌉` clusters / 32 neighbors) and is the
/// canonical spelling of the elided CLI/JSON forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionStrategy {
    /// Full greedy over the whole ground set (the default).
    Exact,
    /// Per-class sharded selection: `shards` label shards (0 = one per
    /// class), each selected independently with a size-proportional budget.
    ClassSharded {
        /// Number of label shards; 0 = one shard per class.
        shards: usize,
    },
    /// Clustered selection on bucket representatives (0 = `4·⌈√n⌉`
    /// buckets), expanded back to member indices.
    Clustered {
        /// Number of projection buckets; 0 = auto.
        k: usize,
    },
    /// Sparse k-NN gains: greedy against precomputed neighbor lists
    /// (0 = 32 neighbors).
    Knn {
        /// Neighbors kept per element (including itself); 0 = auto.
        neighbors: usize,
    },
}

/// One row of the strategy parse table — the single source for `--selection`
/// parsing, help text, and the JSON config key (mirrors how `--method`
/// derives everything from the method registry).
struct StrategySpec {
    name: &'static str,
    usage: &'static str,
    help: &'static str,
    takes_param: bool,
    build: fn(usize) -> SelectionStrategy,
}

/// The strategy table. `parse`, `help_names`, and `describe_all` all derive
/// from this list — adding a strategy is one new row plus its `select` arm.
const STRATEGIES: &[StrategySpec] = &[
    StrategySpec {
        name: "exact",
        usage: "exact",
        help: "full greedy over the whole ground set (default)",
        takes_param: false,
        build: |_| SelectionStrategy::Exact,
    },
    StrategySpec {
        name: "class-sharded",
        usage: "class-sharded[:shards]",
        help: "per-class sharded greedy, size-proportional budgets (0 = one shard per class)",
        takes_param: true,
        build: |p| SelectionStrategy::ClassSharded { shards: p },
    },
    StrategySpec {
        name: "clustered",
        usage: "clustered[:k]",
        help: "greedy on projection-bucket representatives, expanded to members (0 = 4*ceil(sqrt(n)))",
        takes_param: true,
        build: |p| SelectionStrategy::Clustered { k: p },
    },
    StrategySpec {
        name: "knn",
        usage: "knn[:neighbors]",
        help: "greedy over a sparse k-NN distance panel (0 = 32 neighbors)",
        takes_param: true,
        build: |p| SelectionStrategy::Knn { neighbors: p },
    },
];

impl fmt::Display for SelectionStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            SelectionStrategy::Exact => write!(f, "exact"),
            SelectionStrategy::ClassSharded { shards: 0 } => write!(f, "class-sharded"),
            SelectionStrategy::ClassSharded { shards } => write!(f, "class-sharded:{shards}"),
            SelectionStrategy::Clustered { k: 0 } => write!(f, "clustered"),
            SelectionStrategy::Clustered { k } => write!(f, "clustered:{k}"),
            SelectionStrategy::Knn { neighbors: 0 } => write!(f, "knn"),
            SelectionStrategy::Knn { neighbors } => write!(f, "knn:{neighbors}"),
        }
    }
}

impl Default for SelectionStrategy {
    fn default() -> Self {
        SelectionStrategy::Exact
    }
}

impl SelectionStrategy {
    /// Parse a `--selection` / config value: a table name, optionally with
    /// a `:<param>` suffix (`class-sharded:4`, `knn:64`, ...). Round-trips
    /// with [`fmt::Display`].
    pub fn parse(s: &str) -> Result<SelectionStrategy> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let spec = STRATEGIES
            .iter()
            .find(|spec| spec.name == name)
            .with_context(|| {
                format!("unknown selection strategy `{s}` (expected {})", Self::help_names())
            })?;
        let value = match param {
            None => 0,
            Some(_) if !spec.takes_param => {
                bail!("selection strategy `{name}` takes no parameter (got `{s}`)")
            }
            Some(p) => p.parse::<usize>().ok().with_context(|| {
                format!("selection strategy `{s}`: parameter must be a non-negative integer")
            })?,
        };
        Ok((spec.build)(value))
    }

    /// `usage | usage | ...` summary of every strategy, for `--help` text
    /// (the one-table analogue of `MethodRegistry::help_names`).
    pub fn help_names() -> String {
        STRATEGIES.iter().map(|s| s.usage).collect::<Vec<_>>().join(" | ")
    }

    /// `(usage, help)` rows of the strategy table, for long-form help.
    pub fn describe_all() -> Vec<(&'static str, &'static str)> {
        STRATEGIES.iter().map(|s| (s.usage, s.help)).collect()
    }

    /// Run `base` over `g` under this strategy, selecting `k` elements.
    ///
    /// `Exact` forwards untouched (and consumes nothing from `rng` unless
    /// the selector itself draws); the approximate strategies partition the
    /// work as documented on the enum and split child RNG streams from
    /// `rng` in partition order.
    pub fn select(
        &self,
        g: &Ground<'_>,
        k: usize,
        rng: &mut Rng,
        base: &dyn GroundSelector,
    ) -> Selection {
        match *self {
            SelectionStrategy::Exact => base.select(g, k, rng),
            SelectionStrategy::ClassSharded { shards } => class_sharded(g, k, rng, base, shards),
            SelectionStrategy::Clustered { k: buckets } => clustered(g, k, rng, base, buckets),
            SelectionStrategy::Knn { neighbors } => knn(g, k, rng, base, neighbors),
        }
    }
}

// ------------------------------------------------------------ ground view

/// A borrowed view of one selection ground set: gradient embeddings, the
/// optional activation matrix of the product metric, and optional labels
/// (required only by class sharding).
pub struct Ground<'a> {
    /// Gradient embeddings, one row per example — the feature space the
    /// clustering/k-NN approximations partition.
    pub gl: &'a MatF32,
    /// Activations paired with `gl` for the last-layer weight-gradient
    /// metric; `None` selects the plain Euclidean metric over `gl`.
    pub al: Option<&'a MatF32>,
    /// Class labels aligned with the rows of `gl`; `None` disables
    /// class sharding (the strategy falls back to exact).
    pub labels: Option<&'a [i32]>,
}

impl<'a> Ground<'a> {
    /// Ground-set size.
    pub fn n(&self) -> usize {
        self.gl.rows
    }
}

/// Owned sub-ground gathered for one shard/bucket (compact matrices keep
/// the tiled kernels fed).
struct OwnedGround {
    gl: MatF32,
    al: Option<MatF32>,
    labels: Option<Vec<i32>>,
}

impl OwnedGround {
    fn view(&self) -> Ground<'_> {
        Ground { gl: &self.gl, al: self.al.as_ref(), labels: self.labels.as_deref() }
    }
}

fn gather_ground(g: &Ground<'_>, idx: &[usize]) -> OwnedGround {
    OwnedGround {
        gl: g.gl.gather_rows(idx),
        al: g.al.map(|a| a.gather_rows(idx)),
        labels: g.labels.map(|y| idx.iter().map(|&i| y[i]).collect()),
    }
}

// ---------------------------------------------------------- base selectors

/// The exact selection a method runs on each piece of a partition. Every
/// registered method supplies one (facility for CREST/greedy pools, CRAIG's
/// thresholded greedy, OMP for GradMatch, ...); strategies call it on the
/// whole ground set (`Exact`), per shard, on representatives, or — for
/// selectors that are metric-driven — against a sparse metric.
pub trait GroundSelector: Sync {
    /// Select `k` elements of `g` exactly.
    fn select(&self, g: &Ground<'_>, k: usize, rng: &mut Rng) -> Selection;

    /// True when the selector's gains come from a [`SqDistMetric`] (so the
    /// sparse k-NN strategy applies). Override together with
    /// [`GroundSelector::select_metric`].
    fn uses_metric(&self) -> bool {
        false
    }

    /// Select against an arbitrary (possibly sparse) metric; `None` for
    /// selectors whose objective is not distance-driven, in which case the
    /// k-NN strategy falls back to [`GroundSelector::select`].
    fn select_metric(&self, _m: &dyn SqDistMetric, _k: usize, _rng: &mut Rng) -> Option<Selection> {
        None
    }
}

/// Lazy-greedy facility location — the CREST per-batch and
/// greedy-per-batch selector. Never draws from the RNG.
pub struct FacilitySelector;

impl GroundSelector for FacilitySelector {
    fn select(&self, g: &Ground<'_>, k: usize, _rng: &mut Rng) -> Selection {
        match g.al {
            Some(al) => facility::facility_location_prod(al, g.gl, k),
            None => facility::facility_location(g.gl, k),
        }
    }

    fn uses_metric(&self) -> bool {
        true
    }

    fn select_metric(&self, m: &dyn SqDistMetric, k: usize, _rng: &mut Rng) -> Option<Selection> {
        Some(facility_location_metric(m, k))
    }
}

/// CRAIG's epoch-level selector: lazy greedy up to
/// [`craig::STOCHASTIC_THRESHOLD`], stochastic greedy past it.
pub struct CraigSelector;

impl GroundSelector for CraigSelector {
    fn select(&self, g: &Ground<'_>, k: usize, rng: &mut Rng) -> Selection {
        match g.al {
            Some(al) => craig::craig_select(al, g.gl, k, rng),
            None => {
                let metric = EuclidMetric::new(g.gl);
                if g.n() > craig::STOCHASTIC_THRESHOLD {
                    facility_location_stochastic(&metric, k, rng)
                } else {
                    facility_location_metric(&metric, k)
                }
            }
        }
    }

    fn uses_metric(&self) -> bool {
        true
    }

    fn select_metric(&self, m: &dyn SqDistMetric, k: usize, rng: &mut Rng) -> Option<Selection> {
        Some(if m.len() > craig::STOCHASTIC_THRESHOLD {
            facility_location_stochastic(m, k, rng)
        } else {
            facility_location_metric(m, k)
        })
    }
}

/// GradMatch's orthogonal-matching-pursuit selector (not metric-driven:
/// its objective is gradient-sum residual, not pairwise distance).
pub struct GradMatchSelector;

impl GroundSelector for GradMatchSelector {
    fn select(&self, g: &Ground<'_>, k: usize, rng: &mut Rng) -> Selection {
        gradmatch::gradmatch_select(g.gl, k, rng)
    }
}

/// GLISTER's validation-alignment selector: greedy on `⟨g_i, ∇L_val⟩`
/// (not metric-driven).
pub struct GlisterSelector {
    /// Mean validation gradient embedding the training gains align to.
    pub vmean: Vec<f32>,
}

impl GroundSelector for GlisterSelector {
    fn select(&self, g: &Ground<'_>, k: usize, _rng: &mut Rng) -> Selection {
        glister::glister_select(g.gl, &self.vmean, k)
    }
}

/// Top-k by the first embedding column, descending (ties to the lower
/// index) — the loss-topk scorer viewed as a one-column ground set.
pub struct TopKSelector;

impl GroundSelector for TopKSelector {
    fn select(&self, g: &Ground<'_>, k: usize, _rng: &mut Rng) -> Selection {
        let n = g.n();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by(|&a, &b| g.gl.row(b)[0].total_cmp(&g.gl.row(a)[0]).then(a.cmp(&b)));
        order.truncate(k.min(n));
        Selection { gamma: vec![1.0; order.len()], idx: order }
    }
}

/// Strategy-driven facility location over one mini-batch pool (the CREST
/// and greedy-per-batch hot paths). [`FacilitySelector`] never draws
/// randomness, so the RNG stream is a fixed constant: under `Exact` this
/// is bit-for-bit `facility_location_prod(al, gl, m)`, and the call site
/// keeps its own RNG stream untouched.
pub fn facility_select(
    strategy: SelectionStrategy,
    al: &MatF32,
    gl: &MatF32,
    labels: &[i32],
    m: usize,
) -> Selection {
    let g = Ground { gl, al: Some(al), labels: Some(labels) };
    let mut rng = Rng::new(FACILITY_STREAM_SEED);
    strategy.select(&g, m, &mut rng, &FacilitySelector)
}

// ----------------------------------------------------------- class shards

/// Largest-remainder apportionment of `k` over pieces of the given sizes:
/// floor quotas, remainders to the largest fractional parts (ties to the
/// lower index), capped at each piece's size with overflow redistributed
/// in index order. Deterministic, sums to `min(k, Σ sizes)`. Public so the
/// strategy property suite can drive it directly with generated inputs.
pub fn apportion(k: usize, sizes: &[usize]) -> Vec<usize> {
    let n: usize = sizes.iter().sum();
    if n == 0 || k == 0 {
        return vec![0; sizes.len()];
    }
    let k = k.min(n);
    let quota = |sz: usize| (k as u128 * sz as u128 / n as u128) as usize;
    let frac = |sz: usize| k as u128 * sz as u128 % n as u128;
    let mut out: Vec<usize> = sizes.iter().map(|&sz| quota(sz)).collect();
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| frac(sizes[b]).cmp(&frac(sizes[a])).then(a.cmp(&b)));
    let mut short = k - out.iter().sum::<usize>();
    for &i in &order {
        if short == 0 {
            break;
        }
        if out[i] < sizes[i] {
            out[i] += 1;
            short -= 1;
        }
    }
    // cap overflow (possible only when many pieces saturate): sweep spare
    // room in index order until the budget is placed
    while short > 0 {
        let before = short;
        for i in 0..out.len() {
            if short == 0 {
                break;
            }
            if out[i] < sizes[i] {
                out[i] += 1;
                short -= 1;
            }
        }
        if short == before {
            break;
        }
    }
    out
}

/// Per-class sharded selection. Classes map to `s` contiguous shards
/// (`shard = class·s/classes` — shape-only boundaries given the label
/// alphabet), each shard selects independently under a size-proportional
/// budget with its own child RNG stream (split in shard order), and the
/// results concatenate shard-major with local indices remapped.
fn class_sharded(
    g: &Ground<'_>,
    k: usize,
    rng: &mut Rng,
    base: &dyn GroundSelector,
    shards: usize,
) -> Selection {
    let Some(labels) = g.labels else {
        return base.select(g, k, rng);
    };
    let classes = labels.iter().map(|&y| y.max(0) as usize + 1).max().unwrap_or(1);
    let s = if shards == 0 { classes } else { shards.min(classes) };
    if s <= 1 {
        // one shard ≡ exact — and the RNG stream is untouched, so the
        // equivalence is bitwise
        return base.select(g, k, rng);
    }
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); s];
    for (i, &y) in labels.iter().enumerate() {
        let c = (y.max(0) as usize).min(classes - 1);
        members[c * s / classes].push(i);
    }
    let sizes: Vec<usize> = members.iter().map(|m| m.len()).collect();
    let budgets = apportion(k, &sizes);
    let mut shard_rngs: Vec<Rng> = members.iter().map(|_| rng.split()).collect();
    let mut idx = Vec::with_capacity(k);
    let mut gamma = Vec::with_capacity(k);
    for (sh, mem) in members.iter().enumerate() {
        let ks = budgets[sh];
        if ks == 0 {
            continue;
        }
        let sub = gather_ground(g, mem);
        let sel = base.select(&sub.view(), ks, &mut shard_rngs[sh]);
        for (&p, &ga) in sel.idx.iter().zip(sel.gamma.iter()) {
            idx.push(mem[p]);
            gamma.push(ga);
        }
    }
    Selection { idx, gamma }
}

// -------------------------------------------------------------- clustering

fn auto_clusters(n: usize) -> usize {
    (4 * (n as f64).sqrt().ceil() as usize).max(1)
}

/// Members of one bucket ordered by squared distance to the bucket's mean
/// embedding (f64 accumulation in member order; stable sort keeps the
/// projection-rank order on ties). The head of the list is the bucket's
/// representative.
fn rank_by_centroid(gl: &MatF32, members: &[usize]) -> Vec<usize> {
    let d = gl.cols;
    let mut mean = vec![0.0f64; d];
    for &i in members {
        for (a, &v) in mean.iter_mut().zip(gl.row(i)) {
            *a += v as f64;
        }
    }
    let inv = 1.0 / members.len() as f64;
    let mean: Vec<f32> = mean.iter().map(|&v| (v * inv) as f32).collect();
    let mut scored: Vec<(f32, usize)> = members
        .iter()
        .map(|&i| {
            let mut s = 0.0f32;
            for (&v, &mu) in gl.row(i).iter().zip(&mean) {
                let dl = v - mu;
                s += dl * dl;
            }
            (s, i)
        })
        .collect();
    scored.sort_by(|a, b| a.0.total_cmp(&b.0));
    scored.into_iter().map(|(_, i)| i).collect()
}

/// Clustered selection. Rows bucket by deterministic random-projection
/// rank (`k` equal-rank contiguous buckets — shape-only boundaries), the
/// selector runs on one representative per bucket (the member nearest the
/// bucket mean), and winning buckets expand back to their members nearest
/// the mean under a size-apportioned budget. Expanded members share their
/// representative's weight scaled by the bucket's share of the ground set.
fn clustered(
    g: &Ground<'_>,
    m: usize,
    rng: &mut Rng,
    base: &dyn GroundSelector,
    buckets: usize,
) -> Selection {
    let n = g.n();
    let k = if buckets == 0 { auto_clusters(n) } else { buckets };
    if k >= n {
        // every element its own bucket ≡ exact (RNG untouched)
        return base.select(g, m, rng);
    }
    let order = facility::projection_order(g.gl, CLUSTER_PROJ_SEED);
    let lo = |b: usize| b * n / k;
    // per-bucket centroid ranking: buckets are independent, results fold
    // in bucket order — thread-count invariant
    let ranked: Vec<Vec<usize>> =
        Pool::global().map(k, |b| rank_by_centroid(g.gl, &order[lo(b)..lo(b + 1)]));
    let reps: Vec<usize> = ranked.iter().map(|r| r[0]).collect();
    let rep_ground = gather_ground(g, &reps);
    let j = m.min(k);
    let mut crng = rng.split();
    let sel = base.select(&rep_ground.view(), j, &mut crng);
    // apportion the full budget over the winning buckets by member count
    let sizes: Vec<usize> = sel.idx.iter().map(|&b| ranked[b].len()).collect();
    let budgets = apportion(m, &sizes);
    let scale = n as f32 / k as f32; // each representative stands for ~n/k members
    let mut idx = Vec::with_capacity(m);
    let mut gamma = Vec::with_capacity(m);
    for (w, &b) in sel.idx.iter().enumerate() {
        let mc = budgets[w];
        if mc == 0 {
            continue;
        }
        let ga = sel.gamma[w] * scale / mc as f32;
        for &i in &ranked[b][..mc] {
            idx.push(i);
            gamma.push(ga);
        }
    }
    Selection { idx, gamma }
}

// -------------------------------------------------------------- sparse knn

/// Sparse k-NN selection: build a [`SparseKnnMetric`] over the ground set
/// and run the selector's metric path against it. Selectors that are not
/// metric-driven keep their exact path (documented fallback).
fn knn(
    g: &Ground<'_>,
    m: usize,
    rng: &mut Rng,
    base: &dyn GroundSelector,
    neighbors: usize,
) -> Selection {
    let n = g.n();
    let nb = if neighbors == 0 { DEFAULT_KNN_NEIGHBORS } else { neighbors };
    if nb >= n || !base.uses_metric() {
        // full neighborhood ≡ exact; non-metric selectors have no sparse
        // path — both fall through without touching the RNG beyond what
        // the exact selector itself draws
        return base.select(g, m, rng);
    }
    let sel = match g.al {
        Some(al) => {
            let inner = ProdMetric::new(al, g.gl);
            let sparse = SparseKnnMetric::build(&inner, g.gl, nb);
            base.select_metric(&sparse, m, rng)
        }
        None => {
            let inner = EuclidMetric::new(g.gl);
            let sparse = SparseKnnMetric::build(&inner, g.gl, nb);
            base.select_metric(&sparse, m, rng)
        }
    };
    match sel {
        Some(sel) => sel,
        None => base.select(g, m, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coreset::coverage_cost;
    use crate::util::pool;

    fn random_mat(r: usize, c: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        let mut m = MatF32::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn fixture(n: usize, classes: usize, seed: u64) -> (MatF32, MatF32, Vec<i32>) {
        let al = random_mat(n, 7, seed);
        let gl = random_mat(n, 5, seed + 1);
        let labels: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
        (al, gl, labels)
    }

    #[test]
    fn parse_display_roundtrip_all_forms() {
        for s in [
            "exact",
            "class-sharded",
            "class-sharded:4",
            "clustered",
            "clustered:128",
            "knn",
            "knn:64",
        ] {
            let parsed = SelectionStrategy::parse(s).unwrap();
            assert_eq!(parsed.to_string(), s, "canonical form round-trips");
            assert_eq!(SelectionStrategy::parse(&parsed.to_string()).unwrap(), parsed);
        }
        // elided and explicit-zero spell the same strategy
        assert_eq!(
            SelectionStrategy::parse("clustered:0").unwrap(),
            SelectionStrategy::Clustered { k: 0 }
        );
        assert_eq!(SelectionStrategy::default(), SelectionStrategy::Exact);
    }

    #[test]
    fn parse_rejects_bad_values() {
        assert!(SelectionStrategy::parse("nope").is_err());
        assert!(SelectionStrategy::parse("exact:3").is_err(), "exact takes no parameter");
        assert!(SelectionStrategy::parse("knn:abc").is_err());
        assert!(SelectionStrategy::parse("knn:-1").is_err());
        let help = SelectionStrategy::help_names();
        for spec in ["exact", "class-sharded[:shards]", "clustered[:k]", "knn[:neighbors]"] {
            assert!(help.contains(spec), "help `{help}` missing `{spec}`");
        }
        assert_eq!(SelectionStrategy::describe_all().len(), 4);
    }

    #[test]
    fn apportion_sums_caps_and_orders() {
        assert_eq!(apportion(10, &[50, 30, 20]), vec![5, 3, 2]);
        // remainders go to the largest fractional parts
        let a = apportion(10, &[35, 35, 30]);
        assert_eq!(a.iter().sum::<usize>(), 10);
        assert!(a.iter().zip(&[35usize, 35, 30]).all(|(&q, &s)| q <= s));
        // caps respected, overflow redistributed
        assert_eq!(apportion(5, &[1, 1, 100]), vec![1, 1, 3]);
        // k beyond the pool clamps
        assert_eq!(apportion(100, &[2, 3]), vec![2, 3]);
        // zero-size pieces never receive budget
        assert_eq!(apportion(4, &[0, 4, 0]), vec![0, 4, 0]);
        assert_eq!(apportion(3, &[]), Vec::<usize>::new());
    }

    #[test]
    fn degenerate_parameters_match_exact_bitwise() {
        let (al, gl, labels) = fixture(192, 4, 60);
        let g = Ground { gl: &gl, al: Some(&al), labels: Some(&labels) };
        let exact = {
            let mut rng = Rng::new(7);
            SelectionStrategy::Exact.select(&g, 24, &mut rng, &FacilitySelector)
        };
        for strat in [
            SelectionStrategy::ClassSharded { shards: 1 },
            SelectionStrategy::Clustered { k: 192 },
            SelectionStrategy::Clustered { k: usize::MAX },
            SelectionStrategy::Knn { neighbors: 192 },
            SelectionStrategy::Knn { neighbors: usize::MAX },
        ] {
            let mut rng = Rng::new(7);
            let got = strat.select(&g, 24, &mut rng, &FacilitySelector);
            assert_eq!(exact.idx, got.idx, "{strat}");
            let eb: Vec<u32> = exact.gamma.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.gamma.iter().map(|v| v.to_bits()).collect();
            assert_eq!(eb, gb, "{strat}");
        }
    }

    #[test]
    fn class_sharded_covers_classes_with_proportional_budget() {
        let (al, gl, labels) = fixture(240, 4, 61);
        let g = Ground { gl: &gl, al: Some(&al), labels: Some(&labels) };
        let mut rng = Rng::new(9);
        let strat = SelectionStrategy::ClassSharded { shards: 0 };
        let sel = strat.select(&g, 24, &mut rng, &FacilitySelector);
        assert_eq!(sel.idx.len(), 24);
        let uniq: std::collections::HashSet<_> = sel.idx.iter().collect();
        assert_eq!(uniq.len(), 24, "indices unique across shards");
        // balanced classes, balanced budget: 6 picks per class
        for c in 0..4 {
            let got = sel.idx.iter().filter(|&&i| labels[i] == c as i32).count();
            assert_eq!(got, 6, "class {c}");
        }
        // per-shard gammas partition the shard, so the total partitions n
        assert!((sel.gamma.iter().sum::<f32>() - 240.0).abs() < 1e-3);
        // labels absent -> exact fallback
        let g2 = Ground { gl: &gl, al: Some(&al), labels: None };
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let a = strat.select(&g2, 12, &mut r1, &FacilitySelector);
        let b = SelectionStrategy::Exact.select(&g2, 12, &mut r2, &FacilitySelector);
        assert_eq!(a.idx, b.idx);
    }

    #[test]
    fn clustered_expands_winners_to_budget() {
        let (al, gl, labels) = fixture(300, 4, 62);
        let g = Ground { gl: &gl, al: Some(&al), labels: Some(&labels) };
        let mut rng = Rng::new(11);
        let strat = SelectionStrategy::Clustered { k: 30 };
        let sel = strat.select(&g, 24, &mut rng, &FacilitySelector);
        assert_eq!(sel.idx.len(), 24, "expansion fills the budget exactly");
        let uniq: std::collections::HashSet<_> = sel.idx.iter().collect();
        assert_eq!(uniq.len(), 24, "buckets are disjoint, so picks are unique");
        assert!(sel.idx.iter().all(|&i| i < 300));
        assert!(sel.gamma.iter().all(|&ga| ga >= 0.0));
    }

    #[test]
    fn knn_strategy_selects_reasonable_coreset() {
        // two well-separated blobs: sparse-knn greedy must cover both
        let n = 256;
        let mut gl = random_mat(n, 4, 63);
        for i in n / 2..n {
            for v in gl.row_mut(i) {
                *v += 25.0;
            }
        }
        let g = Ground { gl: &gl, al: None, labels: None };
        let mut rng = Rng::new(13);
        let strat = SelectionStrategy::Knn { neighbors: 16 };
        let sel = strat.select(&g, 8, &mut rng, &FacilitySelector);
        assert_eq!(sel.idx.len(), 8);
        assert!(sel.idx.iter().any(|&i| i < n / 2));
        assert!(sel.idx.iter().any(|&i| i >= n / 2));
        let exact_cost = {
            let mut r = Rng::new(13);
            let e = SelectionStrategy::Exact.select(&g, 8, &mut r, &FacilitySelector);
            coverage_cost(&gl, &e.idx)
        };
        let knn_cost = coverage_cost(&gl, &sel.idx);
        assert!(
            knn_cost <= exact_cost * 2.0 + 1e-6,
            "sparse coverage {knn_cost} vs exact {exact_cost}"
        );
    }

    #[test]
    fn knn_falls_back_for_non_metric_selectors() {
        let (_, gl, _) = fixture(64, 4, 64);
        let g = Ground { gl: &gl, al: None, labels: None };
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = SelectionStrategy::Knn { neighbors: 8 }.select(&g, 6, &mut r1, &TopKSelector);
        let b = SelectionStrategy::Exact.select(&g, 6, &mut r2, &TopKSelector);
        assert_eq!(a.idx, b.idx, "non-metric selector keeps its exact path");
    }

    #[test]
    fn topk_selector_orders_by_first_column_desc() {
        let gl = MatF32::from_vec(5, 1, vec![0.5, 2.0, -1.0, 2.0, 1.0]).unwrap();
        let g = Ground { gl: &gl, al: None, labels: None };
        let sel = TopKSelector.select(&g, 3, &mut Rng::new(0));
        assert_eq!(sel.idx, vec![1, 3, 4], "desc order, ties to the lower index");
        assert_eq!(sel.gamma, vec![1.0; 3]);
    }

    #[test]
    fn strategies_bitwise_deterministic_across_thread_counts() {
        let (al, gl, labels) = fixture(1024, 8, 65);
        for strat in [
            SelectionStrategy::ClassSharded { shards: 0 },
            SelectionStrategy::ClassSharded { shards: 3 },
            SelectionStrategy::Clustered { k: 64 },
            SelectionStrategy::Knn { neighbors: 24 },
        ] {
            let run = |t: usize| {
                pool::with_threads(t, || {
                    let g = Ground { gl: &gl, al: Some(&al), labels: Some(&labels) };
                    let mut rng = Rng::new(17);
                    strat.select(&g, 64, &mut rng, &FacilitySelector)
                })
            };
            let base = run(1);
            for t in [2, 4] {
                let got = run(t);
                assert_eq!(base.idx, got.idx, "{strat} threads={t}");
                let bb: Vec<u32> = base.gamma.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.gamma.iter().map(|v| v.to_bits()).collect();
                assert_eq!(bb, gb, "{strat} threads={t}");
            }
        }
    }

    #[test]
    fn facility_select_exact_matches_direct_call() {
        let (al, gl, labels) = fixture(160, 4, 66);
        let direct = facility::facility_location_prod(&al, &gl, 16);
        let via = facility_select(SelectionStrategy::Exact, &al, &gl, &labels, 16);
        assert_eq!(direct.idx, via.idx);
        let db: Vec<u32> = direct.gamma.iter().map(|v| v.to_bits()).collect();
        let vb: Vec<u32> = via.gamma.iter().map(|v| v.to_bits()).collect();
        assert_eq!(db, vb);
    }

    #[test]
    fn approximate_strategies_cut_selection_work_quality_bounded() {
        // clustered blobs: every strategy should land within a modest
        // factor of exact coverage
        let n = 512;
        let mut rng = Rng::new(67);
        let mut gl = MatF32::zeros(n, 6);
        for i in 0..n {
            let c = (i % 8) as f32 * 12.0;
            for v in gl.row_mut(i) {
                *v = c + rng.normal() * 0.3;
            }
        }
        let labels: Vec<i32> = (0..n).map(|i| (i % 8) as i32).collect();
        let g = Ground { gl: &gl, al: None, labels: Some(&labels) };
        let exact_cost = {
            let mut r = Rng::new(1);
            let e = SelectionStrategy::Exact.select(&g, 16, &mut r, &FacilitySelector);
            coverage_cost(&gl, &e.idx)
        };
        for strat in [
            SelectionStrategy::ClassSharded { shards: 0 },
            SelectionStrategy::Clustered { k: 64 },
            SelectionStrategy::Knn { neighbors: 64 },
        ] {
            let mut r = Rng::new(1);
            let sel = strat.select(&g, 16, &mut r, &FacilitySelector);
            assert_eq!(sel.idx.len(), 16, "{strat}");
            let cost = coverage_cost(&gl, &sel.idx);
            assert!(
                cost <= exact_cost * 3.0 + 1e-6,
                "{strat}: cost {cost} vs exact {exact_cost}"
            );
        }
    }
}
