//! Loss-top-k: a hard-example-mining baseline added *purely through the
//! method registry* — no edits to the config or coordinator dispatch
//! sites. It exists both as a real baseline (select the highest-loss
//! examples, the classic heuristic CREST's facility-location selection is
//! implicitly compared against) and as the in-tree proof that
//! [`MethodRegistry::register`](crate::api::MethodRegistry::register)
//! alone makes a method available to `train`, `compare`, and `sweep`.
//!
//! Selection rule: once per budgeted epoch, evaluate the whole training
//! set, keep the k = budget·n highest-loss examples (deterministic
//! tie-break by index), and stream unweighted size-m batches from that
//! pool until the next epoch boundary.

use std::time::Instant;

use anyhow::Result;

use crate::api::registry::{MethodSpec, SourceCtx};
use crate::coordinator::sources::{BatchSource, SelectionRecord, SourceStats, SourcedBatch};
use crate::coreset::strategy::{self, SelectionStrategy};
use crate::data::Dataset;
use crate::runtime::Runtime;
use crate::tensor::MatF32;
use crate::train::{evaluate, TrainState};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimers;

/// Per-epoch hard-example mining batch source; see the module docs.
pub struct LossTopKSource<'a> {
    rt: &'a Runtime,
    train: &'a Dataset,
    /// exact vs. approximate ground-set traversal (`cfg.selection`)
    selection: SelectionStrategy,
    k: usize,
    epoch_steps: usize,
    into_epoch: usize,
    /// current top-k pool (shuffled), streamed m at a time
    order: Vec<usize>,
    rng: Rng,
    n_updates: usize,
    update_steps: Vec<usize>,
}

impl<'a> LossTopKSource<'a> {
    fn reselect(
        &mut self,
        step: usize,
        state: &TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<()> {
        // lint:allow(DET-CLOCK) phase timer: feeds only the wall-clock
        // report fields that deterministic_json excludes
        let t0 = Instant::now();
        let ev = evaluate(self.rt, &state.params, self.train)?;
        // the per-example losses as a one-column ground set: under `Exact`
        // the TopK selector reproduces the historical sort (highest loss
        // first, ties toward the lower index) bit for bit, and the
        // approximate strategies shard/cluster the same view
        let losses = MatF32::from_vec(self.train.n(), 1, ev.per_ex_loss.clone())?;
        let ground = strategy::Ground { gl: &losses, al: None, labels: Some(&self.train.y) };
        let sel = self.selection.select(&ground, self.k, &mut self.rng, &strategy::TopKSelector);
        let mut order = sel.idx;
        self.rng.shuffle(&mut order);
        self.order = order;
        self.into_epoch = 0;
        self.n_updates += 1;
        self.update_steps.push(step);
        timers.add("selection", t0.elapsed());
        Ok(())
    }
}

impl<'a> BatchSource for LossTopKSource<'a> {
    fn next_batch(
        &mut self,
        step: usize,
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        let fresh = self.order.is_empty() || self.into_epoch >= self.epoch_steps;
        if fresh {
            self.reselect(step, state, timers)?;
        }
        let m = self.rt.man.m;
        let start = (self.into_epoch * m) % self.order.len().max(1);
        let idx: Vec<usize> =
            (0..m).map(|j| self.order[(start + j) % self.order.len()]).collect();
        self.into_epoch += 1;
        let selection =
            fresh.then(|| SelectionRecord { step, selected: self.order.clone() });
        Ok(SourcedBatch { idx, gamma: vec![1.0; m], selection })
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            n_updates: self.n_updates,
            update_steps: self.update_steps.clone(),
            ..Default::default()
        }
    }
}

fn make_loss_topk<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    let n = ctx.train.n();
    let m = ctx.rt.man.m;
    let k = ((n as f32 * ctx.cfg.budget_frac) as usize).max(m).min(n);
    Ok(Box::new(LossTopKSource {
        rt: ctx.rt,
        train: ctx.train,
        selection: ctx.cfg.selection,
        k,
        epoch_steps: (k / m).max(1),
        into_epoch: 0,
        order: Vec::new(),
        rng,
        n_updates: 0,
        update_steps: Vec::new(),
    }))
}

/// Registry spec for the `loss-topk` baseline (alias `topk`).
pub fn spec() -> MethodSpec {
    MethodSpec {
        name: "loss-topk".to_string(),
        aliases: vec!["topk".to_string()],
        help: "hard-example mining: per-epoch top-k by training loss".to_string(),
        reference: false,
        full_horizon_schedule: false,
        coreset_lr_scale: false,
        factory: Box::new(make_loss_topk),
    }
}
