//! GRADMATCH baseline (Killamsetty et al. 2021a).
//!
//! Orthogonal matching pursuit over last-layer gradient embeddings: greedily
//! pick the example whose gradient best explains the residual of the full
//! mean gradient, re-fit non-negative weights by least squares, repeat.
//!
//! As the CREST paper notes (§3), "OMP ... does not always find a large
//! enough subset. Hence, the coreset needs to be augmented with random
//! examples" — the embedding space has only `c` dimensions, so OMP
//! saturates after ≈c picks; the remainder of the k-budget is filled with
//! unit-weight random examples, exactly as in the reference implementation.

use crate::coreset::facility::Selection;
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// Solve the small ridge system `(AᵀA + λI)w = Aᵀt` by Gaussian elimination
/// with partial pivoting. `a` is column-major: s columns of dimension c.
fn solve_ridge(cols: &[&[f32]], target: &[f32], lambda: f64) -> Vec<f32> {
    let s = cols.len();
    let c = target.len();
    // normal matrix
    let mut m = vec![vec![0.0f64; s + 1]; s];
    for i in 0..s {
        for j in 0..s {
            let mut dot = 0.0f64;
            for k in 0..c {
                dot += cols[i][k] as f64 * cols[j][k] as f64;
            }
            m[i][j] = dot + if i == j { lambda } else { 0.0 };
        }
        let mut rhs = 0.0f64;
        for k in 0..c {
            rhs += cols[i][k] as f64 * target[k] as f64;
        }
        m[i][s] = rhs;
    }
    // elimination
    for col in 0..s {
        let piv = (col..s).max_by(|&a, &b| m[a][col].abs().partial_cmp(&m[b][col].abs()).unwrap());
        let piv = piv.unwrap();
        m.swap(col, piv);
        let d = m[col][col];
        if d.abs() < 1e-12 {
            continue;
        }
        for row in 0..s {
            if row == col {
                continue;
            }
            let f = m[row][col] / d;
            for k in col..=s {
                m[row][k] -= f * m[col][k];
            }
        }
    }
    (0..s)
        .map(|i| {
            let d = m[i][i];
            if d.abs() < 1e-12 {
                0.0
            } else {
                (m[i][s] / d) as f32
            }
        })
        .collect()
}

/// OMP gradient matching: select up to k examples with weights so that
/// `Σ w_j g_j ≈ n · mean(g)`. Saturated budget is filled with random
/// unit-weight examples.
pub fn gradmatch_select(gl_full: &MatF32, k: usize, rng: &mut Rng) -> Selection {
    let n = gl_full.rows;
    let c = gl_full.cols;
    let k = k.min(n);
    let target = gl_full.mean_row(); // match the mean gradient
    let mut residual: Vec<f32> = target.clone();
    let mut picked: Vec<usize> = Vec::new();
    let mut weights: Vec<f32> = Vec::new();
    let max_omp = k.min(2 * c); // OMP effective capacity in a c-dim space
    let eps = 1e-4 * crate::util::stats::norm2(&target).max(1e-12);
    for _ in 0..max_omp {
        if crate::util::stats::norm2(&residual) < eps {
            break;
        }
        // argmax correlation with the residual
        let mut best = (usize::MAX, 0.0f64);
        for j in 0..n {
            if picked.contains(&j) {
                continue;
            }
            let corr = crate::util::stats::dot(gl_full.row(j), &residual).abs();
            if corr > best.1 {
                best = (j, corr);
            }
        }
        if best.0 == usize::MAX {
            break;
        }
        picked.push(best.0);
        // refit non-negative weights on the picked set
        let cols: Vec<&[f32]> = picked.iter().map(|&j| gl_full.row(j)).collect();
        let w = solve_ridge(&cols, &target, 1e-6);
        weights = w.into_iter().map(|x| x.max(0.0)).collect();
        // new residual
        residual = target.clone();
        for (p, &j) in picked.iter().enumerate() {
            for (rk, &g) in residual.iter_mut().zip(gl_full.row(j)) {
                *rk -= weights[p] * g;
            }
        }
    }
    // random augmentation to reach k (paper §3); dense membership mask
    // instead of a hash set so the loop is allocation- and hash-free
    let mut in_set = vec![false; n];
    for &j in &picked {
        in_set[j] = true;
    }
    while picked.len() < k {
        let j = rng.gen_range(n);
        if !in_set[j] {
            in_set[j] = true;
            picked.push(j);
            weights.push(1.0);
        }
    }
    // rescale so Σγ = n (same convention as facility location weights)
    let sum: f32 = weights.iter().sum();
    let scale = if sum > 0.0 { n as f32 / sum } else { 1.0 };
    for w in weights.iter_mut() {
        *w *= scale;
    }
    Selection { idx: picked, gamma: weights }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn embed(rows: &[&[f32]]) -> MatF32 {
        let c = rows[0].len();
        let mut m = MatF32::zeros(rows.len(), c);
        for (i, r) in rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        m
    }

    #[test]
    fn solve_ridge_exact_square() {
        // cols = e1, e2; target = [3, 4] -> w = [3, 4]
        let c1 = [1.0f32, 0.0];
        let c2 = [0.0f32, 1.0];
        let w = solve_ridge(&[&c1, &c2], &[3.0, 4.0], 0.0);
        assert!((w[0] - 3.0).abs() < 1e-5 && (w[1] - 4.0).abs() < 1e-5);
    }

    #[test]
    fn omp_reconstructs_sparse_combination() {
        // ground set: 2 informative directions + noise rows
        let g = embed(&[
            &[1.0, 0.0, 0.0],
            &[0.0, 1.0, 0.0],
            &[0.01, 0.0, 0.02],
            &[0.0, 0.01, 0.01],
        ]);
        let mut rng = Rng::new(1);
        let sel = gradmatch_select(&g, 2, &mut rng);
        assert_eq!(sel.idx.len(), 2);
        // must include the two informative rows
        assert!(sel.idx.contains(&0) && sel.idx.contains(&1));
    }

    #[test]
    fn gamma_sums_to_n_and_nonnegative() {
        let mut rng = Rng::new(2);
        let mut data = MatF32::zeros(50, 5);
        let mut r2 = Rng::new(3);
        for v in data.data.iter_mut() {
            *v = r2.normal();
        }
        let sel = gradmatch_select(&data, 20, &mut rng);
        assert_eq!(sel.idx.len(), 20);
        assert!(sel.gamma.iter().all(|&g| g >= 0.0));
        let sum: f32 = sel.gamma.iter().sum();
        assert!((sum - 50.0).abs() < 1e-2, "sum {sum}");
        // indices unique
        let set: std::collections::HashSet<_> = sel.idx.iter().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn saturates_then_pads_with_random() {
        // 1-dim embeddings: OMP can use at most ~2 informative picks
        let mut data = MatF32::zeros(30, 1);
        let mut r = Rng::new(4);
        for v in data.data.iter_mut() {
            *v = r.normal();
        }
        let mut rng = Rng::new(5);
        let sel = gradmatch_select(&data, 10, &mut rng);
        assert_eq!(sel.idx.len(), 10, "random augmentation fills the budget");
    }
}
