//! Facility-location submodular maximization — the selection engine.
//!
//! Greedy maximization of `C - Σ_i min_{j∈S} ||g_i - g_j||²` (paper Eq. 5 /
//! Eq. 11) with **lazy evaluation** (Minoux 1978): marginal gains are
//! monotone non-increasing, so stale heap entries upper-bound true gains and
//! most candidates are never re-scored. Gamma weights are cluster sizes —
//! the per-element step sizes of Eq. (4).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::Range;

use crate::kernel::{self, dot4};
use crate::tensor::MatF32;
use crate::util::pool::Pool;

// ---------------------------------------------------------------- threading
//
// The O(n·|candidates|) scans parallelize two ways: candidate-level loops
// (first-medoid scan, heap seeding, stochastic scoring, `best_untaken`)
// fan candidates out to the pool and fold results in index order, which
// reproduces the serial tie-breaking exactly; single-candidate gains sum
// over elements in fixed GAIN_CHUNK-sized chunks folded in chunk order.
// Both schemes are independent of the worker count, so every selection is
// bitwise-identical at `--threads 1` and `--threads N`.
//
// Within one chunk the distances come from `SqDistMetric::sqdist_block`,
// the block-at-a-time kernel: one candidate against a whole contiguous
// element range through the cache-blocked dot panels in `crate::kernel`.
// Block boundaries are a function of the chunk layout only, and every
// panel value is bitwise-identical to the scalar `sqdist`, so blocking
// changes speed, never results.

/// Fixed chunk length for gain reductions (boundaries depend only on the
/// element count, never the thread count).
const GAIN_CHUNK: usize = 512;
/// Minimum elements in one gain before its inner reduction fans out (the
/// candidate-level loops are the cheaper parallelism when both apply —
/// nested calls from pool workers run inline automatically).
const GAIN_PAR_MIN: usize = 16 * GAIN_CHUNK;
/// Minimum sqdist evaluations before a candidate-level scan fans out.
const PAR_MIN_WORK: usize = 1 << 16;
/// Minimum elements before the per-element min-distance update fans out.
const MIND_PAR_MIN: usize = 1 << 14;

/// Sum `part` over fixed GAIN_CHUNK-sized chunks of `0..n`, folding the
/// partials in chunk order — a thread-count-independent f32 reduction.
fn chunked_sum(n: usize, part: impl Fn(Range<usize>) -> f32 + Sync) -> f32 {
    if n < GAIN_PAR_MIN {
        // allocation-free fast path for the lazy-greedy inner loop: same
        // chunk boundaries and left-to-right fold as the pooled branch
        // (`sum()` over collected partials), so results are identical
        let mut s = 0.0f32;
        let mut c = 0;
        while c < n {
            s += part(c..(c + GAIN_CHUNK).min(n));
            c += GAIN_CHUNK;
        }
        return s;
    }
    Pool::global().map_chunks(n, GAIN_CHUNK, part).into_iter().sum()
}

/// Result of one selection: indices into the ground set + gamma weights.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Selected positions within the ground set.
    pub idx: Vec<usize>,
    /// Per-medoid cluster-size weights (unnormalized).
    pub gamma: Vec<f32>,
}

impl Selection {
    /// Scale gammas so a size-m weighted batch is an unbiased estimator of
    /// the ground set's mean loss: γ' = γ · m / Σγ.
    pub fn normalized_gamma(&self, m: usize) -> Vec<f32> {
        let sum: f32 = self.gamma.iter().sum();
        if sum <= 0.0 {
            return vec![1.0; self.gamma.len()];
        }
        self.gamma.iter().map(|&g| g * m as f32 / sum).collect()
    }
}

struct HeapItem {
    gain: f32,
    cand: usize,
    /// selection round when this gain was computed (staleness marker)
    round: usize,
}

// Ordering must be *total* even for NaN gains: a NaN-producing metric (e.g.
// embeddings from a diverged model) under `partial_cmp(..).unwrap_or(Equal)`
// silently violates the BinaryHeap invariants and corrupts lazy-greedy
// order. `f32::total_cmp` ranks +NaN above +inf, so poisoned entries surface
// at the top instead of scrambling the heap.
impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain.total_cmp(&other.gain)
    }
}

/// A squared-distance metric over a ground set of embeddings. `Sync` so
/// the gain scans can share the metric across pool workers.
pub trait SqDistMetric: Sync {
    /// Size of the ground set.
    fn len(&self) -> usize;
    /// Squared distance between ground-set elements `i` and `j`.
    fn sqdist(&self, i: usize, j: usize) -> f32;
    /// Squared distances from candidate `j` to every element of `range`,
    /// written to `out` (`out.len() == range.len()`). The default is the
    /// scalar loop; tiled overrides must produce bitwise-identical values
    /// (asserted by the `kernels` equivalence tests), so the scans below
    /// may consume blocks without affecting any selection.
    fn sqdist_block(&self, j: usize, range: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        for (o, i) in out.iter_mut().zip(range) {
            *o = self.sqdist(j, i);
        }
    }
    /// True when the metric is already a precomputed distance table, so
    /// the entry points must not re-wrap it in [`GramMetric`].
    fn is_cached(&self) -> bool {
        false
    }
    /// True when the ground set is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Plain Euclidean metric over rows of one matrix, with precomputed squared
/// norms (`‖a‖²+‖b‖²−2a·b`).
pub struct EuclidMetric<'a> {
    g: &'a MatF32,
    sq: Vec<f32>,
}

impl<'a> EuclidMetric<'a> {
    /// Metric over the rows of `g`, precomputing the squared norms on the
    /// same unrolled dot kernel the distances use.
    pub fn new(g: &'a MatF32) -> Self {
        let sq = (0..g.rows)
            .map(|i| {
                let r = g.row(i);
                dot4(r, r)
            })
            .collect();
        EuclidMetric { g, sq }
    }
}

impl<'a> SqDistMetric for EuclidMetric<'a> {
    fn len(&self) -> usize {
        self.g.rows
    }

    #[inline]
    fn sqdist(&self, i: usize, j: usize) -> f32 {
        let dot = dot4(self.g.row(i), self.g.row(j));
        (self.sq[i] + self.sq[j] - 2.0 * dot).max(0.0)
    }

    fn sqdist_block(&self, j: usize, range: Range<usize>, out: &mut [f32]) {
        kernel::euclid_block(self.g, &self.sq, j, range, out);
    }
}

/// Last-layer *weight*-gradient metric: example i's gradient is the outer
/// product `a_i ⊗ g_i`, whose pairwise Frobenius distance factorizes as
/// `|a_i|²|g_i|² + |a_j|²|g_j|² − 2(a_i·a_j)(g_i·g_j)` — the same metric as
/// the `pairwise_gradprod` Pallas kernel in `python/compile/kernels/`.
pub struct ProdMetric<'a> {
    a: &'a MatF32,
    g: &'a MatF32,
    sq: Vec<f32>,
}

impl<'a> ProdMetric<'a> {
    /// Metric over paired activation (`a`) and logit-gradient (`g`) rows,
    /// with squared norms precomputed on the unrolled dot kernel.
    pub fn new(a: &'a MatF32, g: &'a MatF32) -> Self {
        assert_eq!(a.rows, g.rows, "ProdMetric: row mismatch");
        let sq = (0..a.rows)
            .map(|i| {
                let ra = a.row(i);
                let rg = g.row(i);
                dot4(ra, ra) * dot4(rg, rg)
            })
            .collect();
        ProdMetric { a, g, sq }
    }
}

impl<'a> SqDistMetric for ProdMetric<'a> {
    fn len(&self) -> usize {
        self.a.rows
    }

    #[inline]
    fn sqdist(&self, i: usize, j: usize) -> f32 {
        let aa = dot4(self.a.row(i), self.a.row(j));
        let gg = dot4(self.g.row(i), self.g.row(j));
        (self.sq[i] + self.sq[j] - 2.0 * aa * gg).max(0.0)
    }

    fn sqdist_block(&self, j: usize, range: Range<usize>, out: &mut [f32]) {
        kernel::prod_block(self.a, self.g, &self.sq, j, range, out);
    }
}

// -------------------------------------------------------------- gram cache

/// Default element cap for the opt-in Gram cache: a 2²⁴-element table
/// (64 MB of f32) covers ground sets up to n = 4096.
pub const DEFAULT_GRAM_CAP: usize = 1 << 24;

/// Parse a `CREST_GRAM_CACHE` value into an element cap: unset / `0` /
/// `false` disables caching, `1` / `true` selects [`DEFAULT_GRAM_CAP`],
/// any other positive integer is the cap in table elements (n²).
pub fn gram_cap(val: Option<&str>) -> Option<usize> {
    match val {
        None | Some("") | Some("0") | Some("false") => None,
        Some("1") | Some("true") => Some(DEFAULT_GRAM_CAP),
        Some(v) => v.parse::<usize>().ok().filter(|&c| c > 0),
    }
}

/// Opt-in precomputed distance table over any inner metric.
///
/// For ground sets small enough that the n×n table fits the budget, the
/// O(n·m·|candidates|) greedy scans collapse to table lookups after one
/// O(n²) blocked precompute pass. Every table entry comes from the inner
/// metric's own `sqdist_block`, so selections through the cache are
/// bitwise-identical to selections against the inner metric.
pub struct GramMetric {
    n: usize,
    d: Vec<f32>,
}

impl GramMetric {
    /// Precompute the full pairwise table (row-parallel; each table row is
    /// written by exactly one worker, so the table is thread-count
    /// independent).
    pub fn new<M: SqDistMetric + ?Sized>(inner: &M) -> GramMetric {
        let n = inner.len();
        if n == 0 {
            return GramMetric { n, d: Vec::new() };
        }
        let mut d = vec![0.0f32; n * n];
        Pool::gated(n * n, PAR_MIN_WORK).for_rows(&mut d, n, 1, |j, row| {
            inner.sqdist_block(j, 0..n, row);
        });
        GramMetric { n, d }
    }

    /// Cache `inner` when the runtime config (`CREST_GRAM_CACHE` or a
    /// session [`RuntimeConfig`](crate::runtime_config::RuntimeConfig)
    /// override) opts in and `n²` fits the configured cap; `None` leaves
    /// the caller on the uncached metric.
    pub fn try_cache<M: SqDistMetric + ?Sized>(inner: &M) -> Option<GramMetric> {
        if inner.is_cached() {
            return None;
        }
        let cap = crate::runtime_config::RuntimeConfig::current().gram_cache?;
        let n = inner.len();
        if n == 0 || n.saturating_mul(n) > cap {
            return None;
        }
        Some(GramMetric::new(inner))
    }
}

impl SqDistMetric for GramMetric {
    fn len(&self) -> usize {
        self.n
    }

    #[inline]
    fn sqdist(&self, i: usize, j: usize) -> f32 {
        self.d[i * self.n + j]
    }

    fn sqdist_block(&self, j: usize, range: Range<usize>, out: &mut [f32]) {
        out.copy_from_slice(&self.d[j * self.n + range.start..j * self.n + range.end]);
    }

    fn is_cached(&self) -> bool {
        true
    }
}

// --------------------------------------------------------- sparse k-NN

/// Deterministic random-projection value of every row of `feat`: one
/// gaussian direction drawn from the fixed `seed` (shape-only — the
/// direction depends on the column count, never on the data), dotted with
/// each row on the same unrolled kernel the metrics use. Row values are
/// independent, so the parallel map is thread-count invariant.
pub(crate) fn projection_values(feat: &MatF32, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let dir: Vec<f32> = (0..feat.cols).map(|_| rng.normal()).collect();
    Pool::gated(feat.rows * feat.cols.max(1), PAR_MIN_WORK)
        .map(feat.rows, |i| dot4(feat.row(i), &dir))
}

/// Row indices of `feat` sorted by projection value (ties broken by index)
/// — a deterministic 1-D locality ordering shared by the sparse k-NN
/// candidate windows and the clustered-selection buckets. Public so the
/// property suite can verify [`SparseKnnMetric`]'s candidate-window
/// bounds against the exact ordering the build used.
pub fn projection_order(feat: &MatF32, seed: u64) -> Vec<usize> {
    let proj = projection_values(feat, seed);
    let mut order: Vec<usize> = (0..feat.rows).collect();
    order.sort_unstable_by(|&a, &b| proj[a].total_cmp(&proj[b]).then(a.cmp(&b)));
    order
}

/// Fixed seed of the k-NN candidate-window projection (any constant works;
/// it only has to be the same for every build of the same shape). Public
/// alongside [`projection_order`] for the candidate-window bound tests.
pub const KNN_PROJ_SEED: u64 = 0x5eed_4b8a_11ce_7e01;

/// Sparse k-nearest-neighbor squared-distance metric.
///
/// Instead of the full n×n panel, each ground-set element keeps its
/// `neighbors` nearest candidates (by the inner metric, searched inside a
/// random-projection rank window), and every other pair reports one finite
/// `far` sentinel distance. Greedy gain scans against this metric touch
/// O(n·neighbors) entries per pass instead of O(n²) — the sparse mode of
/// CRAIG's reference implementation.
///
/// The stored lists are *row-oriented*: `sqdist(j, i)` answers "distance
/// from candidate `j` to element `i`" out of row `j`'s list, which is the
/// orientation every scan in this module uses (candidate first). Pairs
/// outside the list are `far` in both orientations, but listed pairs are
/// only guaranteed exact in candidate-row order — the metric trades exact
/// symmetry for O(neighbors) rows, which changes approximation quality,
/// never determinism.
///
/// Construction is deterministic and thread-count invariant: the candidate
/// window comes from the shape-only projection ordering, per-row searches
/// are independent, and the `far` sentinel folds row maxima in index order.
pub struct SparseKnnMetric {
    n: usize,
    /// neighbors kept per row (uniform across rows)
    k: usize,
    /// per-row neighbor ids, ascending within each row (`n * k` entries)
    ids: Vec<u32>,
    /// inner-metric distances aligned with `ids`
    d: Vec<f32>,
    /// finite stand-in distance for every non-neighbor pair
    far: f32,
}

impl SparseKnnMetric {
    /// Precompute the neighbor lists of `inner` (whose element order must
    /// match the rows of `feat`, the embedding matrix used for the
    /// candidate-window projection). `neighbors` counts the element itself;
    /// it is clamped to `[1, n]`.
    pub fn build<M: SqDistMetric + ?Sized>(
        inner: &M,
        feat: &MatF32,
        neighbors: usize,
    ) -> SparseKnnMetric {
        let n = inner.len();
        assert_eq!(feat.rows, n, "SparseKnnMetric: feature rows must match the metric");
        if n == 0 {
            return SparseKnnMetric { n, k: 0, ids: Vec::new(), d: Vec::new(), far: 1.0 };
        }
        let k = neighbors.clamp(1, n);
        let order = projection_order(feat, KNN_PROJ_SEED);
        let mut rank = vec![0u32; n];
        for (p, &i) in order.iter().enumerate() {
            rank[i] = p as u32;
        }
        // Candidate window: the k projection-ranks on either side of each
        // row's own rank — 2k+1 candidates interior, never fewer than k+1
        // at the edges, so every row keeps exactly k entries.
        let rows: Vec<(Vec<u32>, Vec<f32>)> =
            Pool::gated(n * (2 * k + 1), PAR_MIN_WORK).map(n, |i| {
                let p = rank[i] as usize;
                let lo = p.saturating_sub(k);
                let hi = (p + k + 1).min(n);
                let mut cand: Vec<(f32, u32)> = (lo..hi)
                    .map(|q| {
                        let j = order[q];
                        (inner.sqdist(i, j), j as u32)
                    })
                    .collect();
                cand.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                cand.truncate(k);
                cand.sort_unstable_by_key(|c| c.1);
                (cand.iter().map(|c| c.1).collect(), cand.iter().map(|c| c.0).collect())
            });
        let mut ids = Vec::with_capacity(n * k);
        let mut d = Vec::with_capacity(n * k);
        let mut maxd = 0.0f32;
        for (rid, rd) in rows {
            for &v in &rd {
                if v > maxd {
                    maxd = v;
                }
            }
            ids.extend_from_slice(&rid);
            d.extend_from_slice(&rd);
        }
        // finite sentinel strictly beyond every kept distance: INF here
        // would put INF−INF = NaN into the gain arithmetic
        let far = if maxd > 0.0 { 2.0 * maxd } else { 1.0 };
        SparseKnnMetric { n, k, ids, d, far }
    }

    /// Neighbors kept per element (after clamping).
    pub fn neighbors(&self) -> usize {
        self.k
    }

    /// The finite sentinel distance reported for non-neighbor pairs.
    pub fn far(&self) -> f32 {
        self.far
    }

    #[inline]
    fn row_ids(&self, j: usize) -> &[u32] {
        &self.ids[j * self.k..(j + 1) * self.k]
    }
}

impl SqDistMetric for SparseKnnMetric {
    fn len(&self) -> usize {
        self.n
    }

    #[inline]
    fn sqdist(&self, i: usize, j: usize) -> f32 {
        if i == j {
            return 0.0;
        }
        match self.row_ids(i).binary_search(&(j as u32)) {
            Ok(p) => self.d[i * self.k + p],
            Err(_) => self.far,
        }
    }

    fn sqdist_block(&self, j: usize, range: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        out.fill(self.far);
        for (p, &id) in self.row_ids(j).iter().enumerate() {
            let id = id as usize;
            if range.contains(&id) {
                out[id - range.start] = self.d[j * self.k + p];
            }
        }
        if range.contains(&j) {
            out[j - range.start] = 0.0;
        }
    }

    fn is_cached(&self) -> bool {
        // already a precomputed table: densifying it through GramMetric
        // would undo the whole point
        true
    }
}

// ------------------------------------------------------------- gain scans

/// Marginal gain of candidate `j` given current min-distances, summed in
/// fixed chunks (see [`GAIN_CHUNK`]) for thread-count independence. Each
/// chunk's distances come from one `sqdist_block` call.
fn gain<M: SqDistMetric + ?Sized>(ctx: &M, mind: &[f32], j: usize) -> f32 {
    chunked_sum(mind.len(), |range| {
        let mut buf = [0.0f32; GAIN_CHUNK];
        let b = &mut buf[..range.len()];
        ctx.sqdist_block(j, range.clone(), b);
        let mut s = 0.0f32;
        for (&d, &mv) in b.iter().zip(&mind[range]) {
            if d < mv {
                s += mv - d;
            }
        }
        s
    })
}

/// Dense marginal-gain scan of every candidate against `mind` — the heap
/// seeding pass of the lazy greedy, exposed for `benches/perf.rs` and the
/// kernel equivalence tests.
pub fn gain_scan<M: SqDistMetric + ?Sized>(ctx: &M, mind: &[f32]) -> Vec<f32> {
    Pool::gated(ctx.len() * mind.len(), PAR_MIN_WORK).map(ctx.len(), |j| gain(ctx, mind, j))
}

/// Gain restricted to the still-uncovered elements. Elements whose
/// min-distance has fallen below `floor` can contribute at most `floor`
/// each, so skipping them changes any gain by < active_floor_mass — the
/// hot-loop optimization measured by `benches/perf.rs`.
fn gain_active<M: SqDistMetric + ?Sized>(ctx: &M, mind: &[f32], active: &[u32], j: usize) -> f32 {
    // dense scan is faster until the list actually thins out
    if active.len() == mind.len() {
        return gain(ctx, mind, j);
    }
    chunked_sum(active.len(), |range| {
        let mut s = 0.0f32;
        for &i in &active[range] {
            let i = i as usize;
            let d = ctx.sqdist(j, i);
            if d < mind[i] {
                s += mind[i] - d;
            }
        }
        s
    })
}

/// Lower `mind` against the distances to a freshly selected medoid `j`
/// (element-wise over blocked distances, hence thread-count independent).
fn update_mind<M: SqDistMetric + ?Sized>(ctx: &M, mind: &mut [f32], j: usize) {
    Pool::gated(mind.len(), MIND_PAR_MIN).for_rows(mind, 1, GAIN_CHUNK, |i0, chunk| {
        let mut buf = [0.0f32; GAIN_CHUNK];
        let b = &mut buf[..chunk.len()];
        ctx.sqdist_block(j, i0..i0 + chunk.len(), b);
        for (mv, &d) in chunk.iter_mut().zip(b.iter()) {
            if d < *mv {
                *mv = d;
            }
        }
    });
}

/// Cluster sizes under nearest-medoid assignment. The per-element nearest
/// scan keeps the serial tie-break (strict `<`, first medoid wins).
fn assign_gamma<M: SqDistMetric + ?Sized>(ctx: &M, idx: &[usize], r: usize) -> Vec<f32> {
    let assign: Vec<u32> = Pool::gated(r * idx.len(), PAR_MIN_WORK).map(r, |i| {
        let mut best = 0usize;
        let mut bd = f32::INFINITY;
        for (s, &j) in idx.iter().enumerate() {
            let d = ctx.sqdist(j, i);
            if d < bd {
                bd = d;
                best = s;
            }
        }
        best as u32
    });
    let mut gamma = vec![0.0f32; idx.len()];
    for &a in &assign {
        gamma[a as usize] += 1.0;
    }
    gamma
}

/// Rebuild the active-element list: keep elements whose residual
/// min-distance is above a small fraction of the mean initial coverage.
fn rebuild_active(mind: &[f32], floor: f32) -> Vec<u32> {
    (0..mind.len()).filter(|&i| mind[i] > floor).map(|i| i as u32).collect()
}

/// Select `m` medoids from the rows of `g` (Euclidean metric) by lazy
/// greedy facility location.
pub fn facility_location(g: &MatF32, m: usize) -> Selection {
    facility_location_metric(&EuclidMetric::new(g), m)
}

/// Facility location under the last-layer weight-gradient metric
/// (activations `a` + logit gradients `g`).
pub fn facility_location_prod(a: &MatF32, g: &MatF32, m: usize) -> Selection {
    facility_location_metric(&ProdMetric::new(a, g), m)
}

/// Lazy-greedy facility location over any squared-distance metric.
/// Returns gamma weights (cluster sizes summing to the ground-set size).
/// With `CREST_GRAM_CACHE` opted in (and `n²` under the cap) the scans run
/// against a precomputed [`GramMetric`] table — same selection, fewer
/// flops.
pub fn facility_location_metric<M: SqDistMetric + ?Sized>(ctx: &M, m: usize) -> Selection {
    match GramMetric::try_cache(ctx) {
        Some(gram) => lazy_greedy(&gram, m),
        None => lazy_greedy(ctx, m),
    }
}

/// The lazy-greedy core behind [`facility_location_metric`].
fn lazy_greedy<M: SqDistMetric + ?Sized>(ctx: &M, m: usize) -> Selection {
    let r = ctx.len();
    assert!(m >= 1 && m <= r, "facility_location: m={m} out of range for r={r}");
    // Round 0 has no finite gains (empty assignment): the 1-medoid is the
    // candidate minimizing total distance. Scanned candidate-parallel over
    // blocked distances (elements accumulate in ascending order within
    // each candidate) and folded in index order (strict `<` keeps the
    // serial tie-break).
    let totals: Vec<f32> = Pool::gated(r * r, PAR_MIN_WORK).map(r, |j| {
        let mut buf = [0.0f32; GAIN_CHUNK];
        let mut tot = 0.0f32;
        let mut c = 0;
        while c < r {
            let e = (c + GAIN_CHUNK).min(r);
            let b = &mut buf[..e - c];
            ctx.sqdist_block(j, c..e, b);
            for &v in b.iter() {
                tot += v;
            }
            c = e;
        }
        tot
    });
    let mut first = (0usize, f32::INFINITY);
    for (j, &tot) in totals.iter().enumerate() {
        if tot < first.1 {
            first = (j, tot);
        }
    }
    let j0 = first.0;
    let mut mind = vec![0.0f32; r];
    Pool::gated(r, MIND_PAR_MIN).for_rows(&mut mind, 1, GAIN_CHUNK, |i0, chunk| {
        ctx.sqdist_block(j0, i0..i0 + chunk.len(), chunk);
    });
    let mut idx = Vec::with_capacity(m);
    idx.push(j0);
    // covered-element skip threshold: a small fraction of the mean initial
    // coverage (elements this close to a medoid cannot change greedy order)
    let floor = 1e-4 * (mind.iter().map(|&v| v as f64).sum::<f64>() / r as f64) as f32;
    let mut active = rebuild_active(&mind, floor);
    // Seed the heap with *exact* round-1 gains (one candidate-parallel
    // pass). Gains are monotone non-increasing from here, so stale heap
    // entries are valid upper bounds — the lazy-greedy invariant.
    let seed_gains: Vec<f32> = Pool::gated(r * active.len(), PAR_MIN_WORK).map(r, |j| {
        if j == j0 {
            0.0
        } else {
            gain_active(ctx, &mind, &active, j)
        }
    });
    let mut heap = BinaryHeap::with_capacity(r);
    for (j, &g) in seed_gains.iter().enumerate() {
        if j == j0 {
            continue;
        }
        heap.push(HeapItem { gain: g, cand: j, round: 1 });
    }
    let mut round = 1usize;
    while idx.len() < m {
        let top = heap.pop().expect("heap never empties before m selections");
        if top.round == round {
            // fresh gain: select
            let j = top.cand;
            update_mind(ctx, &mut mind, j);
            idx.push(j);
            round += 1;
            if active.len() > 32 {
                active = rebuild_active(&mind, floor);
            }
        } else {
            // stale: re-score against current mins and push back
            let gnew = gain_active(ctx, &mind, &active, top.cand);
            heap.push(HeapItem { gain: gnew, cand: top.cand, round });
        }
    }
    let gamma = assign_gamma(ctx, &idx, r);
    Selection { idx, gamma }
}

/// Highest-gain untaken candidate under the current min-distances — the
/// scored fallback of stochastic greedy for rounds where every sampled
/// candidate was already taken.
fn best_untaken<M: SqDistMetric + ?Sized>(
    ctx: &M,
    mind: &[f32],
    active: &[u32],
    taken: &[bool],
) -> Option<(usize, f64)> {
    // score untaken candidates in parallel, then fold in index order (the
    // serial scan's tie-breaking exactly)
    let scores: Vec<Option<f64>> = Pool::gated(taken.len() * active.len().max(1), PAR_MIN_WORK)
        .map(taken.len(), |j| {
            if taken[j] {
                return None;
            }
            let g = gain_active(ctx, mind, active, j) as f64;
            // a NaN gain (poisoned embeddings) must never beat finite
            // candidates: `g > best.1` is false for every comparison
            // against NaN, so an early NaN would otherwise win permanently
            Some(if g.is_nan() { f64::NEG_INFINITY } else { g })
        });
    let mut best = (usize::MAX, f64::NEG_INFINITY);
    for (j, score) in scores.into_iter().enumerate() {
        let Some(g) = score else { continue };
        if best.0 == usize::MAX || g > best.1 {
            best = (j, g);
        }
    }
    (best.0 != usize::MAX).then_some(best)
}

/// Stochastic ("lazier than lazy") greedy of Mirzasoleiman et al. 2015:
/// each step scores only a random candidate sample of size
/// `s = (n/m)·ln(1/ε)`, giving a (1 − 1/e − ε) guarantee in O(n·ln(1/ε))
/// gain evaluations — the standard way CRAIG scales to full-dataset
/// selection (paper challenge C3).
pub fn facility_location_stochastic<M: SqDistMetric + ?Sized>(
    ctx: &M,
    m: usize,
    rng: &mut crate::util::rng::Rng,
) -> Selection {
    match GramMetric::try_cache(ctx) {
        Some(gram) => stochastic_greedy(&gram, m, rng),
        None => stochastic_greedy(ctx, m, rng),
    }
}

/// The sampled-greedy core behind [`facility_location_stochastic`].
fn stochastic_greedy<M: SqDistMetric + ?Sized>(
    ctx: &M,
    m: usize,
    rng: &mut crate::util::rng::Rng,
) -> Selection {
    let r = ctx.len();
    assert!(m >= 1 && m <= r, "stochastic greedy: m={m} out of range for r={r}");
    let eps_ln = 2.3f64; // ln(1/ε) with ε = 0.1
    let s = (((r as f64 / m as f64) * eps_ln).ceil() as usize).clamp(8, r);
    let mut mind = vec![f32::INFINITY; r];
    let mut taken = vec![false; r];
    let mut idx = Vec::with_capacity(m);
    // For very large ground sets, score gains on a uniform element sample:
    // E[sampled gain] ∝ true gain, so greedy order is preserved in
    // expectation (sample-based greedy) while cost drops by n/sample.
    let gain_cap = 2048usize;
    let mut active: Vec<u32> = if r > gain_cap {
        let mut v = rng.sample_indices(r, gain_cap);
        v.sort_unstable();
        v.into_iter().map(|i| i as u32).collect()
    } else {
        (0..r as u32).collect()
    };
    let sampled_ground = r > gain_cap;
    let mut floor = 0.0f32;
    for round in 0..m {
        // draw the candidate sample serially (one RNG stream), score the
        // draws in parallel, then fold in draw order — identical picks to
        // the sequential scan at every thread count
        let sample: Vec<usize> = (0..s).map(|_| rng.gen_range(r)).collect();
        let scores: Vec<Option<f64>> =
            Pool::gated(sample.len() * active.len().max(1), PAR_MIN_WORK)
                .map(sample.len(), |si| {
                    let j = sample[si];
                    if taken[j] {
                        return None;
                    }
                    Some(if round == 0 {
                        // empty assignment: minimize total distance (over
                        // the gain sample when the ground set is large)
                        let mut tot = 0.0f64;
                        for &i in &active {
                            tot += ctx.sqdist(j, i as usize) as f64;
                        }
                        -tot
                    } else {
                        gain_active(ctx, &mind, &active, j) as f64
                    })
                });
        let mut best = (usize::MAX, f64::NEG_INFINITY);
        for (si, score) in scores.into_iter().enumerate() {
            let Some(g) = score else { continue };
            if g > best.1 {
                best = (sample[si], g);
            }
        }
        if best.0 == usize::MAX {
            // All sampled candidates were already taken. Score the remaining
            // untaken candidates against the current min-distances instead
            // of grabbing the first untaken index blind — index order is
            // arbitrary, so the blind pick can be a duplicate of an existing
            // medoid while a zero-cost cluster sits uncovered.
            match best_untaken(ctx, &mind, &active, &taken) {
                Some(pick) => best = pick,
                None => break,
            }
        }
        let j = best.0;
        taken[j] = true;
        update_mind(ctx, &mut mind, j);
        idx.push(j);
        if round == 0 {
            floor = 1e-4
                * (mind.iter().map(|&v| v as f64).sum::<f64>() / r as f64) as f32;
        }
        // covered elements cannot change future gains materially: skip them
        // (when the ground set is subsampled, thin the sample instead)
        if !sampled_ground && (round % 8 == 0 || active.len() > 4 * (r / (round + 1))) {
            active = rebuild_active(&mind, floor);
        } else if sampled_ground {
            active.retain(|&i| mind[i as usize] > floor);
        }
    }
    let gamma = assign_gamma(ctx, &idx, r);
    Selection { idx, gamma }
}

/// Facility-location objective value of a selection (for tests/benches):
/// total min squared distance (lower is better coverage).
pub fn coverage_cost(g: &MatF32, idx: &[usize]) -> f64 {
    let ctx = EuclidMetric::new(g);
    let mut total = 0.0f64;
    for i in 0..g.rows {
        let mut bd = f32::INFINITY;
        for &j in idx {
            bd = bd.min(ctx.sqdist(j, i));
        }
        total += bd as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_embed(r: usize, c: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        let mut m = MatF32::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn clustered_embed(clusters: usize, per: usize, c: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        let mut centers = MatF32::zeros(clusters, c);
        for v in centers.data.iter_mut() {
            *v = rng.normal() * 10.0;
        }
        let mut m = MatF32::zeros(clusters * per, c);
        for i in 0..clusters * per {
            let ctr = centers.row(i / per);
            for (o, &b) in m.row_mut(i).iter_mut().zip(ctr) {
                *o = b + rng.normal() * 0.05;
            }
        }
        m
    }

    #[test]
    fn gamma_sums_to_ground_set_size() {
        let g = random_embed(100, 8, 1);
        for m in [1, 5, 32] {
            let s = facility_location(&g, m);
            assert_eq!(s.idx.len(), m);
            assert_eq!(s.gamma.len(), m);
            let sum: f32 = s.gamma.iter().sum();
            assert_eq!(sum, 100.0, "m={m}");
        }
    }

    #[test]
    fn indices_unique_and_in_range() {
        let g = random_embed(64, 4, 2);
        let s = facility_location(&g, 16);
        let set: std::collections::HashSet<_> = s.idx.iter().collect();
        assert_eq!(set.len(), 16);
        assert!(s.idx.iter().all(|&i| i < 64));
    }

    #[test]
    fn recovers_cluster_medoids() {
        let g = clustered_embed(8, 8, 6, 3);
        let s = facility_location(&g, 8);
        let clusters: std::collections::HashSet<_> = s.idx.iter().map(|&i| i / 8).collect();
        assert_eq!(clusters.len(), 8, "one medoid per cluster");
        for &ga in &s.gamma {
            assert_eq!(ga, 8.0);
        }
    }

    #[test]
    fn lazy_matches_naive_greedy_cost() {
        // exhaustive greedy reference
        let g = random_embed(40, 5, 4);
        let m = 10;
        let lazy = facility_location(&g, m);
        // naive greedy
        let ctx_cost = |idx: &[usize]| coverage_cost(&g, idx);
        let mut naive: Vec<usize> = Vec::new();
        for _ in 0..m {
            let mut best = (usize::MAX, f64::INFINITY);
            for j in 0..40 {
                if naive.contains(&j) {
                    continue;
                }
                let mut cand = naive.clone();
                cand.push(j);
                let c = ctx_cost(&cand);
                if c < best.1 {
                    best = (j, c);
                }
            }
            naive.push(best.0);
        }
        let lc = ctx_cost(&lazy.idx);
        let nc = ctx_cost(&naive);
        assert!(lc <= nc * 1.0001 + 1e-9, "lazy {lc} vs naive {nc}");
    }

    #[test]
    fn cost_decreases_with_m() {
        let g = random_embed(80, 6, 5);
        let c4 = coverage_cost(&g, &facility_location(&g, 4).idx);
        let c16 = coverage_cost(&g, &facility_location(&g, 16).idx);
        let c40 = coverage_cost(&g, &facility_location(&g, 40).idx);
        assert!(c16 < c4);
        assert!(c40 < c16);
    }

    #[test]
    fn m_equals_r_zero_cost() {
        let g = random_embed(16, 3, 6);
        let s = facility_location(&g, 16);
        assert!(coverage_cost(&g, &s.idx) < 1e-6);
    }

    #[test]
    fn normalized_gamma_unbiased_scaling() {
        let g = random_embed(64, 4, 7);
        let s = facility_location(&g, 8);
        let gn = s.normalized_gamma(8);
        let sum: f32 = gn.iter().sum();
        assert!((sum - 8.0).abs() < 1e-4);
    }

    #[test]
    fn heap_orders_nan_gains_totally() {
        // regression: partial_cmp(..).unwrap_or(Equal) made NaN compare
        // Equal to everything, silently corrupting BinaryHeap order. Under
        // total_cmp the pop sequence is well defined: +NaN > +inf > finite.
        use std::collections::BinaryHeap;
        let mut heap = BinaryHeap::new();
        for (cand, gain) in
            [1.0f32, f32::NAN, 2.0, f32::INFINITY, -1.0].into_iter().enumerate()
        {
            heap.push(HeapItem { gain, cand, round: 0 });
        }
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop()).map(|it| it.cand).collect();
        assert_eq!(order, vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn nan_embeddings_do_not_corrupt_selection() {
        // a NaN row (e.g. diverged gradients) must not panic the lazy
        // greedy or break medoid uniqueness
        let mut g = random_embed(32, 4, 11);
        for v in g.row_mut(5) {
            *v = f32::NAN;
        }
        let s = facility_location(&g, 8);
        assert_eq!(s.idx.len(), 8);
        let uniq: std::collections::HashSet<_> = s.idx.iter().collect();
        assert_eq!(uniq.len(), 8);
        assert!(s.idx.iter().all(|&i| i < 32));
        let sum: f32 = s.gamma.iter().sum();
        assert_eq!(sum, 32.0);
    }

    #[test]
    fn stochastic_fallback_scores_untaken_candidates() {
        // Ground set: indices 0 and 1 are coincident (taking 1 after 0 gains
        // nothing), index 3 sits in a far uncovered cluster. With 0 and 2
        // taken, the scored fallback must pick 3 — the old behavior
        // ("first untaken index") would return 1.
        let g = MatF32::from_vec(
            4,
            1,
            vec![0.0, 0.0, 10.0, 100.0],
        )
        .unwrap();
        let ctx = EuclidMetric::new(&g);
        let taken = vec![true, false, true, false];
        let mind: Vec<f32> = (0..4)
            .map(|i| ctx.sqdist(0, i).min(ctx.sqdist(2, i)))
            .collect();
        let active: Vec<u32> = (0..4).collect();
        let (pick, gain) = best_untaken(&ctx, &mind, &active, &taken).unwrap();
        assert_eq!(pick, 3, "fallback must score candidates, not take the first untaken");
        assert!(gain > 0.0);
        // nothing untaken -> None
        assert!(best_untaken(&ctx, &mind, &active, &[true; 4]).is_none());
        // NaN distances (poisoned embedding row) must not corrupt the
        // fallback scoring: the finite-gain candidate still wins
        let g_nan = MatF32::from_vec(4, 1, vec![0.0, f32::NAN, 10.0, 100.0]).unwrap();
        let ctx_nan = EuclidMetric::new(&g_nan);
        let mind_nan: Vec<f32> = (0..4)
            .map(|i| ctx_nan.sqdist(0, i).min(ctx_nan.sqdist(2, i)))
            .collect();
        let (pick, _) = best_untaken(&ctx_nan, &mind_nan, &active, &taken).unwrap();
        assert_eq!(pick, 3, "NaN gain must lose to a finite gain");
    }

    #[test]
    fn stochastic_selects_all_when_m_equals_r() {
        // m = r forces the fallback path repeatedly near the end (the
        // candidate sample is mostly taken); the result must still be a
        // permutation of the ground set.
        let g = random_embed(24, 3, 12);
        let metric = EuclidMetric::new(&g);
        let mut rng = Rng::new(13);
        let s = facility_location_stochastic(&metric, 24, &mut rng);
        let mut idx = s.idx.clone();
        idx.sort_unstable();
        assert_eq!(idx, (0..24).collect::<Vec<_>>());
        assert_eq!(s.gamma.iter().sum::<f32>(), 24.0);
    }

    #[test]
    fn lazy_greedy_bitwise_deterministic_across_thread_counts() {
        use crate::util::pool;
        // sized so the candidate-parallel scans and chunked gains engage
        let g = random_embed(1024, 6, 21);
        let a = random_embed(1024, 12, 22);
        let base = pool::with_threads(1, || facility_location_prod(&a, &g, 64));
        for t in [2, 4] {
            let s = pool::with_threads(t, || facility_location_prod(&a, &g, 64));
            assert_eq!(base.idx, s.idx, "threads={t}");
            assert_eq!(base.gamma, s.gamma, "threads={t}");
        }
    }

    #[test]
    fn stochastic_greedy_bitwise_deterministic_across_thread_counts() {
        use crate::util::pool;
        let g = random_embed(1500, 5, 23);
        let metric = EuclidMetric::new(&g);
        let run = |t: usize| {
            pool::with_threads(t, || {
                let mut rng = Rng::new(77);
                facility_location_stochastic(&metric, 50, &mut rng)
            })
        };
        let base = run(1);
        for t in [2, 4] {
            let s = run(t);
            assert_eq!(base.idx, s.idx, "threads={t}");
            assert_eq!(base.gamma, s.gamma, "threads={t}");
        }
    }

    #[test]
    fn blocked_sqdist_matches_scalar_for_builtin_metrics() {
        // odd ground-set sizes and odd dims exercise every remainder path
        // of the dot panels; values must be bitwise-identical
        for (r, c) in [(1usize, 1usize), (3, 5), (7, 4), (33, 9), (130, 17)] {
            let g = random_embed(r, c, 31);
            let a = random_embed(r, c + 3, 32);
            let euclid = EuclidMetric::new(&g);
            let prod = ProdMetric::new(&a, &g);
            let mut blk = vec![0.0f32; r];
            for j in [0, r / 2, r - 1] {
                euclid.sqdist_block(j, 0..r, &mut blk);
                for i in 0..r {
                    assert_eq!(
                        blk[i].to_bits(),
                        euclid.sqdist(j, i).to_bits(),
                        "euclid r={r} c={c} j={j} i={i}"
                    );
                }
                prod.sqdist_block(j, 0..r, &mut blk);
                for i in 0..r {
                    assert_eq!(
                        blk[i].to_bits(),
                        prod.sqdist(j, i).to_bits(),
                        "prod r={r} c={c} j={j} i={i}"
                    );
                }
            }
            // empty and offset sub-ranges
            euclid.sqdist_block(0, 0..0, &mut []);
            let lo = r / 3;
            let hi = (lo + 5).min(r);
            let mut part = vec![0.0f32; hi - lo];
            euclid.sqdist_block(r - 1, lo..hi, &mut part);
            for (k, &v) in part.iter().enumerate() {
                assert_eq!(v.to_bits(), euclid.sqdist(r - 1, lo + k).to_bits());
            }
        }
    }

    #[test]
    fn gram_metric_is_bitwise_transparent() {
        let g = random_embed(97, 6, 33);
        let a = random_embed(97, 13, 34);
        let inner = ProdMetric::new(&a, &g);
        let gram = GramMetric::new(&inner);
        assert_eq!(gram.len(), 97);
        assert!(gram.is_cached());
        for j in [0usize, 13, 96] {
            for i in 0..97 {
                assert_eq!(gram.sqdist(j, i).to_bits(), inner.sqdist(j, i).to_bits());
            }
        }
        // selections through the cache match the uncached metric exactly
        let direct = facility_location_metric(&inner, 12);
        let cached = facility_location_metric(&gram, 12);
        assert_eq!(direct.idx, cached.idx);
        assert_eq!(direct.gamma, cached.gamma);
        // and the stochastic selector agrees too (same RNG stream)
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let s1 = facility_location_stochastic(&inner, 20, &mut r1);
        let s2 = facility_location_stochastic(&gram, 20, &mut r2);
        assert_eq!(s1.idx, s2.idx);
        assert_eq!(s1.gamma, s2.gamma);
    }

    #[test]
    fn gram_metric_handles_empty_ground_set() {
        let g = MatF32::zeros(0, 4);
        let inner = EuclidMetric::new(&g);
        let gram = GramMetric::new(&inner);
        assert_eq!(gram.len(), 0);
        assert!(gram.is_empty());
    }

    #[test]
    fn gram_cap_parses_opt_in_values() {
        assert_eq!(gram_cap(None), None);
        assert_eq!(gram_cap(Some("")), None);
        assert_eq!(gram_cap(Some("0")), None);
        assert_eq!(gram_cap(Some("false")), None);
        assert_eq!(gram_cap(Some("1")), Some(DEFAULT_GRAM_CAP));
        assert_eq!(gram_cap(Some("true")), Some(DEFAULT_GRAM_CAP));
        assert_eq!(gram_cap(Some("4096")), Some(4096));
        assert_eq!(gram_cap(Some("nope")), None);
    }

    #[test]
    fn gain_scan_matches_per_candidate_gains() {
        let g = random_embed(120, 5, 35);
        let ctx = EuclidMetric::new(&g);
        let mind: Vec<f32> = (0..120).map(|i| ctx.sqdist(0, i)).collect();
        let scan = gain_scan(&ctx, &mind);
        assert_eq!(scan.len(), 120);
        for (j, &s) in scan.iter().enumerate() {
            assert_eq!(s.to_bits(), gain(&ctx, &mind, j).to_bits(), "candidate {j}");
        }
    }

    #[test]
    fn beats_random_selection_on_clustered_data() {
        let g = clustered_embed(10, 20, 8, 8);
        let s = facility_location(&g, 10);
        let mut rng = Rng::new(9);
        let mut rand_cost = 0.0;
        for _ in 0..5 {
            let ridx = rng.sample_indices(200, 10);
            rand_cost += coverage_cost(&g, &ridx);
        }
        rand_cost /= 5.0;
        assert!(
            coverage_cost(&g, &s.idx) < rand_cost * 0.5,
            "greedy should cover clusters far better than random"
        );
    }

    #[test]
    fn sparse_knn_block_matches_scalar_bitwise() {
        for (r, c, k) in [(1usize, 3usize, 1usize), (7, 4, 3), (130, 9, 16), (257, 5, 300)] {
            let g = random_embed(r, c, 41);
            let inner = EuclidMetric::new(&g);
            let sparse = SparseKnnMetric::build(&inner, &g, k);
            assert_eq!(sparse.len(), r);
            assert_eq!(sparse.neighbors(), k.min(r));
            assert!(sparse.is_cached(), "must not be re-wrapped by GramMetric");
            let mut blk = vec![0.0f32; r];
            for j in [0, r / 2, r - 1] {
                sparse.sqdist_block(j, 0..r, &mut blk);
                for i in 0..r {
                    assert_eq!(
                        blk[i].to_bits(),
                        sparse.sqdist(j, i).to_bits(),
                        "r={r} k={k} j={j} i={i}"
                    );
                }
                assert_eq!(sparse.sqdist(j, j), 0.0, "self distance");
            }
            // offset sub-range
            let lo = r / 3;
            let hi = (lo + 7).min(r);
            let mut part = vec![0.0f32; hi - lo];
            sparse.sqdist_block(r - 1, lo..hi, &mut part);
            for (p, &v) in part.iter().enumerate() {
                assert_eq!(v.to_bits(), sparse.sqdist(r - 1, lo + p).to_bits());
            }
        }
    }

    #[test]
    fn sparse_knn_neighbors_exact_rest_far() {
        let g = random_embed(64, 6, 42);
        let inner = EuclidMetric::new(&g);
        let sparse = SparseKnnMetric::build(&inner, &g, 8);
        let far = sparse.far();
        assert!(far.is_finite() && far > 0.0);
        let mut listed = 0usize;
        for j in 0..64 {
            for i in 0..64 {
                let d = sparse.sqdist(j, i);
                if i == j {
                    assert_eq!(d, 0.0);
                } else if d < far {
                    // listed pairs report the inner metric's exact value
                    assert_eq!(d.to_bits(), inner.sqdist(j, i).to_bits(), "j={j} i={i}");
                    listed += 1;
                } else {
                    assert_eq!(d, far);
                }
            }
        }
        assert!(listed > 0, "some true neighbor distances must survive");
        assert!(listed <= 64 * 8, "at most k entries per row");
    }

    #[test]
    fn sparse_knn_full_neighborhood_recovers_exact_selection() {
        // neighbors = n keeps every pair (the rank window spans the whole
        // ordering), so greedy over the sparse metric must match the dense
        // metric exactly
        let g = random_embed(96, 5, 43);
        let inner = EuclidMetric::new(&g);
        let sparse = SparseKnnMetric::build(&inner, &g, 96);
        let dense = facility_location_metric(&inner, 12);
        let approx = facility_location_metric(&sparse, 12);
        assert_eq!(dense.idx, approx.idx);
        assert_eq!(dense.gamma, approx.gamma);
    }

    #[test]
    fn sparse_knn_build_bitwise_deterministic_across_thread_counts() {
        use crate::util::pool;
        let g = random_embed(1024, 6, 44);
        let run = |t: usize| {
            pool::with_threads(t, || {
                let inner = EuclidMetric::new(&g);
                let sparse = SparseKnnMetric::build(&inner, &g, 16);
                let sel = facility_location_metric(&sparse, 32);
                (sparse.ids.clone(), sparse.d.clone(), sparse.far, sel.idx, sel.gamma)
            })
        };
        let base = run(1);
        for t in [2, 4] {
            let got = run(t);
            assert_eq!(base.0, got.0, "ids threads={t}");
            assert_eq!(
                base.1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "dists threads={t}"
            );
            assert_eq!(base.2.to_bits(), got.2.to_bits(), "far threads={t}");
            assert_eq!(base.3, got.3, "selection threads={t}");
            assert_eq!(base.4, got.4, "gamma threads={t}");
        }
    }

    #[test]
    fn sparse_knn_selection_approximates_dense_coverage() {
        // clustered data: a 32-neighbor sparse metric must still find one
        // medoid per cluster (cluster diameters are tiny vs. separation)
        let g = clustered_embed(8, 32, 6, 45);
        let inner = EuclidMetric::new(&g);
        let sparse = SparseKnnMetric::build(&inner, &g, 32);
        let sel = facility_location_metric(&sparse, 8);
        let clusters: std::collections::HashSet<_> = sel.idx.iter().map(|&i| i / 32).collect();
        assert_eq!(clusters.len(), 8, "one medoid per cluster through the sparse metric");
    }
}
