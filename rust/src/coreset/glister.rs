//! GLISTER baseline (Killamsetty et al. 2021b).
//!
//! Generalization-based selection: greedily choose training examples whose
//! gradients most increase the one-step validation-loss reduction. With a
//! first-order Taylor approximation the marginal gain of example j is
//! `⟨g_j, g_val⟩` (alignment between the example's gradient and the mean
//! validation gradient), making the greedy a top-k by inner product —
//! the standard "last-layer GLISTER" configuration. Unlike CRAIG/CREST
//! the selection is unweighted.
//!
//! (*) As in the paper's Table 1 footnote, GLISTER is the only method that
//! uses the validation set.

use crate::coreset::facility::Selection;
use crate::tensor::MatF32;

/// Select k examples by greedy maximization of the one-step Taylor
/// approximation of the validation-loss reduction:
///
///   gain(j | S) = ⟨g_j, g_val⟩ − η ⟨g_j, Σ_{i∈S} g_i⟩ − (η/2)‖g_j‖²
///
/// The second-order terms (from ‖∇val − η Σ g‖² expansion) give diminishing
/// returns along already-covered directions — without them a pure top-k
/// collapses onto a single gradient direction (class-imbalanced subsets).
/// η = 2/k normalizes the selected-sum scale (the factor 2 weights the
/// regularizer strongly enough to diversify clone-heavy ground sets).
pub fn glister_select(gl_train: &MatF32, val_mean_grad: &[f32], k: usize) -> Selection {
    assert_eq!(gl_train.cols, val_mean_grad.len());
    let n = gl_train.rows;
    let k = k.min(n);
    let c = gl_train.cols;
    let eta = 2.0f64 / k as f64;
    // precompute alignment and self terms
    let align: Vec<f64> =
        (0..n).map(|j| crate::util::stats::dot(gl_train.row(j), val_mean_grad)).collect();
    let self_term: Vec<f64> = (0..n)
        .map(|j| 0.5 * eta * crate::util::stats::dot(gl_train.row(j), gl_train.row(j)))
        .collect();
    let mut sum_sel = vec![0.0f64; c];
    let mut taken = vec![false; n];
    let mut idx = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = (usize::MAX, f64::NEG_INFINITY);
        for j in 0..n {
            if taken[j] {
                continue;
            }
            let cross: f64 = gl_train
                .row(j)
                .iter()
                .zip(&sum_sel)
                .map(|(&g, &s)| g as f64 * s)
                .sum();
            let gain = align[j] - eta * cross - self_term[j];
            if gain > best.1 {
                best = (j, gain);
            }
        }
        let j = best.0;
        taken[j] = true;
        idx.push(j);
        for (s, &g) in sum_sel.iter_mut().zip(gl_train.row(j)) {
            *s += g as f64;
        }
    }
    Selection { idx, gamma: vec![1.0; k] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn picks_most_aligned_examples() {
        let mut g = MatF32::zeros(4, 2);
        g.row_mut(0).copy_from_slice(&[1.0, 0.0]); // aligned
        g.row_mut(1).copy_from_slice(&[-1.0, 0.0]); // anti-aligned
        g.row_mut(2).copy_from_slice(&[0.5, 0.0]); // somewhat
        g.row_mut(3).copy_from_slice(&[0.0, 1.0]); // orthogonal
        let sel = glister_select(&g, &[1.0, 0.0], 2);
        assert_eq!(sel.idx[0], 0, "best-aligned example first");
        assert!(sel.idx.contains(&2) || sel.idx.contains(&3));
        assert_eq!(sel.gamma, vec![1.0, 1.0]);
    }

    #[test]
    fn k_clamped_to_n() {
        let g = MatF32::zeros(3, 2);
        let sel = glister_select(&g, &[1.0, 0.0], 10);
        assert_eq!(sel.idx.len(), 3);
    }

    #[test]
    fn deterministic_under_ties() {
        let g = MatF32::zeros(5, 2); // all scores equal (0)
        let sel = glister_select(&g, &[1.0, 0.0], 3);
        assert_eq!(sel.idx, vec![0, 1, 2]);
    }

    #[test]
    fn diminishing_returns_diversify_selection() {
        // 3 identical strongly-aligned rows + 1 weakly-aligned orthogonal:
        // the regularized greedy must not take all three clones first.
        let mut g = MatF32::zeros(4, 2);
        g.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        g.row_mut(1).copy_from_slice(&[1.0, 0.0]);
        g.row_mut(2).copy_from_slice(&[1.0, 0.0]);
        g.row_mut(3).copy_from_slice(&[0.0, 0.9]);
        let sel = glister_select(&g, &[1.0, 0.5], 2);
        assert!(sel.idx.contains(&3), "orthogonal direction should be covered: {:?}", sel.idx);
    }

    #[test]
    fn unweighted_selection() {
        let mut rng = Rng::new(1);
        let mut g = MatF32::zeros(20, 4);
        for v in g.data.iter_mut() {
            *v = rng.normal();
        }
        let sel = glister_select(&g, &[0.5, -0.5, 0.1, 0.0], 8);
        assert!(sel.gamma.iter().all(|&w| w == 1.0));
        let set: std::collections::HashSet<_> = sel.idx.iter().collect();
        assert_eq!(set.len(), 8);
    }
}
