//! On-disk cache for gradient embeddings, keyed by (quadratic-region id,
//! subset hash).
//!
//! CREST's selection recomputes last-layer gradient embeddings for its
//! random subsets every reselection, yet within one quadratic region the
//! model parameters are frozen for selection purposes — the embeddings of
//! a given index set cannot change until the region is re-anchored. The
//! cache exploits exactly that: entries are valid for one region id
//! ([`region_id`]: round counter + params fingerprint) and
//! [`EmbedCache::enter_region`] evicts everything from other regions, so
//! a hit can only ever return embeddings the selector would have
//! recomputed bit-for-bit. Within one process a region's entries serve
//! replayed selection rounds; across processes they serve identical
//! reruns (a crashed-and-restarted cell replays region ids exactly).
//! This keeps the determinism contract trivially intact: a cache hit
//! changes wall-clock, never a report.
//!
//! Off by default; enabled by pointing `CREST_EMBED_CACHE` at a
//! directory. All I/O goes through the
//! [`artifact_io`](crate::util::artifact_io) facade: entries publish
//! atomically (temp file + fsync + rename) with a trailing CRC-32, and
//! reads size- and CRC-validate the entry. Any mismatch — a torn write
//! that slipped past rename, a flipped payload byte, a stale
//! pre-integrity entry — evicts the file and reads as a miss, so
//! corruption degrades to recomputation, never to wrong embeddings.
//!
//! Entry file layout (little-endian):
//!
//! ```text
//! magic  8 bytes  "CRSTEC1\0"
//! region u64      quadratic-region id the entry belongs to
//! rows   u64
//! gcols  u64      gradient-embedding width
//! acols  u64      activation-embedding width
//! gl     rows*gcols f32
//! al     rows*acols f32
//! losses rows f32
//! crc    u32      CRC-32 of every preceding byte
//! ```

use std::path::{Path, PathBuf};

use crate::data::store::decode_f32le;
use crate::tensor::MatF32;
use crate::util::artifact_io::{self, READ_DETECTED, WRITE_DEGRADED};
use crate::util::faults::Site;

const MAGIC: &[u8; 8] = b"CRSTEC1\0";

/// FNV-1a over the little-endian bytes of an index set — the subset key.
pub fn subset_key(idx: &[usize]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &i in idx {
        for b in (i as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Region id: the selection-round counter folded with a fingerprint of
/// the model parameters the region is anchored on. Keying regions by
/// params (not just the round number) makes cross-run reuse sound: a
/// rerun with the same seed but a diverged config (different lr, budget,
/// …) reaches round `k` with different params, lands in a different
/// region, and misses instead of returning stale embeddings. An
/// identical rerun replays identical region ids and hits.
pub fn region_id(n_updates: u64, params: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in n_updates.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    for v in params {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Key of the full ground set `0..n` without materializing it.
pub fn subset_key_all(n: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for i in 0..n {
        for b in (i as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Region-scoped on-disk embedding cache.
#[derive(Debug)]
pub struct EmbedCache {
    dir: PathBuf,
    region: Option<u64>,
}

impl EmbedCache {
    /// Cache rooted at `dir` (created on first store).
    pub fn new(dir: &Path) -> EmbedCache {
        EmbedCache { dir: dir.to_path_buf(), region: None }
    }

    /// Build from `CREST_EMBED_CACHE` (or a session
    /// [`RuntimeConfig`](crate::runtime_config::RuntimeConfig) override);
    /// `None` (cache disabled) when unset.
    pub fn from_env() -> Option<EmbedCache> {
        crate::runtime_config::RuntimeConfig::current()
            .embed_cache
            .map(|dir| EmbedCache::new(&dir))
    }

    fn entry_path(&self, region: u64, key: u64) -> PathBuf {
        self.dir.join(format!("emb-{region}-{key:016x}.bin"))
    }

    /// Switch to a quadratic region, evicting every entry that belongs to
    /// a different one — embeddings are stale the moment the model
    /// re-anchors.
    pub fn enter_region(&mut self, region: u64) {
        if self.region == Some(region) {
            return;
        }
        self.region = Some(region);
        let keep = format!("emb-{region}-");
        if let Ok(entries) = artifact_io::read_dir_sorted(&self.dir) {
            for p in entries {
                let Some(name) = p.file_name() else { continue };
                let name = name.to_string_lossy();
                if name.starts_with("emb-") && !name.starts_with(&keep) {
                    let _ = artifact_io::remove_file(&p);
                }
            }
        }
    }

    /// Look up the embeddings of a subset in the current region. A
    /// missing entry is a quiet miss; a malformed or CRC-mismatched
    /// entry is evicted (one warning naming the file) and then misses,
    /// so the selector recomputes instead of trusting corrupt bytes.
    pub fn load(&self, key: u64) -> Option<(MatF32, MatF32, Vec<f32>)> {
        let region = self.region?;
        let path = self.entry_path(region, key);
        let bytes = match artifact_io::read_with(Site::EmbedRead, &path, READ_DETECTED) {
            Ok(b) => b,
            Err(e) if e.is_not_found() => return None,
            Err(e) => {
                log::warn!("embed-cache entry {}: {e}; evicting", path.display());
                let _ = artifact_io::remove_file(&path);
                return None;
            }
        };
        match decode_entry(region, &bytes) {
            Some(hit) => Some(hit),
            None => {
                log::warn!(
                    "embed-cache entry {}: corrupt or stale layout; evicting",
                    path.display()
                );
                let _ = artifact_io::remove_file(&path);
                None
            }
        }
    }

    /// Record the embeddings of a subset in the current region. I/O
    /// failures are logged and swallowed: the cache is an accelerator,
    /// never a correctness dependency.
    pub fn store(&self, key: u64, gl: &MatF32, al: &MatF32, losses: &[f32]) {
        let Some(region) = self.region else { return };
        if artifact_io::create_dir_all(&self.dir).is_err() {
            return;
        }
        let path = self.entry_path(region, key);
        let n_f32 = gl.data.len() + al.data.len() + losses.len();
        let mut bytes = Vec::with_capacity(44 + 4 * n_f32);
        bytes.extend_from_slice(MAGIC);
        for v in [region, gl.rows as u64, gl.cols as u64, al.cols as u64] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for part in [gl.data.as_slice(), al.data.as_slice(), losses] {
            for v in part {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = artifact_io::crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        if let Err(e) = artifact_io::publish_with(Site::EmbedWrite, &path, &bytes, WRITE_DEGRADED) {
            log::warn!("embed-cache store {} failed: {e}; continuing uncached", path.display());
        }
    }
}

/// Decode one entry's bytes, validating magic, region, geometry, and the
/// trailing CRC-32. `None` on any mismatch — including pre-integrity
/// entries that lack the CRC suffix (their length check fails).
fn decode_entry(region: u64, bytes: &[u8]) -> Option<(MatF32, MatF32, Vec<f32>)> {
    if bytes.len() < 44 || &bytes[..8] != *MAGIC {
        return None;
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    if artifact_io::crc32(body) != stored {
        return None;
    }
    let word = |o: usize| u64::from_le_bytes(body[o..o + 8].try_into().unwrap());
    if word(8) != region {
        return None;
    }
    let rows = word(16) as usize;
    let gcols = word(24) as usize;
    let acols = word(32) as usize;
    let payload = rows.checked_mul(gcols + acols + 1).and_then(|e| e.checked_mul(4))?;
    // geometry check before any allocation sized from header words
    if body.len() != 40 + payload {
        return None;
    }
    let mut all = vec![0.0f32; payload / 4];
    decode_f32le(&body[40..], &mut all);
    let losses = all.split_off(rows * (gcols + acols));
    let al_data = all.split_off(rows * gcols);
    let gl = MatF32::from_vec(rows, gcols, all).ok()?;
    let al = MatF32::from_vec(rows, acols, al_data).ok()?;
    Some((gl, al, losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crest_embcache_test_{}_{name}", std::process::id()))
    }

    fn sample() -> (MatF32, MatF32, Vec<f32>) {
        let gl = MatF32::from_vec(3, 4, (0..12).map(|v| v as f32 * 0.25).collect()).unwrap();
        let al = MatF32::from_vec(3, 2, vec![9., 8., 7., 6., 5., 4.]).unwrap();
        (gl, al, vec![0.5, 1.5, 2.5])
    }

    #[test]
    fn roundtrip_bitwise() {
        let dir = tdir("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = EmbedCache::new(&dir);
        let (gl, al, losses) = sample();
        let key = subset_key(&[5, 2, 9]);
        assert!(c.load(key).is_none(), "no region entered yet");
        c.enter_region(1);
        assert!(c.load(key).is_none(), "cold cache");
        c.store(key, &gl, &al, &losses);
        let (g2, a2, l2) = c.load(key).unwrap();
        assert_eq!(g2.data, gl.data);
        assert_eq!((g2.rows, g2.cols), (3, 4));
        assert_eq!(a2.data, al.data);
        assert_eq!(l2, losses);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn region_switch_invalidates() {
        let dir = tdir("region");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = EmbedCache::new(&dir);
        let (gl, al, losses) = sample();
        let key = subset_key(&[1, 2, 3]);
        c.enter_region(7);
        c.store(key, &gl, &al, &losses);
        c.enter_region(8);
        assert!(c.load(key).is_none(), "entry must not survive re-anchoring");
        // and the stale file is physically gone
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        // re-entering the old region must not resurrect it either
        c.enter_region(7);
        assert!(c.load(key).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_entries_read_as_miss() {
        let dir = tdir("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = EmbedCache::new(&dir);
        let (gl, al, losses) = sample();
        let key = subset_key(&[4, 4, 4]);
        c.enter_region(2);
        c.store(key, &gl, &al, &losses);
        let path = c.entry_path(2, key);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        assert!(c.load(key).is_none(), "truncated entry must miss");
        std::fs::write(&path, b"shrt").unwrap();
        assert!(c.load(key).is_none(), "tiny entry must miss");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flipped_payload_byte_is_evicted_not_served() {
        let dir = tdir("flip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = EmbedCache::new(&dir);
        let (gl, al, losses) = sample();
        let key = subset_key(&[6, 6, 6]);
        c.enter_region(3);
        c.store(key, &gl, &al, &losses);
        let path = c.entry_path(3, key);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit in the middle of the f32 payload: geometry stays
        // plausible, only the CRC can catch it
        let mid = 40 + bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        assert!(c.load(key).is_none(), "flipped byte must miss, never serve garbage floats");
        assert!(!path.exists(), "corrupt entry must be evicted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn subset_keys_distinguish_order_and_content() {
        assert_ne!(subset_key(&[1, 2, 3]), subset_key(&[3, 2, 1]));
        assert_ne!(subset_key(&[1, 2, 3]), subset_key(&[1, 2, 4]));
        assert_eq!(subset_key(&[0, 1, 2, 3]), subset_key_all(4));
    }

    #[test]
    fn region_ids_fingerprint_round_and_params() {
        let p = vec![0.5f32, -1.0, 2.0];
        assert_eq!(region_id(3, &p), region_id(3, &p), "deterministic");
        assert_ne!(region_id(3, &p), region_id(4, &p), "round matters");
        let mut q = p.clone();
        q[1] = -1.0000001;
        assert_ne!(region_id(3, &p), region_id(3, &q), "params matter");
    }
}
