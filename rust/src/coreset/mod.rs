//! Coreset selection algorithms: CREST's facility-location engine, the
//! three published baselines it is evaluated against, and the
//! `loss_topk` hard-example-mining baseline (registered purely through
//! the `api::MethodRegistry` — the in-tree pluggability proof).
//!
//! The embedding-based selectors operate on host-side last-layer gradient
//! embeddings (computed by the `grad_embed` backend op) and are pure
//! functions — the coordinator owns all backend interaction.

pub mod craig;
pub mod embed_cache;
pub mod facility;
pub mod glister;
pub mod gradmatch;
pub mod loss_topk;
pub mod strategy;

pub use facility::{coverage_cost, facility_location, Selection};
pub use strategy::SelectionStrategy;

/// A selected mini-batch coreset: global example indices + per-element
/// step sizes normalized so the weighted batch loss is an unbiased
/// estimator (mean gamma = 1).
#[derive(Debug, Clone)]
pub struct MiniBatchCoreset {
    /// Global example indices of the coreset.
    pub idx: Vec<usize>,
    /// Per-element weights (mean 1 over the batch).
    pub gamma: Vec<f32>,
}

impl MiniBatchCoreset {
    /// Build from a facility-location selection over a ground subset.
    /// `pool[sel.idx[j]]` maps subset positions back to global indices.
    pub fn from_selection(sel: &Selection, pool: &[usize], m: usize) -> MiniBatchCoreset {
        MiniBatchCoreset {
            idx: sel.idx.iter().map(|&i| pool[i]).collect(),
            gamma: sel.normalized_gamma(m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_selection_maps_global_indices() {
        let sel = Selection { idx: vec![2, 0], gamma: vec![3.0, 1.0] };
        let pool = vec![10, 20, 30, 40];
        let mb = MiniBatchCoreset::from_selection(&sel, &pool, 2);
        assert_eq!(mb.idx, vec![30, 10]);
        let sum: f32 = mb.gamma.iter().sum();
        assert!((sum - 2.0).abs() < 1e-6);
        assert!(mb.gamma[0] > mb.gamma[1]);
    }
}
