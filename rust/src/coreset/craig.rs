//! CRAIG baseline (Mirzasoleiman, Bilmes & Leskovec 2020).
//!
//! Selects a size-k weighted coreset whose gradient sum matches the full
//! training gradient, by facility location over last-layer gradient
//! embeddings (paper Eq. 4/5) — the configuration the CREST paper compares
//! against: a fresh 10% coreset from the *full* data at every epoch, with
//! gamma weights (cluster sizes) used as per-element step sizes.
//!
//! The pathology CREST's Fig. 1 documents comes from exactly this recipe:
//! weighted mini-batches drawn from the epoch coreset are biased w.r.t. the
//! full gradient once the model moves, and the weight spread inflates
//! variance. We reproduce the method faithfully and measure the same thing.

use crate::coreset::facility::{
    facility_location_metric, facility_location_stochastic, ProdMetric, Selection,
};
use crate::tensor::MatF32;
use crate::util::rng::Rng;

/// Ground sets past this size use stochastic greedy (full lazy greedy's
/// O(n²) seeding pass dominates otherwise — paper challenge C3).
pub const STOCHASTIC_THRESHOLD: usize = 2048;

/// Select a size-k coreset from the full embedding matrices (last-layer
/// weight-gradient metric: activations + logit gradients).
pub fn craig_select(al_full: &MatF32, gl_full: &MatF32, k: usize, rng: &mut Rng) -> Selection {
    let metric = ProdMetric::new(al_full, gl_full);
    if al_full.rows > STOCHASTIC_THRESHOLD {
        facility_location_stochastic(&metric, k, rng)
    } else {
        facility_location_metric(&metric, k)
    }
}

/// Normalize CRAIG gamma weights for mini-batch use: scale so the mean
/// gamma over the *coreset* equals 1 (γ' = γ·k/Σγ = γ·k/n). A weighted
/// batch then estimates the full mean loss without rescaling the learning
/// rate, while preserving the weight spread (the variance pathology).
pub fn craig_batch_gamma(sel: &Selection) -> Vec<f32> {
    let k = sel.gamma.len() as f32;
    let sum: f32 = sel.gamma.iter().sum();
    if sum <= 0.0 {
        return vec![1.0; sel.gamma.len()];
    }
    sel.gamma.iter().map(|&g| g * k / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn embed(n: usize, c: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        let mut m = MatF32::zeros(n, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn ones(n: usize, h: usize) -> MatF32 {
        MatF32::from_vec(n, h, vec![1.0; n * h]).unwrap()
    }

    #[test]
    fn selects_k_and_weights_partition_n() {
        let g = embed(200, 6, 1);
        let sel = craig_select(&ones(200, 4), &g, 20, &mut Rng::new(0));
        assert_eq!(sel.idx.len(), 20);
        assert_eq!(sel.gamma.iter().sum::<f32>(), 200.0);
    }

    #[test]
    fn batch_gamma_mean_is_one() {
        let g = embed(100, 4, 2);
        let sel = craig_select(&ones(100, 4), &g, 10, &mut Rng::new(0));
        let gamma = craig_batch_gamma(&sel);
        let mean: f32 = gamma.iter().sum::<f32>() / gamma.len() as f32;
        assert!((mean - 1.0).abs() < 1e-5);
    }

    #[test]
    fn weight_spread_survives_normalization() {
        // clustered embeddings -> unequal cluster sizes -> gamma spread
        let mut rng = Rng::new(3);
        let mut g = MatF32::zeros(90, 4);
        for i in 0..90 {
            let c = if i < 80 { 0.0 } else { 10.0 }; // 80/10 imbalance
            for v in g.row_mut(i).iter_mut() {
                *v = c + rng.normal() * 0.1;
            }
        }
        let sel = craig_select(&ones(90, 4), &g, 2, &mut Rng::new(0));
        let gamma = craig_batch_gamma(&sel);
        let max = gamma.iter().cloned().fold(0.0f32, f32::max);
        let min = gamma.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(max / min > 3.0, "spread {max}/{min} should persist");
    }
}
