//! Experiment configuration: method selection, budgets, CREST knobs,
//! per-variant presets (paper §5 + Table 6), JSON round-trip.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Which training method drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    /// Full-data mini-batch SGD (the accuracy reference).
    Full,
    /// Random mini-batches under the budget (paper's Random baseline:
    /// LR schedule compressed into the budget so both decays happen).
    Random,
    /// Standard pipeline truncated at the budget (paper's SGD†: LR schedule
    /// laid out for the *full* horizon, so no decay is reached).
    SgdTruncated,
    /// This paper (Algorithm 1).
    Crest,
    /// CRAIG: 10% coreset from full data at every epoch (Mirzasoleiman'20).
    Craig,
    /// GRADMATCH: OMP gradient matching per epoch (Killamsetty'21a).
    GradMatch,
    /// GLISTER: validation-gradient greedy per epoch (Killamsetty'21b).
    Glister,
    /// Ablation of Fig. 3: fresh greedy mini-batch from a random subset at
    /// every step (maximal update count).
    GreedyPerBatch,
}

impl MethodKind {
    /// Canonical CLI/report name of the method.
    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Full => "full",
            MethodKind::Random => "random",
            MethodKind::SgdTruncated => "sgd-truncated",
            MethodKind::Crest => "crest",
            MethodKind::Craig => "craig",
            MethodKind::GradMatch => "gradmatch",
            MethodKind::Glister => "glister",
            MethodKind::GreedyPerBatch => "greedy-per-batch",
        }
    }

    /// Parse a method name; accepts every canonical [`MethodKind::name`]
    /// plus the short aliases `sgd` and `greedy`.
    pub fn parse(s: &str) -> Result<MethodKind> {
        Ok(match s {
            "full" => MethodKind::Full,
            "random" => MethodKind::Random,
            "sgd-truncated" | "sgd" => MethodKind::SgdTruncated,
            "crest" => MethodKind::Crest,
            "craig" => MethodKind::Craig,
            "gradmatch" => MethodKind::GradMatch,
            "glister" => MethodKind::Glister,
            "greedy-per-batch" | "greedy" => MethodKind::GreedyPerBatch,
            _ => bail!("unknown method {s:?}"),
        })
    }

    /// Every method, in presentation order (paper Table 1 columns).
    pub fn all() -> &'static [MethodKind] {
        &[
            MethodKind::Full,
            MethodKind::Random,
            MethodKind::SgdTruncated,
            MethodKind::Crest,
            MethodKind::Craig,
            MethodKind::GradMatch,
            MethodKind::Glister,
            MethodKind::GreedyPerBatch,
        ]
    }

    /// Canonical method names joined with `|` for CLI help text. Generated
    /// from [`MethodKind::all`], so the help string can never drift from
    /// what [`MethodKind::parse`] accepts (every listed name round-trips).
    pub fn help_names() -> String {
        MethodKind::all().iter().map(|m| m.name()).collect::<Vec<_>>().join("|")
    }
}

/// CREST-specific switches (ablations of Table 3 / Fig. 4).
#[derive(Debug, Clone, Copy)]
pub struct CrestOptions {
    /// Use the curvature term in F^l (false = CREST-FIRST ablation).
    pub second_order: bool,
    /// Smooth gradient/curvature with EMAs (false = w/o-smoothing ablation).
    pub smooth: bool,
    /// Drop learned examples (false = w/o-excluding ablation).
    pub exclude: bool,
}

impl Default for CrestOptions {
    fn default() -> Self {
        CrestOptions { second_order: true, smooth: true, exclude: true }
    }
}

/// One experiment: a (variant, method, budget, seed) cell plus knobs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model/dataset variant name (see [`ALL_VARIANTS`] plus `smoke`).
    pub variant: String,
    /// Training method driving the run.
    pub method: MethodKind,
    /// Training budget as a fraction of the full run's backprops.
    pub budget_frac: f32,
    /// Epochs of the full-data reference run.
    pub epochs_full: usize,
    /// Experiment seed; data, init, subsets and probes all derive from it.
    pub seed: u64,
    /// Base learning rate of the schedule.
    pub base_lr: f32,
    /// Decoupled L2 weight decay applied inside train_step.
    pub weight_decay: f32,
    /// Ramp momentum over the first steps (paper training setup).
    pub momentum_warmup: bool,
    // ---- CREST knobs (paper Table 6 / §5 "CREST Setup") ----
    /// ρ threshold τ.
    pub tau: f32,
    /// exclusion threshold α.
    pub alpha: f32,
    /// T₁ multiplier h.
    pub h_mult: f32,
    /// P = b·T₁ multiplier b.
    pub b_mult: usize,
    /// exclusion window / ρ-check cadence T₂ (iterations).
    pub t2: usize,
    /// Exclusion only starts after this fraction of the budget: dropping
    /// interpolated examples is safe once the model is past the rapid
    /// early-drift phase (paper §4.3 "later stages of training").
    pub exclude_after_frac: f32,
    /// clamp for the adaptive T₁.
    pub max_t1: usize,
    /// clamp for the number of simultaneous mini-batch coresets P.
    pub max_p: usize,
    /// EMA parameter β₁ (Eq. 8–9).
    pub beta1: f32,
    /// EMA parameter β₂ (Eq. 8–9).
    pub beta2: f32,
    /// CREST-specific ablation switches.
    pub crest: CrestOptions,
    /// LR multiplier for methods training on variance-reduced mini-batch
    /// coresets (CREST / greedy-per-batch). `None` = the Theorem 4.1 step
    /// size ratio √(r/m); baselines always run the unscaled schedule.
    pub coreset_lr_scale: Option<f32>,
    /// Use the backend's `select_greedy` computation instead of calling the
    /// host lazy greedy directly (in-graph under PJRT).
    pub compiled_selection: bool,
    /// Host-side selection worker threads (P subproblems in parallel).
    pub selection_threads: usize,
    /// Number of evaluation points along training (history resolution).
    pub eval_points: usize,
}

impl ExperimentConfig {
    /// Per-variant preset mirroring paper §5 and Table 6.
    pub fn preset(variant: &str, method: MethodKind, seed: u64) -> Result<ExperimentConfig> {
        // τ/h tuned per variant the same way the paper tunes its Table 6
        // values (τ from the observed ρ scale after warmup; h from the
        // curvature-decay rate). Our loss scale differs from ResNet/CIFAR,
        // so the numbers differ from the paper's.
        let (tau, h_mult) = match variant {
            "cifar10-proxy" => (0.01, 1.0),
            "cifar100-proxy" => (0.01, 4.0),
            "tinyimagenet-proxy" => (0.005, 1.0),
            "snli-proxy" => (0.01, 2.0),
            // tiny fast-test variant: same defaults as cifar10-proxy
            "smoke" => (0.01, 1.0),
            _ => bail!("unknown variant {variant:?}"),
        };
        Ok(ExperimentConfig {
            variant: variant.to_string(),
            method,
            budget_frac: 0.10,
            epochs_full: 50,
            seed,
            base_lr: 0.01,
            weight_decay: 5e-4,
            momentum_warmup: true,
            tau,
            alpha: 0.1,
            h_mult,
            b_mult: 5,
            t2: 20,
            exclude_after_frac: 0.4,
            max_t1: 64,
            max_p: 20,
            beta1: 0.9,
            beta2: 0.999,
            crest: CrestOptions::default(),
            coreset_lr_scale: None,
            compiled_selection: false,
            selection_threads: 4,
            eval_points: 16,
        })
    }

    /// Shrink the workload for fast tests/benches: fewer reference epochs.
    pub fn quick(mut self, epochs_full: usize) -> Self {
        self.epochs_full = epochs_full;
        self
    }

    /// Serialize the tunable knobs (the subset [`ExperimentConfig::apply_json`]
    /// can restore) for experiment bookkeeping.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("variant", self.variant.as_str())
            .set("method", self.method.name())
            .set("budget_frac", self.budget_frac)
            .set("epochs_full", self.epochs_full)
            .set("seed", self.seed)
            .set("base_lr", self.base_lr)
            .set("tau", self.tau)
            .set("alpha", self.alpha)
            .set("h_mult", self.h_mult)
            .set("b_mult", self.b_mult)
            .set("t2", self.t2)
            .set("second_order", self.crest.second_order)
            .set("smooth", self.crest.smooth)
            .set("exclude", self.crest.exclude)
            .set("compiled_selection", self.compiled_selection)
            .set("selection_threads", self.selection_threads)
    }

    /// Apply overrides parsed from JSON (partial object).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("budget_frac") {
            self.budget_frac = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("epochs_full") {
            self.epochs_full = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            self.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.get("base_lr") {
            self.base_lr = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("tau") {
            self.tau = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("alpha") {
            self.alpha = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("h_mult") {
            self.h_mult = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("b_mult") {
            self.b_mult = v.as_usize()?;
        }
        if let Some(v) = j.get("t2") {
            self.t2 = v.as_usize()?;
        }
        if let Some(v) = j.get("second_order") {
            self.crest.second_order = v.as_bool()?;
        }
        if let Some(v) = j.get("smooth") {
            self.crest.smooth = v.as_bool()?;
        }
        if let Some(v) = j.get("exclude") {
            self.crest.exclude = v.as_bool()?;
        }
        if let Some(v) = j.get("compiled_selection") {
            self.compiled_selection = v.as_bool()?;
        }
        if let Some(v) = j.get("selection_threads") {
            self.selection_threads = v.as_usize()?.max(1);
        }
        if let Some(v) = j.get("method") {
            self.method = MethodKind::parse(v.as_str()?)?;
        }
        Ok(())
    }
}

/// The four paper proxy variants (the tiny `smoke` test variant is extra).
pub const ALL_VARIANTS: [&str; 4] =
    ["cifar10-proxy", "cifar100-proxy", "tinyimagenet-proxy", "snli-proxy"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_tuned_table6() {
        let c = ExperimentConfig::preset("cifar10-proxy", MethodKind::Crest, 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 1.0);
        let c = ExperimentConfig::preset("cifar100-proxy", MethodKind::Crest, 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 4.0);
        let c = ExperimentConfig::preset("snli-proxy", MethodKind::Crest, 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 2.0);
        assert_eq!(c.b_mult, 5);
        assert_eq!(c.t2, 20);
        assert_eq!(c.alpha, 0.1);
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(ExperimentConfig::preset("cifar11", MethodKind::Crest, 0).is_err());
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in MethodKind::all() {
            assert_eq!(MethodKind::parse(m.name()).unwrap(), *m);
        }
        assert!(MethodKind::parse("bogus").is_err());
    }

    #[test]
    fn help_names_roundtrip_through_parse() {
        // every name the CLI help advertises must parse back to the method
        // whose canonical name it is — the help string cannot drift
        let help = MethodKind::help_names();
        for name in help.split('|') {
            let parsed = MethodKind::parse(name).unwrap_or_else(|e| {
                panic!("help lists {name:?} but parse rejects it: {e:#}")
            });
            assert_eq!(parsed.name(), name);
        }
        // and the help covers every method
        for m in MethodKind::all() {
            assert!(help.split('|').any(|n| n == m.name()), "help misses {}", m.name());
        }
    }

    #[test]
    fn json_roundtrip_overrides() {
        let mut c = ExperimentConfig::preset("cifar10-proxy", MethodKind::Crest, 0).unwrap();
        let j = Json::parse(
            r#"{"tau": 0.2, "exclude": false, "method": "craig", "epochs_full": 5,
                "selection_threads": 2}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.tau, 0.2);
        assert!(!c.crest.exclude);
        assert_eq!(c.method, MethodKind::Craig);
        assert_eq!(c.epochs_full, 5);
        assert_eq!(c.selection_threads, 2);
        // serialized form parses back
        let s = c.to_json().to_string_pretty();
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j2.get("method").unwrap().as_str().unwrap(), "craig");
    }
}
