//! Experiment configuration: method selection, budgets, CREST knobs,
//! per-variant presets (paper §5 + Table 6), JSON round-trip.
//!
//! Method identity lives in the pluggable [`crate::api::MethodRegistry`];
//! this module re-exports the [`Method`] handle and holds the per-cell
//! knob struct it plugs into.

use anyhow::{bail, Result};

use crate::util::json::Json;

pub use crate::api::registry::Method;
pub use crate::coreset::strategy::SelectionStrategy;

/// CREST-specific switches (ablations of Table 3 / Fig. 4).
#[derive(Debug, Clone, Copy)]
pub struct CrestOptions {
    /// Use the curvature term in F^l (false = CREST-FIRST ablation).
    pub second_order: bool,
    /// Smooth gradient/curvature with EMAs (false = w/o-smoothing ablation).
    pub smooth: bool,
    /// Drop learned examples (false = w/o-excluding ablation).
    pub exclude: bool,
    /// Force unit γ weights in the greedy-per-batch ablation (isolates
    /// subset choice from the facility-location weighting).
    pub unit_gamma: bool,
}

impl Default for CrestOptions {
    fn default() -> Self {
        CrestOptions { second_order: true, smooth: true, exclude: true, unit_gamma: false }
    }
}

/// The JSON keys [`ExperimentConfig::to_json`] emits and
/// [`ExperimentConfig::apply_json`] accepts — one list, so the two can
/// never drift and unknown keys are rejected instead of silently
/// ignored.
const CONFIG_KEYS: &[&str] = &[
    "variant",
    "method",
    "budget_frac",
    "epochs_full",
    "seed",
    "base_lr",
    "tau",
    "alpha",
    "h_mult",
    "b_mult",
    "t2",
    "second_order",
    "smooth",
    "exclude",
    "unit_gamma",
    "compiled_selection",
    "selection_threads",
    "selection",
];

/// One experiment: a (variant, method, budget, seed) cell plus knobs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model/dataset variant name (see [`ALL_VARIANTS`] plus `smoke`).
    pub variant: String,
    /// Training method driving the run (a registry handle).
    pub method: Method,
    /// Training budget as a fraction of the full run's backprops.
    pub budget_frac: f32,
    /// Epochs of the full-data reference run.
    pub epochs_full: usize,
    /// Experiment seed; data, init, subsets and probes all derive from it.
    pub seed: u64,
    /// Base learning rate of the schedule.
    pub base_lr: f32,
    /// Decoupled L2 weight decay applied inside train_step.
    pub weight_decay: f32,
    /// Ramp momentum over the first steps (paper training setup).
    pub momentum_warmup: bool,
    // ---- CREST knobs (paper Table 6 / §5 "CREST Setup") ----
    /// ρ threshold τ.
    pub tau: f32,
    /// exclusion threshold α.
    pub alpha: f32,
    /// T₁ multiplier h.
    pub h_mult: f32,
    /// P = b·T₁ multiplier b.
    pub b_mult: usize,
    /// exclusion window / ρ-check cadence T₂ (iterations).
    pub t2: usize,
    /// Exclusion only starts after this fraction of the budget: dropping
    /// interpolated examples is safe once the model is past the rapid
    /// early-drift phase (paper §4.3 "later stages of training").
    pub exclude_after_frac: f32,
    /// clamp for the adaptive T₁.
    pub max_t1: usize,
    /// clamp for the number of simultaneous mini-batch coresets P.
    pub max_p: usize,
    /// EMA parameter β₁ (Eq. 8–9).
    pub beta1: f32,
    /// EMA parameter β₂ (Eq. 8–9).
    pub beta2: f32,
    /// CREST-specific ablation switches.
    pub crest: CrestOptions,
    /// LR multiplier for methods training on variance-reduced mini-batch
    /// coresets (CREST / greedy-per-batch). `None` = the Theorem 4.1 step
    /// size ratio √(r/m); baselines always run the unscaled schedule.
    pub coreset_lr_scale: Option<f32>,
    /// Use the backend's `select_greedy` computation instead of calling the
    /// host lazy greedy directly (in-graph under PJRT).
    pub compiled_selection: bool,
    /// Host-side selection worker threads (P subproblems in parallel).
    pub selection_threads: usize,
    /// How selections traverse their ground set: exact greedy or one of
    /// the sub-quadratic approximations (applies uniformly to every
    /// registered method through the strategy layer).
    pub selection: SelectionStrategy,
    /// Number of evaluation points along training (history resolution).
    pub eval_points: usize,
}

/// The per-variant (τ, h) tuning pair, mirroring how the paper tunes its
/// Table 6 values (τ from the observed ρ scale after warmup; h from the
/// curvature-decay rate). Our loss scale differs from ResNet/CIFAR, so
/// the numbers differ from the paper's.
fn variant_tuning(variant: &str) -> Result<(f32, f32)> {
    Ok(match variant {
        "cifar10-proxy" => (0.01, 1.0),
        "cifar100-proxy" => (0.01, 4.0),
        "tinyimagenet-proxy" => (0.005, 1.0),
        "snli-proxy" => (0.01, 2.0),
        // tiny fast-test variant: same defaults as cifar10-proxy
        "smoke" => (0.01, 1.0),
        _ => bail!("unknown variant {variant:?}"),
    })
}

impl ExperimentConfig {
    /// Per-variant preset mirroring paper §5 and Table 6.
    pub fn preset(variant: &str, method: Method, seed: u64) -> Result<ExperimentConfig> {
        let (tau, h_mult) = variant_tuning(variant)?;
        Ok(ExperimentConfig {
            variant: variant.to_string(),
            method,
            budget_frac: 0.10,
            epochs_full: 50,
            seed,
            base_lr: 0.01,
            weight_decay: 5e-4,
            momentum_warmup: true,
            tau,
            alpha: 0.1,
            h_mult,
            b_mult: 5,
            t2: 20,
            exclude_after_frac: 0.4,
            max_t1: 64,
            max_p: 20,
            beta1: 0.9,
            beta2: 0.999,
            crest: CrestOptions::default(),
            coreset_lr_scale: None,
            compiled_selection: false,
            selection_threads: 4,
            selection: SelectionStrategy::Exact,
            eval_points: 16,
        })
    }

    /// Shrink the workload for fast tests/benches: fewer reference epochs.
    pub fn quick(mut self, epochs_full: usize) -> Self {
        self.epochs_full = epochs_full;
        self
    }

    /// Serialize the tunable knobs (the subset [`ExperimentConfig::apply_json`]
    /// can restore) for experiment bookkeeping.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("variant", self.variant.as_str())
            .set("method", self.method.name())
            .set("budget_frac", self.budget_frac)
            .set("epochs_full", self.epochs_full)
            .set("seed", self.seed)
            .set("base_lr", self.base_lr)
            .set("tau", self.tau)
            .set("alpha", self.alpha)
            .set("h_mult", self.h_mult)
            .set("b_mult", self.b_mult)
            .set("t2", self.t2)
            .set("second_order", self.crest.second_order)
            .set("smooth", self.crest.smooth)
            .set("exclude", self.crest.exclude)
            .set("unit_gamma", self.crest.unit_gamma)
            .set("compiled_selection", self.compiled_selection)
            .set("selection_threads", self.selection_threads)
            .set("selection", self.selection.to_string().as_str())
    }

    /// Apply overrides parsed from JSON (partial object). Keys outside
    /// the [`ExperimentConfig::to_json`] schema are rejected, so a typo'd
    /// knob fails loudly instead of silently running the preset.
    /// Overriding `variant` re-derives the preset-tuned (τ, h) pair for
    /// the new variant first (and rejects unknown variants), so the
    /// other keys of the same document still win — a full
    /// `to_json`/`apply_json` round-trip restores every knob exactly.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        for (key, _) in j.as_obj()? {
            if !CONFIG_KEYS.contains(&key.as_str()) {
                bail!("unknown config key {key:?} (known: {})", CONFIG_KEYS.join(", "));
            }
        }
        if let Some(v) = j.get("variant") {
            let variant = v.as_str()?;
            if variant != self.variant {
                let (tau, h_mult) = variant_tuning(variant)?;
                self.tau = tau;
                self.h_mult = h_mult;
                self.variant = variant.to_string();
            }
        }
        if let Some(v) = j.get("budget_frac") {
            self.budget_frac = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("epochs_full") {
            self.epochs_full = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            self.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.get("base_lr") {
            self.base_lr = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("tau") {
            self.tau = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("alpha") {
            self.alpha = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("h_mult") {
            self.h_mult = v.as_f64()? as f32;
        }
        if let Some(v) = j.get("b_mult") {
            self.b_mult = v.as_usize()?;
        }
        if let Some(v) = j.get("t2") {
            self.t2 = v.as_usize()?;
        }
        if let Some(v) = j.get("second_order") {
            self.crest.second_order = v.as_bool()?;
        }
        if let Some(v) = j.get("smooth") {
            self.crest.smooth = v.as_bool()?;
        }
        if let Some(v) = j.get("exclude") {
            self.crest.exclude = v.as_bool()?;
        }
        if let Some(v) = j.get("unit_gamma") {
            self.crest.unit_gamma = v.as_bool()?;
        }
        if let Some(v) = j.get("compiled_selection") {
            self.compiled_selection = v.as_bool()?;
        }
        if let Some(v) = j.get("selection_threads") {
            self.selection_threads = v.as_usize()?.max(1);
        }
        if let Some(v) = j.get("selection") {
            self.selection = SelectionStrategy::parse(v.as_str()?)?;
        }
        if let Some(v) = j.get("method") {
            self.method = Method::parse(v.as_str()?)?;
        }
        Ok(())
    }
}

/// The four paper proxy variants (the tiny `smoke` test variant is extra).
pub const ALL_VARIANTS: [&str; 4] =
    ["cifar10-proxy", "cifar100-proxy", "tinyimagenet-proxy", "snli-proxy"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_tuned_table6() {
        let c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 1.0);
        let c = ExperimentConfig::preset("cifar100-proxy", Method::crest(), 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 4.0);
        let c = ExperimentConfig::preset("snli-proxy", Method::crest(), 0).unwrap();
        assert_eq!(c.tau, 0.01);
        assert_eq!(c.h_mult, 2.0);
        assert_eq!(c.b_mult, 5);
        assert_eq!(c.t2, 20);
        assert_eq!(c.alpha, 0.1);
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(ExperimentConfig::preset("cifar11", Method::crest(), 0).is_err());
    }

    #[test]
    fn json_roundtrip_overrides() {
        let mut c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        let j = Json::parse(
            r#"{"tau": 0.2, "exclude": false, "method": "craig", "epochs_full": 5,
                "selection_threads": 2}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.tau, 0.2);
        assert!(!c.crest.exclude);
        assert_eq!(c.method, Method::craig());
        assert_eq!(c.epochs_full, 5);
        assert_eq!(c.selection_threads, 2);
        // selection strategies parse through the one strategy table, and
        // bad values are rejected like any other malformed knob
        c.apply_json(&Json::parse(r#"{"selection": "class-sharded:2"}"#).unwrap()).unwrap();
        assert_eq!(c.selection, SelectionStrategy::ClassSharded { shards: 2 });
        assert!(c.apply_json(&Json::parse(r#"{"selection": "bogus"}"#).unwrap()).is_err());
        // serialized form parses back
        let s = c.to_json().to_string_pretty();
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j2.get("method").unwrap().as_str().unwrap(), "craig");
    }

    #[test]
    fn apply_json_rejects_unknown_keys() {
        let mut c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        let before_tau = c.tau;
        let j = Json::parse(r#"{"taau": 0.5}"#).unwrap();
        let err = c.apply_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("taau"), "error names the bad key: {err:#}");
        assert_eq!(c.tau, before_tau, "rejected override must not apply");
        // non-objects are rejected too
        assert!(c.apply_json(&Json::parse("[1]").unwrap()).is_err());
    }

    #[test]
    fn variant_override_rederives_preset_tuning() {
        // switching variants through JSON must not keep the old
        // variant's Table-6 (τ, h) pair — and explicit τ/h keys in the
        // same document still win regardless of key order
        let mut c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        c.apply_json(&Json::parse(r#"{"variant": "tinyimagenet-proxy"}"#).unwrap()).unwrap();
        assert_eq!(c.variant, "tinyimagenet-proxy");
        assert_eq!(c.tau, 0.005);
        assert_eq!(c.h_mult, 1.0);
        let mut c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        c.apply_json(&Json::parse(r#"{"tau": 0.5, "variant": "cifar100-proxy"}"#).unwrap())
            .unwrap();
        assert_eq!(c.tau, 0.5, "explicit tau beats the re-derived preset value");
        assert_eq!(c.h_mult, 4.0);
        // unknown variants are rejected before anything is applied
        let mut c = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        assert!(c.apply_json(&Json::parse(r#"{"variant": "nope"}"#).unwrap()).is_err());
        assert_eq!(c.variant, "cifar10-proxy");
    }

    #[test]
    fn full_roundtrip_including_crest_options() {
        // mutate every serialized knob (including all CrestOptions
        // fields), serialize, and restore into a fresh preset
        let mut c = ExperimentConfig::preset("cifar100-proxy", Method::glister(), 9).unwrap();
        c.budget_frac = 0.25;
        c.epochs_full = 7;
        c.base_lr = 0.125;
        c.tau = 0.5;
        c.alpha = 0.75;
        c.h_mult = 8.0;
        c.b_mult = 3;
        c.t2 = 11;
        c.crest =
            CrestOptions { second_order: false, smooth: false, exclude: false, unit_gamma: true };
        c.compiled_selection = true;
        c.selection_threads = 2;
        c.selection = SelectionStrategy::Clustered { k: 64 };

        let doc = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        let mut restored = ExperimentConfig::preset("cifar10-proxy", Method::crest(), 0).unwrap();
        restored.apply_json(&doc).unwrap();

        assert_eq!(restored.variant, "cifar100-proxy");
        assert_eq!(restored.method, Method::glister());
        assert_eq!(restored.seed, 9);
        assert_eq!(restored.budget_frac, 0.25);
        assert_eq!(restored.epochs_full, 7);
        assert_eq!(restored.base_lr, 0.125);
        assert_eq!(restored.tau, 0.5);
        assert_eq!(restored.alpha, 0.75);
        assert_eq!(restored.h_mult, 8.0);
        assert_eq!(restored.b_mult, 3);
        assert_eq!(restored.t2, 11);
        assert!(!restored.crest.second_order);
        assert!(!restored.crest.smooth);
        assert!(!restored.crest.exclude);
        assert!(restored.crest.unit_gamma);
        assert!(restored.compiled_selection);
        assert_eq!(restored.selection_threads, 2);
        assert_eq!(restored.selection, SelectionStrategy::Clustered { k: 64 });
        // a second round-trip is a fixed point
        let again = Json::parse(&restored.to_json().to_string_pretty()).unwrap();
        assert_eq!(again.to_string_pretty(), doc.to_string_pretty());
    }
}
