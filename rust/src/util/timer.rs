//! Named wall-clock accumulators for coarse profiling.
//!
//! The coordinator charges every phase (train step, embedding, greedy,
//! ρ-check, eval) to a named bucket; reports print the breakdown that
//! backs paper Table 2.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Accumulates total time and call count per named phase.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    buckets: HashMap<&'static str, (Duration, u64)>,
}

impl PhaseTimers {
    /// Empty timer set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under the given bucket.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    /// Charge `d` to the bucket and bump its call count.
    pub fn add(&mut self, name: &'static str, d: Duration) {
        let e = self.buckets.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total time charged to the bucket (zero when never hit).
    pub fn total(&self, name: &str) -> Duration {
        self.buckets.get(name).map(|(d, _)| *d).unwrap_or(Duration::ZERO)
    }

    /// Calls charged to the bucket.
    pub fn count(&self, name: &str) -> u64 {
        self.buckets.get(name).map(|(_, c)| *c).unwrap_or(0)
    }

    /// Mean seconds per call for the bucket (0 when never hit).
    pub fn mean_secs(&self, name: &str) -> f64 {
        let (d, c) = self.buckets.get(name).copied().unwrap_or((Duration::ZERO, 0));
        if c == 0 {
            0.0
        } else {
            d.as_secs_f64() / c as f64
        }
    }

    /// Merge another set of timers into this one.
    pub fn merge(&mut self, other: &PhaseTimers) {
        for (name, (d, c)) in &other.buckets {
            let e = self.buckets.entry(name).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    /// (name, total_secs, count, mean_secs) sorted by total descending.
    pub fn rows(&self) -> Vec<(&'static str, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .buckets
            .iter()
            .map(|(n, (d, c))| (*n, d.as_secs_f64(), *c, d.as_secs_f64() / (*c).max(1) as f64))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_time_and_count() {
        let mut t = PhaseTimers::new();
        let v = t.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        t.time("work", || ());
        assert_eq!(t.count("work"), 2);
        assert!(t.total("work") >= Duration::from_millis(5));
        assert!(t.mean_secs("work") > 0.0);
        assert_eq!(t.count("missing"), 0);
        assert_eq!(t.mean_secs("missing"), 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimers::new();
        let mut b = PhaseTimers::new();
        a.add("x", Duration::from_millis(10));
        b.add("x", Duration::from_millis(20));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.total("x"), Duration::from_millis(30));
        assert_eq!(a.count("y"), 1);
    }

    #[test]
    fn rows_sorted_by_total() {
        let mut t = PhaseTimers::new();
        t.add("small", Duration::from_millis(1));
        t.add("big", Duration::from_millis(100));
        let rows = t.rows();
        assert_eq!(rows[0].0, "big");
    }
}
