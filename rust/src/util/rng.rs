//! Deterministic pseudo-random generation (PCG32 core, SplitMix64 seeding).
//!
//! The cached crate registry ships no `rand`, so the coordinator carries its
//! own generator. PCG32 (O'Neill 2014) gives solid statistical quality for
//! data synthesis, subset sampling and Rademacher probes; SplitMix64 turns a
//! single experiment seed into independent streams (data / init / subsets /
//! probes) so changing one consumer never perturbs another.

/// SplitMix64: seed expander with good avalanche properties.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second Box-Muller draw
    gauss_spare: Option<f32>,
}

impl Rng {
    /// Construct from a seed; the stream id is derived from the seed too.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Rng { state: 0, inc, gauss_spare: None };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-subsystem RNGs).
    pub fn split(&mut self) -> Rng {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Rng::new(seed)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        let n = n as u32;
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as usize;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Rademacher (+1 / -1) draw — Hutchinson probe vectors (paper Eq. 7).
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with Rademacher entries.
    pub fn rademacher_fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.rademacher();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) — the random subsets V_p.
    ///
    /// Uses Floyd's algorithm for k << n (no O(n) allocation), falling back
    /// to a partial shuffle when k is a large fraction of n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.gen_range(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            return all;
        }
        // Floyd: guarantees uniqueness in O(k) expected time.
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let pick = if seen.insert(t) { t } else { j };
            if pick != t {
                seen.insert(pick);
            }
            out.push(pick);
        }
        out
    }

    /// Sample k indices from the given pool (without replacement).
    pub fn sample_from_pool(&mut self, pool: &[usize], k: usize) -> Vec<usize> {
        let picks = self.sample_indices(pool.len(), k.min(pool.len()));
        picks.into_iter().map(|i| pool[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_are_independent_of_parent_consumption() {
        let mut a = Rng::new(7);
        let mut child = a.split();
        let v1: Vec<u32> = (0..8).map(|_| child.next_u32()).collect();
        // regenerate: same parent seed, same split point
        let mut a2 = Rng::new(7);
        let mut child2 = a2.split();
        let v2: Vec<u32> = (0..8).map(|_| child2.next_u32()).collect();
        assert_eq!(v1, v2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_unique_and_in_range() {
        let mut r = Rng::new(6);
        for &(n, k) in &[(10, 10), (100, 5), (1000, 999), (512, 128)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_roughly_uniform() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            for i in r.sample_indices(16, 4) {
                counts[i] += 1;
            }
        }
        // each index expected 1000 times
        for (i, &c) in counts.iter().enumerate() {
            assert!((700..1300).contains(&c), "idx {i} count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::new(10);
        let mut z = vec![0.0f32; 10_000];
        r.rademacher_fill(&mut z);
        let pos = z.iter().filter(|&&x| x == 1.0).count();
        assert!(z.iter().all(|&x| x == 1.0 || x == -1.0));
        assert!((4500..5500).contains(&pos));
    }
}
