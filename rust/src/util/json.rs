//! Minimal JSON parser + writer (no serde in the offline registry).
//!
//! Covers the full JSON grammar the project needs: manifests written by
//! `python/compile/aot.py`, experiment configs, and run reports. Numbers are
//! f64 (like JavaScript); object key order is preserved so reports diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, with key order preserved.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------------- parse

    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    /// Object member lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing ergonomics).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    /// The value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// The value as (key, value) pairs in document order.
    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Array of integers (shape vectors etc.).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------- builders

    /// Empty object for builder-style construction.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key/value pair (builder style; no-op on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kvs) = self {
            kvs.push((key.to_string(), val.into()));
        }
        self
    }

    // -------------------------------------------------------------- writing

    /// Render with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Render without whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, x)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !kvs.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Write `doc` to `path` atomically (temp file + fsync + rename + parent
/// fsync, via [`artifact_io::publish_raw`](crate::util::artifact_io)),
/// so neither a killed process nor a power cut can leave a truncated
/// document behind. The temp name embeds the process id so concurrent
/// writers from different processes (e.g. two sweeps sharing one `--out`
/// trajectory) cannot interleave into one temp file; last rename wins
/// with an internally-consistent document. Shared by the bench
/// trajectory writer; the sweep checkpoint store publishes through the
/// fault-injectable `artifact_io::publish_with` directly.
pub fn write_atomic(path: &Path, doc: &Json) -> Result<()> {
    crate::util::artifact_io::publish_raw(path, doc.to_string_pretty().as_bytes())
        .with_context(|| format!("committing {}", path.display()))
}

fn write_number(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/±inf tokens; emitting them would make the whole
        // document unparseable (and e.g. wipe an append-merge trajectory
        // file on the next read). `null` keeps the document valid; readers
        // treat the field as absent/invalid instead.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(v: BTreeMap<String, Json>) -> Json {
        Json::Obj(v.into_iter().collect())
    }
}

// ---------------------------------------------------------------- the parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string().context("object key")?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if &self.b[self.i..self.i + 2] != b"\\u" {
                                    bail!("lone high surrogate");
                                }
                                self.i += 2;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        c => bail!("invalid escape \\{}", c as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // multi-byte UTF-8: copy remaining bytes of the char
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e3").unwrap(), Json::Num(-12000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"m","shape":[2,3],"f":1.5,"flag":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        for s in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn non_finite_numbers_write_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::obj().set("x", bad).to_string_compact();
            assert_eq!(doc, r#"{"x":null}"#);
            // the emitted document must stay parseable
            assert!(Json::parse(&doc).is_ok());
        }
    }

    #[test]
    fn builder_api() {
        let v = Json::obj().set("a", 1usize).set("b", "x").set("c", vec![1.0f64, 2.0]);
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn usize_vec_and_errors() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
        assert!(Json::parse("[-1]").unwrap().as_usize_vec().is_err());
    }
}
