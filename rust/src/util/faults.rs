//! Seeded, dependency-free fault injector for artifact I/O.
//!
//! Every artifact read/write in the repo funnels through the
//! [`artifact_io`](crate::util::artifact_io) facade, and the facade asks
//! this module — per *site class* — whether the current operation should
//! fail, and how. A schedule is named by `CREST_FAULTS` (or the
//! `RuntimeConfig::faults` session knob): a comma-separated spec like
//!
//! ```text
//! seed=7,ckpt-write=0.5,embed-read=0.25,mmap-map=1.0
//! ```
//!
//! naming per-site injection probabilities in `[0, 1]`. Decisions are a
//! pure function of `(seed, site, per-site counter)` via a splitmix64
//! stream: the counter is a per-site atomic that increments on every
//! draw, so a fixed spec replays the same decision sequence bitwise in a
//! single-threaded run, and the same decision *multiset* under parallel
//! scheduling. No wall clock, no OS randomness, no dependencies — the
//! injector is as deterministic as the code it attacks, which is what
//! lets the chaos suite (`rust/tests/faults.rs`) assert that
//! `deterministic_json` survives a schedule bit-for-bit.
//!
//! The spec is sampled from [`RuntimeConfig`] lazily on first draw and
//! re-sampled by [`refresh`] (called from
//! [`set_session`](crate::runtime_config::set_session)), *not* on every
//! draw — the disabled fast path must stay one relaxed atomic load
//! because `draw` sits on block-read hot paths.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Once, RwLock};

use crate::runtime_config::RuntimeConfig;

/// Site classes the injector can target. Each names one artifact-I/O
/// surface; the spec keys are the kebab-case [`Site::name`] strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Reads of packed-corpus artifacts (`meta.json`, `labels.bin`,
    /// shard payload verification).
    PackRead,
    /// Packed-corpus writes (shard/labels creation, `meta.json` publish).
    PackWrite,
    /// Sweep checkpoint cell loads.
    CkptRead,
    /// Sweep checkpoint cell publishes.
    CkptWrite,
    /// Monolithic dataset-cache loads (`data/cache.rs`).
    CacheLoad,
    /// Monolithic dataset-cache saves.
    CacheStore,
    /// Gradient-embedding cache entry loads.
    EmbedRead,
    /// Gradient-embedding cache entry publishes.
    EmbedWrite,
    /// `mmap(2)` establishment in `MmapStore` (injection refuses the
    /// map, forcing the pread / in-memory degradation ladder).
    MmapMap,
}

/// Number of site classes (sizes the probability/counter tables).
pub const N_SITES: usize = 9;

/// Every site, in spec/table order.
pub const ALL_SITES: [Site; N_SITES] = [
    Site::PackRead,
    Site::PackWrite,
    Site::CkptRead,
    Site::CkptWrite,
    Site::CacheLoad,
    Site::CacheStore,
    Site::EmbedRead,
    Site::EmbedWrite,
    Site::MmapMap,
];

impl Site {
    /// The kebab-case spec key for this site.
    pub fn name(self) -> &'static str {
        match self {
            Site::PackRead => "pack-read",
            Site::PackWrite => "pack-write",
            Site::CkptRead => "ckpt-read",
            Site::CkptWrite => "ckpt-write",
            Site::CacheLoad => "cache-load",
            Site::CacheStore => "cache-store",
            Site::EmbedRead => "embed-read",
            Site::EmbedWrite => "embed-write",
            Site::MmapMap => "mmap-map",
        }
    }

    fn idx(self) -> usize {
        ALL_SITES.iter().position(|&s| s == self).expect("site in table")
    }

    fn parse(key: &str) -> Option<Site> {
        ALL_SITES.into_iter().find(|s| s.name() == key)
    }
}

/// One positive injection decision. The two words are independent
/// splitmix64 outputs derived from the decision hash; the facade uses
/// them to pick the fault kind and its parameter (cut offset, flipped
/// bit, ...) so a schedule fixes not just *whether* but *how* each
/// operation fails.
#[derive(Debug, Clone, Copy)]
pub struct Draw {
    /// Kind-selection word.
    pub a: u64,
    /// Parameter word (offset / bit index / byte count).
    pub b: u64,
}

struct State {
    /// The spec string this state was parsed from (for change detection).
    spec: String,
    seed: u64,
    prob: [f64; N_SITES],
    counters: [AtomicU64; N_SITES],
}

fn state_cell() -> &'static RwLock<Option<State>> {
    static CELL: RwLock<Option<State>> = RwLock::new(None);
    &CELL
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn init_once() {
    static INIT: Once = Once::new();
    INIT.call_once(refresh);
}

/// splitmix64 — the same finalizer the RNG substrate uses; one round is
/// a full-avalanche mix of its input.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Parse a `CREST_FAULTS` spec into `(seed, per-site probabilities)`.
/// Grammar: comma-separated `key=value` pairs; `seed=<u64>` (default 0)
/// plus `<site-name>=<prob in [0,1]>` entries. Unknown keys and
/// out-of-range probabilities are errors — a chaos schedule that
/// silently drops a typoed site would "pass" by testing nothing.
pub fn parse_spec(spec: &str) -> Result<(u64, [f64; N_SITES]), String> {
    let mut seed = 0u64;
    let mut prob = [0.0; N_SITES];
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, value) = part
            .split_once('=')
            .ok_or_else(|| format!("fault spec entry `{part}` is not key=value"))?;
        let (key, value) = (key.trim(), value.trim());
        if key == "seed" {
            seed = value.parse().map_err(|_| format!("fault seed `{value}` is not a u64"))?;
            continue;
        }
        let site = Site::parse(key).ok_or_else(|| {
            let known: Vec<&str> = ALL_SITES.iter().map(|s| s.name()).collect();
            format!("unknown fault site `{key}` (known: seed, {})", known.join(", "))
        })?;
        let p: f64 =
            value.parse().map_err(|_| format!("fault probability `{value}` is not a number"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("fault probability {p} for `{key}` is outside [0, 1]"));
        }
        prob[site.idx()] = p;
    }
    Ok((seed, prob))
}

/// Re-sample the fault spec from [`RuntimeConfig::current`] and install
/// it, resetting every per-site counter. Called from `set_session` and
/// lazily on the first [`draw`]; a malformed spec logs one error line
/// and disables injection rather than poisoning the run.
pub fn refresh() {
    let spec = RuntimeConfig::current().faults;
    let mut guard = state_cell().write().unwrap();
    match spec {
        None => {
            *guard = None;
            ENABLED.store(false, Ordering::Relaxed);
        }
        Some(spec) => {
            if let Some(st) = guard.as_ref() {
                if st.spec == spec {
                    return; // same schedule: keep the counter streams
                }
            }
            match parse_spec(&spec) {
                Ok((seed, prob)) => {
                    log::warn!("fault injection armed: {spec}");
                    *guard = Some(State {
                        spec,
                        seed,
                        prob,
                        counters: std::array::from_fn(|_| AtomicU64::new(0)),
                    });
                    ENABLED.store(true, Ordering::Relaxed);
                }
                Err(e) => {
                    log::error!("ignoring malformed fault spec `{spec}`: {e}");
                    *guard = None;
                    ENABLED.store(false, Ordering::Relaxed);
                }
            }
        }
    }
}

/// The currently armed spec string, if any (diagnostics and tests).
pub fn active_spec() -> Option<String> {
    init_once();
    state_cell().read().unwrap().as_ref().map(|s| s.spec.clone())
}

/// Ask whether the next operation at `site` should fail. `None` means
/// proceed normally; `Some(draw)` carries the decision words the facade
/// maps onto a concrete fault. Each call consumes one tick of the
/// site's counter stream, so decisions replay under a fixed spec.
pub fn draw(site: Site) -> Option<Draw> {
    init_once();
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let guard = state_cell().read().unwrap();
    let st = guard.as_ref()?;
    let i = site.idx();
    let p = st.prob[i];
    if p <= 0.0 {
        return None;
    }
    let c = st.counters[i].fetch_add(1, Ordering::Relaxed);
    let h = splitmix64(splitmix64(st.seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F)) ^ c);
    let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    if unit < p {
        Some(Draw { a: splitmix64(h ^ 0x2545_F491_4F6C_DD1D), b: splitmix64(h ^ 0x6C62_272E_07BB_0142) })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_seed_and_sites() {
        let (seed, prob) = parse_spec("seed=7, ckpt-write=0.5,mmap-map=1").unwrap();
        assert_eq!(seed, 7);
        assert_eq!(prob[Site::CkptWrite.idx()], 0.5);
        assert_eq!(prob[Site::MmapMap.idx()], 1.0);
        assert_eq!(prob[Site::PackRead.idx()], 0.0);
    }

    #[test]
    fn spec_rejects_unknown_sites_and_bad_probabilities() {
        assert!(parse_spec("pack-raed=0.5").unwrap_err().contains("unknown fault site"));
        assert!(parse_spec("pack-read=1.5").unwrap_err().contains("outside [0, 1]"));
        assert!(parse_spec("pack-read").unwrap_err().contains("not key=value"));
        assert!(parse_spec("seed=x").unwrap_err().contains("not a u64"));
    }

    #[test]
    fn decision_stream_is_a_pure_function_of_seed_site_counter() {
        // replay the decision math by hand for a few ticks and check the
        // accept rate lands near the nominal probability
        let (seed, prob) = parse_spec("seed=42,embed-read=0.25").unwrap();
        let i = Site::EmbedRead.idx();
        let mut hits = 0;
        for c in 0..4000u64 {
            let h = splitmix64(
                splitmix64(seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F)) ^ c,
            );
            let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if unit < prob[i] {
                hits += 1;
            }
        }
        let rate = hits as f64 / 4000.0;
        assert!((rate - 0.25).abs() < 0.03, "rate {rate}");
    }

    #[test]
    fn every_site_name_round_trips() {
        for s in ALL_SITES {
            assert_eq!(Site::parse(s.name()), Some(s));
        }
    }
}
