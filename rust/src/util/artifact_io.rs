//! The artifact-I/O facade: every filesystem touch on an artifact path
//! goes through here.
//!
//! The five artifact surfaces (`data/shard.rs`, `data/store.rs`,
//! `data/cache.rs`, `sweep/store.rs`, `coreset/embed_cache.rs`) never
//! call `std::fs` directly — the `IO-FACADE` lint rule enforces it.
//! Routing through one module buys three things at once:
//!
//! 1. **Fault injection** — the [`faults`] injector wraps each call, so
//!    the chaos suite can attack every artifact path from one choke
//!    point. Call sites pass a *kind menu* declaring which fault kinds
//!    their consumer can absorb: a path whose reader CRC-verifies and
//!    recomputes may be handed flipped bytes ([`READ_DETECTED`]); a
//!    path whose corruption would change results only ever sees
//!    transient/short faults ([`READ_STRICT`]). Injected transient
//!    faults fail only the first attempt, so the bounded retry below
//!    always converges — both properties together are what keep every
//!    committed chaos schedule bitwise identity-preserving.
//! 2. **Typed errors + bounded retry** — [`ArtifactError`] separates
//!    retry-worthy conditions from corruption from hard failures, and
//!    transient errors (`Interrupted`/`WouldBlock`) are retried a fixed
//!    [`ATTEMPTS`] times with *no wall-clock sleeps* (CONTRACTS.md
//!    DET-CLOCK covers the calling modules): retry happens on the next
//!    loop iteration or not at all.
//! 3. **Crash-safe publication** — [`publish_with`] is the single
//!    tmp+rename implementation: tmp is fsynced before the rename and
//!    the parent directory after, so a power cut can lose an update but
//!    can never publish a partial artifact.
//!
//! Integrity is end-to-end, not per-call: writers append a hand-rolled
//! [`Crc32`] to their formats (shard-pack `meta.json`, checkpoint
//! cells, embed-cache entries) and readers verify on every load, so a
//! torn or flipped artifact is *detected* — never silently loaded.

use std::fmt;
use std::fs::File;
use std::io::{ErrorKind, Read, Write};
use std::path::{Path, PathBuf};

use crate::util::faults::{self, Draw, Site};

/// Fixed attempt budget for transient-error retry. Deterministic by
/// construction: a plain loop bound, no backoff clock.
pub const ATTEMPTS: usize = 4;

// ---------------------------------------------------------------- errors

/// Typed failure taxonomy for artifact I/O. The variant tells the
/// caller what to *do*: retry ([`Transient`](ArtifactError::Transient)
/// — already exhausted by the facade's own bounded loop by the time the
/// caller sees it), discard-and-recompute
/// ([`Corrupt`](ArtifactError::Corrupt)), or propagate
/// ([`Fatal`](ArtifactError::Fatal)).
#[derive(Debug)]
pub enum ArtifactError {
    /// A retryable condition (`Interrupted`/`WouldBlock`) that survived
    /// the facade's [`ATTEMPTS`]-bounded retry loop.
    Transient(std::io::Error),
    /// Content failed validation — size, magic, or CRC. Retrying cannot
    /// help; the artifact must be discarded or the run must stop.
    Corrupt(String),
    /// Everything else: missing file, permissions, disk full, ...
    Fatal(std::io::Error),
}

impl ArtifactError {
    /// Build a [`Corrupt`](ArtifactError::Corrupt) error from a message.
    pub fn corrupt(msg: impl Into<String>) -> ArtifactError {
        ArtifactError::Corrupt(msg.into())
    }

    /// True when the underlying cause is a missing file — callers that
    /// treat absence as a cache miss branch on this, not on the text.
    pub fn is_not_found(&self) -> bool {
        matches!(
            self,
            ArtifactError::Transient(e) | ArtifactError::Fatal(e)
                if e.kind() == ErrorKind::NotFound
        )
    }
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Transient(e) => {
                write!(f, "transient I/O failure ({ATTEMPTS} attempts exhausted): {e}")
            }
            ArtifactError::Corrupt(m) => write!(f, "corrupt artifact: {m}"),
            ArtifactError::Fatal(e) => write!(f, "I/O failure: {e}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Transient(e) | ArtifactError::Fatal(e) => Some(e),
            ArtifactError::Corrupt(_) => None,
        }
    }
}

// ------------------------------------------------------------- fault kinds

/// The concrete fault shapes the injector can impose on one operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The first attempt fails with `ErrorKind::Interrupted`; the retry
    /// loop recovers. Exercises the bounded-retry path.
    Transient,
    /// The first `read` call returns fewer bytes than requested; the
    /// read loop must complete the tail. Exercises short-read handling.
    ShortRead,
    /// One bit of the returned payload is flipped. Exercises CRC /
    /// validation detection; only offered to consumers that recover.
    FlipByte,
    /// A partial tmp file is written and the rename never happens —
    /// the aftermath of a crash mid-publish. The operation reports
    /// failure; the destination is untouched.
    Torn,
}

/// Menu for readers that CRC-verify and degrade (checkpoint cells,
/// embed-cache entries): corruption is detectable, so flips are fair.
pub const READ_DETECTED: &[FaultKind] =
    &[FaultKind::Transient, FaultKind::ShortRead, FaultKind::FlipByte];

/// Menu for readers whose corruption would have to fail the run (pack
/// payloads on the training path): recoverable kinds only.
pub const READ_STRICT: &[FaultKind] = &[FaultKind::Transient, FaultKind::ShortRead];

/// Menu for publishers whose loss is tolerated (checkpoints, cache
/// entries — the value is recomputed next time).
pub const WRITE_DEGRADED: &[FaultKind] = &[FaultKind::Transient, FaultKind::Torn];

/// Menu for publishers that must land for the run to proceed.
pub const WRITE_STRICT: &[FaultKind] = &[FaultKind::Transient];

fn pick(d: Draw, menu: &[FaultKind]) -> Option<(FaultKind, Draw)> {
    if menu.is_empty() {
        return None;
    }
    Some((menu[(d.a % menu.len() as u64) as usize], d))
}

fn is_transient(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::Interrupted | ErrorKind::WouldBlock)
}

fn injected_interrupt() -> std::io::Error {
    std::io::Error::new(ErrorKind::Interrupted, "injected transient fault")
}

// ------------------------------------------------------------------ reads

/// Read a whole artifact with the given fault menu. Transient errors
/// (real or injected) are retried up to [`ATTEMPTS`] times; short reads
/// are completed by the chunk loop; an injected flip corrupts the
/// returned bytes (the caller's validation is expected to catch it).
pub fn read_with(site: Site, path: &Path, menu: &[FaultKind]) -> Result<Vec<u8>, ArtifactError> {
    let (mut fail_first, mut short_cap, mut flip) = (false, None, None);
    if let Some((kind, d)) = faults::draw(site).and_then(|d| pick(d, menu)) {
        match kind {
            FaultKind::Transient => fail_first = true,
            FaultKind::ShortRead => short_cap = Some((d.b % (32 * 1024)) as usize + 1),
            FaultKind::FlipByte => flip = Some(d.b),
            FaultKind::Torn => {} // write-only kind; read menus never carry it
        }
        log::debug!("fault[{}]: {kind:?} at {}", site.name(), path.display());
    }
    let mut last = None;
    for attempt in 0..ATTEMPTS {
        if attempt == 0 && fail_first {
            last = Some(injected_interrupt());
            continue;
        }
        match read_once(path, short_cap.take()) {
            Ok(mut bytes) => {
                if let Some(word) = flip {
                    if !bytes.is_empty() {
                        let bit = (word % (bytes.len() as u64 * 8)) as usize;
                        bytes[bit / 8] ^= 1 << (bit % 8);
                    }
                }
                return Ok(bytes);
            }
            Err(e) if is_transient(&e) => last = Some(e),
            Err(e) => return Err(ArtifactError::Fatal(e)),
        }
    }
    Err(ArtifactError::Transient(last.unwrap_or_else(injected_interrupt)))
}

/// [`read_with`] + UTF-8 decode; invalid UTF-8 (e.g. a flipped byte in
/// a JSON document) classifies as [`ArtifactError::Corrupt`].
pub fn read_to_string_with(
    site: Site,
    path: &Path,
    menu: &[FaultKind],
) -> Result<String, ArtifactError> {
    let bytes = read_with(site, path, menu)?;
    String::from_utf8(bytes)
        .map_err(|_| ArtifactError::corrupt(format!("{}: invalid UTF-8", path.display())))
}

fn read_once(path: &Path, short_cap: Option<usize>) -> std::io::Result<Vec<u8>> {
    let mut f = File::open(path)?;
    let mut out = Vec::new();
    let mut cap = short_cap;
    let mut buf = [0u8; 64 * 1024];
    let mut spurious = 0;
    loop {
        // an injected short read caps only the first chunk; the loop
        // then finishes the tail like any honest reader must
        let want = cap.take().map_or(buf.len(), |c| c.clamp(1, buf.len()));
        match f.read(&mut buf[..want]) {
            Ok(0) => return Ok(out),
            Ok(n) => out.extend_from_slice(&buf[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted && spurious < ATTEMPTS => spurious += 1,
            Err(e) => return Err(e),
        }
    }
}

/// Open an artifact for reading (streaming consumers: the mmap store,
/// the dataset cache). Injection and retry cover the open itself; what
/// the caller streams afterwards is its own contract.
pub fn open(site: Site, path: &Path) -> Result<File, ArtifactError> {
    retry_file(site, path, File::open)
}

/// Create an artifact for streaming writes (shard/labels files). The
/// caller owns flushing and must [`sync_file`] before treating the
/// artifact as durable.
pub fn create(site: Site, path: &Path) -> Result<File, ArtifactError> {
    retry_file(site, path, |p| File::create(p))
}

fn retry_file(
    site: Site,
    path: &Path,
    op: impl Fn(&Path) -> std::io::Result<File>,
) -> Result<File, ArtifactError> {
    let fail_first = matches!(
        faults::draw(site).and_then(|d| pick(d, WRITE_STRICT)),
        Some((FaultKind::Transient, _))
    );
    let mut last = None;
    for attempt in 0..ATTEMPTS {
        if attempt == 0 && fail_first {
            last = Some(injected_interrupt());
            continue;
        }
        match op(path) {
            Ok(f) => return Ok(f),
            Err(e) if is_transient(&e) => last = Some(e),
            Err(e) => return Err(ArtifactError::Fatal(e)),
        }
    }
    Err(ArtifactError::Transient(last.unwrap_or_else(injected_interrupt)))
}

// ----------------------------------------------------------- publication

/// Atomically publish `bytes` at `path`: write `path.<pid>.tmp`, fsync
/// the tmp file, rename over the destination, fsync the parent
/// directory. A crash at any point leaves either the old artifact or
/// the new one — never a partial file under the real name. An injected
/// [`FaultKind::Torn`] simulates exactly that crash: partial tmp bytes,
/// no rename, error returned.
pub fn publish_with(
    site: Site,
    path: &Path,
    bytes: &[u8],
    menu: &[FaultKind],
) -> Result<(), ArtifactError> {
    let tmp = tmp_path(path);
    let mut fail_first = false;
    if let Some((kind, d)) = faults::draw(site).and_then(|d| pick(d, menu)) {
        log::debug!("fault[{}]: {kind:?} at {}", site.name(), path.display());
        match kind {
            FaultKind::Transient => fail_first = true,
            FaultKind::Torn => {
                let keep = if bytes.is_empty() { 0 } else { (d.b % bytes.len() as u64) as usize };
                let _ = std::fs::write(&tmp, &bytes[..keep]);
                return Err(ArtifactError::Fatal(std::io::Error::other(format!(
                    "injected torn write at {} (partial tmp, no rename)",
                    path.display()
                ))));
            }
            FaultKind::ShortRead | FaultKind::FlipByte => {} // read-only kinds
        }
    }
    let mut last = None;
    for attempt in 0..ATTEMPTS {
        if attempt == 0 && fail_first {
            last = Some(injected_interrupt());
            continue;
        }
        match publish_once(&tmp, path, bytes) {
            Ok(()) => return Ok(()),
            Err(e) if is_transient(&e) => last = Some(e),
            Err(e) => return Err(ArtifactError::Fatal(e)),
        }
    }
    Err(ArtifactError::Transient(last.unwrap_or_else(injected_interrupt)))
}

/// [`publish_with`] outside any fault site — for non-artifact callers
/// (the bench trajectory writer behind `json::write_atomic`) that still
/// want the fsync-correct tmp+rename sequence.
pub fn publish_raw(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    publish_once(&tmp_path(path), path, bytes)
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(format!(".{}.tmp", std::process::id()));
    PathBuf::from(name)
}

fn publish_once(tmp: &Path, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = File::create(tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    std::fs::rename(tmp, path)?;
    sync_parent(path);
    Ok(())
}

/// fsync a streamed artifact before it is treated as durable.
pub fn sync_file(f: &File) -> std::io::Result<()> {
    f.sync_all()
}

/// fsync the parent directory of a just-renamed artifact so the
/// directory entry itself is durable. Best-effort: a filesystem that
/// refuses directory fsync (or a non-unix target) degrades to a no-op —
/// the rename's atomicity is not affected, only its durability.
pub fn sync_parent(path: &Path) {
    #[cfg(unix)]
    {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    #[cfg(not(unix))]
    {
        let _ = path;
    }
}

// -------------------------------------------------------------- utilities

/// Remove an artifact; absence counts as success (removal is how
/// consumers *evict*, and eviction is idempotent).
pub fn remove_file(path: &Path) -> Result<(), ArtifactError> {
    match std::fs::remove_file(path) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == ErrorKind::NotFound => Ok(()),
        Err(e) => Err(ArtifactError::Fatal(e)),
    }
}

/// Create a directory tree for artifact storage.
pub fn create_dir_all(path: &Path) -> Result<(), ArtifactError> {
    std::fs::create_dir_all(path).map_err(ArtifactError::Fatal)
}

/// Directory listing in sorted order (deterministic iteration for
/// eviction sweeps). I/O errors on individual entries are skipped.
pub fn read_dir_sorted(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    out.sort();
    Ok(out)
}

// ------------------------------------------------------------------- crc32

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// Incremental IEEE CRC-32 (the `cksum`/zlib polynomial), hand-rolled
/// because the offline registry has no checksum crate. Streaming
/// writers feed it as they write so integrity costs no second pass.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    /// Fresh hasher.
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb `bytes`.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = CRC_TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    /// The digest of everything absorbed so far (does not consume).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::Site;

    // injection behaviour is exercised in `rust/tests/faults.rs`, which
    // owns the process-global fault state behind a serializing mutex;
    // the unit tests here stay injection-free so they can run in
    // parallel with the rest of the lib suite.

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("crest-aio-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_matches_the_ieee_check_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let mut h = Crc32::new();
        h.update(b"1234");
        h.update(b"56789");
        assert_eq!(h.finish(), 0xCBF4_3926, "incremental == one-shot");
    }

    #[test]
    fn publish_then_read_round_trips_and_leaves_no_tmp() {
        let d = tdir("pub");
        let p = d.join("artifact.bin");
        publish_with(Site::CkptWrite, &p, b"payload", WRITE_STRICT).unwrap();
        assert_eq!(read_with(Site::CkptRead, &p, READ_STRICT).unwrap(), b"payload");
        let leftovers = read_dir_sorted(&d).unwrap();
        assert_eq!(leftovers, vec![p.clone()], "no tmp residue");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn missing_artifact_classifies_as_not_found() {
        let e = read_with(Site::CkptRead, Path::new("/nonexistent/x.bin"), READ_STRICT)
            .unwrap_err();
        assert!(e.is_not_found(), "{e}");
        assert!(matches!(e, ArtifactError::Fatal(_)));
    }

    #[test]
    fn remove_is_idempotent() {
        let d = tdir("rm");
        let p = d.join("gone.bin");
        std::fs::write(&p, b"x").unwrap();
        remove_file(&p).unwrap();
        remove_file(&p).unwrap(); // second removal: absence is success
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn error_display_names_the_taxonomy() {
        let c = ArtifactError::corrupt("bad crc");
        assert!(c.to_string().contains("corrupt artifact"));
        let t = ArtifactError::Transient(injected_interrupt());
        assert!(t.to_string().contains("attempts exhausted"));
    }
}
