//! Dependency-free thread pool: scoped workers over chunked work queues.
//!
//! The offline registry carries no rayon, so the hot paths fan out through
//! this module instead: `std::thread::scope` workers claim work from a
//! shared counter or queue, and every reduction primitive uses chunk
//! boundaries that depend only on the problem size — never on the thread
//! count — so results are bitwise-identical at `--threads 1` and
//! `--threads N` (deterministic f32 summation order).
//!
//! Configuration: the `CREST_THREADS` env var or [`set_threads`] (the
//! `crest` binary wires `--threads` to it); default is the machine's
//! available parallelism. Nested use is safe: primitives invoked from
//! inside a pool worker run inline on that worker, so parallel callers
//! (e.g. the coordinator's per-subset selection) never oversubscribe.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Configured global worker count; 0 = not yet resolved from the env.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Serializes [`with_threads`] sections so concurrent tests that flip the
/// global count cannot interleave their set/restore pairs.
static CONFIG_LOCK: Mutex<()> = Mutex::new(());

thread_local! {
    /// True on pool worker threads: nested primitives run inline there.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn default_threads() -> usize {
    crate::runtime_config::RuntimeConfig::current()
        .threads
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The global worker count (resolved from `CREST_THREADS` / core count on
/// first use, overridable via [`set_threads`]).
pub fn threads() -> usize {
    let t = GLOBAL_THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let resolved = default_threads();
    let _ = GLOBAL_THREADS.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed);
    GLOBAL_THREADS.load(Ordering::Relaxed)
}

/// Override the global worker count (the `--threads` CLI flag).
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Run `f` with the global worker count pinned to `n`, restoring the
/// previous count afterwards (even on panic). Sections are serialized, so
/// determinism tests comparing thread counts cannot race each other.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = CONFIG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    with_threads_unlocked(n, f)
}

/// Core of [`with_threads`]; the caller must hold [`CONFIG_LOCK`].
fn with_threads_unlocked<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            GLOBAL_THREADS.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(threads());
    set_threads(n);
    f()
}

/// A worker-count handle; all primitives spawn scoped threads per call, so
/// the pool itself holds no state beyond the count.
#[derive(Debug, Clone, Copy)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit worker count (min 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool at the configured global worker count.
    pub fn global() -> Pool {
        Pool::new(threads())
    }

    /// Single-worker pool: primitives run inline, in chunk order.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Global pool when `work` (caller-defined op units) amortizes the
    /// scoped-thread spawn cost, else the inline serial pool. Because every
    /// primitive is chunk-deterministic, gating only affects speed.
    pub fn gated(work: usize, min_work: usize) -> Pool {
        if work >= min_work {
            Pool::global()
        } else {
            Pool::serial()
        }
    }

    /// This pool's worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Worker count actually used for `jobs` units of work: 1 when inside a
    /// pool worker already (inline nesting) or when there is nothing to
    /// share.
    fn effective(&self, jobs: usize) -> usize {
        if jobs <= 1 || IN_POOL.with(|c| c.get()) {
            1
        } else {
            self.threads.min(jobs)
        }
    }

    /// Execute `f(i)` for every `i` in `0..n`; indices are claimed
    /// dynamically, so `f` must be safe to run concurrently for distinct
    /// `i` (and must not care about execution order).
    pub fn for_each(&self, n: usize, f: impl Fn(usize) + Sync) {
        let t = self.effective(n);
        if t <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..t {
                scope.spawn(|| {
                    IN_POOL.with(|c| c.set(true));
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        f(i);
                    }
                });
            }
        });
    }

    /// `f` over `0..n` with results returned in index order.
    pub fn map<R: Send>(&self, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
        if self.effective(n) <= 1 {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.for_each(n, |i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("pool map slot unfilled"))
            .collect()
    }

    /// Map fixed-size chunks of `0..n` and return the per-chunk results in
    /// chunk order. Boundaries depend only on `n` and `chunk`, never on the
    /// thread count — fold the returned vec sequentially for a reduction
    /// that is bitwise-identical at any worker count.
    pub fn map_chunks<R: Send>(
        &self,
        n: usize,
        chunk: usize,
        f: impl Fn(Range<usize>) -> R + Sync,
    ) -> Vec<R> {
        assert!(chunk > 0, "map_chunks: chunk must be positive");
        let n_chunks = n.div_ceil(chunk);
        self.map(n_chunks, |c| f(c * chunk..((c + 1) * chunk).min(n)))
    }

    /// Drain `jobs` across the workers (each job runs exactly once; order
    /// is unspecified on the parallel path).
    fn run_queue<J: Send>(&self, mut jobs: Vec<J>, f: impl Fn(J) + Sync) {
        let t = self.effective(jobs.len());
        if t <= 1 {
            for j in jobs.drain(..) {
                f(j);
            }
            return;
        }
        let queue = Mutex::new(jobs);
        std::thread::scope(|scope| {
            for _ in 0..t {
                scope.spawn(|| {
                    IN_POOL.with(|c| c.set(true));
                    loop {
                        let job = queue.lock().unwrap().pop();
                        match job {
                            Some(j) => f(j),
                            None => break,
                        }
                    }
                });
            }
        });
    }

    /// Partition a row-major buffer (`cols` elements per row) into chunks
    /// of `grain` rows and run `f(first_row, rows_slice)` on each. Every
    /// row is written by exactly one worker, so per-row computations are
    /// thread-count independent by construction.
    pub fn for_rows<T: Send>(
        &self,
        data: &mut [T],
        cols: usize,
        grain: usize,
        f: impl Fn(usize, &mut [T]) + Sync,
    ) {
        assert!(cols > 0 && grain > 0, "for_rows: cols/grain must be positive");
        debug_assert_eq!(data.len() % cols, 0);
        let jobs: Vec<(usize, &mut [T])> = data
            .chunks_mut(grain * cols)
            .enumerate()
            .map(|(c, chunk)| (c * grain, chunk))
            .collect();
        self.run_queue(jobs, |(row0, chunk)| f(row0, chunk));
    }

    /// [`Pool::for_rows`] over two buffers sharing the same row count,
    /// partitioned on identical row boundaries.
    pub fn for_rows2<A: Send, B: Send>(
        &self,
        a: &mut [A],
        acols: usize,
        b: &mut [B],
        bcols: usize,
        grain: usize,
        f: impl Fn(usize, &mut [A], &mut [B]) + Sync,
    ) {
        assert!(acols > 0 && bcols > 0 && grain > 0);
        debug_assert_eq!(a.len() / acols, b.len() / bcols);
        let jobs: Vec<(usize, &mut [A], &mut [B])> = a
            .chunks_mut(grain * acols)
            .zip(b.chunks_mut(grain * bcols))
            .enumerate()
            .map(|(c, (ca, cb))| (c * grain, ca, cb))
            .collect();
        self.run_queue(jobs, |(row0, ca, cb)| f(row0, ca, cb));
    }

    /// [`Pool::for_rows`] over three buffers sharing the same row count.
    pub fn for_rows3<A: Send, B: Send, C: Send>(
        &self,
        a: &mut [A],
        acols: usize,
        b: &mut [B],
        bcols: usize,
        c: &mut [C],
        ccols: usize,
        grain: usize,
        f: impl Fn(usize, &mut [A], &mut [B], &mut [C]) + Sync,
    ) {
        assert!(acols > 0 && bcols > 0 && ccols > 0 && grain > 0);
        debug_assert_eq!(a.len() / acols, b.len() / bcols);
        debug_assert_eq!(a.len() / acols, c.len() / ccols);
        let jobs: Vec<(usize, &mut [A], &mut [B], &mut [C])> = a
            .chunks_mut(grain * acols)
            .zip(b.chunks_mut(grain * bcols))
            .zip(c.chunks_mut(grain * ccols))
            .enumerate()
            .map(|(i, ((ca, cb), cc))| (i * grain, ca, cb, cc))
            .collect();
        self.run_queue(jobs, |(row0, ca, cb, cc)| f(row0, ca, cb, cc));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_index_order() {
        for t in [1, 4] {
            let out = Pool::new(t).map(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn for_rows_touches_every_row_once() {
        // 7 rows of 3 with grain 2 -> ragged last chunk
        let mut data = vec![0u32; 7 * 3];
        Pool::new(4).for_rows(&mut data, 3, 2, |row0, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v += (row0 * 3 + k) as u32 + 1;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, k as u32 + 1);
        }
    }

    #[test]
    fn for_rows2_partitions_consistently() {
        let mut a = vec![0usize; 5];
        let mut b = vec![0usize; 10]; // 5 rows of 2
        Pool::new(2).for_rows2(&mut a, 1, &mut b, 2, 2, |row0, ca, cb| {
            for (k, v) in ca.iter_mut().enumerate() {
                *v = row0 + k;
            }
            for (k, v) in cb.iter_mut().enumerate() {
                *v = row0 * 2 + k;
            }
        });
        assert_eq!(a, vec![0, 1, 2, 3, 4]);
        assert_eq!(b, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunks_covers_range_exactly() {
        let parts = Pool::new(3).map_chunks(10, 4, |r| r);
        assert_eq!(parts, vec![0..4, 4..8, 8..10]);
        assert!(Pool::new(2).map_chunks(0, 4, |r| r).is_empty());
    }

    #[test]
    fn chunked_sum_bitwise_identical_across_thread_counts() {
        let xs: Vec<f32> =
            (0..10_000).map(|i| ((i * 2_654_435_761_usize) as f32).sin() * 1e3).collect();
        let sum = |p: &Pool| -> f32 {
            p.map_chunks(xs.len(), 256, |r| xs[r].iter().sum::<f32>()).into_iter().sum()
        };
        let s1 = sum(&Pool::new(1));
        for t in [2, 3, 8] {
            assert_eq!(s1.to_bits(), sum(&Pool::new(t)).to_bits(), "threads={t}");
        }
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let out = Pool::new(4).map(8, |i| {
            Pool::global().map_chunks(100, 10, |r| r.len()).into_iter().sum::<usize>() + i
        });
        assert_eq!(out, (0..8).map(|i| 100 + i).collect::<Vec<_>>());
    }

    #[test]
    fn with_threads_restores_previous_count() {
        // hold the config lock across the before/after reads so concurrent
        // with_threads sections in other tests cannot flip the global
        let _guard = CONFIG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = threads();
        let inside = with_threads_unlocked(3, threads);
        assert_eq!(inside, 3);
        assert_eq!(threads(), before);
    }

    #[test]
    fn gated_pool_selects_by_work() {
        let _guard = CONFIG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(Pool::gated(10, 100).threads(), 1);
        assert_eq!(Pool::gated(100, 100).threads(), threads());
    }
}
