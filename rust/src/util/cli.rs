//! Declarative command-line flag parsing (no clap in the offline registry).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated flags,
//! positional arguments and automatic `--help` text. Used by the `crest`
//! binary, the examples and the bench harnesses.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// One registered flag.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: &'static str,
    flags: Vec<FlagSpec>,
    values: HashMap<&'static str, Vec<String>>,
    positionals: Vec<String>,
}

impl Cli {
    /// Parser for `program` with a one-line description.
    pub fn new(program: &str, about: &'static str) -> Self {
        Cli { program: program.to_string(), about, ..Default::default() }
    }

    /// Register a flag that takes a value, with a default. Help text may
    /// be built at runtime (e.g. generated from an enum's variant list).
    pub fn opt(mut self, name: &'static str, default: &str, help: impl Into<String>) -> Self {
        self.flags.push(FlagSpec {
            name,
            help: help.into(),
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Register a flag that takes a value, without a default (optional).
    pub fn opt_maybe(mut self, name: &'static str, help: impl Into<String>) -> Self {
        self.flags.push(FlagSpec { name, help: help.into(), takes_value: true, default: None });
        self
    }

    /// Register a boolean flag.
    pub fn flag(mut self, name: &'static str, help: impl Into<String>) -> Self {
        self.flags.push(FlagSpec { name, help: help.into(), takes_value: false, default: None });
        self
    }

    fn spec(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Parse the given args (not including argv[0]). On `--help`, prints
    /// usage and exits the process.
    pub fn parse(mut self, args: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = match self.spec(&name) {
                    Some(s) => s.clone(),
                    None => bail!("unknown flag --{name} (try --help)"),
                };
                let value = if spec.takes_value {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= args.len() {
                                bail!("flag --{name} requires a value");
                            }
                            args[i].clone()
                        }
                    }
                } else {
                    if inline.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    "true".to_string()
                };
                self.values.entry(spec.name).or_default().push(value);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // fill defaults
        for f in &self.flags {
            if let Some(d) = &f.default {
                self.values.entry(f.name).or_insert_with(|| vec![d.clone()]);
            }
        }
        Ok(Parsed { values: self.values, positionals: self.positionals })
    }

    /// The `--help` text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [FLAGS]\n\nFLAGS:\n",
                            self.program, self.about, self.program);
        for f in &self.flags {
            let v = if f.takes_value { " <value>" } else { "" };
            let d = f.default.as_deref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{v}\n      {}{d}\n", f.name, f.help));
        }
        s.push_str("  --help\n      print this message\n");
        s
    }
}

/// Result of parsing.
#[derive(Debug)]
pub struct Parsed {
    values: HashMap<&'static str, Vec<String>>,
    /// Non-flag arguments, in order.
    pub positionals: Vec<String>,
}

impl Parsed {
    /// Last value given for the flag (or its default), if any.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a repeated flag, in order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    /// Flag value as an owned string (empty when absent).
    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }

    /// Boolean flag presence.
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Flag value parsed as `usize`.
    pub fn usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        Ok(v.parse()?)
    }

    /// Flag value parsed as `u64`.
    pub fn u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        Ok(v.parse()?)
    }

    /// Flag value parsed as `f32`.
    pub fn f32(&self, name: &str) -> Result<f32> {
        let v = self.get(name).ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        Ok(v.parse()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("variant", "cifar10-proxy", "variant name")
            .opt("seed", "42", "rng seed")
            .opt_maybe("out", "output file")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(&args(&[])).unwrap();
        assert_eq!(p.get("variant"), Some("cifar10-proxy"));
        assert_eq!(p.u64("seed").unwrap(), 42);
        assert_eq!(p.get("out"), None);
        assert!(!p.bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = cli().parse(&args(&["--variant", "snli-proxy", "--seed=7"])).unwrap();
        assert_eq!(p.get("variant"), Some("snli-proxy"));
        assert_eq!(p.u64("seed").unwrap(), 7);
    }

    #[test]
    fn bool_flag_and_positional() {
        let p = cli().parse(&args(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(p.bool("verbose"));
        assert_eq!(p.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn repeated_flag_last_wins_and_all_available() {
        let p = cli().parse(&args(&["--seed", "1", "--seed", "2"])).unwrap();
        assert_eq!(p.u64("seed").unwrap(), 2);
        assert_eq!(p.get_all("seed"), vec!["1", "2"]);
    }

    #[test]
    fn errors() {
        assert!(cli().parse(&args(&["--nope"])).is_err());
        assert!(cli().parse(&args(&["--variant"])).is_err());
        assert!(cli().parse(&args(&["--verbose=x"])).is_err());
        let p = cli().parse(&args(&["--seed", "abc"])).unwrap();
        assert!(p.u64("seed").is_err());
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cli().usage();
        assert!(u.contains("--variant"));
        assert!(u.contains("default: 42"));
    }
}
