//! Foundation substrates: RNG, JSON, CLI parsing, logging, stats, timers.
//!
//! The offline crate registry carries none of the usual ecosystem crates
//! (rand / serde / clap / env_logger), so the project builds these pieces
//! itself — each sized to exactly what the coordinator needs.

pub mod artifact_io;
pub mod cli;
pub mod faults;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;
