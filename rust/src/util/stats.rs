//! Small statistics toolbox: summary stats, online accumulation, vector math.
//!
//! Used by the metrics probes (gradient bias/variance, Fig. 1/6/9), the
//! bench harness (median ± MAD timing) and the quadratic model.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population variance; 0 for len < 2.
pub fn variance(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64) as f32
}

/// Population standard deviation.
pub fn stddev(xs: &[f32]) -> f32 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f32;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f32) * (v[hi] - v[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f32]) -> f32 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread for bench timings).
pub fn mad(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let dev: Vec<f32> = xs.iter().map(|&x| (x - med).abs()).collect();
    median(&dev)
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Observations folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

// ------------------------------------------------------------- vector math

/// Dot product accumulated in f64.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Euclidean norm.
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// out = a - b
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// a += s * b
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += s * y;
    }
}

/// a *= s
pub fn scale(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((variance(&xs) - 1.25).abs() < 1e-6);
        assert!((stddev(&xs) - 1.25f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((median(&xs) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x as f64);
        }
        assert!((w.mean() as f32 - mean(&xs)).abs() < 1e-5);
        assert!((w.variance() as f32 - variance(&xs)).abs() < 1e-5);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn vector_ops() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-9);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(sub(&a, &b), vec![-3.0, -3.0, -3.0]);
        let mut c = a;
        axpy(&mut c, 2.0, &b);
        assert_eq!(c, [9.0, 12.0, 15.0]);
        scale(&mut c, 0.5);
        assert_eq!(c, [4.5, 6.0, 7.5]);
    }
}
