//! Leveled stderr logger behind the `log` facade.
//!
//! Level comes from `CREST_LOG` (error|warn|info|debug|trace; default info).
//! Timestamps are relative to process start — enough to read selection /
//! training interleavings without pulling in a clock-formatting dependency.

use std::sync::{Once, OnceLock};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static INIT: Once = Once::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Parse a level name (case-insensitive).
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent). Level from `CREST_LOG`, default Info.
pub fn init() {
    INIT.call_once(|| {
        let _ = start(); // anchor relative timestamps at first init
        let level = std::env::var("CREST_LOG")
            .ok()
            .and_then(|s| parse_level(&s))
            .unwrap_or(LevelFilter::Info);
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

/// Install with an explicit level (benches/tests that want quiet output).
pub fn init_with(level: LevelFilter) {
    init();
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("INFO"), Some(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        init_with(LevelFilter::Warn);
        assert_eq!(log::max_level(), LevelFilter::Warn);
        init_with(LevelFilter::Info);
    }
}
