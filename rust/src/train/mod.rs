//! Training-loop primitives shared by all methods: parameter state,
//! chunked evaluation, single-batch stepping.
//!
//! Parameter and momentum state live as host vectors and flow through the
//! active `runtime::Backend`, so the loop is identical under the native and
//! PJRT execution paths. Batch staging gathers feature rows from the
//! dataset's store into [`Workspace`]-pooled buffers, so steady-state
//! stepping allocates nothing per step and never assumes the features are
//! RAM-resident.

use anyhow::Result;

use crate::data::Dataset;
use crate::kernel::Workspace;
use crate::runtime::Runtime;

/// Mutable training state (flat params + momentum vectors).
pub struct TrainState {
    /// Flat parameter vector (backend layout).
    pub params: Vec<f32>,
    /// Flat momentum vector, same layout as `params`.
    pub momentum: Vec<f32>,
    /// Steps taken so far.
    pub step: usize,
    /// Pooled staging buffers for batch assembly (features).
    ws: Workspace,
    /// Reused label staging buffer.
    y_buf: Vec<i32>,
}

impl TrainState {
    /// Fresh state from host-side initial parameters (zero momentum).
    pub fn new(rt: &Runtime, init: &[f32]) -> Result<TrainState> {
        Ok(TrainState {
            params: rt.params_from_host(init)?,
            momentum: rt.zero_momentum(),
            step: 0,
            ws: Workspace::new(),
            y_buf: Vec::new(),
        })
    }

    /// One weighted SGD step on the given examples. Returns
    /// (mean batch loss, per-example losses). Staging reuses this state's
    /// workspace, so repeated steps are allocation-free.
    pub fn step_batch(
        &mut self,
        rt: &Runtime,
        ds: &Dataset,
        idx: &[usize],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<(f32, Vec<f32>)> {
        let mut x = self.ws.mat(idx.len(), ds.d());
        ds.gather_into(idx, &mut x);
        self.y_buf.clear();
        self.y_buf.extend(idx.iter().map(|&i| ds.y[i]));
        let out = rt.train_step(&self.params, &self.momentum, &x, &self.y_buf, gamma, lr, wd)?;
        self.ws.recycle_mat(x);
        self.params = out.params;
        self.momentum = out.momentum;
        self.step += 1;
        Ok((out.mean_loss, out.per_ex_loss))
    }

    /// Snapshot params to the host (for the quadratic δ bookkeeping).
    pub fn params_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.params_to_host(&self.params)
    }
}

/// Evaluation summary over a dataset.
#[derive(Debug, Clone)]
pub struct EvalOut {
    /// Mean loss over the dataset.
    pub mean_loss: f32,
    /// Fraction of examples classified correctly.
    pub accuracy: f32,
    /// Per-example losses.
    pub per_ex_loss: Vec<f32>,
    /// Per-example 0/1 correctness.
    pub per_ex_correct: Vec<f32>,
}

/// Chunked evaluation with tail padding (pad indices wrap; padded outputs
/// are discarded so statistics are exact). Each chunk is gathered from the
/// dataset's store into one reused staging matrix.
pub fn evaluate(rt: &Runtime, params: &[f32], ds: &Dataset) -> Result<EvalOut> {
    let e = rt.man.eval_chunk;
    let n = ds.n();
    let mut per_ex_loss = Vec::with_capacity(n);
    let mut per_ex_correct = Vec::with_capacity(n);
    let mut sum_loss = 0.0f64;
    let mut n_correct = 0.0f64;
    let mut ws = Workspace::new();
    let mut idx = Vec::with_capacity(e);
    let mut y = Vec::with_capacity(e);
    let mut start = 0;
    while start < n {
        let end = (start + e).min(n);
        let valid = end - start;
        idx.clear();
        idx.extend((start..start + e).map(|i| i % n));
        let mut x = ws.mat(e, ds.d());
        ds.gather_into(&idx, &mut x);
        y.clear();
        y.extend(idx.iter().map(|&i| ds.y[i]));
        let (_, _, pl, pc) = rt.eval_chunk(params, &x, &y)?;
        ws.recycle_mat(x);
        for k in 0..valid {
            sum_loss += pl[k] as f64;
            n_correct += pc[k] as f64;
            per_ex_loss.push(pl[k]);
            per_ex_correct.push(pc[k]);
        }
        start = end;
    }
    Ok(EvalOut {
        mean_loss: (sum_loss / n as f64) as f32,
        accuracy: (n_correct / n as f64) as f32,
        per_ex_loss,
        per_ex_correct,
    })
}

/// Mean loss over a specific index set (used for the ρ-check's L^r and the
/// dropped-example analysis of Fig. 7a). Evaluates ⌈len/e⌉ chunks.
pub fn eval_on_indices(
    rt: &Runtime,
    params: &[f32],
    ds: &Dataset,
    idx: &[usize],
) -> Result<EvalOut> {
    let sub = ds.subset(idx);
    evaluate(rt, params, &sub)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, SynthSpec};
    use crate::model::init_params;
    use crate::util::rng::Rng;

    #[test]
    fn evaluate_and_step_on_native_backend() {
        let rt = Runtime::native_variant("smoke").unwrap();
        let splits = generate(&SynthSpec::preset("smoke", 3).unwrap());
        let mut rng = Rng::new(3);
        let mut state = TrainState::new(&rt, &init_params(&rt.man, &mut rng)).unwrap();
        let ev0 = evaluate(&rt, &state.params, &splits.val).unwrap();
        assert_eq!(ev0.per_ex_loss.len(), splits.val.n());
        // a few steps on one batch should not corrupt state shapes
        let idx: Vec<usize> = (0..rt.man.m).collect();
        let gamma = vec![1.0; rt.man.m];
        for _ in 0..3 {
            let (loss, per_ex) =
                state.step_batch(&rt, &splits.train, &idx, &gamma, 0.05, 0.0).unwrap();
            assert!(loss.is_finite());
            assert_eq!(per_ex.len(), rt.man.m);
        }
        assert_eq!(state.step, 3);
        assert_eq!(state.params_host(&rt).unwrap().len(), rt.man.p_dim);
        // subset eval path
        let sub = eval_on_indices(&rt, &state.params, &splits.train, &[0, 5, 9]).unwrap();
        assert_eq!(sub.per_ex_loss.len(), 3);
    }
}
