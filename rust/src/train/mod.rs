//! Training-loop primitives shared by all methods: parameter state,
//! chunked evaluation, single-batch stepping.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::Runtime;

/// Mutable training state (params + momentum as device literals).
pub struct TrainState {
    pub params: xla::Literal,
    pub momentum: xla::Literal,
    pub step: usize,
}

impl TrainState {
    pub fn new(rt: &Runtime, init: &[f32]) -> Result<TrainState> {
        Ok(TrainState {
            params: rt.params_from_host(init)?,
            momentum: rt.zero_momentum(),
            step: 0,
        })
    }

    /// One weighted SGD step on the given examples. Returns
    /// (mean batch loss, per-example losses).
    pub fn step_batch(
        &mut self,
        rt: &Runtime,
        ds: &Dataset,
        idx: &[usize],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<(f32, Vec<f32>)> {
        let (x, y) = ds.batch(idx);
        let out = rt.train_step(&self.params, &self.momentum, &x, &y, gamma, lr, wd)?;
        self.params = out.params;
        self.momentum = out.momentum;
        self.step += 1;
        Ok((out.mean_loss, out.per_ex_loss))
    }

    /// Snapshot params to the host (for the quadratic δ bookkeeping).
    pub fn params_host(&self, rt: &Runtime) -> Result<Vec<f32>> {
        rt.params_to_host(&self.params)
    }
}

/// Evaluation summary over a dataset.
#[derive(Debug, Clone)]
pub struct EvalOut {
    pub mean_loss: f32,
    pub accuracy: f32,
    pub per_ex_loss: Vec<f32>,
    pub per_ex_correct: Vec<f32>,
}

/// Chunked evaluation with tail padding (pad indices wrap; padded outputs
/// are discarded so statistics are exact).
pub fn evaluate(rt: &Runtime, params: &xla::Literal, ds: &Dataset) -> Result<EvalOut> {
    let e = rt.man.eval_chunk;
    let n = ds.n();
    let mut per_ex_loss = Vec::with_capacity(n);
    let mut per_ex_correct = Vec::with_capacity(n);
    let mut sum_loss = 0.0f64;
    let mut n_correct = 0.0f64;
    let mut start = 0;
    while start < n {
        let end = (start + e).min(n);
        let valid = end - start;
        let idx: Vec<usize> = (start..start + e).map(|i| i % n).collect();
        let (x, y) = ds.batch(&idx);
        let (_, _, pl, pc) = rt.eval_chunk(params, &x, &y)?;
        for k in 0..valid {
            sum_loss += pl[k] as f64;
            n_correct += pc[k] as f64;
            per_ex_loss.push(pl[k]);
            per_ex_correct.push(pc[k]);
        }
        start = end;
    }
    Ok(EvalOut {
        mean_loss: (sum_loss / n as f64) as f32,
        accuracy: (n_correct / n as f64) as f32,
        per_ex_loss,
        per_ex_correct,
    })
}

/// Mean loss over a specific index set (used for the ρ-check's L^r and the
/// dropped-example analysis of Fig. 7a). Evaluates ⌈len/e⌉ chunks.
pub fn eval_on_indices(
    rt: &Runtime,
    params: &xla::Literal,
    ds: &Dataset,
    idx: &[usize],
) -> Result<EvalOut> {
    let sub = ds.subset(idx);
    evaluate(rt, params, &sub)
}

#[cfg(test)]
mod tests {
    // Execution-dependent behaviour is covered by rust/tests/ integration
    // tests (requires artifacts). Nothing pure to test here.
}
