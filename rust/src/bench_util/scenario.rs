//! Shared glue for the paper-reproduction benches (`rust/benches/*.rs`).
//!
//! Each bench regenerates one table or figure. They all need the same
//! setup — load a variant's runtime, generate its proxy corpus, run
//! experiment cells — and the same scale knobs:
//!
//! * `CREST_BENCH_SEEDS`   seeds per cell (default 2)
//! * `CREST_BENCH_EPOCHS`  full-run epochs (default 50)
//! * `CREST_BENCH_VARIANTS` comma list (default cifar10-proxy,cifar100-proxy)
//! * `CREST_BENCH_FULL=1`   all four variants, 3 seeds
//!
//! Runtimes load on the native backend (builtin manifests), so `cargo
//! bench` works from a clean checkout; a bench exits 0 with a notice only
//! for unknown variant names.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{ExperimentConfig, Method};
use crate::coordinator::run_experiment;
use crate::data::{prepare_splits, Splits, SynthSpec};
use crate::report::RunReport;
use crate::runtime::Runtime;
use crate::util::stats;

/// Artifact root (`CREST_ARTIFACTS`, default `artifacts`).
pub fn artifact_root() -> PathBuf {
    std::env::var("CREST_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
}

/// Seeds per cell (`CREST_BENCH_SEEDS`; 3 at full scale, else 2).
pub fn seeds() -> Vec<u64> {
    let n: usize = std::env::var("CREST_BENCH_SEEDS").ok().and_then(|s| s.parse().ok())
        .unwrap_or(if full_scale() { 3 } else { 2 });
    (1..=n as u64).collect()
}

/// Full-run reference epochs (`CREST_BENCH_EPOCHS`, default 50).
pub fn epochs_full() -> usize {
    std::env::var("CREST_BENCH_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(50)
}

/// True under `CREST_BENCH_FULL` (all variants, 3 seeds).
pub fn full_scale() -> bool {
    std::env::var("CREST_BENCH_FULL").is_ok()
}

/// Sweep checkpoint directory for resumable benches (`CREST_SWEEP_CKPT`);
/// `None` (fresh cells every run) when unset.
pub fn checkpoint_dir() -> Option<PathBuf> {
    std::env::var("CREST_SWEEP_CKPT").ok().map(PathBuf::from)
}

/// True when `variant` has both a loadable runtime and a synthetic
/// preset; prints a `[skip]` notice otherwise, so benches can filter
/// unknown variant names and still exit 0 (the historical contract).
pub fn known(variant: &str) -> bool {
    if SynthSpec::preset(variant, 1).is_none() {
        println!("[skip] {variant}: no synthetic preset");
        return false;
    }
    match Runtime::load(&artifact_root(), variant) {
        Ok(_) => true,
        Err(e) => {
            println!("[skip] {variant}: no runtime available ({e:#})");
            false
        }
    }
}

/// Variant list: `CREST_BENCH_VARIANTS`, else all four at full scale,
/// else the two headline proxies.
pub fn variants() -> Vec<String> {
    if let Ok(v) = std::env::var("CREST_BENCH_VARIANTS") {
        return v.split(',').map(|s| s.trim().to_string()).collect();
    }
    if full_scale() {
        crate::config::ALL_VARIANTS.iter().map(|s| s.to_string()).collect()
    } else {
        vec!["cifar10-proxy".to_string(), "cifar100-proxy".to_string()]
    }
}

/// Load a variant's runtime + data, or None (with a notice) when the
/// variant is unknown. Data goes through [`prepare_splits`], so benches
/// honor `--data-store` / `CREST_DATA_STORE` like the CLI does (a
/// `Splits` clone is shallow: the feature store sits behind an `Arc`).
pub fn load(variant: &str, seed: u64) -> Option<(Runtime, Splits)> {
    let root = artifact_root();
    SynthSpec::preset(variant, seed)?;
    match Runtime::load(&root, variant) {
        Ok(rt) => match prepare_splits(variant, seed) {
            Ok(splits) => Some((rt, splits.as_ref().clone())),
            Err(e) => {
                println!("[skip] {variant}: data preparation failed ({e:#})");
                None
            }
        },
        Err(e) => {
            println!("[skip] {variant}: no runtime available ({e:#})");
            None
        }
    }
}

/// Run one experiment cell with config tweaks applied by `patch`.
pub fn cell(
    rt: &Runtime,
    splits: &Splits,
    variant: &str,
    method: Method,
    seed: u64,
    patch: impl FnOnce(&mut ExperimentConfig),
) -> Result<RunReport> {
    let mut cfg = ExperimentConfig::preset(variant, method, seed)?;
    cfg.epochs_full = epochs_full();
    patch(&mut cfg);
    run_experiment(rt, splits, cfg)
}

/// Mean ± std of an accuracy list, formatted like the paper's tables.
pub fn fmt_mean_std(vals: &[f32]) -> String {
    format!("{:.2}±{:.1}", stats::mean(vals), stats::stddev(vals))
}

/// Relative error (%) per paper Table 1 definition.
pub fn rel_err(acc_coreset: f32, acc_full: f32) -> f32 {
    crate::metrics::relative_error_pct(acc_coreset * 100.0, acc_full * 100.0)
}

/// Spec for the out-of-core scaling scenario: the smoke model geometry
/// (d=16, 4 classes — so the builtin smoke runtime trains it) with the
/// training split scaled to `n_train` examples. At 10^6 examples the
/// feature payload is 64 MB per copy, big enough to exercise the sharded
/// mmap path honestly while staying inside CI disk budgets.
pub fn oocore_spec(n_train: usize, seed: u64) -> SynthSpec {
    SynthSpec {
        n_train,
        n_val: 512,
        n_test: 1024,
        ..SynthSpec::preset("smoke", seed).expect("smoke preset exists")
    }
}

/// The strategy axis of the selection-crossover scaling scenario:
/// `Exact` plus each approximate strategy at its auto parameter, labeled
/// with its canonical name. `benches/scaling.rs` and the CI scaling-smoke
/// job both sweep this one table, so the measured strategies cannot drift
/// from the shipped ones.
pub fn selection_strategies() -> Vec<(&'static str, crate::coreset::SelectionStrategy)> {
    use crate::coreset::SelectionStrategy as S;
    vec![
        ("exact", S::Exact),
        ("class-sharded", S::ClassSharded { shards: 0 }),
        ("clustered", S::Clustered { k: 0 }),
        ("knn", S::Knn { neighbors: 0 }),
    ]
}
