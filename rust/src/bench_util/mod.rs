//! Micro-benchmark harness (criterion replacement for the offline registry).
//!
//! Warmup + timed repetitions with p50/p95 reporting; benches under
//! `rust/benches/` use `harness = false` and drive this directly.
//!
//! Env knobs:
//!
//! * `CREST_BENCH_WARMUP` / `CREST_BENCH_REPS` — override every bench's
//!   warmup / measured repetitions (quick mode caps both; explicit env
//!   values win over the caps)
//! * `CREST_BENCH_QUICK=1` — reduced problem sizes + capped reps (the CI
//!   perf-smoke configuration)
//! * `CREST_BENCH_JSON=<path>` — [`flush_json`] appends every recorded
//!   result to a JSON array at this path (the perf trajectory file)
//!
//! Benches with a known arithmetic cost use [`bench_recorded_flops`] to
//! report GFLOP/s alongside p50/p95; [`diff_baseline`] (exposed as
//! `crest bench-diff`) gates a fresh trajectory against the committed
//! `BENCH_perf.json` baseline.

pub mod diff;
pub mod scenario;

pub use diff::{baseline_records, diff_baseline, DiffOutcome};

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::pool;
use crate::util::stats;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (`section/op` style).
    pub name: String,
    /// Measured repetitions.
    pub reps: usize,
    /// Median absolute deviation of the measured reps.
    pub mad_secs: f64,
    /// Mean of the measured reps.
    pub mean_secs: f64,
    /// Fastest measured rep.
    pub min_secs: f64,
    /// Median of the measured reps.
    pub p50_secs: f64,
    /// 95th percentile of the measured reps.
    pub p95_secs: f64,
    /// Pool worker count the bench ran with.
    pub threads: usize,
    /// Arithmetic operations one call performs (0 = not reported).
    pub flops: u64,
    /// True when the bench ran in quick (CI smoke) mode — quick and full
    /// records are never diffed against each other.
    pub quick: bool,
    /// Kernel ISA the dispatching kernels used during the bench (records
    /// carry it so a trajectory mixing machines stays interpretable).
    pub isa: String,
}

impl BenchResult {
    /// Throughput in GFLOP/s at the p50 time (`None` when no op count was
    /// supplied).
    pub fn gflops_p50(&self) -> Option<f64> {
        (self.flops > 0 && self.p50_secs > 0.0)
            .then(|| self.flops as f64 / self.p50_secs / 1e9)
    }

    /// One fixed-width human-readable result line.
    pub fn report(&self) -> String {
        let gf = match self.gflops_p50() {
            Some(g) => format!(" {g:>8.2} GF/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10} {:>12} {:>14} {:>12}{}",
            self.name,
            format_secs(self.p50_secs),
            format!("±{}", format_secs(self.mad_secs)),
            format!("p95 {}", format_secs(self.p95_secs)),
            format!("min {}", format_secs(self.min_secs)),
            gf,
        )
    }

    /// Machine-readable record for the perf trajectory (`CREST_BENCH_JSON`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("reps", self.reps)
            .set("threads", self.threads)
            .set("mean_secs", self.mean_secs)
            .set("min_secs", self.min_secs)
            .set("p50_secs", self.p50_secs)
            .set("p95_secs", self.p95_secs)
            .set("mad_secs", self.mad_secs)
            .set("quick", self.quick)
            .set("isa", self.isa.as_str());
        if let Some(g) = self.gflops_p50() {
            j = j.set("flops", self.flops as f64).set("gflops_p50", g);
        }
        j
    }
}

/// Human-scaled seconds.
pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|s| s.parse().ok())
}

/// True under `CREST_BENCH_QUICK=1`: benches shrink problem sizes and the
/// harness caps warmup/reps (p50/p95 still report the residual noise).
/// Empty, `0`, and `false` values mean full mode, so an exported-but-off
/// flag cannot silently shrink the perf trajectory.
pub fn quick() -> bool {
    matches!(std::env::var("CREST_BENCH_QUICK").ok().as_deref(),
        Some(v) if !v.is_empty() && v != "0" && v != "false")
}

/// Time `f` with `warmup` unmeasured calls and `reps` measured calls.
/// `CREST_BENCH_WARMUP` / `CREST_BENCH_REPS` override both; quick mode
/// caps them (warmup ≤ 1, reps ≤ 5) unless explicitly overridden.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    let warmup =
        env_usize("CREST_BENCH_WARMUP").unwrap_or(if quick() { warmup.min(1) } else { warmup });
    let reps = env_usize("CREST_BENCH_REPS")
        .unwrap_or(if quick() { reps.min(5) } else { reps })
        .max(1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64() as f32);
    }
    BenchResult {
        name: name.to_string(),
        reps,
        mad_secs: stats::mad(&times) as f64,
        mean_secs: stats::mean(&times) as f64,
        min_secs: times.iter().cloned().fold(f32::INFINITY, f32::min) as f64,
        p50_secs: stats::median(&times) as f64,
        p95_secs: stats::percentile(&times, 95.0) as f64,
        threads: pool::threads(),
        flops: 0,
        quick: quick(),
        isa: crate::kernel::active_isa().name().to_string(),
    }
}

/// [`bench`] with a per-call arithmetic-op count attached, so the report
/// and the JSON record carry GFLOP/s alongside p50/p95.
pub fn bench_flops<T>(
    name: &str,
    warmup: usize,
    reps: usize,
    flops: u64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, warmup, reps, f);
    r.flops = flops;
    r
}

/// Results queued for [`flush_json`].
static RECORDS: Mutex<Vec<Json>> = Mutex::new(Vec::new());

/// Queue a result for the JSON trajectory.
pub fn record(r: &BenchResult) {
    RECORDS.lock().unwrap().push(r.to_json());
}

/// Run, print, and record in one call — the standard bench step.
pub fn bench_recorded<T>(
    name: &str,
    warmup: usize,
    reps: usize,
    f: impl FnMut() -> T,
) -> BenchResult {
    let r = bench(name, warmup, reps, f);
    println!("{}", r.report());
    record(&r);
    r
}

/// [`bench_recorded`] with a per-call op count (GFLOP/s reporting).
pub fn bench_recorded_flops<T>(
    name: &str,
    warmup: usize,
    reps: usize,
    flops: u64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let r = bench_flops(name, warmup, reps, flops, f);
    println!("{}", r.report());
    record(&r);
    r
}

/// Write all recorded results to `$CREST_BENCH_JSON`, merging with an
/// existing array at that path so `--bench perf --bench scaling` land in
/// one trajectory file. No-op when the env var is unset. Call at the end
/// of every bench `main`.
pub fn flush_json() -> Result<()> {
    match std::env::var("CREST_BENCH_JSON") {
        Ok(path) => flush_json_to(Path::new(&path)),
        Err(_) => Ok(()),
    }
}

/// Env-independent core of [`flush_json`] (drains the record queue).
pub fn flush_json_to(path: &Path) -> Result<()> {
    let drained: Vec<Json> = std::mem::take(&mut *RECORDS.lock().unwrap());
    let n_new = append_json_records(path, drained)?;
    println!("[bench] appended {n_new} perf records to {}", path.display());
    Ok(())
}

/// Append `records` to the JSON array at `path`, merging with existing
/// content; returns how many records were appended. The shared
/// append-merge primitive behind `CREST_BENCH_JSON` and `crest sweep
/// --out`, so perf records and sweep aggregates can share one trajectory
/// file. An unreadable or corrupt existing file (e.g. a truncated write
/// from a killed run) starts a fresh array instead of failing the caller.
pub fn append_json_records(path: &Path, records: Vec<Json>) -> Result<usize> {
    let mut all: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text).and_then(|j| Ok(j.as_arr()?.to_vec())) {
            Ok(existing) => existing,
            Err(e) => {
                eprintln!(
                    "[bench] {}: existing trajectory unreadable ({e:#}); starting fresh",
                    path.display()
                );
                Vec::new()
            }
        },
        Err(_) => Vec::new(),
    };
    let n_new = records.len();
    all.extend(records);
    // atomic write: a kill mid-write must never truncate the accumulated
    // trajectory (a truncated file would "start fresh" above)
    crate::util::json::write_atomic(path, &Json::Arr(all))?;
    Ok(n_new)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.p50_secs > 0.0);
        assert!(r.min_secs <= r.p50_secs);
        assert_eq!(r.reps, 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn format_scales() {
        assert!(format_secs(2.5).ends_with('s'));
        assert!(format_secs(2.5e-3).ends_with("ms"));
        assert!(format_secs(2.5e-6).ends_with("µs"));
        assert!(format_secs(2.5e-10).ends_with("ns"));
    }

    #[test]
    fn percentiles_ordered_and_json_complete() {
        let r = bench("sleep", 0, 7, || {
            std::thread::sleep(std::time::Duration::from_micros(50));
        });
        assert!(r.min_secs <= r.p50_secs && r.p50_secs <= r.p95_secs);
        assert!(r.threads >= 1);
        let j = r.to_json();
        for key in
            ["name", "reps", "threads", "mean_secs", "min_secs", "p50_secs", "p95_secs", "mad_secs", "isa"]
        {
            assert!(j.get(key).is_some(), "to_json missing {key}");
        }
    }

    #[test]
    fn append_json_records_merges_arbitrary_records() {
        let dir = std::env::temp_dir().join("crest-bench-json-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("merge.json");
        let _ = std::fs::remove_file(&path);
        let n = append_json_records(&path, vec![Json::obj().set("name", "sweep/x")]).unwrap();
        assert_eq!(n, 1);
        append_json_records(&path, vec![Json::obj().set("name", "perf/y")]).unwrap();
        let arr = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = arr.as_arr().unwrap();
        assert_eq!(arr.len(), 2, "records from separate callers merge into one array");
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "sweep/x");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flush_appends_to_existing_json() {
        let dir = std::env::temp_dir().join("crest-bench-json-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_perf.json");
        let _ = std::fs::remove_file(&path);
        let r = bench("flush-probe", 0, 1, || 1 + 1);
        record(&r);
        flush_json_to(&path).unwrap();
        record(&r);
        flush_json_to(&path).unwrap();
        let arr = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(arr.as_arr().unwrap().len() >= 2, "records must accumulate across flushes");
        assert!(arr.as_arr().unwrap().iter().any(|v| {
            v.get("name").and_then(|n| n.as_str().ok()) == Some("flush-probe")
        }));
        // a corrupt trajectory (truncated write) must not abort the flush
        std::fs::write(&path, "{truncated").unwrap();
        record(&r);
        flush_json_to(&path).unwrap();
        let arr = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(!arr.as_arr().unwrap().is_empty(), "fresh array after corruption");
        let _ = std::fs::remove_file(&path);
    }
}
