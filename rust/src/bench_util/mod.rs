//! Micro-benchmark harness (criterion replacement for the offline registry).
//!
//! Warmup + timed repetitions with median ± MAD reporting; benches under
//! `rust/benches/` use `harness = false` and drive this directly.

pub mod scenario;

use std::time::Instant;

use crate::util::stats;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub median_secs: f64,
    pub mad_secs: f64,
    pub mean_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} {:>12} {:>12}",
            self.name,
            format_secs(self.median_secs),
            format!("±{}", format_secs(self.mad_secs)),
            format!("min {}", format_secs(self.min_secs)),
        )
    }
}

/// Human-scaled seconds.
pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured calls and `reps` measured calls.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(reps > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64() as f32);
    }
    BenchResult {
        name: name.to_string(),
        reps,
        median_secs: stats::median(&times) as f64,
        mad_secs: stats::mad(&times) as f64,
        mean_secs: stats::mean(&times) as f64,
        min_secs: times.iter().cloned().fold(f32::INFINITY, f32::min) as f64,
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.median_secs > 0.0);
        assert!(r.min_secs <= r.median_secs);
        assert_eq!(r.reps, 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn format_scales() {
        assert!(format_secs(2.5).ends_with('s'));
        assert!(format_secs(2.5e-3).ends_with("ms"));
        assert!(format_secs(2.5e-6).ends_with("µs"));
        assert!(format_secs(2.5e-10).ends_with("ns"));
    }
}
