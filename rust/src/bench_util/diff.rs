//! Perf-baseline regression diff — the `crest bench-diff` core.
//!
//! Compares freshly measured bench records against a committed baseline
//! trajectory (both in the `CREST_BENCH_JSON` array format). Records are
//! keyed by `(name, threads, quick)`; when the same key appears several
//! times in one file (an appended trajectory), the latest record wins, so
//! a file that accumulates history still diffs against its newest state.
//! A fresh p50 beyond `factor ×` the baseline p50 is a regression.
//!
//! The gate is deliberately forgiving about coverage: a baseline with no
//! overlapping keys (e.g. the empty seed committed before the first
//! measured run, or a bench whose names changed) produces a warning and
//! zero regressions rather than a failure — only measured slowdowns fail
//! the gate.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One record key: benchmark name, pool worker count, quick-mode flag.
type Key = (String, usize, bool);

/// Result of one baseline diff.
#[derive(Debug)]
pub struct DiffOutcome {
    /// Keys present in both files and compared.
    pub compared: usize,
    /// Human-readable lines for every regression beyond the factor.
    pub regressions: Vec<String>,
    /// Full human-readable comparison table.
    pub report: String,
}

/// Load a trajectory file into `(key → latest p50)`. Records without a
/// `name` or `p50_secs` (e.g. sweep aggregate rows sharing the file) are
/// skipped.
fn index(path: &Path) -> Result<HashMap<Key, f64>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench records from {}", path.display()))?;
    let doc = Json::parse(&text)
        .with_context(|| format!("parsing bench records in {}", path.display()))?;
    let mut map = HashMap::new();
    for rec in doc.as_arr()? {
        let Some(name) = rec.get("name").and_then(|n| n.as_str().ok()) else { continue };
        let Some(p50) = rec.get("p50_secs").and_then(|v| v.as_f64().ok()) else { continue };
        let threads = rec.get("threads").and_then(|v| v.as_usize().ok()).unwrap_or(0);
        let quick = rec.get("quick").and_then(|v| v.as_bool().ok()).unwrap_or(false);
        map.insert((name.to_string(), threads, quick), p50);
    }
    Ok(map)
}

/// Count the gateable records in a trajectory file (records carrying a
/// `name` and a `p50_secs`). Lets callers refuse to run against a baseline
/// that is still the empty `[]` seed — see `bench-diff --require-baseline`.
pub fn baseline_records(path: &Path) -> Result<usize> {
    Ok(index(path)?.len())
}

/// Diff `fresh` against `baseline`: every key present in both must have a
/// fresh p50 within `factor ×` the baseline p50. Returns the comparison
/// report and the list of regressions (empty = gate passes).
pub fn diff_baseline(baseline: &Path, fresh: &Path, factor: f64) -> Result<DiffOutcome> {
    anyhow::ensure!(factor > 0.0, "bench-diff: factor must be positive, got {factor}");
    let base = index(baseline)?;
    let new = index(fresh)?;
    let mut keys: Vec<&Key> = new.keys().filter(|k| base.contains_key(*k)).collect();
    keys.sort();
    let mut report = String::new();
    let mut regressions = Vec::new();
    report.push_str(&format!(
        "{:<52} {:>12} {:>12} {:>8}  status\n",
        "benchmark (threads, mode)", "baseline", "fresh", "ratio"
    ));
    for key in &keys {
        let b = base[*key];
        let f = new[*key];
        let ratio = if b > 0.0 { f / b } else { f64::INFINITY };
        let label = format!(
            "{} (t={}, {})",
            key.0,
            key.1,
            if key.2 { "quick" } else { "full" }
        );
        let status = if ratio > factor { "REGRESSED" } else { "ok" };
        let line = format!(
            "{:<52} {:>12} {:>12} {:>7.2}x  {}",
            label,
            super::format_secs(b),
            super::format_secs(f),
            ratio,
            status
        );
        report.push_str(&line);
        report.push('\n');
        if ratio > factor {
            regressions.push(line);
        }
    }
    if keys.is_empty() {
        report.push_str(
            "(no overlapping records between baseline and fresh run — \
             nothing to gate; commit a measured baseline to arm the diff)\n",
        );
    } else {
        report.push_str(&format!(
            "{} record(s) compared, {} regression(s) beyond {factor}x\n",
            keys.len(),
            regressions.len()
        ));
    }
    Ok(DiffOutcome { compared: keys.len(), regressions, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::write_atomic;

    fn rec(name: &str, threads: usize, quick: bool, p50: f64) -> Json {
        Json::obj()
            .set("name", name)
            .set("threads", threads)
            .set("p50_secs", p50)
            .set("quick", quick)
    }

    fn write(path: &Path, recs: Vec<Json>) {
        write_atomic(path, &Json::Arr(recs)).unwrap();
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("crest-bench-diff-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn passes_within_factor_and_flags_regressions() {
        let b = tmp("base.json");
        let f = tmp("fresh.json");
        write(&b, vec![rec("op/a", 1, false, 1.0), rec("op/b", 1, false, 1.0)]);
        write(&f, vec![rec("op/a", 1, false, 1.5), rec("op/b", 1, false, 2.5)]);
        let out = diff_baseline(&b, &f, 2.0).unwrap();
        assert_eq!(out.compared, 2);
        assert_eq!(out.regressions.len(), 1);
        assert!(out.regressions[0].contains("op/b"));
        assert!(out.report.contains("REGRESSED"));
    }

    #[test]
    fn latest_record_per_key_wins() {
        let b = tmp("base-latest.json");
        let f = tmp("fresh-latest.json");
        // the baseline accumulated history: old slow record, then a fast one
        write(&b, vec![rec("op/a", 1, false, 9.0), rec("op/a", 1, false, 1.0)]);
        write(&f, vec![rec("op/a", 1, false, 2.5)]);
        let out = diff_baseline(&b, &f, 2.0).unwrap();
        assert_eq!(out.compared, 1);
        assert_eq!(out.regressions.len(), 1, "diffed against the latest (fast) baseline");
    }

    #[test]
    fn quick_and_full_records_never_cross_compare() {
        let b = tmp("base-quick.json");
        let f = tmp("fresh-quick.json");
        write(&b, vec![rec("op/a", 1, false, 0.001)]);
        write(&f, vec![rec("op/a", 1, true, 1.0)]);
        let out = diff_baseline(&b, &f, 2.0).unwrap();
        assert_eq!(out.compared, 0);
        assert!(out.regressions.is_empty());
        assert!(out.report.contains("no overlapping records"));
    }

    #[test]
    fn seed_baseline_passes_with_warning() {
        let b = tmp("base-empty.json");
        let f = tmp("fresh-some.json");
        write(&b, Vec::new());
        write(&f, vec![rec("op/a", 1, false, 1.0)]);
        let out = diff_baseline(&b, &f, 2.0).unwrap();
        assert_eq!(out.compared, 0);
        assert!(out.regressions.is_empty());
    }

    #[test]
    fn non_bench_rows_are_skipped() {
        let b = tmp("base-mixed.json");
        let f = tmp("fresh-mixed.json");
        // sweep aggregate rows share the trajectory file but carry no p50
        write(&b, vec![Json::obj().set("variant", "smoke"), rec("op/a", 1, false, 1.0)]);
        write(&f, vec![rec("op/a", 1, false, 1.2)]);
        let out = diff_baseline(&b, &f, 2.0).unwrap();
        assert_eq!(out.compared, 1);
        assert!(out.regressions.is_empty());
    }

    #[test]
    fn baseline_records_counts_gateable_rows_only() {
        let b = tmp("base-count.json");
        write(&b, vec![Json::obj().set("variant", "smoke"), rec("op/a", 1, false, 1.0)]);
        assert_eq!(baseline_records(&b).unwrap(), 1);
        let e = tmp("base-count-empty.json");
        write(&e, Vec::new());
        assert_eq!(baseline_records(&e).unwrap(), 0);
    }

    #[test]
    fn missing_file_is_an_error() {
        let f = tmp("fresh-alone.json");
        write(&f, vec![rec("op/a", 1, false, 1.0)]);
        assert!(diff_baseline(Path::new("/nonexistent/base.json"), &f, 2.0).is_err());
        assert!(diff_baseline(&f, Path::new("/nonexistent/fresh.json"), 2.0).is_err());
    }
}
