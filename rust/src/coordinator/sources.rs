//! Batch sources: one per training method.
//!
//! A `BatchSource` hands the coordinator the next weighted mini-batch. All
//! method-specific machinery — CREST's Algorithm 1, the per-epoch baseline
//! reselections, greedy-per-batch — lives behind this interface so the
//! outer loop (budget, LR, eval, forgettability) is shared.
//!
//! Each builtin method is described to the
//! [`MethodRegistry`](crate::api::MethodRegistry) by a [`MethodSpec`]
//! (see `builtin_specs`): a name, help text, behavior flags, and a
//! factory closing over the source implementation here. There is no
//! method `match` anywhere — adding a method means registering a new
//! spec, not editing this file.

use std::time::Instant;

use anyhow::Result;

use crate::api::registry::{MethodSpec, SourceCtx};
use crate::config::ExperimentConfig;
use crate::coreset::embed_cache::{region_id, subset_key, subset_key_all, EmbedCache};
use crate::coreset::strategy::{self, SelectionStrategy};
use crate::coreset::{craig, facility, MiniBatchCoreset};
use crate::data::Dataset;
use crate::exclusion::ExclusionTracker;
use crate::quadratic::{QuadOptions, QuadraticModel};
use crate::runtime::Runtime;
use crate::tensor::MatF32;
use crate::train::TrainState;
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::timer::PhaseTimers;

/// What a source knows about one selection event (for Fig. 5 post-hoc).
#[derive(Debug, Clone)]
pub struct SelectionRecord {
    /// Step the selection happened at.
    pub step: usize,
    /// Global indices the round selected.
    pub selected: Vec<usize>,
}

/// One batch handed to the trainer.
pub struct SourcedBatch {
    /// Global example indices of the batch.
    pub idx: Vec<usize>,
    /// Per-element weights.
    pub gamma: Vec<f32>,
    /// Set when producing this batch ran a selection round.
    pub selection: Option<SelectionRecord>,
}

/// Aggregate statistics a source reports at the end of the run.
#[derive(Debug, Clone, Default)]
pub struct SourceStats {
    /// Selection rounds performed.
    pub n_updates: usize,
    /// Examples excluded as learned.
    pub n_excluded: usize,
    /// indices currently excluded as learned (Fig. 7a analysis)
    pub excluded_indices: Vec<usize>,
    /// (step, ρ) at each threshold check.
    pub rho_history: Vec<(usize, f32)>,
    /// (step, T₁) after each adaptation.
    pub t1_history: Vec<(usize, usize)>,
    /// Steps at which a selection update ran.
    pub update_steps: Vec<usize>,
}

/// A training-batch producer; one implementation per method.
pub trait BatchSource {
    /// Produce the next weighted mini-batch (running a selection round
    /// first when the method calls for one).
    fn next_batch(
        &mut self,
        step: usize,
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch>;

    /// Hook after the weight update (CREST runs its ρ-check here).
    fn after_step(
        &mut self,
        _step: usize,
        _idx: &[usize],
        _per_ex_loss: &[f32],
        _state: &mut TrainState,
        _timers: &mut PhaseTimers,
    ) -> Result<()> {
        Ok(())
    }

    /// Aggregate statistics for the run report.
    fn stats(&self) -> SourceStats;
}

// ------------------------------------------------------ builtin factories

fn make_random<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(Box::new(RandomSource::new(ctx.train.n(), ctx.rt.man.m, rng)))
}

fn make_greedy_per_batch<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(Box::new(GreedyPerBatchSource {
        rt: ctx.rt,
        train: ctx.train,
        selection: ctx.cfg.selection,
        unit_gamma: ctx.cfg.crest.unit_gamma,
        rng,
        n_updates: 0,
    }))
}

fn make_epoch<'a>(
    selector: EpochSelector,
    ctx: SourceCtx<'a>,
    rng: Rng,
) -> Box<dyn BatchSource + 'a> {
    let k = ((ctx.train.n() as f32 * ctx.cfg.budget_frac) as usize).max(ctx.rt.man.m);
    let epoch_steps = (k / ctx.rt.man.m).max(1);
    Box::new(EpochCoresetSource {
        selector,
        rt: ctx.rt,
        train: ctx.train,
        val: ctx.val,
        selection: ctx.cfg.selection,
        k,
        epoch_steps,
        into_epoch: 0,
        entries: Vec::new(),
        rng,
        embed_cache: EmbedCache::from_env(),
        n_updates: 0,
        update_steps: Vec::new(),
    })
}

fn make_craig<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(make_epoch(EpochSelector::Craig, ctx, rng))
}

fn make_gradmatch<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(make_epoch(EpochSelector::GradMatch, ctx, rng))
}

fn make_glister<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(make_epoch(EpochSelector::Glister, ctx, rng))
}

fn make_crest<'a>(ctx: SourceCtx<'a>, rng: Rng) -> Result<Box<dyn BatchSource + 'a>> {
    Ok(Box::new(CrestSource::new(ctx.cfg, ctx.rt, ctx.train, ctx.steps_total, rng)))
}

/// Registry specs of the eight paper methods, in Table-1 presentation
/// order. This is the single builtin table `--method` help, sweep grids,
/// and `compare` rows all derive from.
pub(crate) fn builtin_specs() -> Vec<MethodSpec> {
    fn spec(
        name: &str,
        aliases: &[&str],
        help: &str,
        factory: crate::api::registry::MethodFactory,
    ) -> MethodSpec {
        MethodSpec {
            name: name.to_string(),
            aliases: aliases.iter().map(|s| s.to_string()).collect(),
            help: help.to_string(),
            reference: false,
            full_horizon_schedule: false,
            coreset_lr_scale: false,
            factory,
        }
    }
    vec![
        MethodSpec {
            reference: true,
            ..spec(
                "full",
                &[],
                "full-data mini-batch SGD (the accuracy reference)",
                Box::new(make_random),
            )
        },
        spec(
            "random",
            &[],
            "random mini-batches under the budget (compressed LR schedule)",
            Box::new(make_random),
        ),
        MethodSpec {
            full_horizon_schedule: true,
            ..spec(
                "sgd-truncated",
                &["sgd"],
                "standard pipeline truncated at the budget (SGD†, full-horizon LR)",
                Box::new(make_random),
            )
        },
        MethodSpec {
            coreset_lr_scale: true,
            ..spec(
                "crest",
                &[],
                "this paper (Algorithm 1): adaptive mini-batch coresets",
                Box::new(make_crest),
            )
        },
        spec(
            "craig",
            &[],
            "CRAIG: per-epoch full-data coreset (Mirzasoleiman'20)",
            Box::new(make_craig),
        ),
        spec(
            "gradmatch",
            &[],
            "GRADMATCH: OMP gradient matching per epoch (Killamsetty'21a)",
            Box::new(make_gradmatch),
        ),
        spec(
            "glister",
            &[],
            "GLISTER: validation-gradient greedy per epoch (Killamsetty'21b)",
            Box::new(make_glister),
        ),
        MethodSpec {
            coreset_lr_scale: true,
            ..spec(
                "greedy-per-batch",
                &["greedy"],
                "Fig. 3 ablation: fresh greedy mini-batch at every step",
                Box::new(make_greedy_per_batch),
            )
        },
    ]
}

// ---------------------------------------------------------------- random

/// Epoch-shuffled unweighted batches (Random / Full / SGD†).
struct RandomSource {
    n: usize,
    m: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl RandomSource {
    fn new(n: usize, m: usize, rng: Rng) -> Self {
        RandomSource { n, m, order: (0..n).collect(), cursor: n, rng }
    }
}

impl BatchSource for RandomSource {
    fn next_batch(
        &mut self,
        step: usize,
        _state: &mut TrainState,
        _timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        if self.cursor.wrapping_add(self.m) > self.n {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let idx = self.order[self.cursor..self.cursor + self.m].to_vec();
        self.cursor += self.m;
        Ok(SourcedBatch {
            gamma: vec![1.0; self.m],
            selection: Some(SelectionRecord { step, selected: idx.clone() }),
            idx,
        })
    }

    fn stats(&self) -> SourceStats {
        SourceStats::default()
    }
}

// ------------------------------------------------------- epoch baselines

/// Which per-epoch full-data selector an [`EpochCoresetSource`] runs.
enum EpochSelector {
    Craig,
    GradMatch,
    Glister,
}

/// CRAIG / GRADMATCH / GLISTER: reselect a size-k coreset from the full
/// data at the start of every (budgeted) epoch, then stream weighted
/// batches from it.
struct EpochCoresetSource<'a> {
    selector: EpochSelector,
    rt: &'a Runtime,
    train: &'a Dataset,
    val: &'a Dataset,
    /// exact vs. approximate ground-set traversal (`cfg.selection`)
    selection: SelectionStrategy,
    k: usize,
    epoch_steps: usize,
    into_epoch: usize,
    /// (global index, batch gamma) shuffled each epoch
    entries: Vec<(usize, f32)>,
    rng: Rng,
    /// optional on-disk embedding cache (`CREST_EMBED_CACHE`)
    embed_cache: Option<EmbedCache>,
    n_updates: usize,
    update_steps: Vec<usize>,
}

/// Embeddings of the full dataset, computed in r-chunks (tail wraps; the
/// duplicate rows are overwritten by their earlier occurrence, so each
/// example gets exactly one embedding).
pub fn full_embeddings(
    rt: &Runtime,
    params: &[f32],
    ds: &Dataset,
) -> Result<(MatF32, MatF32, Vec<f32>)> {
    let r = rt.man.r;
    let n = ds.n();
    let h = *rt.man.hidden.last().expect("hidden layer");
    let mut gl = MatF32::zeros(n, rt.man.classes);
    let mut al = MatF32::zeros(n, h);
    let mut losses = vec![0.0f32; n];
    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (start..start + r).map(|i| i % n).collect();
        let (x, y) = ds.batch(&idx);
        let (g, a, l) = rt.grad_embed(params, &x, &y)?;
        let valid = r.min(n - start);
        for k in 0..valid {
            gl.row_mut(start + k).copy_from_slice(g.row(k));
            al.row_mut(start + k).copy_from_slice(a.row(k));
            losses[start + k] = l[k];
        }
        start += valid;
    }
    Ok((gl, al, losses))
}

impl<'a> EpochCoresetSource<'a> {
    /// Full-data embeddings, consulting the region-scoped on-disk cache
    /// when enabled. The region fingerprints the reselection ordinal and
    /// the current params: parameters change between reselections, so
    /// prior entries are evicted, and a hit (same round, bitwise-same
    /// params — e.g. an identical rerun) can only return what this round
    /// would have recomputed.
    fn cached_full_embeddings(&mut self, state: &TrainState) -> Result<(MatF32, MatF32, Vec<f32>)> {
        let key = subset_key_all(self.train.n());
        if let Some(cache) = self.embed_cache.as_mut() {
            cache.enter_region(region_id(self.n_updates as u64, &state.params));
            if let Some(hit) = cache.load(key) {
                return Ok(hit);
            }
        }
        let out = full_embeddings(self.rt, &state.params, self.train)?;
        if let Some(cache) = self.embed_cache.as_ref() {
            cache.store(key, &out.0, &out.1, &out.2);
        }
        Ok(out)
    }

    fn reselect(
        &mut self,
        step: usize,
        state: &TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<()> {
        let t0 = Instant::now();
        let (gl, al, _) = self.cached_full_embeddings(state)?;
        let entries: Vec<(usize, f32)> = match self.selector {
            EpochSelector::Craig => {
                let ground =
                    strategy::Ground { gl: &gl, al: Some(&al), labels: Some(&self.train.y) };
                let sel =
                    self.selection.select(&ground, self.k, &mut self.rng, &strategy::CraigSelector);
                let gamma = craig::craig_batch_gamma(&sel);
                sel.idx.into_iter().zip(gamma).collect()
            }
            EpochSelector::GradMatch => {
                let ground = strategy::Ground { gl: &gl, al: None, labels: Some(&self.train.y) };
                let sel = self.selection.select(
                    &ground,
                    self.k,
                    &mut self.rng,
                    &strategy::GradMatchSelector,
                );
                // scale Σγ=n down to batch convention (mean 1 over coreset)
                let k = sel.idx.len() as f32;
                let sum: f32 = sel.gamma.iter().sum();
                let scale = if sum > 0.0 { k / sum } else { 1.0 };
                sel.idx.into_iter().zip(sel.gamma.into_iter().map(|g| g * scale)).collect()
            }
            EpochSelector::Glister => {
                // validation mean gradient from one r-chunk of val data
                let r = self.rt.man.r;
                let idx: Vec<usize> = (0..r).map(|i| i % self.val.n()).collect();
                let (x, y) = self.val.batch(&idx);
                let (gval, _, _) = self.rt.grad_embed(&state.params, &x, &y)?;
                let vmean = gval.mean_row();
                let ground = strategy::Ground { gl: &gl, al: None, labels: Some(&self.train.y) };
                let sel = self.selection.select(
                    &ground,
                    self.k,
                    &mut self.rng,
                    &strategy::GlisterSelector { vmean },
                );
                sel.idx.into_iter().zip(sel.gamma).collect()
            }
        };
        self.entries = entries;
        self.rng.shuffle(&mut self.entries);
        self.into_epoch = 0;
        self.n_updates += 1;
        self.update_steps.push(step);
        timers.add("selection", t0.elapsed());
        Ok(())
    }
}

impl<'a> BatchSource for EpochCoresetSource<'a> {
    fn next_batch(
        &mut self,
        step: usize,
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        let fresh = self.entries.is_empty() || self.into_epoch >= self.epoch_steps;
        if fresh {
            self.reselect(step, state, timers)?;
        }
        let m = self.rt.man.m;
        let start = (self.into_epoch * m) % self.entries.len().max(1);
        let mut idx = Vec::with_capacity(m);
        let mut gamma = Vec::with_capacity(m);
        for j in 0..m {
            let (i, g) = self.entries[(start + j) % self.entries.len()];
            idx.push(i);
            gamma.push(g);
        }
        self.into_epoch += 1;
        let selection = fresh.then(|| SelectionRecord {
            step,
            selected: self.entries.iter().map(|&(i, _)| i).collect(),
        });
        Ok(SourcedBatch { idx, gamma, selection })
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            n_updates: self.n_updates,
            update_steps: self.update_steps.clone(),
            ..Default::default()
        }
    }
}

// ------------------------------------------------------ greedy-per-batch

/// Fig. 3 ablation: fresh facility-location mini-batch from a new random
/// subset at every single step (maximal selection effort).
struct GreedyPerBatchSource<'a> {
    rt: &'a Runtime,
    train: &'a Dataset,
    /// exact vs. approximate traversal of the per-batch pool
    selection: SelectionStrategy,
    /// force γ = 1 (config `unit_gamma`: isolates subset choice from the
    /// facility-location weighting in the Fig. 3 ablation)
    unit_gamma: bool,
    rng: Rng,
    n_updates: usize,
}

impl<'a> BatchSource for GreedyPerBatchSource<'a> {
    fn next_batch(
        &mut self,
        step: usize,
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        let t0 = Instant::now();
        let r = self.rt.man.r;
        let m = self.rt.man.m;
        let pool = self.rng.sample_indices(self.train.n(), r);
        let (x, y) = self.train.batch(&pool);
        let (gl, al, _) = self.rt.grad_embed(&state.params, &x, &y)?;
        let sel = strategy::facility_select(self.selection, &al, &gl, &y, m);
        let mut mb = MiniBatchCoreset::from_selection(&sel, &pool, m);
        if self.unit_gamma {
            mb.gamma = vec![1.0; mb.gamma.len()];
        }
        self.n_updates += 1;
        timers.add("selection", t0.elapsed());
        Ok(SourcedBatch {
            selection: Some(SelectionRecord { step, selected: mb.idx.clone() }),
            idx: mb.idx,
            gamma: mb.gamma,
        })
    }

    fn stats(&self) -> SourceStats {
        SourceStats { n_updates: self.n_updates, ..Default::default() }
    }
}

// --------------------------------------------------------------- CREST

/// Algorithm 1 (paper §4): the full CREST engine.
pub struct CrestSource<'a> {
    rt: &'a Runtime,
    train: &'a Dataset,
    rng: Rng,
    // knobs
    tau: f32,
    h_mult: f32,
    b_mult: usize,
    t2: usize,
    max_t1: usize,
    max_p: usize,
    compiled_selection: bool,
    selection_threads: usize,
    /// exact vs. approximate traversal of each subset pool (`cfg.selection`)
    selection: SelectionStrategy,
    exclude: bool,
    /// first step at which exclusion windows may close (§4.3 timing)
    exclude_after: usize,
    // state
    quad: QuadraticModel,
    excl: ExclusionTracker,
    /// optional on-disk embedding cache (`CREST_EMBED_CACHE`), keyed by
    /// (quadratic-region id, subset hash)
    embed_cache: Option<EmbedCache>,
    coresets: Vec<MiniBatchCoreset>,
    update: bool,
    t1: usize,
    p: usize,
    iters_since_select: usize,
    anchor_params: Vec<f32>,
    /// the fixed random sample V_r anchored with F^l: the ρ-check compares
    /// F^l(δ) against the loss of the *same* subset so sampling noise does
    /// not masquerade as model drift
    vr_idx: Vec<usize>,
    // stats
    n_updates: usize,
    rho_history: Vec<(usize, f32)>,
    t1_history: Vec<(usize, usize)>,
    update_steps: Vec<usize>,
}

impl<'a> CrestSource<'a> {
    /// CREST source for one cell (Algorithm 1 state).
    pub fn new(
        cfg: &ExperimentConfig,
        rt: &'a Runtime,
        train: &'a Dataset,
        steps_total: usize,
        rng: Rng,
    ) -> Self {
        let opts = QuadOptions {
            second_order: cfg.crest.second_order,
            smooth: cfg.crest.smooth,
        };
        CrestSource {
            rt,
            train,
            rng,
            tau: cfg.tau,
            h_mult: cfg.h_mult,
            b_mult: cfg.b_mult.max(1),
            t2: cfg.t2.max(1),
            max_t1: cfg.max_t1.max(1),
            max_p: cfg.max_p.max(1),
            compiled_selection: cfg.compiled_selection,
            selection_threads: cfg.selection_threads.max(1),
            selection: cfg.selection,
            exclude: cfg.crest.exclude,
            exclude_after: (steps_total as f32 * cfg.exclude_after_frac) as usize,
            quad: QuadraticModel::new(rt.man.p_dim, cfg.beta1, cfg.beta2, opts),
            excl: ExclusionTracker::new(train.n(), cfg.alpha, cfg.crest.exclude),
            embed_cache: EmbedCache::from_env(),
            coresets: Vec::new(),
            update: true,
            t1: 1,
            p: cfg.b_mult.max(1),
            iters_since_select: 0,
            anchor_params: Vec::new(),
            vr_idx: Vec::new(),
            n_updates: 0,
            rho_history: Vec::new(),
            t1_history: Vec::new(),
            update_steps: Vec::new(),
        }
    }

    /// Sample a size-r index set from the active pool (with replacement once
    /// the pool shrinks below r).
    fn sample_subset(&mut self, r: usize) -> Vec<usize> {
        let pool = self.excl.active_pool();
        if pool.len() >= r {
            self.rng.sample_from_pool(&pool, r)
        } else if pool.is_empty() {
            (0..r).map(|_| self.rng.gen_range(self.train.n())).collect()
        } else {
            (0..r).map(|_| pool[self.rng.gen_range(pool.len())]).collect()
        }
    }

    /// Selection round: P random subsets → P mini-batch coresets
    /// (paper §4.2), then re-anchor the quadratic model (paper §4.1).
    fn select(&mut self, step: usize, state: &TrainState, timers: &mut PhaseTimers) -> Result<()> {
        let r = self.rt.man.r;
        let m = self.rt.man.m;
        // --- embeddings for P random subsets ---
        let t0 = Instant::now();
        // Draw all P index sets first. The RNG stream is identical to the
        // historical interleaved loop (draws happen in the same order and
        // observe_batch never alters the active pool mid-round), but with
        // the draws hoisted, batch assembly becomes a pure read fan-out.
        let mut index_sets: Vec<Vec<usize>> = Vec::with_capacity(self.p);
        for _ in 0..self.p {
            index_sets.push(self.sample_subset(r));
        }
        // Shard-parallel gathers through the dataset's store: results come
        // back in subset order, and gathers are pure reads, so the bytes
        // are identical at any thread count and for either store backend.
        let batches: Vec<(MatF32, Vec<i32>)> = {
            let train = self.train;
            let sets = &index_sets;
            Pool::global().map(sets.len(), |i| train.batch(&sets[i]))
        };
        // Embeddings per subset (backend, serial), consulting the
        // region-scoped cache when enabled: within one quadratic region
        // the params are fixed, so a hit returns exactly what grad_embed
        // would recompute — including the losses fed to the exclusion
        // tracker, which therefore observes identical values either way.
        if let Some(cache) = self.embed_cache.as_mut() {
            cache.enter_region(region_id(self.n_updates as u64, &state.params));
        }
        let mut subsets: Vec<(Vec<usize>, Vec<i32>, MatF32, MatF32)> = Vec::with_capacity(self.p);
        for (idx, (x, y)) in index_sets.into_iter().zip(batches) {
            let key = subset_key(&idx);
            let (gl, al, losses) = match self.embed_cache.as_ref().and_then(|c| c.load(key)) {
                Some(hit) => hit,
                None => {
                    let out = self.rt.grad_embed(&state.params, &x, &y)?;
                    if let Some(cache) = self.embed_cache.as_ref() {
                        cache.store(key, &out.0, &out.1, &out.2);
                    }
                    out
                }
            };
            self.excl.observe_batch(&idx, &losses);
            subsets.push((idx, y, gl, al));
        }
        // --- greedy per subset (host, parallel over P) ---
        let coresets: Vec<MiniBatchCoreset> = if self.compiled_selection {
            let mut out = Vec::with_capacity(self.p);
            for (idx, _ys, gl, al) in &subsets {
                let (sel_idx, w) = self.rt.select_greedy(gl, al)?;
                let sel = facility::Selection { idx: sel_idx, gamma: w };
                out.push(MiniBatchCoreset::from_selection(&sel, idx, m));
            }
            out
        } else if self.selection_threads > 1 && subsets.len() > 1 {
            // one P-subset greedy per pool worker; facility's own scans run
            // inline inside the workers (nested pool calls), and results
            // come back in subset order — identical to the serial path.
            // Capped by the global count so --threads/CREST_THREADS=1
            // forces serial execution here too (results never change).
            let pool = Pool::new(self.selection_threads.min(crate::util::pool::threads()));
            let selection = self.selection;
            pool.map(subsets.len(), |i| {
                let (idx, ys, gl, al) = &subsets[i];
                let sel = strategy::facility_select(selection, al, gl, ys, m);
                MiniBatchCoreset::from_selection(&sel, idx, m)
            })
        } else {
            subsets
                .iter()
                .map(|(idx, ys, gl, al)| {
                    let sel = strategy::facility_select(self.selection, al, gl, ys, m);
                    MiniBatchCoreset::from_selection(&sel, idx, m)
                })
                .collect()
        };
        self.coresets = coresets;
        timers.add("selection", t0.elapsed());

        // --- quadratic re-anchor (Eq. 6-9): Hutchinson probe on a fresh
        // random subset ---
        let t0 = Instant::now();
        let probe_idx = self.sample_subset(r);
        let (px, py) = self.train.batch(&probe_idx);
        let mut z = vec![0.0f32; self.rt.man.p_dim];
        self.rng.rademacher_fill(&mut z);
        let probe = self.rt.hess_probe(&state.params, &px, &py, &z)?;
        let hdiag: Vec<f32> = z.iter().zip(&probe.hz).map(|(&zi, &hzi)| zi * hzi).collect();
        self.quad.observe_grad(&probe.grad);
        self.quad.observe_hdiag(&hdiag);
        // anchor F^l on the probe subset's loss and keep the subset as V_r:
        // the ρ-check re-evaluates the SAME subset at w+δ (Eq. 10)
        self.quad.set_anchor(probe.mean_loss);
        self.vr_idx = probe_idx;
        self.anchor_params = state.params_host(self.rt)?;
        timers.add("loss_approx", t0.elapsed());

        self.update = false;
        self.iters_since_select = 0;
        self.n_updates += 1;
        self.update_steps.push(step);
        Ok(())
    }
}

impl<'a> BatchSource for CrestSource<'a> {
    fn next_batch(
        &mut self,
        step: usize,
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<SourcedBatch> {
        let selection = if self.update || self.coresets.is_empty() {
            self.select(step, state, timers)?;
            let union: Vec<usize> =
                self.coresets.iter().flat_map(|c| c.idx.iter().copied()).collect();
            Some(SelectionRecord { step, selected: union })
        } else {
            None
        };
        // train on a random member of the current coreset pool (§4.2)
        let pick = self.rng.gen_range(self.coresets.len());
        let c = &self.coresets[pick];
        Ok(SourcedBatch { idx: c.idx.clone(), gamma: c.gamma.clone(), selection })
    }

    fn after_step(
        &mut self,
        step: usize,
        _idx: &[usize],
        _per_ex_loss: &[f32],
        state: &mut TrainState,
        timers: &mut PhaseTimers,
    ) -> Result<()> {
        self.iters_since_select += 1;
        // learned-example exclusion windows (§4.3); freeze once the pool
        // cannot fill a random subset anymore
        if self.exclude && step >= self.exclude_after && (step + 1) % self.t2 == 0 {
            let pool = self.excl.active_pool();
            if pool.len() > 2 * self.rt.man.r {
                self.excl.end_window();
            }
        }
        // ρ-check (Eq. 10) at the end of each T₁ block
        if self.iters_since_select >= self.t1 && !self.update {
            let t0 = Instant::now();
            let (x, y) = self.train.batch(&self.vr_idx);
            let (_, _, losses) = self.rt.grad_embed(&state.params, &x, &y)?;
            self.excl.observe_batch(&self.vr_idx, &losses);
            let l_r = stats::mean(&losses);
            let now = state.params_host(self.rt)?;
            let delta = stats::sub(&now, &self.anchor_params);
            let rho = self.quad.rho(&delta, l_r);
            self.rho_history.push((step, rho));
            timers.add("rho_check", t0.elapsed());
            if rho > self.tau {
                self.update = true;
                self.t1 = self.quad.adapt_t1(self.h_mult, self.max_t1);
                self.p = (self.b_mult * self.t1).clamp(1, self.max_p);
                self.t1_history.push((step, self.t1));
            } else {
                // quadratic still valid: keep the coresets another T₁ block
                self.iters_since_select = 0;
            }
        }
        Ok(())
    }

    fn stats(&self) -> SourceStats {
        SourceStats {
            n_updates: self.n_updates,
            n_excluded: self.excl.n_excluded(),
            excluded_indices: self.excl.excluded_indices(),
            rho_history: self.rho_history.clone(),
            t1_history: self.t1_history.clone(),
            update_steps: self.update_steps.clone(),
        }
    }
}
