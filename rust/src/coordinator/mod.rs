//! The coordinator — the paper's L3 contribution.
//!
//! Owns the end-to-end run of one experiment cell: data, budget, learning
//! -rate schedule, the method's batch source (instantiated through the
//! [`crate::api::MethodRegistry`] factory), evaluation cadence, and the
//! phase-time accounting behind Table 2 / Fig. 2. Everything the run
//! *reports* flows through the [`crate::api::RunObserver`] event stream:
//! the built-in [`ReportObserver`] folds the events into the
//! [`RunReport`], and any extra observers attached via
//! [`Coordinator::run_observed`] see the same stream (streaming progress,
//! early stopping, external metric sinks).
//!
//! CREST itself (Algorithm 1) lives in `sources::CrestSource`: piece-wise
//! quadratic modeling (`quadratic`), mini-batch coresets from random
//! subsets (`coreset::facility`, parallelized over the P subproblems with
//! scoped threads), and learned-example exclusion (`exclusion`).

pub mod sources;

use std::time::Instant;

use anyhow::Result;

use crate::api::observer::{
    EvalEvent, ExclusionEvent, ReportObserver, RunEnd, RunObserver, SelectionEvent, Signal,
    StepEvent,
};
use crate::api::registry::SourceCtx;
use crate::config::ExperimentConfig;
use crate::data::Splits;
use crate::model::init_params;
use crate::opt::{Budget, LrSchedule};
use crate::report::RunReport;
use crate::runtime::Runtime;
use crate::train::{evaluate, TrainState};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimers;

/// Drives one experiment run.
pub struct Coordinator<'a> {
    /// Execution runtime of the variant.
    pub rt: &'a Runtime,
    /// Train/val/test data of the cell.
    pub splits: &'a Splits,
    /// The cell configuration.
    pub cfg: ExperimentConfig,
}

impl<'a> Coordinator<'a> {
    /// Coordinator for one experiment cell.
    pub fn new(rt: &'a Runtime, splits: &'a Splits, cfg: ExperimentConfig) -> Self {
        Coordinator { rt, splits, cfg }
    }

    /// Total steps of the *full* reference run (LR-schedule horizon of SGD†).
    fn full_steps(&self) -> usize {
        self.splits.train.n() * self.cfg.epochs_full / self.rt.man.m
    }

    /// Run the configured method to budget exhaustion.
    pub fn run(&self) -> Result<RunReport> {
        self.run_observed(&mut [])
    }

    /// Run the configured method with extra observers attached. Observers
    /// receive every step/eval/selection/exclusion event plus the final
    /// report; they never change training results, but a [`Signal::Stop`]
    /// from a step or eval hook ends the run early (after the final
    /// evaluation).
    pub fn run_observed(&self, observers: &mut [Box<dyn RunObserver>]) -> Result<RunReport> {
        let t_start = Instant::now();
        let cfg = &self.cfg;
        let rt = self.rt;
        let ds = &self.splits.train;
        let n = ds.n();
        let m = rt.man.m;

        let mut rng = Rng::new(cfg.seed);
        let mut init_rng = rng.split();
        let mut source_rng = rng.split();

        let budget_frac = if cfg.method.is_reference() { 1.0 } else { cfg.budget_frac };
        let mut budget = Budget::fraction_of_full(n, cfg.epochs_full, budget_frac);
        let steps_total = budget.steps(m).max(1);

        // SGD† keeps the schedule laid out for the full horizon (so the
        // decays are never reached inside the budget); everyone else
        // compresses the schedule into their own horizon (paper §5 Evaluation).
        let sched = LrSchedule::paper_default(cfg.base_lr);
        let sched_horizon =
            if cfg.method.full_horizon_schedule() { self.full_steps() } else { steps_total };
        // Variance-reduced coreset batches support the Theorem 4.1 step
        // size: η ∝ √r instead of √m (the r/m speedup mechanism). Applies
        // to CREST and the greedy-per-batch ablation only.
        let lr_mult = if cfg.method.coreset_lr_scale() {
            cfg.coreset_lr_scale.unwrap_or(((rt.man.r as f32) / (rt.man.m as f32)).sqrt())
        } else {
            1.0
        };

        let mut state = TrainState::new(rt, &init_params(&rt.man, &mut init_rng))?;
        let mut timers = PhaseTimers::new();
        let ctx = SourceCtx { cfg, rt, train: ds, val: &self.splits.val, steps_total };
        let mut source = cfg.method.make_source(ctx, &mut source_rng)?;
        let mut report_obs = ReportObserver::new(cfg, budget_frac, n);

        let eval_every = (steps_total / cfg.eval_points.max(1)).max(1);
        let mut step = 0usize;
        let mut stop = false;
        while !stop && budget.charge(m) {
            let lr = sched.lr_at(step, sched_horizon) * lr_mult;
            // ask the active method for the next weighted batch
            let batch = source.next_batch(step, &mut state, &mut timers)?;
            let t0 = Instant::now();
            let (mean_loss, per_ex) =
                state.step_batch(rt, ds, &batch.idx, &batch.gamma, lr, cfg.weight_decay)?;
            timers.add("train_step_host", t0.elapsed());
            source.after_step(step, &batch.idx, &per_ex, &mut state, &mut timers)?;

            if let Some(rec) = &batch.selection {
                let ev = SelectionEvent { step: rec.step, selected: &rec.selected };
                report_obs.on_selection(&ev);
                for obs in observers.iter_mut() {
                    obs.on_selection(&ev);
                }
            }
            let ev = StepEvent {
                step,
                steps_total,
                lr,
                mean_loss,
                idx: &batch.idx,
                backprops: budget.used(),
            };
            report_obs.on_step(&ev);
            for obs in observers.iter_mut() {
                if obs.on_step(&ev) == Signal::Stop {
                    stop = true;
                }
            }

            // evaluation cadence
            if step % eval_every == 0 || step + 1 == steps_total {
                let t0 = Instant::now();
                let test = evaluate(rt, &state.params, &self.splits.test)?;
                let train = evaluate(rt, &state.params, ds)?;
                timers.add("eval", t0.elapsed());
                let ev = EvalEvent {
                    step,
                    backprops: budget.used(),
                    test_acc: test.accuracy,
                    test_loss: test.mean_loss,
                    train_acc: train.accuracy,
                    wall_secs: t_start.elapsed().as_secs_f64(),
                    train_per_ex_correct: &train.per_ex_correct,
                };
                report_obs.on_eval(&ev);
                for obs in observers.iter_mut() {
                    if obs.on_eval(&ev) == Signal::Stop {
                        stop = true;
                    }
                }
                // Fig. 7a: do the dropped (excluded-as-learned) examples
                // stay correctly classified?
                let dropped = source.stats().excluded_indices;
                if !dropped.is_empty() {
                    let acc = dropped
                        .iter()
                        .map(|&i| train.per_ex_correct[i] as f64)
                        .sum::<f64>() as f32
                        / dropped.len() as f32;
                    let ev =
                        ExclusionEvent { step, n_excluded: dropped.len(), dropped_acc: acc };
                    report_obs.on_exclusion(&ev);
                    for obs in observers.iter_mut() {
                        obs.on_exclusion(&ev);
                    }
                }
            }
            step += 1;
        }

        // final evaluation (always recorded)
        let t0 = Instant::now();
        let test = evaluate(rt, &state.params, &self.splits.test)?;
        timers.add("eval", t0.elapsed());

        let end = RunEnd {
            final_test_acc: test.accuracy,
            final_test_loss: test.mean_loss,
            steps: step,
            backprops: budget.used(),
            stats: source.stats(),
            selection_secs: timers.total("selection").as_secs_f64(),
            train_secs: timers.total("train_step_host").as_secs_f64(),
            eval_secs: timers.total("eval").as_secs_f64(),
            check_secs: timers.total("rho_check").as_secs_f64(),
            approx_secs: timers.total("loss_approx").as_secs_f64(),
            total_secs: t_start.elapsed().as_secs_f64(),
            mean_step_secs: timers.mean_secs("train_step_host"),
        };
        let report = report_obs.finish(end);
        for obs in observers.iter_mut() {
            obs.on_run_end(&report);
        }
        log::info!(
            "{}/{} seed={} acc={:.4} steps={} updates={} excl={} {:.2}s",
            report.variant,
            report.method,
            report.seed,
            report.final_test_acc,
            report.steps,
            report.n_selection_updates,
            report.n_excluded,
            report.total_secs
        );
        Ok(report)
    }
}

/// Convenience: run one (variant, method, seed) cell against prepared
/// splits and runtime — the low-level entry point for callers that
/// manage `Runtime`/`Splits` sharing themselves (the bench harness).
/// Library users should prefer [`crate::api::Experiment`].
pub fn run_experiment(
    rt: &Runtime,
    splits: &Splits,
    cfg: ExperimentConfig,
) -> Result<RunReport> {
    Coordinator::new(rt, splits, cfg).run()
}
