//! The coordinator — the paper's L3 contribution.
//!
//! Owns the end-to-end run of one experiment cell: data, budget, learning
//! -rate schedule, method dispatch (CREST / CRAIG / GRADMATCH / GLISTER /
//! Random / SGD† / greedy-per-batch), evaluation cadence, forgettability
//! bookkeeping, and the phase-time accounting behind Table 2 / Fig. 2.
//!
//! CREST itself (Algorithm 1) lives in `crest_source`: piece-wise quadratic
//! modeling (`quadratic`), mini-batch coresets from random subsets
//! (`coreset::facility`, parallelized over the P subproblems with scoped
//! threads), and learned-example exclusion (`exclusion`).

pub mod sources;

use std::time::Instant;

use anyhow::Result;

use crate::config::{ExperimentConfig, MethodKind};
use crate::data::Splits;
use crate::metrics::forget::ForgetTracker;
use crate::model::init_params;
use crate::opt::{Budget, LrSchedule};
use crate::report::{EvalPoint, RunReport};
use crate::runtime::Runtime;
use crate::train::{evaluate, TrainState};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimers;

use sources::SelectionRecord;

/// Drives one experiment run.
pub struct Coordinator<'a> {
    /// Execution runtime of the variant.
    pub rt: &'a Runtime,
    /// Train/val/test data of the cell.
    pub splits: &'a Splits,
    /// The cell configuration.
    pub cfg: ExperimentConfig,
}

impl<'a> Coordinator<'a> {
    /// Coordinator for one experiment cell.
    pub fn new(rt: &'a Runtime, splits: &'a Splits, cfg: ExperimentConfig) -> Self {
        Coordinator { rt, splits, cfg }
    }

    /// Total steps of the *full* reference run (LR-schedule horizon of SGD†).
    fn full_steps(&self) -> usize {
        self.splits.train.n() * self.cfg.epochs_full / self.rt.man.m
    }

    /// Run the configured method to budget exhaustion.
    pub fn run(&self) -> Result<RunReport> {
        let t_start = Instant::now();
        let cfg = &self.cfg;
        let rt = self.rt;
        let ds = &self.splits.train;
        let n = ds.n();
        let m = rt.man.m;

        let mut rng = Rng::new(cfg.seed);
        let mut init_rng = rng.split();
        let mut source_rng = rng.split();

        let budget_frac =
            if cfg.method == MethodKind::Full { 1.0 } else { cfg.budget_frac };
        let mut budget = Budget::fraction_of_full(n, cfg.epochs_full, budget_frac);
        let steps_total = budget.steps(m).max(1);

        // SGD† keeps the schedule laid out for the full horizon (so the
        // decays are never reached inside the budget); everyone else
        // compresses the schedule into their own horizon (paper §5 Evaluation).
        let sched = LrSchedule::paper_default(cfg.base_lr);
        let sched_horizon = match cfg.method {
            MethodKind::SgdTruncated => self.full_steps(),
            _ => steps_total,
        };
        // Variance-reduced coreset batches support the Theorem 4.1 step
        // size: η ∝ √r instead of √m (the r/m speedup mechanism). Applies
        // to CREST and the greedy-per-batch ablation only.
        let lr_mult = match cfg.method {
            MethodKind::Crest | MethodKind::GreedyPerBatch => cfg
                .coreset_lr_scale
                .unwrap_or(((rt.man.r as f32) / (rt.man.m as f32)).sqrt()),
            _ => 1.0,
        };

        let mut state = TrainState::new(rt, &init_params(&rt.man, &mut init_rng))?;
        let mut timers = PhaseTimers::new();
        let mut forget = ForgetTracker::new(n);
        let mut source =
            sources::make_source(cfg, rt, ds, &self.splits.val, steps_total, &mut source_rng)?;

        let eval_every = (steps_total / cfg.eval_points.max(1)).max(1);
        let mut history: Vec<EvalPoint> = Vec::new();
        let mut best_acc = 0.0f32;
        let mut selections: Vec<SelectionRecord> = Vec::new();
        let mut dropped_acc_history: Vec<(usize, f32)> = Vec::new();

        let mut step = 0usize;
        while budget.charge(m) {
            let lr = sched.lr_at(step, sched_horizon) * lr_mult;
            // ask the active method for the next weighted batch
            let batch = source.next_batch(step, &mut state, &mut timers)?;
            if let Some(rec) = batch.selection {
                selections.push(rec);
            }
            forget.count_selection(&batch.idx);
            let t0 = Instant::now();
            let (_loss, per_ex) =
                state.step_batch(rt, ds, &batch.idx, &batch.gamma, lr, cfg.weight_decay)?;
            timers.add("train_step_host", t0.elapsed());
            source.after_step(step, &batch.idx, &per_ex, &mut state, &mut timers)?;

            // evaluation cadence
            if step % eval_every == 0 || step + 1 == steps_total {
                let t0 = Instant::now();
                let test = evaluate(rt, &state.params, &self.splits.test)?;
                let train = evaluate(rt, &state.params, ds)?;
                timers.add("eval", t0.elapsed());
                forget.observe_batch(
                    &(0..n).collect::<Vec<_>>(),
                    &train.per_ex_correct,
                );
                // Fig. 7a: do the dropped (excluded-as-learned) examples
                // stay correctly classified?
                let dropped = source.stats().excluded_indices;
                if !dropped.is_empty() {
                    let acc = dropped
                        .iter()
                        .map(|&i| train.per_ex_correct[i] as f64)
                        .sum::<f64>() as f32
                        / dropped.len() as f32;
                    dropped_acc_history.push((step, acc));
                }
                best_acc = best_acc.max(test.accuracy);
                history.push(EvalPoint {
                    step,
                    backprops: budget.used(),
                    test_acc: test.accuracy,
                    test_loss: test.mean_loss,
                    train_acc: train.accuracy,
                    wall_secs: t_start.elapsed().as_secs_f64(),
                });
            }
            step += 1;
        }

        // final evaluation (always recorded)
        let t0 = Instant::now();
        let test = evaluate(rt, &state.params, &self.splits.test)?;
        timers.add("eval", t0.elapsed());
        best_acc = best_acc.max(test.accuracy);

        // post-hoc Fig. 5 series: mean *final* forgettability of the
        // examples each selection round picked.
        let max_score = forget.max_observed_score().max(1);
        let forget_of_selected: Vec<(usize, f32)> = selections
            .iter()
            .map(|s| (s.step, forget.mean_score(&s.selected, max_score)))
            .collect();

        let stats = source.stats();
        let total_secs = t_start.elapsed().as_secs_f64();
        let sel_secs = timers.total("selection").as_secs_f64();
        let report = RunReport {
            method: cfg.method.name().to_string(),
            variant: cfg.variant.clone(),
            seed: cfg.seed,
            budget_frac,
            final_test_acc: test.accuracy,
            final_test_loss: test.mean_loss,
            best_test_acc: best_acc,
            steps: step,
            backprops: budget.used(),
            n_selection_updates: stats.n_updates,
            selection_secs: sel_secs,
            train_secs: timers.total("train_step_host").as_secs_f64(),
            eval_secs: timers.total("eval").as_secs_f64(),
            check_secs: timers.total("rho_check").as_secs_f64(),
            approx_secs: timers.total("loss_approx").as_secs_f64(),
            total_secs,
            n_excluded: stats.n_excluded,
            history,
            rho_history: stats.rho_history,
            t1_history: stats.t1_history,
            update_steps: stats.update_steps,
            forget_of_selected,
            selection_counts: forget.selection_counts().to_vec(),
            dropped_acc_history,
            excluded_indices: stats.excluded_indices.clone(),
            mean_step_secs: timers.mean_secs("train_step_host"),
            mean_selection_secs: if stats.n_updates > 0 {
                sel_secs / stats.n_updates as f64
            } else {
                0.0
            },
        };
        log::info!(
            "{}/{} seed={} acc={:.4} steps={} updates={} excl={} {:.2}s",
            report.variant,
            report.method,
            report.seed,
            report.final_test_acc,
            report.steps,
            report.n_selection_updates,
            report.n_excluded,
            report.total_secs
        );
        Ok(report)
    }
}

/// Convenience: run one (variant, method, seed) cell against prepared
/// splits and runtime.
pub fn run_experiment(
    rt: &Runtime,
    splits: &Splits,
    cfg: ExperimentConfig,
) -> Result<RunReport> {
    Coordinator::new(rt, splits, cfg).run()
}
