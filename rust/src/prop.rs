//! Mini property-testing harness (proptest replacement).
//!
//! Runs a checker over many seeded random cases and reports the failing
//! seed + case debug on the first violation, so failures are reproducible
//! by re-running with the printed seed.

use crate::util::rng::Rng;

/// Run `check` on `cases` values drawn by `gen`. Panics with the failing
/// case on the first `Err`.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(msg) = check(&value) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  \
                 {msg}\n  value: {value:?}"
            );
        }
    }
}

/// Draw a random f32 vector with entries in [-scale, scale].
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.uniform_in(-scale, scale)).collect()
}

/// Draw a random usize in [lo, hi).
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.gen_range(hi - lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("sum-commutes", 1, 50,
            |r| (vec_f32(r, 8, 10.0), usize_in(r, 1, 8)),
            |(v, k)| {
                let a: f32 = v.iter().take(*k).sum();
                let b: f32 = v.iter().take(*k).rev().sum();
                if (a - b).abs() < 1e-3 { Ok(()) } else { Err(format!("{a} != {b}")) }
            });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_panics_with_seed() {
        forall("always-fails", 2, 10, |r| r.gen_range(100), |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = vec_f32(&mut r, 4, 2.0);
            assert!(v.iter().all(|&x| (-2.0..=2.0).contains(&x)));
            let u = usize_in(&mut r, 5, 10);
            assert!((5..10).contains(&u));
        }
    }
}
