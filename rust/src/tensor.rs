//! Row-major f32 matrices — the host-side tensor substrate.
//!
//! The coordinator's tensor needs are modest (gather rows for a batch, hold
//! gradient embeddings, hand dense buffers to the active `runtime::Backend`);
//! this module provides exactly that with zero-copy accessors where
//! possible. Conversions to `xla::Literal` live in `runtime::pjrt` behind
//! the `pjrt` feature.

use anyhow::{ensure, Result};

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major backing buffer (`rows * cols` elements).
    pub data: Vec<f32>,
}

impl MatF32 {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer; errors on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        ensure!(data.len() == rows * cols, "data len {} != {rows}x{cols}", data.len());
        Ok(MatF32 { rows, cols, data })
    }

    /// Consume the matrix, returning its backing buffer (used by the
    /// kernel layer's [`crate::kernel::Workspace`] to recycle storage).
    #[inline]
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// New matrix from the given row indices (batch assembly).
    pub fn gather_rows(&self, idx: &[usize]) -> MatF32 {
        let mut out = MatF32::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Mean of all rows.
    pub fn mean_row(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v as f64;
            }
        }
        out.into_iter().map(|v| (v / self.rows.max(1) as f64) as f32).collect()
    }

    /// Weighted mean of rows: sum_i w[i]·row_i / norm.
    pub fn weighted_mean_row(&self, w: &[f32], norm: f32) -> Vec<f32> {
        debug_assert_eq!(w.len(), self.rows);
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let wi = w[i] as f64;
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += wi * v as f64;
            }
        }
        out.into_iter().map(|v| (v / norm as f64) as f32).collect()
    }

    /// Squared Euclidean distance between rows i and j.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f32 {
        let (a, b) = (self.row(i), self.row(j));
        let mut s = 0.0f32;
        for k in 0..self.cols {
            let d = a[k] - b[k];
            s += d * d;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_rows() {
        let m = MatF32::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.row(1), &[3., 4.]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
        assert_eq!(g.rows, 2);
    }

    #[test]
    fn from_vec_validates() {
        assert!(MatF32::from_vec(2, 2, vec![1.0; 3]).is_err());
    }

    #[test]
    fn means() {
        let m = MatF32::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(m.mean_row(), vec![2., 3.]);
        let wm = m.weighted_mean_row(&[1.0, 3.0], 4.0);
        assert_eq!(wm, vec![2.5, 3.5]);
    }

    #[test]
    fn sqdist() {
        let m = MatF32::from_vec(2, 3, vec![0., 0., 0., 1., 2., 2.]).unwrap();
        assert_eq!(m.sqdist(0, 1), 9.0);
        assert_eq!(m.sqdist(1, 1), 0.0);
    }

}
