//! Learned-example exclusion (paper §4.3, challenge C3).
//!
//! Examples whose observed loss stays below `α` for a whole window of
//! `T₂` iterations are dropped from the selection ground set. Only losses
//! already computed for the random subsets are used — exclusion adds no
//! extra forward passes.

/// Tracks per-example losses within non-overlapping T₂ windows.
#[derive(Debug, Clone)]
pub struct ExclusionTracker {
    alpha: f32,
    /// max loss observed for each example in the current window
    window_max: Vec<f32>,
    /// whether the example was observed at all this window
    observed: Vec<bool>,
    excluded: Vec<bool>,
    n_excluded: usize,
    enabled: bool,
}

impl ExclusionTracker {
    /// Tracker over `n` examples with exclusion threshold `alpha`;
    /// `enabled = false` makes every call a no-op (the w/o-excluding
    /// ablation).
    pub fn new(n: usize, alpha: f32, enabled: bool) -> Self {
        ExclusionTracker {
            alpha,
            window_max: vec![f32::NEG_INFINITY; n],
            observed: vec![false; n],
            excluded: vec![false; n],
            n_excluded: 0,
            enabled,
        }
    }

    /// Record a loss observation for example `idx`.
    pub fn observe(&mut self, idx: usize, loss: f32) {
        if !self.enabled {
            return;
        }
        self.observed[idx] = true;
        if loss > self.window_max[idx] {
            self.window_max[idx] = loss;
        }
    }

    /// Record a batch of observations.
    pub fn observe_batch(&mut self, idx: &[usize], losses: &[f32]) {
        debug_assert_eq!(idx.len(), losses.len());
        for (&i, &l) in idx.iter().zip(losses) {
            self.observe(i, l);
        }
    }

    /// Close the current T₂ window: exclude every example that was observed
    /// and never exceeded α. Returns how many were newly excluded.
    pub fn end_window(&mut self) -> usize {
        if !self.enabled {
            return 0;
        }
        let mut newly = 0;
        for i in 0..self.window_max.len() {
            if self.observed[i] && !self.excluded[i] && self.window_max[i] < self.alpha {
                self.excluded[i] = true;
                self.n_excluded += 1;
                newly += 1;
            }
            self.observed[i] = false;
            self.window_max[i] = f32::NEG_INFINITY;
        }
        newly
    }

    /// Whether example `idx` is currently excluded as learned.
    pub fn is_excluded(&self, idx: usize) -> bool {
        self.excluded[idx]
    }

    /// Total examples excluded so far.
    pub fn n_excluded(&self) -> usize {
        self.n_excluded
    }

    /// Remaining selection ground set.
    pub fn active_pool(&self) -> Vec<usize> {
        (0..self.excluded.len()).filter(|&i| !self.excluded[i]).collect()
    }

    /// Indices excluded so far (Fig. 7a analysis).
    pub fn excluded_indices(&self) -> Vec<usize> {
        (0..self.excluded.len()).filter(|&i| self.excluded[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn excludes_consistently_low_loss() {
        let mut t = ExclusionTracker::new(4, 0.1, true);
        t.observe_batch(&[0, 1], &[0.01, 0.5]);
        t.observe_batch(&[0, 2], &[0.05, 0.02]);
        let newly = t.end_window();
        assert_eq!(newly, 2); // 0 (always < 0.1) and 2 (< 0.1)
        assert!(t.is_excluded(0));
        assert!(!t.is_excluded(1)); // exceeded alpha
        assert!(t.is_excluded(2));
        assert!(!t.is_excluded(3)); // never observed
        assert_eq!(t.active_pool(), vec![1, 3]);
    }

    #[test]
    fn one_high_loss_saves_example_within_window() {
        let mut t = ExclusionTracker::new(1, 0.1, true);
        t.observe(0, 0.01);
        t.observe(0, 0.9); // spike
        t.observe(0, 0.01);
        assert_eq!(t.end_window(), 0);
        assert!(!t.is_excluded(0));
    }

    #[test]
    fn windows_are_independent() {
        let mut t = ExclusionTracker::new(1, 0.1, true);
        t.observe(0, 0.9);
        t.end_window();
        assert!(!t.is_excluded(0));
        // next window: consistently low -> excluded now
        t.observe(0, 0.01);
        assert_eq!(t.end_window(), 1);
        assert!(t.is_excluded(0));
    }

    #[test]
    fn exclusion_is_permanent_and_counted() {
        let mut t = ExclusionTracker::new(2, 0.1, true);
        t.observe(0, 0.0);
        t.end_window();
        assert_eq!(t.n_excluded(), 1);
        // later high observation does not resurrect
        t.observe(0, 5.0);
        t.end_window();
        assert!(t.is_excluded(0));
        assert_eq!(t.n_excluded(), 1);
        assert_eq!(t.excluded_indices(), vec![0]);
    }

    #[test]
    fn disabled_tracker_never_excludes() {
        let mut t = ExclusionTracker::new(3, 0.1, false);
        t.observe_batch(&[0, 1, 2], &[0.0, 0.0, 0.0]);
        assert_eq!(t.end_window(), 0);
        assert_eq!(t.active_pool().len(), 3);
    }
}
