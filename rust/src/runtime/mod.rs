//! Execution runtime: the [`Backend`] abstraction plus a shape-checked
//! facade ([`Runtime`]) over it.
//!
//! The coordinator needs exactly five compiled computations — `train_step`,
//! `grad_embed`, `eval_chunk`, `hess_probe`, `select_greedy` — declared by
//! the [`manifest::VariantManifest`] shape contract. [`Backend`] abstracts
//! who executes them:
//!
//! * [`native::NativeBackend`] (default) computes them in pure Rust on the
//!   host, straight from the manifest's MLP architecture. No external
//!   libraries, no artifact files, no Python.
//! * `pjrt::PjrtBackend` (behind the off-by-default `pjrt` cargo feature)
//!   loads the AOT HLO artifacts produced by `python/compile/aot.py` and
//!   executes them through XLA/PJRT. Enabling the feature requires an `xla`
//!   crate dependency; see README.md.
//!
//! All parameter/momentum state crosses this boundary as host `Vec<f32>` /
//! `&[f32]`, so the training loop, metrics and coordinator are backend
//! agnostic. [`Runtime`] validates every buffer against the manifest before
//! dispatch and charges per-op wall-clock to [`PhaseTimers`] (backs Table 2).

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::tensor::MatF32;
use crate::util::timer::PhaseTimers;
use manifest::{DType, VariantManifest};

/// Output of one training step (updated state stays on the host).
pub struct StepOut {
    /// Updated flat parameters.
    pub params: Vec<f32>,
    /// Updated momentum buffer.
    pub momentum: Vec<f32>,
    /// Weighted mean batch loss.
    pub mean_loss: f32,
    /// Unweighted per-example losses.
    pub per_ex_loss: Vec<f32>,
}

/// Output of the Hutchinson probe.
#[derive(Debug)]
pub struct ProbeOut {
    /// H·z for the supplied probe vector.
    pub hz: Vec<f32>,
    /// Mean gradient of the probed subset (param space).
    pub grad: Vec<f32>,
    /// Mean loss of the probed subset.
    pub mean_loss: f32,
}

/// An execution engine for the five manifest computations.
///
/// Implementations may assume shapes were already validated against the
/// manifest by [`Runtime`]; they re-check only what they need for memory
/// safety. Semantics (shared with `python/compile/model.py`):
///
/// * `train_step`: loss `(1/m)·Σ γ_i·ce_i`, gradient `g + wd·w`, momentum
///   `v ← μ·v + g`, update `w ← w − lr·v`; returns unweighted per-example
///   losses.
/// * `grad_embed`: logit gradients `p − y`, penultimate activations,
///   per-example losses.
/// * `eval_chunk`: `(Σ loss, Σ correct, per-example loss, per-example 0/1)`.
/// * `hess_probe`: exact `H·z` of the subset's mean loss, its mean gradient,
///   and the mean loss.
/// * `select_greedy`: m-medoid facility-location selection over the
///   last-layer weight-gradient metric, with cluster-size weights.
pub trait Backend {
    /// Short engine name (`native` / `pjrt`).
    fn name(&self) -> &'static str;

    /// One weighted momentum-SGD step; see the trait docs for semantics.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        params: &[f32],
        momentum: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<StepOut>;

    /// Last-layer gradient embeddings: (logit gradients, penultimate
    /// activations, per-example losses).
    fn grad_embed(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(MatF32, MatF32, Vec<f32>)>;

    /// Evaluate one chunk: (Σ loss, Σ correct, per-example losses,
    /// per-example 0/1 correctness).
    fn eval_chunk(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)>;

    /// Exact Hessian-vector product of the subset's mean loss.
    fn hess_probe(&self, params: &[f32], x: &MatF32, y: &[i32], z: &[f32])
        -> Result<ProbeOut>;

    /// m-medoid facility-location selection (indices, cluster weights).
    fn select_greedy(&self, g: &MatF32, a: &MatF32) -> Result<(Vec<usize>, Vec<f32>)>;
}

/// Manifest + backend + per-op timing for one variant.
pub struct Runtime {
    /// The variant's shape contract.
    pub man: VariantManifest,
    backend: Box<dyn Backend>,
    /// Per-artifact wall-clock accounting (backs Table 2).
    pub timers: RefCell<PhaseTimers>,
    dir: PathBuf,
}

impl Runtime {
    /// Native runtime from an explicit manifest.
    pub fn native(man: VariantManifest) -> Runtime {
        let backend = Box::new(native::NativeBackend::new(man.clone()));
        Runtime { man, backend, timers: RefCell::new(PhaseTimers::new()), dir: PathBuf::new() }
    }

    /// Native runtime for a builtin variant name (no files required).
    pub fn native_variant(variant: &str) -> Result<Runtime> {
        Ok(Self::native(VariantManifest::builtin(variant)?))
    }

    /// Load a variant: read `artifact_root/<variant>/manifest.json` when it
    /// exists (so tuned shape overrides are honored), otherwise fall back to
    /// the builtin spec. Executes on the native backend either way; the
    /// PJRT path is explicit via [`Runtime::load_pjrt`].
    pub fn load(artifact_root: &Path, variant: &str) -> Result<Runtime> {
        let dir = artifact_root.join(variant);
        let man = if dir.join("manifest.json").exists() {
            VariantManifest::load(&dir)
                .with_context(|| format!("loading manifest for {variant}"))?
        } else {
            VariantManifest::builtin(variant)
                .context("no manifest on disk and no builtin spec")?
        };
        let mut rt = Self::native(man);
        rt.dir = dir;
        Ok(rt)
    }

    /// Compile and execute the variant's AOT artifacts through XLA/PJRT.
    #[cfg(feature = "pjrt")]
    pub fn load_pjrt(artifact_root: &Path, variant: &str) -> Result<Runtime> {
        let dir = artifact_root.join(variant);
        let backend = pjrt::PjrtBackend::load(&dir, variant)?;
        let man = backend.manifest().clone();
        Ok(Runtime {
            man,
            backend: Box::new(backend),
            timers: RefCell::new(PhaseTimers::new()),
            dir,
        })
    }

    /// Name of the active execution backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Directory the variant's artifacts live in (may not exist for
    /// builtin native runtimes).
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn check_len(&self, name: &str, what: &str, got: usize, want: usize) -> Result<()> {
        if got != want {
            anyhow::bail!("{name}: {what} has {got} elements, manifest wants {want}");
        }
        Ok(())
    }

    // -------------------------------------------------------------- wrappers

    /// Fresh all-zero momentum buffer.
    pub fn zero_momentum(&self) -> Vec<f32> {
        vec![0.0f32; self.man.p_dim]
    }

    /// Validate a host parameter vector against the manifest.
    pub fn params_from_host(&self, p: &[f32]) -> Result<Vec<f32>> {
        self.check_len("params_from_host", "params", p.len(), self.man.p_dim)?;
        Ok(p.to_vec())
    }

    /// Parameter state back to a host vector (trivial for host backends).
    pub fn params_to_host(&self, p: &[f32]) -> Result<Vec<f32>> {
        self.check_len("params_to_host", "params", p.len(), self.man.p_dim)?;
        Ok(p.to_vec())
    }

    /// One weighted SGD+momentum step (paper Eq. 2 with per-element gamma).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &[f32],
        momentum: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<StepOut> {
        let m = self.man.m;
        self.check_len("train_step", "x rows", x.rows, m)?;
        self.check_len("train_step", "x cols", x.cols, self.man.d_in)?;
        self.check_len("train_step", "y", y.len(), m)?;
        self.check_len("train_step", "gamma", gamma.len(), m)?;
        let t0 = Instant::now();
        let out = self.backend.train_step(params, momentum, x, y, gamma, lr, wd)?;
        self.timers.borrow_mut().add("train_step", t0.elapsed());
        Ok(out)
    }

    /// Extract the *gradient* a weighted batch induces, without stepping:
    /// train_step with zero momentum and lr=0 leaves params unchanged while
    /// `mom_out = μ·0 + grad = grad`. Used by the bias/variance probes
    /// behind Figs. 1/6/9.
    pub fn batch_gradient(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
    ) -> Result<Vec<f32>> {
        let zero = self.zero_momentum();
        let out = self.train_step(params, &zero, x, y, gamma, 0.0, 0.0)?;
        Ok(out.momentum)
    }

    /// Selection embeddings for a size-r subset (paper Eq. 11 inputs):
    /// logit gradients g = p − y, penultimate activations a, and losses.
    /// (g, a) define the last-layer weight gradient a ⊗ g used as the
    /// selection metric.
    pub fn grad_embed(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(MatF32, MatF32, Vec<f32>)> {
        let r = self.man.r;
        self.check_len("grad_embed", "x rows", x.rows, r)?;
        self.check_len("grad_embed", "y", y.len(), r)?;
        let t0 = Instant::now();
        let out = self.backend.grad_embed(params, x, y)?;
        self.timers.borrow_mut().add("grad_embed", t0.elapsed());
        Ok(out)
    }

    /// Per-chunk evaluation: (sum_loss, n_correct, per_ex_loss, correct).
    pub fn eval_chunk(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let e = self.man.eval_chunk;
        self.check_len("eval_chunk", "x rows", x.rows, e)?;
        self.check_len("eval_chunk", "y", y.len(), e)?;
        let t0 = Instant::now();
        let out = self.backend.eval_chunk(params, x, y)?;
        self.timers.borrow_mut().add("eval_chunk", t0.elapsed());
        Ok(out)
    }

    /// Hutchinson probe on a size-r subset (paper Eq. 7).
    pub fn hess_probe(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
        z: &[f32],
    ) -> Result<ProbeOut> {
        let r = self.man.r;
        self.check_len("hess_probe", "x rows", x.rows, r)?;
        self.check_len("hess_probe", "z", z.len(), self.man.p_dim)?;
        let t0 = Instant::now();
        let out = self.backend.hess_probe(params, x, y, z)?;
        self.timers.borrow_mut().add("hess_probe", t0.elapsed());
        Ok(out)
    }

    /// In-backend greedy selection over r gradient embeddings (the
    /// backend-side alternative to calling `coreset::facility` directly;
    /// compared in benches).
    pub fn select_greedy(&self, g: &MatF32, a: &MatF32) -> Result<(Vec<usize>, Vec<f32>)> {
        let r = self.man.r;
        self.check_len("select_greedy", "g rows", g.rows, r)?;
        self.check_len("select_greedy", "g cols", g.cols, self.man.classes)?;
        self.check_len("select_greedy", "a rows", a.rows, r)?;
        let t0 = Instant::now();
        let out = self.backend.select_greedy(g, a)?;
        self.timers.borrow_mut().add("select_greedy", t0.elapsed());
        Ok(out)
    }

    /// Human-readable interface summary (used by `crest inspect`).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "variant {} [{} backend] (p_dim={}, m={}, r={}, classes={})\n",
            self.man.name,
            self.backend.name(),
            self.man.p_dim,
            self.man.m,
            self.man.r,
            self.man.classes
        );
        for (name, a) in &self.man.artifacts {
            let ins: Vec<String> = a
                .inputs
                .iter()
                .map(|t| format!("{}:{:?}{:?}", t.name, t.dtype, t.shape))
                .collect();
            s.push_str(&format!("  {name}({})\n", ins.join(", ")));
        }
        s
    }
}

/// Size in bytes of one element of the given dtype.
pub fn dtype_bytes(d: DType) -> usize {
    match d {
        DType::F32 | DType::I32 => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(dtype_bytes(DType::F32), 4);
        assert_eq!(dtype_bytes(DType::I32), 4);
    }

    #[test]
    fn load_unknown_variant_fails() {
        assert!(Runtime::load(Path::new("/nonexistent"), "nope").is_err());
    }

    #[test]
    fn load_falls_back_to_builtin_spec() {
        // no artifacts directory anywhere, yet known variants load natively
        let rt = Runtime::load(Path::new("/nonexistent"), "smoke").unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert_eq!(rt.man.name, "smoke");
        let desc = rt.describe();
        for name in ["train_step", "grad_embed", "eval_chunk", "hess_probe", "select_greedy"]
        {
            assert!(desc.contains(name), "missing {name} in {desc}");
        }
    }

    #[test]
    fn wrappers_enforce_manifest_shapes() {
        let rt = Runtime::native_variant("smoke").unwrap();
        let params = rt.zero_momentum();
        let x = MatF32::zeros(3, rt.man.d_in); // wrong row count
        let y = vec![0i32; 3];
        assert!(rt.eval_chunk(&params, &x, &y).is_err());
        assert!(rt.grad_embed(&params, &x, &y).is_err());
        assert!(rt.params_from_host(&[0.0; 3]).is_err());
    }
}
