//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only module that talks to XLA. It compiles each
//! `artifacts/<variant>/*.hlo.txt` once at startup
//! (`HloModuleProto::from_text_file` → `client.compile`) and exposes typed,
//! shape-checked wrappers for the five computations the coordinator uses.
//! Python is never involved at runtime.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::{lit_f32, lit_f32_2d, lit_i32, lit_scalar, lit_to_f32, lit_to_i32, lit_to_scalar, MatF32};
use crate::util::timer::PhaseTimers;
use manifest::{DType, VariantManifest};

/// Output of one training step.
pub struct StepOut {
    /// Updated parameters (kept as a literal: feeds the next step without a
    /// host round-trip).
    pub params: xla::Literal,
    pub momentum: xla::Literal,
    pub mean_loss: f32,
    pub per_ex_loss: Vec<f32>,
}

/// Output of the Hutchinson probe.
#[derive(Debug)]
pub struct ProbeOut {
    /// H·z for the supplied probe vector.
    pub hz: Vec<f32>,
    /// Mean gradient of the probed subset (param space).
    pub grad: Vec<f32>,
    pub mean_loss: f32,
}

/// Compiled executables + manifest for one variant.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub man: VariantManifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Per-artifact wall-clock accounting (backs Table 2).
    pub timers: RefCell<PhaseTimers>,
    dir: PathBuf,
}

impl Runtime {
    /// Compile all artifacts of `variant` found under `artifact_root`.
    pub fn load(artifact_root: &Path, variant: &str) -> Result<Runtime> {
        let dir = artifact_root.join(variant);
        let man = VariantManifest::load(&dir)
            .with_context(|| format!("loading manifest for {variant}"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut exes = HashMap::new();
        for (name, art) in &man.artifacts {
            let path = dir.join(&art.file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            log::debug!("compiled {variant}/{name} in {:.3}s", t0.elapsed().as_secs_f64());
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime { client, man, exes, timers: RefCell::new(PhaseTimers::new()), dir })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Raw execution: run artifact `name`, unpack the result tuple, verify
    /// output arity against the manifest.
    fn exec(&self, name: &'static str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable {name:?}"))?;
        let spec = self.man.artifact(name)?;
        if args.len() != spec.inputs.len() {
            bail!("{name}: got {} args, manifest says {}", args.len(), spec.inputs.len());
        }
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: single tuple output.
        let parts = result.to_tuple()?;
        self.timers.borrow_mut().add(name, t0.elapsed());
        if parts.len() != spec.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}", parts.len(), spec.outputs.len());
        }
        Ok(parts)
    }

    fn check_len(&self, name: &str, what: &str, got: usize, want: usize) -> Result<()> {
        if got != want {
            bail!("{name}: {what} has {got} elements, manifest wants {want}");
        }
        Ok(())
    }

    // -------------------------------------------------------------- wrappers

    /// Fresh all-zero momentum literal.
    pub fn zero_momentum(&self) -> xla::Literal {
        lit_f32(&vec![0.0f32; self.man.p_dim])
    }

    /// Host params -> literal.
    pub fn params_from_host(&self, p: &[f32]) -> Result<xla::Literal> {
        self.check_len("params_from_host", "params", p.len(), self.man.p_dim)?;
        Ok(lit_f32(p))
    }

    /// Literal params -> host vector.
    pub fn params_to_host(&self, p: &xla::Literal) -> Result<Vec<f32>> {
        lit_to_f32(p)
    }

    /// One weighted SGD+momentum step (paper Eq. 2 with per-element gamma).
    pub fn train_step(
        &self,
        params: &xla::Literal,
        momentum: &xla::Literal,
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<StepOut> {
        let m = self.man.m;
        self.check_len("train_step", "x rows", x.rows, m)?;
        self.check_len("train_step", "x cols", x.cols, self.man.d_in)?;
        self.check_len("train_step", "y", y.len(), m)?;
        self.check_len("train_step", "gamma", gamma.len(), m)?;
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let gl = lit_f32(gamma);
        let lrl = lit_scalar(lr);
        let wdl = lit_scalar(wd);
        let mut out = self.exec("train_step", &[params, momentum, &xl, &yl, &gl, &lrl, &wdl])?;
        let per_ex_loss = lit_to_f32(&out[3])?;
        let mean_loss = lit_to_scalar(&out[2])?;
        let momentum = out.swap_remove(1);
        let params = out.swap_remove(0);
        Ok(StepOut { params, momentum, mean_loss, per_ex_loss })
    }

    /// Extract the *gradient* a weighted batch induces, without stepping:
    /// train_step with zero momentum and lr=0 leaves params unchanged while
    /// `mom_out = 0.9·0 + grad = grad`. Used by the bias/variance probes
    /// behind Figs. 1/6/9.
    pub fn batch_gradient(
        &self,
        params: &xla::Literal,
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
    ) -> Result<Vec<f32>> {
        let zero = self.zero_momentum();
        let out = self.train_step(params, &zero, x, y, gamma, 0.0, 0.0)?;
        lit_to_f32(&out.momentum)
    }

    /// Selection embeddings for a size-r subset (paper Eq. 11 inputs):
    /// logit gradients g = p − y, penultimate activations a, and losses.
    /// (g, a) define the last-layer weight gradient a ⊗ g used as the
    /// selection metric.
    pub fn grad_embed(
        &self,
        params: &xla::Literal,
        x: &MatF32,
        y: &[i32],
    ) -> Result<(MatF32, MatF32, Vec<f32>)> {
        let r = self.man.r;
        self.check_len("grad_embed", "x rows", x.rows, r)?;
        self.check_len("grad_embed", "y", y.len(), r)?;
        let h = *self.man.hidden.last().expect("at least one hidden layer");
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let out = self.exec("grad_embed", &[params, &xl, &yl])?;
        let g = MatF32::from_vec(r, self.man.classes, lit_to_f32(&out[0])?)?;
        let a = MatF32::from_vec(r, h, lit_to_f32(&out[1])?)?;
        let loss = lit_to_f32(&out[2])?;
        Ok((g, a, loss))
    }

    /// Per-chunk evaluation: (sum_loss, n_correct, per_ex_loss, correct).
    pub fn eval_chunk(
        &self,
        params: &xla::Literal,
        x: &MatF32,
        y: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let e = self.man.eval_chunk;
        self.check_len("eval_chunk", "x rows", x.rows, e)?;
        self.check_len("eval_chunk", "y", y.len(), e)?;
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let out = self.exec("eval_chunk", &[params, &xl, &yl])?;
        Ok((
            lit_to_scalar(&out[0])?,
            lit_to_scalar(&out[1])?,
            lit_to_f32(&out[2])?,
            lit_to_f32(&out[3])?,
        ))
    }

    /// Hutchinson probe on a size-r subset (paper Eq. 7).
    pub fn hess_probe(
        &self,
        params: &xla::Literal,
        x: &MatF32,
        y: &[i32],
        z: &[f32],
    ) -> Result<ProbeOut> {
        let r = self.man.r;
        self.check_len("hess_probe", "x rows", x.rows, r)?;
        self.check_len("hess_probe", "z", z.len(), self.man.p_dim)?;
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let zl = lit_f32(z);
        let out = self.exec("hess_probe", &[params, &xl, &yl, &zl])?;
        Ok(ProbeOut {
            hz: lit_to_f32(&out[0])?,
            grad: lit_to_f32(&out[1])?,
            mean_loss: lit_to_scalar(&out[2])?,
        })
    }

    /// Compiled in-graph greedy selection over r gradient embeddings
    /// (the XLA alternative to `coreset::facility`; compared in benches).
    pub fn select_greedy(&self, g: &MatF32, a: &MatF32) -> Result<(Vec<usize>, Vec<f32>)> {
        let r = self.man.r;
        self.check_len("select_greedy", "g rows", g.rows, r)?;
        self.check_len("select_greedy", "g cols", g.cols, self.man.classes)?;
        self.check_len("select_greedy", "a rows", a.rows, r)?;
        let gl = lit_f32_2d(&g.data, g.rows, g.cols)?;
        let al = lit_f32_2d(&a.data, a.rows, a.cols)?;
        let out = self.exec("select_greedy", &[&gl, &al])?;
        let idxs = lit_to_i32(&out[0])?.into_iter().map(|i| i as usize).collect();
        let weights = lit_to_f32(&out[1])?;
        Ok((idxs, weights))
    }

    /// Human-readable artifact summary (used by `crest inspect`).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "variant {} (p_dim={}, m={}, r={}, classes={})\n",
            self.man.name, self.man.p_dim, self.man.m, self.man.r, self.man.classes
        );
        for (name, a) in &self.man.artifacts {
            let ins: Vec<String> = a
                .inputs
                .iter()
                .map(|t| format!("{}:{:?}{:?}", t.name, t.dtype, t.shape))
                .collect();
            s.push_str(&format!("  {name}({})\n", ins.join(", ")));
        }
        s
    }
}

/// Size in bytes of one element of the given dtype.
pub fn dtype_bytes(d: DType) -> usize {
    match d {
        DType::F32 | DType::I32 => 4,
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests cover pure logic; executions against real artifacts live
    //! in `rust/tests/` (they need `make artifacts`).
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(dtype_bytes(DType::F32), 4);
        assert_eq!(dtype_bytes(DType::I32), 4);
    }

    #[test]
    fn load_missing_dir_fails() {
        assert!(Runtime::load(Path::new("/nonexistent"), "nope").is_err());
    }
}
