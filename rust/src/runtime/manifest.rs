//! Manifest: the shape contract of the five coordinator computations.
//!
//! A [`VariantManifest`] comes from one of two places:
//!
//! * **builtin** — [`VariantManifest::builtin`] synthesizes the manifest for
//!   a known variant directly from [`ModelSpec`] shape parameters. This is
//!   all the native backend needs; no files are involved.
//! * **JSON** — `artifacts/<variant>/manifest.json`, written by
//!   `python/compile/aot.py` for the optional `pjrt` execution path.
//!
//! Either way the runtime validates host buffers against these specs before
//! every execution so shape bugs surface as errors at the call site, not as
//! garbage numerics.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor in the artifact interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Parameter name in the artifact signature.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Dense shape.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count of the shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str()?.to_string(),
            dtype: DType::parse(j.req("dtype")?.as_str()?)?,
            shape: j.req("shape")?.as_usize_vec()?,
        })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// HLO file name relative to the variant directory.
    pub file: String,
    /// Input signature.
    pub inputs: Vec<TensorSpec>,
    /// Output signature.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn parse(j: &Json) -> Result<ArtifactSpec> {
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?.as_arr()?.iter().map(TensorSpec::parse).collect()
        };
        Ok(ArtifactSpec {
            file: j.req("file")?.as_str()?.to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
        })
    }
}

/// Full manifest for one model/dataset variant.
#[derive(Debug, Clone)]
pub struct VariantManifest {
    /// Variant name.
    pub name: String,
    /// Input feature dimensionality.
    pub d_in: usize,
    /// Hidden layer widths.
    pub hidden: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
    /// Mini-batch (coreset) size m.
    pub m: usize,
    /// Random-subset size r.
    pub r: usize,
    /// Examples per evaluation chunk.
    pub eval_chunk: usize,
    /// Total flat parameter count.
    pub p_dim: usize,
    /// SGD momentum coefficient.
    pub momentum: f32,
    /// (in, out) per dense layer.
    pub layer_shapes: Vec<(usize, usize)>,
    /// Declared computations, keyed by artifact name.
    pub artifacts: Vec<(String, ArtifactSpec)>,
}

/// Shape parameters of one model/dataset variant — the Rust mirror of
/// `python/compile/configs.py::VariantSpec`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Variant name.
    pub name: &'static str,
    /// Input feature dimensionality.
    pub d_in: usize,
    /// Hidden layer widths.
    pub hidden: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
    /// Mini-batch (coreset) size m.
    pub m: usize,
    /// Random-subset size r.
    pub r: usize,
    /// Examples per evaluation chunk.
    pub eval_chunk: usize,
    /// SGD momentum coefficient.
    pub momentum: f32,
}

impl ModelSpec {
    /// Spec for a known variant. Numbers mirror `configs.py::VARIANTS`
    /// (the four paper proxies) plus the tiny `smoke` variant used by
    /// fast tests.
    pub fn builtin(variant: &str) -> Option<ModelSpec> {
        let (name, d_in, hidden, classes, m, r, eval_chunk) = match variant {
            "cifar10-proxy" => ("cifar10-proxy", 64, vec![128, 64], 10, 32, 256, 512),
            "cifar100-proxy" => ("cifar100-proxy", 96, vec![256, 128], 20, 32, 256, 512),
            "tinyimagenet-proxy" => {
                ("tinyimagenet-proxy", 128, vec![256, 128], 40, 32, 320, 512)
            }
            "snli-proxy" => ("snli-proxy", 96, vec![256], 3, 32, 128, 512),
            "smoke" => ("smoke", 16, vec![32], 4, 16, 64, 128),
            _ => return None,
        };
        Some(ModelSpec { name, d_in, hidden, classes, m, r, eval_chunk, momentum: 0.9 })
    }
}

/// File name used for artifact entries of manifests built in-process (no
/// HLO file exists; the native backend computes the op directly).
pub const NATIVE_ARTIFACT_FILE: &str = "<native>";

impl VariantManifest {
    /// Synthesize the manifest for a spec: layer shapes, flat parameter
    /// count, and the five artifact signatures (mirroring what
    /// `python/compile/aot.py` writes to `manifest.json`).
    pub fn from_spec(spec: &ModelSpec) -> Result<VariantManifest> {
        let t = |name: &str, dtype: DType, shape: &[usize]| TensorSpec {
            name: name.to_string(),
            dtype,
            shape: shape.to_vec(),
        };
        let art = |inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| ArtifactSpec {
            file: NATIVE_ARTIFACT_FILE.to_string(),
            inputs,
            outputs,
        };
        let mut dims = vec![spec.d_in];
        dims.extend_from_slice(&spec.hidden);
        dims.push(spec.classes);
        let layer_shapes: Vec<(usize, usize)> =
            dims.windows(2).map(|w| (w[0], w[1])).collect();
        let p: usize = layer_shapes.iter().map(|(i, o)| i * o + o).sum();
        let h_last = *spec.hidden.last().context("spec needs a hidden layer")?;
        let (d, c, m, r, e) = (spec.d_in, spec.classes, spec.m, spec.r, spec.eval_chunk);
        let f = DType::F32;
        let i = DType::I32;
        let artifacts = vec![
            (
                "train_step".to_string(),
                art(
                    vec![
                        t("params", f, &[p]),
                        t("momentum", f, &[p]),
                        t("x", f, &[m, d]),
                        t("y", i, &[m]),
                        t("gamma", f, &[m]),
                        t("lr", f, &[]),
                        t("wd", f, &[]),
                    ],
                    vec![
                        t("params", f, &[p]),
                        t("momentum", f, &[p]),
                        t("mean_loss", f, &[]),
                        t("per_ex_loss", f, &[m]),
                    ],
                ),
            ),
            (
                "grad_embed".to_string(),
                art(
                    vec![t("params", f, &[p]), t("x", f, &[r, d]), t("y", i, &[r])],
                    vec![
                        t("g", f, &[r, c]),
                        t("act", f, &[r, h_last]),
                        t("per_ex_loss", f, &[r]),
                    ],
                ),
            ),
            (
                "eval_chunk".to_string(),
                art(
                    vec![t("params", f, &[p]), t("x", f, &[e, d]), t("y", i, &[e])],
                    vec![
                        t("sum_loss", f, &[]),
                        t("n_correct", f, &[]),
                        t("per_ex_loss", f, &[e]),
                        t("correct", f, &[e]),
                    ],
                ),
            ),
            (
                "hess_probe".to_string(),
                art(
                    vec![
                        t("params", f, &[p]),
                        t("x", f, &[r, d]),
                        t("y", i, &[r]),
                        t("z", f, &[p]),
                    ],
                    vec![t("hz", f, &[p]), t("grad", f, &[p]), t("mean_loss", f, &[])],
                ),
            ),
            (
                "select_greedy".to_string(),
                art(
                    vec![t("g", f, &[r, c]), t("act", f, &[r, h_last])],
                    vec![t("indices", i, &[m]), t("weights", f, &[m])],
                ),
            ),
        ];
        let man = VariantManifest {
            name: spec.name.to_string(),
            d_in: spec.d_in,
            hidden: spec.hidden.clone(),
            classes: spec.classes,
            m: spec.m,
            r: spec.r,
            eval_chunk: spec.eval_chunk,
            p_dim: p,
            momentum: spec.momentum,
            layer_shapes,
            artifacts,
        };
        man.validate()?;
        Ok(man)
    }

    /// Builtin manifest for a known variant name.
    pub fn builtin(variant: &str) -> Result<VariantManifest> {
        let spec = ModelSpec::builtin(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant:?}"))?;
        Self::from_spec(&spec)
    }

    /// Parse and validate a `manifest.json` document.
    pub fn parse(text: &str) -> Result<VariantManifest> {
        let j = Json::parse(text).context("manifest json")?;
        let layer_shapes = j
            .req("layer_shapes")?
            .as_arr()?
            .iter()
            .map(|v| {
                let s = v.as_usize_vec()?;
                if s.len() != 2 {
                    bail!("layer shape must be [in, out]");
                }
                Ok((s[0], s[1]))
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .req("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), ArtifactSpec::parse(v)?)))
            .collect::<Result<Vec<_>>>()?;
        let man = VariantManifest {
            name: j.req("name")?.as_str()?.to_string(),
            d_in: j.req("d_in")?.as_usize()?,
            hidden: j.req("hidden")?.as_usize_vec()?,
            classes: j.req("classes")?.as_usize()?,
            m: j.req("m")?.as_usize()?,
            r: j.req("r")?.as_usize()?,
            eval_chunk: j.req("eval_chunk")?.as_usize()?,
            p_dim: j.req("p_dim")?.as_usize()?,
            momentum: j.req("momentum")?.as_f64()? as f32,
            layer_shapes,
            artifacts,
        };
        man.validate()?;
        Ok(man)
    }

    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<VariantManifest> {
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    /// Spec of the named computation; errors when undeclared.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow::anyhow!("manifest has no artifact {name:?}"))
    }

    /// Internal consistency checks (p_dim vs layer shapes, required artifacts).
    fn validate(&self) -> Result<()> {
        let p: usize = self.layer_shapes.iter().map(|(i, o)| i * o + o).sum();
        if p != self.p_dim {
            bail!("p_dim {} inconsistent with layer shapes (sum {})", self.p_dim, p);
        }
        for required in ["train_step", "grad_embed", "eval_chunk", "hess_probe", "select_greedy"] {
            self.artifact(required)?;
        }
        let ts = self.artifact("train_step")?;
        if ts.inputs[0].shape != [self.p_dim] {
            bail!("train_step params shape mismatch");
        }
        if ts.inputs[2].shape != [self.m, self.d_in] {
            bail!("train_step x shape mismatch");
        }
        Ok(())
    }
}

/// Top-level artifacts index (artifacts/manifest.json).
pub fn load_index(artifact_root: &Path) -> Result<Vec<String>> {
    let path = artifact_root.join("manifest.json");
    let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
    let j = Json::parse(&text)?;
    j.req("variants")?.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
          "name": "t", "d_in": 4, "hidden": [8], "classes": 3,
          "m": 2, "r": 4, "eval_chunk": 4, "p_dim": 67, "momentum": 0.9,
          "layer_shapes": [[4, 8], [8, 3]],
          "artifacts": {
            "train_step": {"file": "train_step.hlo.txt",
              "inputs": [
                {"name": "params", "dtype": "f32", "shape": [67]},
                {"name": "momentum", "dtype": "f32", "shape": [67]},
                {"name": "x", "dtype": "f32", "shape": [2, 4]},
                {"name": "y", "dtype": "i32", "shape": [2]},
                {"name": "gamma", "dtype": "f32", "shape": [2]},
                {"name": "lr", "dtype": "f32", "shape": []}],
              "outputs": [{"name": "params", "dtype": "f32", "shape": [67]}]},
            "grad_embed": {"file": "g.hlo.txt", "inputs": [], "outputs": []},
            "eval_chunk": {"file": "e.hlo.txt", "inputs": [], "outputs": []},
            "hess_probe": {"file": "h.hlo.txt", "inputs": [], "outputs": []},
            "select_greedy": {"file": "s.hlo.txt", "inputs": [], "outputs": []}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_valid_manifest() {
        let m = VariantManifest::parse(&sample()).unwrap();
        assert_eq!(m.p_dim, 67);
        assert_eq!(m.layer_shapes, vec![(4, 8), (8, 3)]);
        let ts = m.artifact("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 6);
        assert_eq!(ts.inputs[3].dtype, DType::I32);
        assert_eq!(ts.inputs[5].shape, Vec::<usize>::new());
        assert_eq!(ts.inputs[5].elements(), 1);
    }

    #[test]
    fn rejects_inconsistent_pdim() {
        let bad = sample().replace("\"p_dim\": 67", "\"p_dim\": 66");
        assert!(VariantManifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let bad = sample().replace("\"select_greedy\"", "\"other_thing\"");
        assert!(VariantManifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = sample().replace("\"dtype\": \"i32\"", "\"dtype\": \"u8\"");
        assert!(VariantManifest::parse(&bad).is_err());
    }

    #[test]
    fn builtin_specs_validate_for_all_variants() {
        for v in
            ["cifar10-proxy", "cifar100-proxy", "tinyimagenet-proxy", "snli-proxy", "smoke"]
        {
            let man = VariantManifest::builtin(v).unwrap();
            assert_eq!(man.name, v);
            let p: usize = man.layer_shapes.iter().map(|(i, o)| i * o + o).sum();
            assert_eq!(man.p_dim, p);
            for required in
                ["train_step", "grad_embed", "eval_chunk", "hess_probe", "select_greedy"]
            {
                let art = man.artifact(required).unwrap();
                assert_eq!(art.file, NATIVE_ARTIFACT_FILE);
            }
        }
        assert!(VariantManifest::builtin("bogus").is_err());
    }

    #[test]
    fn builtin_cifar10_matches_python_configs() {
        // mirror of python/compile/configs.py::VARIANTS["cifar10-proxy"]
        let man = VariantManifest::builtin("cifar10-proxy").unwrap();
        assert_eq!(man.d_in, 64);
        assert_eq!(man.hidden, vec![128, 64]);
        assert_eq!(man.classes, 10);
        assert_eq!(man.m, 32);
        assert_eq!(man.r, 256);
        assert_eq!(man.eval_chunk, 512);
        assert_eq!(man.layer_shapes, vec![(64, 128), (128, 64), (64, 10)]);
        let ts = man.artifact("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 7);
        assert_eq!(ts.inputs[2].shape, vec![32, 64]);
        assert_eq!(ts.outputs.len(), 4);
    }

    #[test]
    fn real_manifests_parse_if_present() {
        // Integration-level check against the actual AOT output when built.
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.exists() {
            return; // artifacts not built in this environment
        }
        for v in load_index(&root).unwrap() {
            let man = VariantManifest::load(&root.join(&v)).unwrap();
            assert_eq!(man.name, v);
            assert!(man.p_dim > 0);
        }
    }
}
