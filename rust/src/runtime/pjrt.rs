//! PJRT backend: load AOT artifacts (HLO text) and execute them via XLA.
//!
//! Compiled only with `--features pjrt`, which additionally requires an
//! `xla` crate (e.g. a vendored checkout of `xla-rs`) to be added to
//! `[dependencies]` — the crate is deliberately not a default dependency so
//! a clean checkout builds offline with zero native libraries. See
//! README.md for the setup.
//!
//! This is the only module that talks to XLA. It compiles each
//! `artifacts/<variant>/*.hlo.txt` once at startup
//! (`HloModuleProto::from_text_file` → `client.compile`) and adapts the
//! host-vector [`Backend`] interface to literal-valued executions. Python
//! is never involved at runtime.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::VariantManifest;
use crate::runtime::{Backend, ProbeOut, StepOut};
use crate::tensor::MatF32;

// ------------------------------------------------------------ literal bridge

/// f32 slice -> rank-1 literal.
fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 slice -> rank-2 literal with the given shape.
fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(v.len() == rows * cols, "len {} != {rows}x{cols}", v.len());
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// i32 slice -> rank-1 literal.
fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 scalar literal.
fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal -> Vec<f32> (any rank; row-major order).
fn lit_to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Literal -> Vec<i32>.
fn lit_to_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// Scalar literal -> f32.
fn lit_to_scalar(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}

/// Compiled executables + manifest for one variant.
pub struct PjrtBackend {
    /// Never read after compilation, but must outlive the executables.
    #[allow(dead_code)]
    client: xla::PjRtClient,
    man: VariantManifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Compile all artifacts found under `dir` (one variant's directory).
    pub fn load(dir: &Path, variant: &str) -> Result<PjrtBackend> {
        let man = VariantManifest::load(dir)
            .with_context(|| format!("loading manifest for {variant}"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut exes = HashMap::new();
        for (name, art) in &man.artifacts {
            let path = dir.join(&art.file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            log::debug!("compiled {variant}/{name} in {:.3}s", t0.elapsed().as_secs_f64());
            exes.insert(name.clone(), exe);
        }
        Ok(PjrtBackend { client, man, exes })
    }

    /// The manifest the artifacts were compiled against.
    pub fn manifest(&self) -> &VariantManifest {
        &self.man
    }

    /// Raw execution: run artifact `name`, unpack the result tuple, verify
    /// output arity against the manifest.
    fn exec(&self, name: &'static str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable {name:?}"))?;
        let spec = self.man.artifact(name)?;
        if args.len() != spec.inputs.len() {
            bail!("{name}: got {} args, manifest says {}", args.len(), spec.inputs.len());
        }
        let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: single tuple output.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}", parts.len(), spec.outputs.len());
        }
        Ok(parts)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_step(
        &self,
        params: &[f32],
        momentum: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<StepOut> {
        let pl = lit_f32(params);
        let ml = lit_f32(momentum);
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let gl = lit_f32(gamma);
        let lrl = lit_scalar(lr);
        let wdl = lit_scalar(wd);
        let out = self.exec("train_step", &[&pl, &ml, &xl, &yl, &gl, &lrl, &wdl])?;
        Ok(StepOut {
            params: lit_to_f32(&out[0])?,
            momentum: lit_to_f32(&out[1])?,
            mean_loss: lit_to_scalar(&out[2])?,
            per_ex_loss: lit_to_f32(&out[3])?,
        })
    }

    fn grad_embed(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(MatF32, MatF32, Vec<f32>)> {
        let r = x.rows;
        let h = *self.man.hidden.last().expect("at least one hidden layer");
        let pl = lit_f32(params);
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let out = self.exec("grad_embed", &[&pl, &xl, &yl])?;
        let g = MatF32::from_vec(r, self.man.classes, lit_to_f32(&out[0])?)?;
        let a = MatF32::from_vec(r, h, lit_to_f32(&out[1])?)?;
        let loss = lit_to_f32(&out[2])?;
        Ok((g, a, loss))
    }

    fn eval_chunk(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let pl = lit_f32(params);
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let out = self.exec("eval_chunk", &[&pl, &xl, &yl])?;
        Ok((
            lit_to_scalar(&out[0])?,
            lit_to_scalar(&out[1])?,
            lit_to_f32(&out[2])?,
            lit_to_f32(&out[3])?,
        ))
    }

    fn hess_probe(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
        z: &[f32],
    ) -> Result<ProbeOut> {
        let pl = lit_f32(params);
        let xl = lit_f32_2d(&x.data, x.rows, x.cols)?;
        let yl = lit_i32(y);
        let zl = lit_f32(z);
        let out = self.exec("hess_probe", &[&pl, &xl, &yl, &zl])?;
        Ok(ProbeOut {
            hz: lit_to_f32(&out[0])?,
            grad: lit_to_f32(&out[1])?,
            mean_loss: lit_to_scalar(&out[2])?,
        })
    }

    fn select_greedy(&self, g: &MatF32, a: &MatF32) -> Result<(Vec<usize>, Vec<f32>)> {
        let gl = lit_f32_2d(&g.data, g.rows, g.cols)?;
        let al = lit_f32_2d(&a.data, a.rows, a.cols)?;
        let out = self.exec("select_greedy", &[&gl, &al])?;
        let idxs = lit_to_i32(&out[0])?.into_iter().map(|i| i as usize).collect();
        let weights = lit_to_f32(&out[1])?;
        Ok((idxs, weights))
    }
}
