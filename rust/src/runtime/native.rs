//! Pure-Rust native CPU backend: the default execution engine.
//!
//! Implements the five coordinator computations directly from the
//! [`VariantManifest`] shape contract — the same math `python/compile/model.py`
//! lowers to HLO, re-derived on the host:
//!
//! * `train_step` — weighted softmax cross-entropy, backprop through the
//!   MLP, SGD + momentum with L2 (`g += wd·w`, `v ← μv + g`, `w ← w − ηv`);
//! * `grad_embed` — last-layer selection embeddings: logit gradients
//!   `g = p − y`, penultimate activations, per-example losses (paper Eq. 11);
//! * `eval_chunk` — per-chunk loss sums and argmax accuracy;
//! * `hess_probe` — exact Hessian-vector products `Hz` by forward-over-reverse
//!   differentiation (tangent propagation through the gradient computation),
//!   backing the Hutchinson diagonal estimate of paper Eq. 7;
//! * `select_greedy` — facility-location greedy under the last-layer
//!   weight-gradient metric (`coreset::facility`).
//!
//! The flat parameter layout (per layer: row-major W then b) follows
//! `model::param_offsets`, which mirrors `python/compile/model.py::unflatten`.

use anyhow::{ensure, Result};

use crate::coreset::facility;
use crate::kernel::{self, Workspace, WorkspacePool, PAR_MIN_OPS, ROW_GRAIN};
use crate::model::param_offsets;
use crate::runtime::manifest::VariantManifest;
use crate::runtime::{Backend, ProbeOut, StepOut};
use crate::tensor::MatF32;
use crate::util::pool::Pool;

// ---------------------------------------------------------------- threading
//
// The dense kernels live in `crate::kernel`: register-tiled microkernels
// that are row-partitioned (matmuls), feature-partitioned (weight
// gradients) or chunk-partitioned (bias gradients, masks) with boundaries
// that depend only on problem shapes. Each output element is produced by
// exactly one worker with a fixed per-element accumulation order, so
// every backend result is bitwise-identical at every thread count,
// including 1. Scratch buffers come from a shared [`WorkspacePool`]: the
// forward/backward/HVP pipelines reuse their intermediate matrices across
// steps instead of allocating per call.

/// Minimum flat-parameter count before the SGD update parallelizes.
const SGD_PAR_MIN: usize = 1 << 17;
/// Flat parameter elements per work unit in the SGD update.
const SGD_GRAIN: usize = 1 << 14;

/// Offsets of one dense layer inside the flat parameter vector.
#[derive(Debug, Clone, Copy)]
struct Layer {
    w_off: usize,
    d_in: usize,
    d_out: usize,
    b_off: usize,
}

impl Layer {
    #[inline]
    fn w_range(&self) -> std::ops::Range<usize> {
        self.w_off..self.w_off + self.d_in * self.d_out
    }

    #[inline]
    fn b_range(&self) -> std::ops::Range<usize> {
        self.b_off..self.b_off + self.d_out
    }
}

/// Native CPU implementation of [`Backend`].
pub struct NativeBackend {
    man: VariantManifest,
    layers: Vec<Layer>,
    /// Scratch-buffer pool shared by all five computations: intermediate
    /// activations/gradients reuse their allocations across steps.
    ws: WorkspacePool,
}

impl NativeBackend {
    /// Backend for the manifest's MLP architecture.
    pub fn new(man: VariantManifest) -> NativeBackend {
        let layers = param_offsets(&man)
            .into_iter()
            .map(|(w_off, (d_in, d_out), b_off, _)| Layer { w_off, d_in, d_out, b_off })
            .collect();
        log::debug!("native backend kernels dispatch to the {} ISA", kernel::active_isa());
        NativeBackend { man, layers, ws: WorkspacePool::new() }
    }

    /// The manifest this backend was built from.
    pub fn manifest(&self) -> &VariantManifest {
        &self.man
    }

    fn check_inputs(&self, params: &[f32], x: &MatF32, y: &[i32]) -> Result<()> {
        ensure!(
            params.len() == self.man.p_dim,
            "native: params has {} elements, want {}",
            params.len(),
            self.man.p_dim
        );
        ensure!(x.cols == self.man.d_in, "native: x cols {} != d_in {}", x.cols, self.man.d_in);
        ensure!(y.len() == x.rows, "native: y len {} != batch {}", y.len(), x.rows);
        for &label in y {
            ensure!(
                label >= 0 && (label as usize) < self.man.classes,
                "native: label {label} outside [0, {})",
                self.man.classes
            );
        }
        Ok(())
    }

    /// Full forward pass through a pool-borrowed workspace — the form the
    /// unit tests drive directly (the hot paths use [`Self::forward_ws`]
    /// inside their own workspace scope, so this has no non-test caller).
    #[cfg(test)]
    fn forward(&self, params: &[f32], x: &MatF32, y: &[i32]) -> Result<Forward> {
        self.ws.with(|ws| self.forward_ws(ws, params, x, y))
    }

    /// Full forward pass: hidden activations, softmax probabilities,
    /// per-example CE losses, 0/1 correctness — all backed by workspace
    /// buffers (return them with [`Workspace::recycle_mat`] when done).
    fn forward_ws(
        &self,
        ws: &mut Workspace,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<Forward> {
        self.check_inputs(params, x, y)?;
        let n_layers = self.layers.len();
        let mut hidden: Vec<MatF32> = Vec::with_capacity(n_layers.saturating_sub(1));
        for l in 0..n_layers - 1 {
            let layer = &self.layers[l];
            let input = if l == 0 { x } else { &hidden[l - 1] };
            let mut z = affine_ws(
                ws,
                input,
                &params[layer.w_range()],
                &params[layer.b_range()],
                layer.d_out,
            );
            for v in z.data.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            hidden.push(z);
        }
        let last = &self.layers[n_layers - 1];
        let input = if n_layers == 1 { x } else { &hidden[n_layers - 2] };
        let logits =
            affine_ws(ws, input, &params[last.w_range()], &params[last.b_range()], last.d_out);
        let (probs, ce, correct) = softmax_ce(ws, &logits, y);
        ws.recycle_mat(logits);
        Ok(Forward { hidden, probs, ce, correct })
    }

    /// Reverse pass: accumulate the flat parameter gradient from the logit
    /// gradient `dlogits` (which must already carry per-example scaling).
    /// The ReLU mask is fused into the backward matmul (masked elements
    /// are never computed), and the returned gradient buffer comes from
    /// the workspace — recycle it when it does not escape.
    fn backward(
        &self,
        ws: &mut Workspace,
        params: &[f32],
        x: &MatF32,
        hidden: &[MatF32],
        dlogits: MatF32,
    ) -> Vec<f32> {
        let mut grad = ws.buf(self.man.p_dim);
        let mut d = dlogits;
        for l in (0..self.layers.len()).rev() {
            let layer = self.layers[l];
            let input = if l == 0 { x } else { &hidden[l - 1] };
            kernel::accum_wgrad(&mut grad[layer.w_range()], input, &d, layer.d_out);
            kernel::accum_bgrad(&mut grad[layer.b_range()], &d);
            if l > 0 {
                let act = &hidden[l - 1];
                let mut dprev = ws.mat(d.rows, layer.d_in);
                kernel::add_matmul_nt_masked(
                    &mut dprev,
                    &d,
                    &params[layer.w_range()],
                    layer.d_out,
                    act,
                );
                ws.recycle_mat(std::mem::replace(&mut d, dprev));
            }
        }
        ws.recycle_mat(d);
        grad
    }
}

/// Forward-pass state retained for backprop.
struct Forward {
    /// Post-ReLU activations, one matrix per hidden layer.
    hidden: Vec<MatF32>,
    /// Softmax probabilities (batch × classes).
    probs: MatF32,
    /// Per-example cross-entropy.
    ce: Vec<f32>,
    /// Per-example 0/1 correctness under argmax prediction.
    correct: Vec<f32>,
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &self,
        params: &[f32],
        momentum: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
        lr: f32,
        wd: f32,
    ) -> Result<StepOut> {
        let m = x.rows;
        ensure!(gamma.len() == m, "native: gamma len {} != batch {m}", gamma.len());
        ensure!(
            momentum.len() == self.man.p_dim,
            "native: momentum len {} != p_dim {}",
            momentum.len(),
            self.man.p_dim
        );
        self.ws.with(|ws| {
            let fwd = self.forward_ws(ws, params, x, y)?;
            // dlogits_i = (gamma_i / m) · (p_i − onehot(y_i)) — gradient of
            // (1/m)·Σ gamma_i·ce_i, the weighted objective of model.py
            let mut dlogits = ws.mat_copy(&fwd.probs);
            for i in 0..m {
                let row = dlogits.row_mut(i);
                row[y[i] as usize] -= 1.0;
                let s = gamma[i] / m as f32;
                for v in row.iter_mut() {
                    *v *= s;
                }
            }
            let mut grad = self.backward(ws, params, x, &fwd.hidden, dlogits);
            for (g, &p) in grad.iter_mut().zip(params) {
                *g += wd * p;
            }
            let mu = self.man.momentum;
            let p_dim = params.len();
            let mut mom_new = vec![0.0f32; p_dim];
            let mut params_new = vec![0.0f32; p_dim];
            // element-wise, so the parallel split cannot change any result
            let grad_ref: &[f32] = &grad;
            Pool::gated(p_dim, SGD_PAR_MIN).for_rows2(
                &mut mom_new,
                1,
                &mut params_new,
                1,
                SGD_GRAIN,
                |off, mom_c, par_c| {
                    for k in 0..mom_c.len() {
                        let v_new = mu * momentum[off + k] + grad_ref[off + k];
                        mom_c[k] = v_new;
                        par_c[k] = params[off + k] - lr * v_new;
                    }
                },
            );
            let mean_loss = fwd
                .ce
                .iter()
                .zip(gamma)
                .map(|(&c, &g)| (c * g) as f64)
                .sum::<f64>() as f32
                / m as f32;
            // recycle the scratch (ce escapes as per_ex_loss)
            ws.recycle(grad);
            let Forward { hidden, probs, ce, correct } = fwd;
            for h in hidden {
                ws.recycle_mat(h);
            }
            ws.recycle_mat(probs);
            ws.recycle(correct);
            Ok(StepOut { params: params_new, momentum: mom_new, mean_loss, per_ex_loss: ce })
        })
    }

    fn grad_embed(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(MatF32, MatF32, Vec<f32>)> {
        self.ws.with(|ws| {
            let mut fwd = self.forward_ws(ws, params, x, y)?;
            let mut g = fwd.probs;
            for (i, &label) in y.iter().enumerate() {
                g.row_mut(i)[label as usize] -= 1.0;
            }
            // g, act and ce escape the workspace; the rest is recycled
            let act = fwd.hidden.pop().expect("at least one hidden layer");
            for h in fwd.hidden {
                ws.recycle_mat(h);
            }
            ws.recycle(fwd.correct);
            Ok((g, act, fwd.ce))
        })
    }

    fn eval_chunk(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        self.ws.with(|ws| {
            let fwd = self.forward_ws(ws, params, x, y)?;
            let sum_loss = fwd.ce.iter().map(|&v| v as f64).sum::<f64>() as f32;
            let n_correct = fwd.correct.iter().map(|&v| v as f64).sum::<f64>() as f32;
            for h in fwd.hidden {
                ws.recycle_mat(h);
            }
            ws.recycle_mat(fwd.probs);
            Ok((sum_loss, n_correct, fwd.ce, fwd.correct))
        })
    }

    fn hess_probe(
        &self,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
        z: &[f32],
    ) -> Result<ProbeOut> {
        ensure!(
            z.len() == self.man.p_dim,
            "native: z len {} != p_dim {}",
            z.len(),
            self.man.p_dim
        );
        let r = x.rows;
        let s = 1.0 / r as f32;
        let n_layers = self.layers.len();
        self.ws.with(|ws| {
            let fwd = self.forward_ws(ws, params, x, y)?;

            // --- tangent forward: d/dε of every activation at params + ε·z ---
            // t(z_l) = t(h_{l−1})·W_l + h_{l−1}·tW_l + tb_l ; t(h_l) = 1[h_l>0]∘t(z_l)
            let mut thidden: Vec<MatF32> = Vec::with_capacity(n_layers - 1);
            for l in 0..n_layers - 1 {
                let layer = &self.layers[l];
                let input = if l == 0 { x } else { &fwd.hidden[l - 1] };
                let mut tz =
                    affine_ws(ws, input, &z[layer.w_range()], &z[layer.b_range()], layer.d_out);
                if l > 0 {
                    kernel::add_matmul(
                        &mut tz,
                        &thidden[l - 1],
                        &params[layer.w_range()],
                        layer.d_out,
                    );
                }
                kernel::relu_mask(&mut tz, &fwd.hidden[l]);
                thidden.push(tz);
            }
            let last = &self.layers[n_layers - 1];
            let input = if n_layers == 1 { x } else { &fwd.hidden[n_layers - 2] };
            let mut tlogits =
                affine_ws(ws, input, &z[last.w_range()], &z[last.b_range()], last.d_out);
            if n_layers > 1 {
                kernel::add_matmul(
                    &mut tlogits,
                    &thidden[n_layers - 2],
                    &params[last.w_range()],
                    last.d_out,
                );
            }

            // --- logit gradient and its tangent ---
            // δ_i = s·(p_i − y_i) ; t(δ_i) = s·t(p_i) with the softmax Jacobian
            // t(p) = p ∘ (t(logit) − ⟨p, t(logit)⟩)
            let classes = self.man.classes;
            let mut d = ws.mat_copy(&fwd.probs);
            for (i, &label) in y.iter().enumerate() {
                let row = d.row_mut(i);
                row[label as usize] -= 1.0;
                for v in row.iter_mut() {
                    *v *= s;
                }
            }
            let mut td = ws.mat(r, classes);
            for i in 0..r {
                let p = fwd.probs.row(i);
                let tl = tlogits.row(i);
                let dot: f32 = p.iter().zip(tl).map(|(&a, &b)| a * b).sum();
                for ((tv, &pv), &tlv) in td.row_mut(i).iter_mut().zip(p).zip(tl) {
                    *tv = s * pv * (tlv - dot);
                }
            }
            ws.recycle_mat(tlogits);

            // --- primal + tangent backward ---
            // t(gW_l) = t(h_{l−1})ᵀ·δ_l + h_{l−1}ᵀ·t(δ_l)
            // t(δ_{l−1}) = (t(δ_l)·W_lᵀ + δ_l·tW_lᵀ) ∘ 1[h_{l−1}>0]
            // (the mask is fused into the backward matmuls: masked elements
            // of δ_{l−1} and t(δ_{l−1}) are never computed)
            let mut grad = vec![0.0f32; self.man.p_dim];
            let mut hz = vec![0.0f32; self.man.p_dim];
            for l in (0..n_layers).rev() {
                let layer = self.layers[l];
                let input = if l == 0 { x } else { &fwd.hidden[l - 1] };
                kernel::accum_wgrad(&mut grad[layer.w_range()], input, &d, layer.d_out);
                kernel::accum_wgrad(&mut hz[layer.w_range()], input, &td, layer.d_out);
                if l > 0 {
                    kernel::accum_wgrad(&mut hz[layer.w_range()], &thidden[l - 1], &d, layer.d_out);
                }
                kernel::accum_bgrad(&mut grad[layer.b_range()], &d);
                kernel::accum_bgrad(&mut hz[layer.b_range()], &td);
                if l > 0 {
                    let w = &params[layer.w_range()];
                    let tw = &z[layer.w_range()];
                    let act = &fwd.hidden[l - 1];
                    let mut dprev = ws.mat(r, layer.d_in);
                    kernel::add_matmul_nt_masked(&mut dprev, &d, w, layer.d_out, act);
                    let mut tdprev = ws.mat(r, layer.d_in);
                    kernel::add_matmul_nt_masked(&mut tdprev, &td, w, layer.d_out, act);
                    kernel::add_matmul_nt_masked(&mut tdprev, &d, tw, layer.d_out, act);
                    ws.recycle_mat(std::mem::replace(&mut d, dprev));
                    ws.recycle_mat(std::mem::replace(&mut td, tdprev));
                }
            }
            let mean_loss = fwd.ce.iter().map(|&v| v as f64).sum::<f64>() as f32 / r as f32;
            ws.recycle_mat(d);
            ws.recycle_mat(td);
            for t in thidden {
                ws.recycle_mat(t);
            }
            let Forward { hidden, probs, ce, correct } = fwd;
            for h in hidden {
                ws.recycle_mat(h);
            }
            ws.recycle_mat(probs);
            ws.recycle(ce);
            ws.recycle(correct);
            Ok(ProbeOut { hz, grad, mean_loss })
        })
    }

    fn select_greedy(&self, g: &MatF32, a: &MatF32) -> Result<(Vec<usize>, Vec<f32>)> {
        ensure!(g.rows == a.rows, "native: g rows {} != act rows {}", g.rows, a.rows);
        let m = self.man.m.min(g.rows);
        let sel = facility::facility_location_prod(a, g, m);
        Ok((sel.idx, sel.gamma))
    }
}

// ------------------------------------------------------------ dense kernels
//
// The matmul microkernels live in `crate::kernel`; what remains here is
// the bias-broadcast affine wrapper and the softmax head, both drawing
// their outputs from the call's workspace.

/// `x·W + b` with `W` row-major `(d_in × d_out)`, `b` broadcast into a
/// workspace-backed output fed to the register-tiled matmul.
fn affine_ws(ws: &mut Workspace, x: &MatF32, w: &[f32], b: &[f32], d_out: usize) -> MatF32 {
    let mut out = ws.mat_rows(x.rows, b);
    kernel::add_matmul(&mut out, x, w, d_out);
    out
}

/// Row-wise stable softmax + cross-entropy + argmax correctness.
/// Row-parallel: all three outputs are partitioned on the same row
/// boundaries, so every row is computed exactly as in the serial loop.
fn softmax_ce(ws: &mut Workspace, logits: &MatF32, y: &[i32]) -> (MatF32, Vec<f32>, Vec<f32>) {
    let rows = logits.rows;
    let cols = logits.cols;
    let mut probs = ws.mat(rows, cols);
    let mut ce = ws.buf(rows);
    let mut correct = ws.buf(rows);
    // exp-heavy rows: weigh each element ~32 MACs for the spawn gate
    let pool = Pool::gated(rows * cols * 32, PAR_MIN_OPS);
    pool.for_rows3(
        &mut probs.data,
        cols,
        &mut ce,
        1,
        &mut correct,
        1,
        ROW_GRAIN,
        |row0, probs_rows, ce_rows, correct_rows| {
            for i in 0..ce_rows.len() {
                let row = logits.row(row0 + i);
                let mut maxv = f32::NEG_INFINITY;
                let mut argmax = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > maxv {
                        maxv = v;
                        argmax = j;
                    }
                }
                let pi = &mut probs_rows[i * cols..(i + 1) * cols];
                let mut sum = 0.0f32;
                for (p, &v) in pi.iter_mut().zip(row) {
                    let e = (v - maxv).exp();
                    *p = e;
                    sum += e;
                }
                let inv = 1.0 / sum;
                for p in pi.iter_mut() {
                    *p *= inv;
                }
                let yi = y[row0 + i] as usize;
                // −log softmax(y) = ln Σe^{v−max} − (v_y − max), stable
                ce_rows[i] = sum.ln() - (row[yi] - maxv);
                correct_rows[i] = if argmax == yi { 1.0 } else { 0.0 };
            }
        },
    );
    (probs, ce, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::manifest::ModelSpec;
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn tiny_backend() -> NativeBackend {
        let spec = ModelSpec {
            name: "tiny",
            d_in: 4,
            hidden: vec![8],
            classes: 3,
            m: 4,
            r: 8,
            eval_chunk: 8,
            momentum: 0.9,
        };
        NativeBackend::new(VariantManifest::from_spec(&spec).unwrap())
    }

    fn random_batch(bk: &NativeBackend, n: usize, seed: u64) -> (Vec<f32>, MatF32, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let params = init_params(bk.manifest(), &mut rng);
        let mut x = MatF32::zeros(n, bk.manifest().d_in);
        for v in x.data.iter_mut() {
            *v = rng.normal();
        }
        let y: Vec<i32> =
            (0..n).map(|_| rng.gen_range(bk.manifest().classes) as i32).collect();
        (params, x, y)
    }

    /// grad via train_step: with zero momentum and lr=0, mom_out = grad.
    fn grad_of(
        bk: &NativeBackend,
        params: &[f32],
        x: &MatF32,
        y: &[i32],
        gamma: &[f32],
    ) -> Vec<f32> {
        let zero = vec![0.0f32; params.len()];
        bk.train_step(params, &zero, x, y, gamma, 0.0, 0.0).unwrap().momentum
    }

    #[test]
    fn hand_computed_single_example_gradient() {
        // 1 → relu(1 unit) → 2 classes, all weights explicit:
        //   h = relu(2·1+0) = 2, logits = (2, −2), p = softmax
        //   δ = p − (1,0);  gW2 = h·δ;  gb2 = δ
        //   dh = δ·W2ᵀ = (p0−1) − p1;  gW1 = x·dh;  gb1 = dh
        let spec = ModelSpec {
            name: "scalar",
            d_in: 1,
            hidden: vec![1],
            classes: 2,
            m: 1,
            r: 1,
            eval_chunk: 1,
            momentum: 0.9,
        };
        let bk = NativeBackend::new(VariantManifest::from_spec(&spec).unwrap());
        let params = vec![1.0f32, 0.0, 1.0, -1.0, 0.0, 0.0];
        let x = MatF32::from_vec(1, 1, vec![2.0]).unwrap();
        let y = vec![0i32];
        let p0 = 1.0f32 / (1.0 + (-4.0f32).exp());
        let p1 = 1.0 - p0;
        let dh = (p0 - 1.0) - p1;
        let want = [2.0 * dh, dh, 2.0 * (p0 - 1.0), 2.0 * p1, p0 - 1.0, p1];
        let got = grad_of(&bk, &params, &x, &y, &[1.0]);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-5, "grad[{i}] = {g}, want {w}");
        }
        // gamma scales the gradient linearly
        let got2 = grad_of(&bk, &params, &x, &y, &[2.0]);
        for (&g2, &g1) in got2.iter().zip(&got) {
            assert!((g2 - 2.0 * g1).abs() < 1e-5);
        }
        // loss bookkeeping: ce = −ln p0, mean_loss = γ·ce/m
        let zero = vec![0.0f32; 6];
        let out = bk.train_step(&params, &zero, &x, &y, &[1.0], 0.0, 0.0).unwrap();
        assert!((out.per_ex_loss[0] - (-p0.ln())).abs() < 1e-5);
        assert!((out.mean_loss - (-p0.ln())).abs() < 1e-5);
    }

    #[test]
    fn gamma_weighted_gradient_is_linear_combination() {
        let bk = tiny_backend();
        let m = 4;
        let (params, x, y) = random_batch(&bk, m, 11);
        let gamma = [0.5f32, 2.0, 1.0, 0.25];
        let combined = grad_of(&bk, &params, &x, &y, &gamma);
        // per-example gradients: gamma = m·e_i makes grad = ∇ce_i
        let mut want = vec![0.0f64; params.len()];
        for i in 0..m {
            let mut onehot = vec![0.0f32; m];
            onehot[i] = m as f32;
            let gi = grad_of(&bk, &params, &x, &y, &onehot);
            for (w, &v) in want.iter_mut().zip(&gi) {
                *w += (gamma[i] / m as f32) as f64 * v as f64;
            }
        }
        for (k, (&g, &w)) in combined.iter().zip(&want).enumerate() {
            assert!(
                (g as f64 - w).abs() < 1e-4 * (1.0 + w.abs()),
                "grad[{k}] = {g}, want {w}"
            );
        }
    }

    #[test]
    fn train_step_decreases_loss_on_fixed_batch() {
        let bk = tiny_backend();
        let (mut params, x, y) = random_batch(&bk, 4, 12);
        let mut mom = vec![0.0f32; params.len()];
        let gamma = [1.0f32; 4];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let out = bk.train_step(&params, &mom, &x, &y, &gamma, 0.1, 0.0).unwrap();
            first.get_or_insert(out.mean_loss);
            last = out.mean_loss;
            params = out.params;
            mom = out.momentum;
        }
        assert!(last < 0.5 * first.unwrap(), "{last} vs {first:?}");
    }

    #[test]
    fn hess_probe_grad_matches_train_step_gradient() {
        let bk = tiny_backend();
        let r = 8;
        let (params, x, y) = random_batch(&bk, r, 13);
        let probe = bk.hess_probe(&params, &x, &y, &vec![0.0; params.len()]).unwrap();
        // mean-of-r gradient == train_step gradient with unit gamma on the
        // same r examples
        let g = grad_of(&bk, &params, &x, &y, &vec![1.0; r]);
        for (i, (&a, &b)) in probe.grad.iter().zip(&g).enumerate() {
            assert!((a - b).abs() < 1e-5, "grad[{i}]: {a} vs {b}");
        }
        assert!(stats::norm2(&probe.hz) < 1e-7, "Hz must vanish for z = 0");
        assert!(probe.mean_loss > 0.0);
    }

    #[test]
    fn hess_probe_matches_finite_difference_hvp() {
        let bk = tiny_backend();
        let r = 8;
        let (params, x, y) = random_batch(&bk, r, 14);
        let mut rng = Rng::new(15);
        let mut z = vec![0.0f32; params.len()];
        rng.rademacher_fill(&mut z);
        let hz = bk.hess_probe(&params, &x, &y, &z).unwrap().hz;
        // Central difference of the gradient along z. The loss is only
        // piecewise-smooth (ReLU), so shrink eps until the activation
        // pattern is identical at w, w+eps·z and w−eps·z — then the FD
        // secant and the analytic HVP live on the same smooth piece.
        let relu_mask_at = |p: &[f32]| -> Vec<bool> {
            let fwd = bk.forward(p, &x, &y).unwrap();
            fwd.hidden.iter().flat_map(|h| h.data.iter().map(|&v| v > 0.0)).collect()
        };
        let base_mask = relu_mask_at(&params);
        let mut eps = 1e-2f32;
        let (plus, minus) = loop {
            let plus: Vec<f32> =
                params.iter().zip(&z).map(|(&p, &zi)| p + eps * zi).collect();
            let minus: Vec<f32> =
                params.iter().zip(&z).map(|(&p, &zi)| p - eps * zi).collect();
            if eps < 2e-4
                || (relu_mask_at(&plus) == base_mask && relu_mask_at(&minus) == base_mask)
            {
                break (plus, minus);
            }
            eps *= 0.5;
        };
        let zero = vec![0.0f32; params.len()];
        let gp = bk.hess_probe(&plus, &x, &y, &zero).unwrap().grad;
        let gm = bk.hess_probe(&minus, &x, &y, &zero).unwrap().grad;
        let fd: Vec<f32> =
            gp.iter().zip(&gm).map(|(&a, &b)| (a - b) / (2.0 * eps)).collect();
        let err = stats::norm2(&stats::sub(&fd, &hz));
        let scale = stats::norm2(&hz).max(1e-6);
        assert!(err / scale < 0.05, "relative HVP error {} (|Hz| = {scale})", err / scale);
    }

    #[test]
    fn hessian_vector_products_are_symmetric() {
        let bk = tiny_backend();
        let r = 8;
        let (params, x, y) = random_batch(&bk, r, 16);
        let mut rng = Rng::new(17);
        let mut z1 = vec![0.0f32; params.len()];
        let mut z2 = vec![0.0f32; params.len()];
        rng.rademacher_fill(&mut z1);
        rng.rademacher_fill(&mut z2);
        let hz1 = bk.hess_probe(&params, &x, &y, &z1).unwrap().hz;
        let hz2 = bk.hess_probe(&params, &x, &y, &z2).unwrap().hz;
        let a: f64 = z2.iter().zip(&hz1).map(|(&u, &v)| (u * v) as f64).sum();
        let b: f64 = z1.iter().zip(&hz2).map(|(&u, &v)| (u * v) as f64).sum();
        let scale = a.abs().max(b.abs()).max(1e-6);
        assert!((a - b).abs() / scale < 1e-3, "z2ᵀHz1 = {a} vs z1ᵀHz2 = {b}");
    }

    #[test]
    fn grad_embed_and_eval_are_consistent() {
        let bk = tiny_backend();
        let (params, x, y) = random_batch(&bk, 8, 18);
        let (g, act, losses) = bk.grad_embed(&params, &x, &y).unwrap();
        assert_eq!(g.rows, 8);
        assert_eq!(g.cols, 3);
        assert_eq!(act.cols, 8);
        // softmax-gradient rows (p − y) sum to ~0
        for i in 0..g.rows {
            let s: f32 = g.row(i).iter().sum();
            assert!(s.abs() < 1e-5, "row {i} sums to {s}");
        }
        // same losses through the eval path
        let (sum_loss, n_correct, ce, correct) = bk.eval_chunk(&params, &x, &y).unwrap();
        for i in 0..8 {
            assert!((losses[i] - ce[i]).abs() < 1e-6);
        }
        let manual: f32 = ce.iter().sum();
        assert!((sum_loss - manual).abs() < 1e-4);
        assert_eq!(n_correct, correct.iter().sum::<f32>());
        assert!(correct.iter().all(|&c| c == 0.0 || c == 1.0));
    }

    #[test]
    fn select_greedy_delegates_to_facility_location() {
        let bk = tiny_backend();
        let mut rng = Rng::new(19);
        let r = 8;
        let mut g = MatF32::zeros(r, 3);
        let mut a = MatF32::zeros(r, 8);
        for v in g.data.iter_mut() {
            *v = rng.normal();
        }
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let (idx, w) = bk.select_greedy(&g, &a).unwrap();
        let host = facility::facility_location_prod(&a, &g, bk.manifest().m);
        assert_eq!(idx, host.idx);
        assert_eq!(w, host.gamma);
        assert_eq!(w.iter().sum::<f32>(), r as f32);
    }

    #[test]
    fn parallel_kernels_bitwise_deterministic_across_thread_counts() {
        use crate::util::pool;
        // sized so the row-parallel kernels actually engage (first-layer
        // work 64·128·160 ≈ 1.3M MACs, above the spawn gate)
        let spec = ModelSpec {
            name: "par",
            d_in: 128,
            hidden: vec![160],
            classes: 10,
            m: 64,
            r: 64,
            eval_chunk: 64,
            momentum: 0.9,
        };
        let bk = NativeBackend::new(VariantManifest::from_spec(&spec).unwrap());
        let (params, x, y) = random_batch(&bk, 64, 99);
        let gamma = vec![1.0f32; 64];
        let mom = vec![0.01f32; params.len()];
        let mut z = vec![0.0f32; params.len()];
        let mut zrng = Rng::new(5);
        zrng.rademacher_fill(&mut z);
        let run = |t: usize| {
            pool::with_threads(t, || {
                let s = bk.train_step(&params, &mom, &x, &y, &gamma, 0.05, 1e-4).unwrap();
                let (g, a, l) = bk.grad_embed(&params, &x, &y).unwrap();
                let p = bk.hess_probe(&params, &x, &y, &z).unwrap();
                (s.params, s.momentum, s.per_ex_loss, g, a, l, p.hz, p.grad)
            })
        };
        let base = run(1);
        for t in [2, 4] {
            assert_eq!(base, run(t), "thread count {t} changed backend results");
        }
    }

    #[test]
    fn workspace_reuse_is_run_to_run_deterministic() {
        // the workspace pool must never change results: repeated calls
        // (first call allocates, later calls reuse buffers) and
        // interleaved ops must be bitwise-identical
        let bk = tiny_backend();
        let (params, x, y) = random_batch(&bk, 8, 21);
        let gamma = [1.0f32; 8];
        let mom = vec![0.01f32; params.len()];
        let mut z = vec![0.0f32; params.len()];
        let mut zrng = Rng::new(3);
        zrng.rademacher_fill(&mut z);
        let run = || {
            let s = bk.train_step(&params, &mom, &x, &y, &gamma, 0.05, 1e-4).unwrap();
            let (g, a, l) = bk.grad_embed(&params, &x, &y).unwrap();
            let p = bk.hess_probe(&params, &x, &y, &z).unwrap();
            let e = bk.eval_chunk(&params, &x, &y).unwrap();
            (s.params, s.momentum, s.per_ex_loss, g, a, l, p.hz, p.grad, e)
        };
        let first = run();
        for rep in 0..3 {
            assert_eq!(first, run(), "workspace reuse changed results on rep {rep}");
        }
    }

    #[test]
    fn rejects_bad_shapes_and_labels() {
        let bk = tiny_backend();
        let (params, x, _) = random_batch(&bk, 4, 20);
        let bad_y = [0i32, 1, 99, 0];
        assert!(bk.eval_chunk(&params, &x, &bad_y).is_err());
        let good_y = [0i32; 4];
        let short = [0.0f32; 3];
        assert!(bk.eval_chunk(&short, &x, &good_y).is_err());
        let zero = vec![0.0f32; params.len()];
        let bad_gamma = [1.0f32; 3];
        assert!(bk.train_step(&params, &zero, &x, &good_y, &bad_gamma, 0.1, 0.0).is_err());
    }
}
