//! `crest` — CLI entrypoint for the CREST reproduction.
//!
//! Subcommands:
//!   train    run one method on one variant and print the run report
//!   compare  run several methods on one variant (Table-1-style rows)
//!   sweep    run a resumable (variant × method × seed × budget) grid
//!            with per-cell checkpoints and mean±std aggregate tables
//!   bench-diff  gate fresh bench records against a committed baseline
//!   inspect  print a variant's computation interface and active backend
//!   gen-data generate a proxy dataset and write the binary cache
//!   pack     generate a proxy dataset as a sharded pack (mmap store)
//!   lint     run the contract checker over the crate's own sources
//!
//! Every subcommand flows through one shared pre-dispatch setup path
//! (`dispatch`): the common `--artifacts`/`--threads`/`--data-store`
//! flags are registered and applied there exactly once, so a new
//! subcommand can never silently miss them. Method names (`--method`/`--methods`) are
//! resolved against the `api::MethodRegistry`, so registered methods —
//! builtin or custom — are uniformly available everywhere.
//!
//! Runs on the native CPU backend by default (no artifacts required); the
//! `--artifacts` root is consulted for manifest.json shape overrides.
//!
//! Example:
//!   crest train --variant cifar10-proxy --method crest --seed 1
//!   crest compare --variant cifar10-proxy --methods crest,random,craig
//!   crest sweep --variant smoke --methods crest,random --seeds 1,2 --out sweep.json

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crest::api::{Experiment, Method, MethodRegistry, SelectionStrategy};
use crest::bench_util;
use crest::data::{self, cache, shard, synth, SynthSpec};
use crest::metrics::relative_error_pct;
use crest::report::{aggregate_markdown, Table};
use crest::runtime::Runtime;
use crest::sweep::{self, SweepGrid, SweepSpec};
use crest::util::cli::{Cli, Parsed};
use crest::util::json::Json;
use crest::util::logging;
use crest::util::pool;

/// Everything a subcommand handler receives from the shared pre-dispatch
/// setup: parsed flags plus the resolved artifact root (`--threads` has
/// already been applied to the global pool).
struct Ctx {
    args: Parsed,
    artifacts: PathBuf,
}

type Handler = fn(&Ctx) -> Result<()>;

/// One subcommand: its per-command flags and its handler. The common
/// flags are appended by `dispatch`, never per command.
struct Command {
    name: &'static str,
    about: &'static str,
    flags: fn(Cli) -> Cli,
    run: Handler,
}

const COMMANDS: &[Command] = &[
    Command {
        name: "train",
        about: "run one method on one variant",
        flags: train_flags,
        run: cmd_train,
    },
    Command {
        name: "compare",
        about: "run several methods on one variant",
        flags: compare_flags,
        run: cmd_compare,
    },
    Command {
        name: "sweep",
        about: "run a resumable (variant × method × seed × budget) grid",
        flags: sweep_flags,
        run: cmd_sweep,
    },
    Command {
        name: "bench-diff",
        about: "diff fresh bench records against a committed baseline",
        flags: bench_diff_flags,
        run: cmd_bench_diff,
    },
    Command {
        name: "inspect",
        about: "print the compiled artifact interface",
        flags: inspect_flags,
        run: cmd_inspect,
    },
    Command {
        name: "gen-data",
        about: "generate a proxy dataset cache",
        flags: gen_data_flags,
        run: cmd_gen_data,
    },
    Command {
        name: "pack",
        about: "generate a proxy dataset as a sharded on-disk pack",
        flags: pack_flags,
        run: cmd_pack,
    },
    Command {
        name: "lint",
        about: "run the contract checker over the crate's own sources",
        flags: lint_flags,
        run: cmd_lint,
    },
];

/// The one shared pre-dispatch setup path: register the common flags,
/// parse, apply `--threads` to the global pool, resolve the artifact
/// root, and hand the context to the subcommand.
fn dispatch(cmd: &Command, args: &[String]) -> Result<()> {
    let cli = (cmd.flags)(Cli::new(&format!("crest {}", cmd.name), cmd.about))
        .opt("artifacts", "artifacts", "artifact root directory")
        .opt_maybe("threads", "pool worker threads (default: CREST_THREADS or all cores)")
        .opt_maybe("data-store", "feature store: mem|mmap (default: CREST_DATA_STORE or mem)");
    let p = cli.parse(args)?;
    if let Some(t) = p.get("threads") {
        pool::set_threads(t.parse::<usize>().context("parsing --threads")?);
    }
    if let Some(s) = p.get("data-store") {
        data::set_default_store(data::StoreKind::parse(s)?);
    }
    let root = p.str("artifacts");
    let artifacts =
        if root.is_empty() { PathBuf::from("artifacts") } else { PathBuf::from(root) };
    (cmd.run)(&Ctx { args: p, artifacts })
}

fn main() -> Result<()> {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let names = || COMMANDS.iter().map(|c| c.name).collect::<Vec<_>>().join("|");
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("usage: crest <{}> [flags] (--help per command)", names());
            std::process::exit(2);
        }
    };
    match COMMANDS.iter().find(|c| c.name == cmd) {
        Some(c) => dispatch(c, &rest),
        None => bail!("unknown command {cmd:?} ({})", names()),
    }
}

// ------------------------------------------------------------------ train

fn train_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "model/dataset variant")
        // generated from the method registry so the help cannot drift
        // from what Method::parse accepts (see the registry round-trip
        // test); custom-registered methods appear here automatically
        .opt("method", "crest", MethodRegistry::help_names())
        // generated from the strategy table the same way, for the same
        // reason: parse and help share one source
        .opt("selection", "exact", SelectionStrategy::help_names())
        .opt("seed", "1", "experiment seed")
        .opt("budget", "0.1", "training budget as a fraction of full")
        .opt("epochs-full", "60", "epochs of the full reference run")
        .opt_maybe("out", "write the run report JSON here")
        .opt_maybe("lr", "override the base learning rate")
        .opt_maybe("tau", "override the ρ threshold τ")
        .opt_maybe("alpha", "override the exclusion threshold α")
        .flag("no-exclude", "disable learned-example exclusion")
        .flag("first-order", "use a first-order loss model (CREST-FIRST)")
        .flag("no-smooth", "disable EMA smoothing of grad/curvature")
        .flag("compiled-selection", "route greedy selection through the backend")
}

fn cmd_train(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let lr: Option<f32> = p.get("lr").map(|l| l.parse()).transpose()?;
    let tau: Option<f32> = p.get("tau").map(|t| t.parse()).transpose()?;
    let alpha: Option<f32> = p.get("alpha").map(|a| a.parse()).transpose()?;
    let compiled = p.bool("compiled-selection");
    let no_exclude = p.bool("no-exclude");
    let first_order = p.bool("first-order");
    let no_smooth = p.bool("no-smooth");

    let report = Experiment::builder()
        .variant(p.str("variant"))
        .method(p.str("method"))
        .selection(SelectionStrategy::parse(&p.str("selection"))?)
        .seed(p.u64("seed")?)
        .budget_frac(p.f32("budget")?)
        .epochs_full(p.usize("epochs-full")?)
        .artifact_root(&ctx.artifacts)
        .configure(move |cfg| {
            cfg.compiled_selection = compiled;
            if let Some(l) = lr {
                cfg.base_lr = l;
            }
            if let Some(t) = tau {
                cfg.tau = t;
            }
            if let Some(a) = alpha {
                cfg.alpha = a;
            }
            if no_exclude {
                cfg.crest.exclude = false;
            }
            if first_order {
                cfg.crest.second_order = false;
            }
            if no_smooth {
                cfg.crest.smooth = false;
            }
        })
        .build()?
        .run()?;

    println!(
        "method={} variant={} acc={:.4} loss={:.4} steps={} updates={} excluded={} total={:.2}s (sel {:.2}s, train {:.2}s)",
        report.method,
        report.variant,
        report.final_test_acc,
        report.final_test_loss,
        report.steps,
        report.n_selection_updates,
        report.n_excluded,
        report.total_secs,
        report.selection_secs,
        report.train_secs,
    );
    if let Some(out) = p.get("out") {
        std::fs::write(out, report.to_json().to_string_pretty())?;
        println!("report written to {out}");
    }
    Ok(())
}

// ---------------------------------------------------------------- compare

fn compare_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt(
            "methods",
            "full,random,crest,craig",
            format!("comma-separated method list ({})", MethodRegistry::help_names()),
        )
        .opt("selection", "exact", SelectionStrategy::help_names())
        .opt("seed", "1", "experiment seed")
        .opt("budget", "0.1", "training budget fraction")
        .opt("epochs-full", "60", "epochs of the full reference run")
}

fn cmd_compare(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let variant = p.str("variant");
    let seed = p.u64("seed")?;
    let selection = SelectionStrategy::parse(&p.str("selection"))?;
    // one corpus shared by every method row (same (variant, seed) data),
    // prepared through the selected feature store
    let splits = data::prepare_splits(&variant, seed)?;

    let mut full_acc = None;
    let mut table = Table::new(&["method", "test acc", "rel err %", "updates", "time (s)"]);
    for name in p.str("methods").split(',') {
        let method = Method::parse(name.trim())?;
        let rep = Experiment::builder()
            .variant(&variant)
            .with_method(method)
            .selection(selection)
            .seed(seed)
            .budget_frac(p.f32("budget")?)
            .epochs_full(p.usize("epochs-full")?)
            .artifact_root(&ctx.artifacts)
            .splits(splits.clone())
            .build()?
            .run()?;
        if method.is_reference() {
            full_acc = Some(rep.final_test_acc);
        }
        let rel = full_acc
            .map(|fa| relative_error_pct(rep.final_test_acc * 100.0, fa * 100.0))
            .map(|r| format!("{r:.2}"))
            .unwrap_or_else(|| "-".to_string());
        table.row(&[
            rep.method.clone(),
            format!("{:.4}", rep.final_test_acc),
            rel,
            format!("{}", rep.n_selection_updates),
            format!("{:.2}", rep.total_secs),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

// ------------------------------------------------------------------ sweep

fn sweep_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "comma-separated variant list")
        .opt(
            "methods",
            "full,random,crest",
            format!("comma-separated method list ({})", MethodRegistry::help_names()),
        )
        .opt("selection", "exact", SelectionStrategy::help_names())
        .opt("seeds", "1,2", "comma-separated seed list (the mean±std axis)")
        .opt("budgets", "0.1", "comma-separated budget fractions")
        .opt("epochs-full", "60", "epochs of the full reference run")
        .opt(
            "checkpoint-dir",
            "sweep-ckpt",
            "per-cell checkpoint directory (resume skips completed cells)",
        )
        .flag("no-checkpoint", "disable the on-disk checkpoint store")
        .opt_maybe("jobs", "cells scheduled concurrently (default: auto from pool worker count)")
        .opt_maybe("out", "append the aggregate rows to this JSON trajectory file")
}

fn cmd_sweep(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let grid = SweepGrid {
        variants: sweep::grid::parse_variants(&p.str("variant"))?,
        methods: sweep::grid::parse_methods(&p.str("methods"))?,
        seeds: sweep::grid::parse_seeds(&p.str("seeds"))?,
        budgets: sweep::grid::parse_budgets(&p.str("budgets"))?,
    };
    let mut spec = SweepSpec::new(grid, p.usize("epochs-full")?);
    spec.selection = SelectionStrategy::parse(&p.str("selection"))?;
    spec.artifact_root = ctx.artifacts.clone();
    if !p.bool("no-checkpoint") {
        spec.checkpoint_dir = Some(PathBuf::from(p.str("checkpoint-dir")));
    }
    if let Some(j) = p.get("jobs") {
        spec.jobs = j.parse().context("parsing --jobs")?;
    }

    let outcome = sweep::run_collect(&spec)?;
    // extras appear only when nonzero, keeping the common-case summary
    // line stable for scripts that grep it
    let mut extra = String::new();
    if outcome.recovered > 0 {
        extra.push_str(&format!(", {} recovered from corrupt checkpoints", outcome.recovered));
    }
    if !outcome.failed.is_empty() {
        extra.push_str(&format!(", {} failed", outcome.failed.len()));
    }
    println!(
        "# sweep: {} cells ({} executed, {} restored from checkpoints{extra})",
        outcome.cells.len() + outcome.failed.len(),
        outcome.n_executed(),
        outcome.n_restored()
    );
    for f in &outcome.failed {
        eprintln!("# failed cell {}: {}", f.key.label(), f.error);
    }
    print!("{}", aggregate_markdown(&outcome.rows));
    if let Some(out) = p.get("out") {
        let records: Vec<Json> = outcome.rows.iter().map(|r| r.to_json()).collect();
        let n = bench_util::append_json_records(Path::new(out), records)?;
        println!("appended {n} aggregate rows to {out}");
    }
    // the partial table above still helps diagnosis, but the exit code
    // must say the grid is incomplete
    outcome.ensure_complete()
}

// ------------------------------------------------------------- bench-diff

fn bench_diff_flags(c: Cli) -> Cli {
    c.opt("baseline", "BENCH_perf.json", "committed baseline trajectory (JSON array)")
        .opt("fresh", "fresh.json", "freshly measured records to gate")
        .opt("factor", "2.0", "allowed p50 regression factor (fresh ≤ factor × baseline)")
        .flag("require-baseline", "fail if the baseline has no gateable records (still the [] seed)")
}

fn cmd_bench_diff(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let factor = p.f32("factor")? as f64;
    if p.bool("require-baseline") {
        let n = bench_util::baseline_records(Path::new(&p.str("baseline")))?;
        if n == 0 {
            bail!(
                "bench-diff --require-baseline: {} has no gateable records — \
                 commit a measured baseline (see PERF.md)",
                p.str("baseline")
            );
        }
    }
    let out = bench_util::diff_baseline(
        Path::new(&p.str("baseline")),
        Path::new(&p.str("fresh")),
        factor,
    )?;
    print!("{}", out.report);
    if !out.regressions.is_empty() {
        bail!(
            "{} bench regression(s) beyond {factor}x the committed baseline",
            out.regressions.len()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- inspect

fn inspect_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "model/dataset variant")
}

fn cmd_inspect(ctx: &Ctx) -> Result<()> {
    let rt = Runtime::load(&ctx.artifacts, &ctx.args.str("variant"))?;
    print!("{}", rt.describe());
    Ok(())
}

// --------------------------------------------------------------- gen-data

fn gen_data_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "dataset variant")
        .opt("seed", "1", "generation seed")
        .opt("out", "/tmp/crest-data", "output directory")
}

fn cmd_gen_data(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let variant = p.str("variant");
    let spec = SynthSpec::preset(&variant, p.u64("seed")?).context("no preset")?;
    let splits = data::generate(&spec);
    let dir = PathBuf::from(p.str("out"));
    std::fs::create_dir_all(&dir)?;
    for (name, ds) in
        [("train", &splits.train), ("val", &splits.val), ("test", &splits.test)]
    {
        let path = dir.join(format!("{variant}.{name}.bin"));
        cache::save(ds, &path)?;
        println!("wrote {} examples to {}", ds.n(), path.display());
    }
    Ok(())
}

// ------------------------------------------------------------------- pack

fn pack_flags(c: Cli) -> Cli {
    c.opt("variant", "cifar10-proxy", "dataset variant")
        .opt("seed", "1", "generation seed")
        .opt_maybe("out", "output directory (default: <CREST_PACK_DIR>/<variant>-s<seed>)")
        .opt("shard-rows", "8192", "feature rows per shard file")
        .opt_maybe("n-train", "override the training-split size (scaling corpora)")
}

fn cmd_pack(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    let variant = p.str("variant");
    let mut spec = SynthSpec::preset(&variant, p.u64("seed")?).context("no preset")?;
    if let Some(n) = p.get("n-train") {
        spec.n_train = n.parse().context("parsing --n-train")?;
    }
    let shard_rows = p.usize("shard-rows")?;
    let root = match p.get("out") {
        Some(out) => PathBuf::from(out),
        // the canonical location `--data-store mmap` resolves lazily
        None => data::pack_root().join(format!("{}-s{}", spec.name, spec.seed)),
    };
    // streams straight to shards: the corpus is never resident, so
    // --n-train far beyond RAM is fine
    synth::generate_packed(&spec, &root, shard_rows)?;
    let packed = shard::load_packed_splits(&root)?;
    for (name, ds) in
        [("train", &packed.train), ("val", &packed.val), ("test", &packed.test)]
    {
        println!(
            "packed {} examples ({} features each) into {}",
            ds.n(),
            ds.d(),
            root.join(name).display()
        );
    }
    // `--data-store mmap` resolves packs under CREST_PACK_DIR as
    // <variant>-s<seed>, so point the trainer at this pack's parent
    println!(
        "train with: CREST_PACK_DIR={} crest train --variant {variant} --data-store mmap",
        root.parent().unwrap_or(&root).display()
    );
    Ok(())
}

// ------------------------------------------------------------------- lint

fn lint_flags(c: Cli) -> Cli {
    c.opt("root", ".", "repo root to scan (README.md env table + the Rust source roots)")
        .flag("list-rules", "print the rule table and exit")
}

fn cmd_lint(ctx: &Ctx) -> Result<()> {
    let p = &ctx.args;
    if p.bool("list-rules") {
        for r in crest::lint::RULES {
            println!("{:<13} {}", r.id, r.summary);
        }
        return Ok(());
    }
    let root = PathBuf::from(p.str("root"));
    let diags = crest::lint::lint_tree(&root)?;
    for d in &diags {
        println!("{d}");
    }
    if !diags.is_empty() {
        bail!("crest lint: {} finding(s) — see CONTRACTS.md for the contracts", diags.len());
    }
    println!("crest lint: clean ({} rules, see CONTRACTS.md)", crest::lint::RULES.len());
    Ok(())
}
