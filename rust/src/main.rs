//! `crest` — CLI entrypoint for the CREST reproduction.
//!
//! Subcommands:
//!   train    run one method on one variant and print the run report
//!   compare  run several methods on one variant (Table-1-style rows)
//!   sweep    run a resumable (variant × method × seed × budget) grid
//!            with per-cell checkpoints and mean±std aggregate tables
//!   inspect  print a variant's computation interface and active backend
//!   gen-data generate a proxy dataset and write the binary cache
//!
//! Runs on the native CPU backend by default (no artifacts required); the
//! `--artifacts` root is consulted for manifest.json shape overrides.
//!
//! Example:
//!   crest train --variant cifar10-proxy --method crest --seed 1
//!   crest compare --variant cifar10-proxy --methods crest,random,craig
//!   crest sweep --variant smoke --methods crest,random --seeds 1,2 --out sweep.json

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crest::bench_util;
use crest::config::{ExperimentConfig, MethodKind};
use crest::coordinator::run_experiment;
use crest::data::{cache, generate, SynthSpec};
use crest::metrics::relative_error_pct;
use crest::report::{aggregate_markdown, Table};
use crest::runtime::Runtime;
use crest::sweep::{self, SweepGrid, SweepSpec};
use crest::util::cli::{Cli, Parsed};
use crest::util::json::Json;
use crest::util::logging;
use crest::util::pool;

/// Apply `--threads` (falls back to `CREST_THREADS` / core count).
fn apply_threads(p: &Parsed) -> Result<()> {
    if let Some(t) = p.get("threads") {
        let n: usize = t.parse().context("parsing --threads")?;
        pool::set_threads(n);
    }
    Ok(())
}

fn artifact_root(p: &str) -> PathBuf {
    if p.is_empty() {
        PathBuf::from("artifacts")
    } else {
        PathBuf::from(p)
    }
}

fn main() -> Result<()> {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!(
                "usage: crest <train|compare|sweep|inspect|gen-data> [flags] (--help per command)"
            );
            std::process::exit(2);
        }
    };
    match cmd {
        "train" => cmd_train(&rest),
        "compare" => cmd_compare(&rest),
        "sweep" => cmd_sweep(&rest),
        "inspect" => cmd_inspect(&rest),
        "gen-data" => cmd_gen_data(&rest),
        _ => bail!("unknown command {cmd:?} (train|compare|sweep|inspect|gen-data)"),
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = Cli::new("crest train", "run one method on one variant")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        // generated from MethodKind::all() so the help cannot drift from
        // what MethodKind::parse accepts (see config.rs round-trip test)
        .opt("method", "crest", MethodKind::help_names())
        .opt("seed", "1", "experiment seed")
        .opt("budget", "0.1", "training budget as a fraction of full")
        .opt("epochs-full", "60", "epochs of the full reference run")
        .opt("artifacts", "artifacts", "artifact root directory")
        .opt_maybe("threads", "pool worker threads (default: CREST_THREADS or all cores)")
        .opt_maybe("out", "write the run report JSON here")
        .opt_maybe("lr", "override the base learning rate")
        .opt_maybe("tau", "override the ρ threshold τ")
        .opt_maybe("alpha", "override the exclusion threshold α")
        .flag("no-exclude", "disable learned-example exclusion")
        .flag("first-order", "use a first-order loss model (CREST-FIRST)")
        .flag("no-smooth", "disable EMA smoothing of grad/curvature")
        .flag("compiled-selection", "route greedy selection through the backend")
        .parse(args)?;
    apply_threads(&p)?;

    let variant = p.str("variant");
    let mut cfg =
        ExperimentConfig::preset(&variant, MethodKind::parse(&p.str("method"))?, p.u64("seed")?)?;
    cfg.budget_frac = p.f32("budget")?;
    cfg.epochs_full = p.usize("epochs-full")?;
    cfg.compiled_selection = p.bool("compiled-selection");
    if let Some(l) = p.get("lr") {
        cfg.base_lr = l.parse()?;
    }
    if let Some(t) = p.get("tau") {
        cfg.tau = t.parse()?;
    }
    if let Some(a) = p.get("alpha") {
        cfg.alpha = a.parse()?;
    }
    if p.bool("no-exclude") {
        cfg.crest.exclude = false;
    }
    if p.bool("first-order") {
        cfg.crest.second_order = false;
    }
    if p.bool("no-smooth") {
        cfg.crest.smooth = false;
    }

    let rt = Runtime::load(&artifact_root(&p.str("artifacts")), &variant)?;
    let splits = generate(&SynthSpec::preset(&variant, cfg.seed).context("no preset")?);
    let report = run_experiment(&rt, &splits, cfg)?;

    println!(
        "method={} variant={} acc={:.4} loss={:.4} steps={} updates={} excluded={} total={:.2}s (sel {:.2}s, train {:.2}s)",
        report.method,
        report.variant,
        report.final_test_acc,
        report.final_test_loss,
        report.steps,
        report.n_selection_updates,
        report.n_excluded,
        report.total_secs,
        report.selection_secs,
        report.train_secs,
    );
    if let Some(out) = p.get("out") {
        std::fs::write(out, report.to_json().to_string_pretty())?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_compare(args: &[String]) -> Result<()> {
    let p = Cli::new("crest compare", "run several methods on one variant")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("methods", "full,random,crest,craig", "comma-separated method list")
        .opt("seed", "1", "experiment seed")
        .opt("budget", "0.1", "training budget fraction")
        .opt("epochs-full", "60", "epochs of the full reference run")
        .opt("artifacts", "artifacts", "artifact root directory")
        .opt_maybe("threads", "pool worker threads (default: CREST_THREADS or all cores)")
        .parse(args)?;
    apply_threads(&p)?;

    let variant = p.str("variant");
    let seed = p.u64("seed")?;
    let rt = Runtime::load(&artifact_root(&p.str("artifacts")), &variant)?;
    let splits = generate(&SynthSpec::preset(&variant, seed).context("no preset")?);

    let mut full_acc = None;
    let mut table = Table::new(&["method", "test acc", "rel err %", "updates", "time (s)"]);
    for name in p.str("methods").split(',') {
        let method = MethodKind::parse(name.trim())?;
        let mut cfg = ExperimentConfig::preset(&variant, method, seed)?;
        cfg.budget_frac = p.f32("budget")?;
        cfg.epochs_full = p.usize("epochs-full")?;
        let rep = run_experiment(&rt, &splits, cfg)?;
        if method == MethodKind::Full {
            full_acc = Some(rep.final_test_acc);
        }
        let rel = full_acc
            .map(|fa| relative_error_pct(rep.final_test_acc * 100.0, fa * 100.0))
            .map(|r| format!("{r:.2}"))
            .unwrap_or_else(|| "-".to_string());
        table.row(&[
            rep.method.clone(),
            format!("{:.4}", rep.final_test_acc),
            rel,
            format!("{}", rep.n_selection_updates),
            format!("{:.2}", rep.total_secs),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let p = Cli::new("crest sweep", "run a resumable (variant × method × seed × budget) grid")
        .opt("variant", "cifar10-proxy", "comma-separated variant list")
        .opt(
            "methods",
            "full,random,crest",
            format!("comma-separated method list ({})", MethodKind::help_names()),
        )
        .opt("seeds", "1,2", "comma-separated seed list (the mean±std axis)")
        .opt("budgets", "0.1", "comma-separated budget fractions")
        .opt("epochs-full", "60", "epochs of the full reference run")
        .opt("artifacts", "artifacts", "artifact root directory")
        .opt(
            "checkpoint-dir",
            "sweep-ckpt",
            "per-cell checkpoint directory (resume skips completed cells)",
        )
        .flag("no-checkpoint", "disable the on-disk checkpoint store")
        .opt_maybe("jobs", "cells scheduled concurrently (default: auto from pool worker count)")
        .opt_maybe("threads", "pool worker threads (default: CREST_THREADS or all cores)")
        .opt_maybe("out", "append the aggregate rows to this JSON trajectory file")
        .parse(args)?;
    apply_threads(&p)?;

    let grid = SweepGrid {
        variants: sweep::grid::parse_variants(&p.str("variant"))?,
        methods: sweep::grid::parse_methods(&p.str("methods"))?,
        seeds: sweep::grid::parse_seeds(&p.str("seeds"))?,
        budgets: sweep::grid::parse_budgets(&p.str("budgets"))?,
    };
    let mut spec = SweepSpec::new(grid, p.usize("epochs-full")?);
    spec.artifact_root = artifact_root(&p.str("artifacts"));
    if !p.bool("no-checkpoint") {
        spec.checkpoint_dir = Some(PathBuf::from(p.str("checkpoint-dir")));
    }
    if let Some(j) = p.get("jobs") {
        spec.jobs = j.parse().context("parsing --jobs")?;
    }

    let outcome = sweep::run(&spec)?;
    println!(
        "# sweep: {} cells ({} executed, {} restored from checkpoints)",
        outcome.cells.len(),
        outcome.n_executed(),
        outcome.n_restored()
    );
    print!("{}", aggregate_markdown(&outcome.rows));
    if let Some(out) = p.get("out") {
        let records: Vec<Json> = outcome.rows.iter().map(|r| r.to_json()).collect();
        let n = bench_util::append_json_records(Path::new(out), records)?;
        println!("appended {n} aggregate rows to {out}");
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let p = Cli::new("crest inspect", "print the compiled artifact interface")
        .opt("variant", "cifar10-proxy", "model/dataset variant")
        .opt("artifacts", "artifacts", "artifact root directory")
        .parse(args)?;
    let rt = Runtime::load(&artifact_root(&p.str("artifacts")), &p.str("variant"))?;
    print!("{}", rt.describe());
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> Result<()> {
    let p = Cli::new("crest gen-data", "generate a proxy dataset cache")
        .opt("variant", "cifar10-proxy", "dataset variant")
        .opt("seed", "1", "generation seed")
        .opt("out", "/tmp/crest-data", "output directory")
        .parse(args)?;
    let variant = p.str("variant");
    let spec = SynthSpec::preset(&variant, p.u64("seed")?).context("no preset")?;
    let splits = generate(&spec);
    let dir = PathBuf::from(p.str("out"));
    std::fs::create_dir_all(&dir)?;
    for (name, ds) in
        [("train", &splits.train), ("val", &splits.val), ("test", &splits.test)]
    {
        let path = dir.join(format!("{variant}.{name}.bin"));
        cache::save(ds, &path)?;
        println!("wrote {} examples to {}", ds.n(), path.display());
    }
    Ok(())
}
