//! A minimal hand-rolled Rust lexer for the contract checker.
//!
//! The linter needs exactly four things a regex grep cannot provide:
//! tokens with **comments and string literals stripped** (so `fmadd` in a
//! doc comment is not a finding), **string literal contents** (so
//! `CREST_*` env names can be checked against the README), **comment
//! text with position** (so `// SAFETY:` and `// lint:allow(..)`
//! directives can be attached to code lines), and **line numbers** for
//! diagnostics. It does not parse — rules work on the token stream —
//! and it tolerates invalid Rust (fixtures need not compile).
//!
//! Handled: line comments, nested block comments, normal/raw/byte string
//! literals, char literals vs. lifetimes, identifiers, numbers, and
//! punctuation (`::` is fused into one token because the rules match
//! qualified paths).

/// Token class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (lexed loosely; the rules never read the value).
    Num,
    /// String literal; `text` holds the raw contents between the quotes.
    Str,
    /// Punctuation, one char each except the fused `::`.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token class.
    pub kind: Kind,
    /// Token text (for [`Kind::Str`], the contents between the quotes).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: usize,
}

/// One comment (line or block) with its span and position context.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// 1-based line the comment ends on (same as `line` for `//`).
    pub end_line: usize,
    /// Comment text without the `//` / `/* */` markers.
    pub text: String,
    /// True when code tokens precede the comment on its starting line.
    pub trailing: bool,
}

/// Lexer output: the token stream plus the stripped comments.
#[derive(Debug, Default)]
pub struct Lexed {
    /// Tokens in source order, comments and literals stripped.
    pub toks: Vec<Tok>,
    /// Comments in source order.
    pub comments: Vec<Comment>,
    /// Total number of source lines.
    pub n_lines: usize,
}

impl Lexed {
    /// True when any token starts on `line`.
    pub fn line_has_code(&self, line: usize) -> bool {
        self.toks.iter().any(|t| t.line == line)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Lexer {
    cs: Vec<char>,
    i: usize,
    line: usize,
    line_has_tok: bool,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.cs.get(self.i).copied();
        if let Some(ch) = c {
            self.i += 1;
            if ch == '\n' {
                self.line += 1;
                self.line_has_tok = false;
            }
        }
        c
    }

    fn push(&mut self, kind: Kind, text: String, line: usize) {
        self.line_has_tok = true;
        self.out.toks.push(Tok { kind, text, line });
    }

    fn line_comment(&mut self) {
        let start = self.line;
        let trailing = self.line_has_tok;
        let mut text = String::new();
        self.i += 2; // the `//`
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { line: start, end_line: start, text, trailing });
    }

    fn block_comment(&mut self) {
        let start = self.line;
        let trailing = self.line_has_tok;
        let mut text = String::new();
        self.i += 2; // the `/*`
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some('/'), Some('*')) => {
                    depth += 1;
                    self.bump();
                    self.bump();
                }
                (Some('*'), Some('/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                }
                (Some(_), _) => {
                    text.push(self.peek(0).unwrap());
                    self.bump();
                }
                (None, _) => break,
            }
        }
        let end = self.line;
        self.out.comments.push(Comment { line: start, end_line: end, text, trailing });
    }

    /// Consume a normal string body starting after the opening quote.
    fn string_body(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            match c {
                '\\' => {
                    // keep escapes verbatim — the rules only scan for
                    // CREST_* names, which contain no escapes
                    text.push(c);
                    self.bump();
                    if let Some(e) = self.peek(0) {
                        text.push(e);
                        self.bump();
                    }
                }
                '"' => {
                    self.bump();
                    break;
                }
                _ => {
                    text.push(c);
                    self.bump();
                }
            }
        }
        self.push(Kind::Str, text, line);
    }

    /// Consume a raw string starting at the first `#` or `"` after `r`/`br`.
    fn raw_string_body(&mut self, line: usize) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek(0) != Some('"') {
            return; // not actually a raw string; nothing sensible to emit
        }
        self.bump();
        let mut text = String::new();
        'outer: while let Some(c) = self.peek(0) {
            if c == '"' {
                // candidate closer: `"` followed by `hashes` hashes
                for k in 0..hashes {
                    if self.peek(1 + k) != Some('#') {
                        text.push(c);
                        self.bump();
                        continue 'outer;
                    }
                }
                self.bump();
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(Kind::Str, text, line);
    }

    /// `'` — char literal or lifetime. Consumes either; lifetimes emit no
    /// token (the rules never match on lifetime names).
    fn quote(&mut self) {
        self.bump(); // the `'`
        match self.peek(0) {
            Some('\\') => {
                // escape char literal: consume to the closing quote
                self.bump();
                self.bump(); // the escaped char (enough for \n, \', \\, \0)
                while let Some(c) = self.peek(0) {
                    let done = c == '\'';
                    self.bump();
                    if done {
                        break;
                    }
                }
            }
            Some(c) if self.peek(1) == Some('\'') => {
                // one-char literal 'x' (covers idents, digits and puncts)
                let _ = c;
                self.bump();
                self.bump();
            }
            Some(c) if is_ident_start(c) => {
                // lifetime: consume the identifier, no closing quote
                while let Some(c2) = self.peek(0) {
                    if !is_ident_cont(c2) {
                        break;
                    }
                    self.bump();
                }
            }
            _ => {}
        }
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            match c {
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => {
                    let line = self.line;
                    self.bump();
                    self.string_body(line);
                }
                '\'' => self.quote(),
                c if c.is_whitespace() => {
                    self.bump();
                }
                c if is_ident_start(c) => {
                    let line = self.line;
                    let mut id = String::new();
                    while let Some(c2) = self.peek(0) {
                        if !is_ident_cont(c2) {
                            break;
                        }
                        id.push(c2);
                        self.bump();
                    }
                    // string prefixes: r"..", r#".."#, b"..", br".."
                    let prefix = matches!(id.as_str(), "r" | "b" | "br");
                    match self.peek(0) {
                        Some('"') if prefix => {
                            if id == "b" {
                                self.bump();
                                self.string_body(line);
                            } else {
                                self.raw_string_body(line);
                            }
                        }
                        Some('#') if prefix && id != "b" => self.raw_string_body(line),
                        Some('\'') if id == "b" => self.quote(),
                        _ => self.push(Kind::Ident, id, line),
                    }
                }
                c if c.is_ascii_digit() => {
                    let line = self.line;
                    let mut num = String::new();
                    while let Some(c2) = self.peek(0) {
                        // a `.` continues the number only before a digit, so
                        // `x.0.method()` keeps `method` as its own identifier
                        let frac = c2 == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit());
                        if !(c2.is_alphanumeric() || c2 == '_' || frac) {
                            break;
                        }
                        num.push(c2);
                        self.bump();
                    }
                    self.push(Kind::Num, num, line);
                }
                ':' if self.peek(1) == Some(':') => {
                    let line = self.line;
                    self.bump();
                    self.bump();
                    self.push(Kind::Punct, "::".to_string(), line);
                }
                c => {
                    let line = self.line;
                    self.bump();
                    self.push(Kind::Punct, c.to_string(), line);
                }
            }
        }
        self.out.n_lines = self.line;
        self.out
    }
}

/// Lex `src` into tokens and comments.
pub fn lex(src: &str) -> Lexed {
    Lexer {
        cs: src.chars().collect(),
        i: 0,
        line: 1,
        line_has_tok: false,
        out: Lexed::default(),
    }
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_are_stripped_but_kept() {
        let lx = lex("let a = 1; // trailing fmadd\n/* block\nfmadd */ let b = 2;\n");
        assert!(lx.toks.iter().all(|t| t.text != "fmadd"));
        assert_eq!(lx.comments.len(), 2);
        assert!(lx.comments[0].trailing);
        assert_eq!(lx.comments[0].text.trim(), "trailing fmadd");
        assert!(!lx.comments[1].trailing);
        assert_eq!(lx.comments[1].end_line, 3);
    }

    #[test]
    fn strings_capture_contents() {
        let lx = lex(r#"let v = std::env::var("CREST_THREADS");"#);
        let strs: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == Kind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, vec!["CREST_THREADS"]);
    }

    #[test]
    fn raw_and_byte_strings() {
        let lx = lex("let a = r#\"CREST_A \"quoted\" tail\"#; let b = b\"CREST_B\"; let r = r;");
        let strs: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == Kind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs.len(), 2);
        assert!(strs[0].contains("CREST_A"));
        assert!(strs[1].contains("CREST_B"));
        // a bare `r` identifier survives as an identifier
        assert!(lx.toks.iter().any(|t| t.kind == Kind::Ident && t.text == "r"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ids = idents("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(ids.contains(&"str".to_string()));
        // neither the lifetime name nor the char literal become idents
        // that the rules could mistake for code identifiers
        assert!(!ids.contains(&"x".to_string()) || ids.iter().filter(|s| *s == "x").count() == 1);
        let lx = lex("let c = '\\n'; let l: &'static str = \"s\";");
        assert!(lx.toks.iter().any(|t| t.kind == Kind::Str && t.text == "s"));
    }

    #[test]
    fn qualified_path_tokens() {
        let lx = lex("std::env::var(\"X\")");
        let texts: Vec<_> = lx.toks.iter().map(|t| t.text.clone()).collect();
        assert_eq!(texts, vec!["std", "::", "env", "::", "var", "(", "X", ")"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let lx = lex("a\nb\n  c d\n");
        let lines: Vec<_> = lx.toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3, 3]);
        assert_eq!(lx.n_lines, 4);
    }

    #[test]
    fn nested_block_comments() {
        let lx = lex("/* outer /* inner */ still comment */ code");
        assert_eq!(lx.comments.len(), 1);
        assert_eq!(lx.toks.len(), 1);
        assert_eq!(lx.toks[0].text, "code");
    }
}
