//! `crest lint` — a dependency-free contract checker over the crate's
//! own sources.
//!
//! The determinism guarantees the sweep/resume, mmap-vs-mem and
//! SIMD-vs-scalar gates pin are *bitwise*, and most of the ways to break
//! them (a `HashMap` fold in selection math, a fused multiply-add in a
//! kernel, a stray `env::var` read) compile cleanly and pass any finite
//! test set. This module turns those prose contracts (`CONTRACTS.md`)
//! into machine-checked rules: a small hand-rolled lexer ([`lex`]) feeds
//! token-level checks ([`rules`]), and `crest lint` exits nonzero on any
//! finding, so CI holds the line.
//!
//! Findings render as `file:line: [RULE-ID] message`. A justified
//! exception is written in-source as `// lint:allow(RULE-ID) reason`
//! (trailing on the offending line, or a standalone comment directly
//! above it); directives without a real reason are themselves findings.

pub mod lex;
pub mod rules;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Repo-relative file path (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule ID, e.g. `DET-HASH`.
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Rule metadata, for `crest lint --list-rules` and the docs tests.
#[derive(Debug)]
pub struct RuleInfo {
    /// Stable rule ID as it appears in diagnostics and `lint:allow`.
    pub id: &'static str,
    /// One-line summary of the contract the rule enforces.
    pub summary: &'static str,
}

/// Every rule the checker knows, in diagnostic-ID order. `CONTRACTS.md`
/// documents each one; a test asserts the two lists agree.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "DET-CLOCK",
        summary: "no Instant/SystemTime in modules feeding deterministic_json",
    },
    RuleInfo {
        id: "DET-FMA",
        summary: "no fused multiply-add in the kernel layer (bitwise SIMD-vs-scalar contract)",
    },
    RuleInfo {
        id: "DET-HASH",
        summary: "no HashMap/HashSet in determinism-critical modules",
    },
    RuleInfo {
        id: "ENV-HYGIENE",
        summary: "env reads only in runtime_config.rs + registered readers; CREST_* documented",
    },
    RuleInfo {
        id: "IO-FACADE",
        summary: "artifact modules do file I/O only through the artifact_io facade",
    },
    RuleInfo {
        id: "ISA-DISPATCH",
        summary: "#[target_feature] bodies private to kernel.rs behind the KernelIsa dispatch",
    },
    RuleInfo {
        id: "LINT-ALLOW",
        summary: "every lint:allow names a real rule, attaches to code, and carries a reason",
    },
    RuleInfo {
        id: "UNSAFE-SCOPE",
        summary: "unsafe only in registered modules, each block SAFETY-justified",
    },
];

/// Rule IDs a `lint:allow` directive may name (everything except the
/// meta-rule, which must not be suppressible).
pub(crate) fn allowable_rules() -> Vec<&'static str> {
    RULES.iter().map(|r| r.id).filter(|id| *id != "LINT-ALLOW").collect()
}

/// The contract checker. Holds the README text so ENV-HYGIENE can check
/// `CREST_*` literals against the documented env table.
#[derive(Debug)]
pub struct Linter {
    readme: String,
}

impl Linter {
    /// Checker with an explicit README text (fixture tests use this to
    /// control the documented-variable set).
    pub fn with_readme(readme: &str) -> Linter {
        Linter { readme: readme.to_string() }
    }

    /// Checker for the repo at `root`, loading `README.md` from it.
    pub fn for_tree(root: &Path) -> Result<Linter> {
        let path = root.join("README.md");
        let readme = fs::read_to_string(&path)
            .with_context(|| format!("reading {} for the env table", path.display()))?;
        Ok(Linter { readme })
    }

    /// Run every rule over one source file. `rel` is the repo-relative
    /// path with forward slashes (e.g. `rust/src/kernel.rs`); the rules
    /// use it to decide which module lists and registries apply.
    pub fn lint_file(&self, rel: &str, src: &str) -> Vec<Diagnostic> {
        let lx = lex::lex(src);
        let cx = rules::FileCx::new(rel, &lx);
        let allowable = allowable_rules();
        let mut out = Vec::new();
        rules::det_hash(&cx, &allowable, &mut out);
        rules::det_clock(&cx, &allowable, &mut out);
        rules::det_fma(&cx, &allowable, &mut out);
        rules::unsafe_scope(&cx, &allowable, &mut out);
        rules::env_hygiene(&cx, &self.readme, &allowable, &mut out);
        rules::io_facade(&cx, &allowable, &mut out);
        rules::isa_dispatch(&cx, &allowable, &mut out);
        rules::lint_allow(&cx, &allowable, &mut out);
        out.sort_by(|a, b| (a.line, a.rule, &a.message).cmp(&(b.line, b.rule, &b.message)));
        out
    }
}

/// Source roots the tree walk covers, relative to the repo root.
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Directory names excluded from the walk: the golden fixtures contain
/// deliberate violations.
const SKIP_DIRS: &[&str] = &["lint_fixtures"];

/// Lint every `.rs` file under [`SCAN_ROOTS`] of the repo at `root`.
/// Files are visited in sorted order, so output is deterministic.
pub fn lint_tree(root: &Path) -> Result<Vec<Diagnostic>> {
    let linter = Linter::for_tree(root)?;
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut out = Vec::new();
    for path in files {
        let rel: Vec<String> = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect();
        let rel = rel.join("/");
        let src = fs::read_to_string(&path)
            .with_context(|| format!("reading {} for lint", path.display()))?;
        out.extend(linter.lint_file(&rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("walking {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_str()) {
                collect_rs(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagnostic_render_format() {
        let d = Diagnostic {
            file: "rust/src/kernel.rs".to_string(),
            line: 7,
            rule: "DET-FMA",
            message: "msg".to_string(),
        };
        assert_eq!(d.to_string(), "rust/src/kernel.rs:7: [DET-FMA] msg");
    }

    #[test]
    fn rules_table_is_sorted_and_complete() {
        let ids: Vec<_> = RULES.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "RULES must stay in ID order");
        assert!(ids.contains(&"LINT-ALLOW"));
        assert_eq!(allowable_rules().len(), RULES.len() - 1);
    }

    #[test]
    fn lint_file_sorts_by_line() {
        let linter = Linter::with_readme("");
        let src = "fn g() { let b = std::time::Instant::now(); }\n\
                   fn f() { let m = std::collections::HashMap::<u32, u32>::new(); }\n";
        let d = linter.lint_file("rust/src/coreset/x.rs", src);
        assert_eq!(d.len(), 2);
        assert!(d[0].line < d[1].line);
        assert_eq!(d[0].rule, "DET-CLOCK");
        assert_eq!(d[1].rule, "DET-HASH");
    }
}
