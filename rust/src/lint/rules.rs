//! The contract rules: module lists, registered exceptions, and the
//! token-level checks behind each rule ID.
//!
//! Every rule is a *machine-checkable approximation* of a prose contract
//! from `CONTRACTS.md` (rationale and precise scope live there). The
//! approximations are deliberately conservative: they match type and
//! function *names* in the token stream, so renaming-based evasion is
//! possible but accidental violations — the only kind that happens in
//! practice — are caught. Violations that are individually justified
//! carry an in-source `// lint:allow(RULE-ID) reason` directive on or
//! directly above the offending line; a directive without a written
//! reason is itself a finding (`LINT-ALLOW`).

use super::lex::{Kind, Lexed};
use super::Diagnostic;

/// Module prefixes (and exact files) whose selection math must stay a
/// deterministic function of data and seed: no hash-order iteration.
pub const DET_MODULES: &[&str] = &[
    "rust/src/coreset/",
    "rust/src/sweep/",
    "rust/src/data/",
    "rust/src/kernel.rs",
    "rust/src/runtime/native.rs",
];

/// Modules whose outputs feed `deterministic_json`: no wall-clock reads.
/// The coordinator's phase timers are exempt by scope — their output goes
/// only to the wall-clock report fields that `deterministic_json` drops.
pub const CLOCK_MODULES: &[&str] = &[
    "rust/src/coreset/",
    "rust/src/sweep/",
    "rust/src/data/",
    "rust/src/kernel.rs",
    "rust/src/runtime/native.rs",
    "rust/src/report.rs",
];

/// Files whose float kernels must keep multiply and add as separate
/// instructions (the bitwise SIMD-vs-scalar contract forbids fused
/// rounding).
pub const FMA_MODULES: &[&str] = &["rust/src/kernel.rs", "rust/src/runtime/native.rs"];

/// One registered `unsafe` scope: the only file+module pairs allowed to
/// contain the `unsafe` keyword, each with the reason on record.
#[derive(Debug)]
pub struct UnsafeScope {
    /// Repo-relative file allowed to contain `unsafe`.
    pub file: &'static str,
    /// The single module inside that file the blocks must live in.
    pub module: &'static str,
    /// Why this scope exists.
    pub reason: &'static str,
}

/// The crate's registered `unsafe` scopes (mirrors the `Cargo.toml`
/// `unsafe_code = "deny"` exceptions).
pub const UNSAFE_SCOPES: &[UnsafeScope] = &[
    UnsafeScope {
        file: "rust/src/kernel.rs",
        module: "avx2",
        reason: "std::arch SIMD intrinsics behind the KernelIsa runtime dispatch",
    },
    UnsafeScope {
        file: "rust/src/data/store.rs",
        module: "mm",
        reason: "raw mmap(2)/munmap(2) binding; the offline registry has no libc/memmap2",
    },
];

/// One registered environment reader: a file allowed to call
/// `std::env::var*` outside `runtime_config.rs`, with the reason on
/// record.
#[derive(Debug)]
pub struct EnvReader {
    /// Repo-relative file allowed to read the environment.
    pub file: &'static str,
    /// Why this reader is exempt from the consolidation.
    pub reason: &'static str,
}

/// The registered environment readers. Everything else goes through
/// `RuntimeConfig` so env is read in one typed, documented place.
pub const ENV_READERS: &[EnvReader] = &[
    EnvReader {
        file: "rust/src/runtime_config.rs",
        reason: "the consolidation point itself — the one place CREST_* knobs are read",
    },
    EnvReader {
        file: "rust/src/util/logging.rs",
        reason: "CREST_LOG at logger install; verbosity only, cannot affect computed results",
    },
    EnvReader {
        file: "rust/src/bench_util/mod.rs",
        reason: "bench-harness knobs (CREST_BENCH_*): workload size and trajectory output \
                 for `cargo bench` runs; never consulted on library paths",
    },
    EnvReader {
        file: "rust/src/bench_util/scenario.rs",
        reason: "bench scenario sizing (CREST_BENCH_*, CREST_ARTIFACTS, CREST_SWEEP_CKPT); \
                 never consulted on library paths",
    },
];

const ENV_READS: &[&str] = &["var", "var_os", "vars", "vars_os"];
const ENV_WRITES: &[&str] = &["set_var", "remove_var"];

/// Exact files whose artifact I/O must go through the `artifact_io`
/// facade (fault injection, bounded retries, CRC stamping, fsync
/// discipline). An exact list, not a prefix: e.g. `data/synth.rs` writes
/// packs via `SplitWriter`, whose I/O already lives in `data/shard.rs`.
pub const ARTIFACT_MODULES: &[&str] = &[
    "rust/src/coreset/embed_cache.rs",
    "rust/src/data/cache.rs",
    "rust/src/data/shard.rs",
    "rust/src/data/store.rs",
    "rust/src/sweep/store.rs",
];

/// The registered facade scopes: the files where raw `std::fs` calls
/// *implement* artifact I/O, and therefore the only places they may
/// appear. (Listed for the record and CONTRACTS.md; the scan exempts
/// them by construction since they are not artifact modules.)
pub const IO_FACADE_SCOPES: &[&str] = &["rust/src/util/artifact_io.rs"];

/// Parsed `// lint:allow(RULE-ID) reason` directive.
#[derive(Debug)]
struct Allow {
    rule: String,
    reason: String,
    /// Line the directive suppresses (usize::MAX when unattached).
    target: usize,
    /// Line the directive itself sits on (for LINT-ALLOW findings).
    line: usize,
}

impl Allow {
    fn valid(&self, allowable: &[&str]) -> bool {
        allowable.contains(&self.rule.as_str()) && reason_ok(&self.reason)
    }
}

fn reason_ok(reason: &str) -> bool {
    reason.chars().filter(|c| c.is_alphanumeric()).count() >= 3
}

/// Everything the rules need about one lexed file.
pub(crate) struct FileCx<'a> {
    rel: &'a str,
    lx: &'a Lexed,
    /// Per 1-based line: inside a `#[cfg(test)]` / `#[test]` region (or a
    /// `rust/tests/` integration-test file, which is test code wholesale).
    test_line: Vec<bool>,
    /// Per token: part of a `#[...]` / `#![...]` attribute.
    attr_tok: Vec<bool>,
    /// Per token: part of a `use ...;` declaration.
    use_tok: Vec<bool>,
    allows: Vec<Allow>,
}

/// `(start, end)` inclusive token-index spans.
type Span = (usize, usize);

fn balance(toks: &[super::lex::Tok], open_idx: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open_idx) {
        if t.kind == Kind::Punct {
            if t.text == open {
                depth += 1;
            } else if t.text == close {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
        }
    }
    toks.len().saturating_sub(1)
}

impl<'a> FileCx<'a> {
    pub(crate) fn new(rel: &'a str, lx: &'a Lexed) -> FileCx<'a> {
        let toks = &lx.toks;
        let n = toks.len();
        let mut attr_tok = vec![false; n];
        let mut use_tok = vec![false; n];
        let mut test_line = vec![false; lx.n_lines + 2];

        // attribute spans, and which of them mark test regions
        let mut attr_spans: Vec<(Span, bool)> = Vec::new();
        let mut i = 0;
        while i < n {
            let punct = |k: usize, s: &str| {
                toks.get(k).is_some_and(|t| t.kind == Kind::Punct && t.text == s)
            };
            if toks[i].kind == Kind::Punct && toks[i].text == "#" {
                let open = if punct(i + 1, "[") {
                    Some(i + 1)
                } else if punct(i + 1, "!") && punct(i + 2, "[") {
                    Some(i + 2)
                } else {
                    None
                };
                if let Some(o) = open {
                    let j = balance(toks, o, "[", "]");
                    for k in i..=j {
                        attr_tok[k] = true;
                    }
                    let mut has_test = false;
                    let mut has_not = false;
                    for t in &toks[o..=j] {
                        if t.kind == Kind::Ident {
                            has_test |= t.text == "test";
                            has_not |= t.text == "not";
                        }
                    }
                    attr_spans.push(((i, j), has_test && !has_not));
                    i = j + 1;
                    continue;
                }
            }
            i += 1;
        }

        // use-declaration spans
        let mut i = 0;
        while i < n {
            if toks[i].kind == Kind::Ident && toks[i].text == "use" && !attr_tok[i] {
                let mut j = i;
                while j < n && !(toks[j].kind == Kind::Punct && toks[j].text == ";") {
                    use_tok[j] = true;
                    j += 1;
                }
                if j < n {
                    use_tok[j] = true;
                }
                i = j + 1;
                continue;
            }
            i += 1;
        }

        // test regions: whole file for integration tests, else the item
        // following each #[cfg(test)] / #[test] attribute
        if rel.starts_with("rust/tests/") {
            test_line.fill(true);
        } else {
            for &((astart, aend), is_test) in &attr_spans {
                if !is_test {
                    continue;
                }
                // skip any further attributes stacked on the same item
                let mut k = aend + 1;
                while k < n && attr_tok[k] {
                    k += 1;
                }
                // the item region: to the matching `}` of its first brace,
                // or to the `;` when the item has no body
                let mut end_tok = n.saturating_sub(1);
                let mut m = k;
                while m < n {
                    let t = &toks[m];
                    if t.kind == Kind::Punct && t.text == ";" {
                        end_tok = m;
                        break;
                    }
                    if t.kind == Kind::Punct && t.text == "{" {
                        end_tok = balance(toks, m, "{", "}");
                        break;
                    }
                    m += 1;
                }
                let from = toks[astart].line;
                let to = toks.get(end_tok).map(|t| t.line).unwrap_or(from);
                for line in from..=to.min(lx.n_lines + 1) {
                    test_line[line] = true;
                }
            }
        }

        // directive parsing: a comment is a directive only when its text
        // *starts with* the `lint:allow` token, so prose and doc comments
        // that merely mention the syntax are not parsed as directives
        let mut allows = Vec::new();
        for c in &lx.comments {
            let trimmed = c.text.trim_start();
            if !trimmed.starts_with("lint:allow") {
                continue;
            }
            let rest = &trimmed["lint:allow".len()..];
            let (rule, reason) = match rest.strip_prefix('(').and_then(|r| r.split_once(')')) {
                Some((id, why)) => (id.trim().to_string(), why.trim().to_string()),
                None => (String::new(), String::new()),
            };
            let target = if c.trailing {
                c.line
            } else {
                (c.end_line + 1..=lx.n_lines + 1)
                    .find(|&l| lx.line_has_code(l))
                    .unwrap_or(usize::MAX)
            };
            allows.push(Allow { rule, reason, target, line: c.line });
        }

        FileCx { rel, lx, test_line, attr_tok, use_tok, allows }
    }

    fn is_test_line(&self, line: usize) -> bool {
        self.test_line.get(line).copied().unwrap_or(false)
    }

    fn suppressed(&self, rule: &str, line: usize, allowable: &[&str]) -> bool {
        self.allows.iter().any(|a| a.rule == rule && a.target == line && a.valid(allowable))
    }

    /// Whether any line of `lines` (descending walk from an `unsafe`
    /// token) carries a `SAFETY:` comment. Blank, comment-only and
    /// attribute-only lines are looked through; the walk stops at the
    /// first other code line.
    fn safety_covered(&self, line: usize) -> bool {
        let has_safety = |ln: usize| {
            self.lx
                .comments
                .iter()
                .any(|c| (c.line..=c.end_line).contains(&ln) && c.text.contains("SAFETY:"))
        };
        if has_safety(line) {
            return true;
        }
        let mut ln = line;
        for _ in 0..10 {
            if ln <= 1 {
                return false;
            }
            ln -= 1;
            if has_safety(ln) {
                return true;
            }
            let toks_on_line: Vec<_> =
                self.lx.toks.iter().enumerate().filter(|(_, t)| t.line == ln).collect();
            if toks_on_line.is_empty() {
                continue; // blank or comment-only
            }
            if toks_on_line.iter().all(|(i, _)| self.attr_tok[*i]) {
                continue; // attribute-only line (e.g. #[target_feature])
            }
            return false; // a code line without a SAFETY comment
        }
        false
    }
}

fn in_modules(rel: &str, modules: &[&str]) -> bool {
    modules.iter().any(|m| if m.ends_with('/') { rel.starts_with(m) } else { rel == *m })
}

fn push(out: &mut Vec<Diagnostic>, rel: &str, line: usize, rule: &'static str, msg: String) {
    out.push(Diagnostic { file: rel.to_string(), line, rule, message: msg });
}

/// DET-HASH: no `HashMap`/`HashSet` in determinism-critical modules
/// outside test code and `use` declarations. Hash containers iterate in
/// randomized order; a fold over one inside selection math silently
/// breaks the bitwise reproducibility the sweep/resume and
/// mmap-vs-mem gates pin.
pub(crate) fn det_hash(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    if !in_modules(cx.rel, DET_MODULES) {
        return;
    }
    for (i, t) in cx.lx.toks.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        if cx.use_tok[i] || cx.attr_tok[i] || cx.is_test_line(t.line) {
            continue;
        }
        if cx.suppressed("DET-HASH", t.line, allowable) {
            continue;
        }
        push(
            out,
            cx.rel,
            t.line,
            "DET-HASH",
            format!(
                "`{}` in a determinism-critical module: hash iteration order is \
                 randomized; use Vec/BTreeMap or justify a membership-only use \
                 with `// lint:allow(DET-HASH) reason`",
                t.text
            ),
        );
    }
}

/// DET-CLOCK: no `Instant`/`SystemTime` in modules whose outputs feed
/// `deterministic_json`.
pub(crate) fn det_clock(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    if !in_modules(cx.rel, CLOCK_MODULES) {
        return;
    }
    for (i, t) in cx.lx.toks.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "Instant" && t.text != "SystemTime") {
            continue;
        }
        if cx.use_tok[i] || cx.attr_tok[i] || cx.is_test_line(t.line) {
            continue;
        }
        if cx.suppressed("DET-CLOCK", t.line, allowable) {
            continue;
        }
        push(
            out,
            cx.rel,
            t.line,
            "DET-CLOCK",
            format!(
                "`{}` in a module feeding deterministic_json: wall-clock reads \
                 must stay behind the report's excluded timing fields",
                t.text
            ),
        );
    }
}

/// DET-FMA: no fused multiply-add in the kernel layer. `a.mul_add(b, c)`
/// and `_mm256_fmadd_ps` round once where `a*b + c` rounds twice, so a
/// fused path would diverge bitwise from the scalar reference.
pub(crate) fn det_fma(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    if !in_modules(cx.rel, FMA_MODULES) {
        return;
    }
    for t in &cx.lx.toks {
        if t.kind != Kind::Ident {
            continue;
        }
        let fused = t.text == "mul_add" || t.text.to_ascii_lowercase().contains("fmadd");
        if !fused {
            continue;
        }
        if cx.suppressed("DET-FMA", t.line, allowable) {
            continue;
        }
        push(
            out,
            cx.rel,
            t.line,
            "DET-FMA",
            format!(
                "`{}` fuses multiply and add into one rounding; the bitwise \
                 SIMD-vs-scalar contract requires separate mul + add",
                t.text
            ),
        );
    }
}

/// UNSAFE-SCOPE: `unsafe` only inside the registered file+module scopes,
/// each block justified by a `// SAFETY:` comment, each scope under
/// `#[allow(unsafe_code)]` (the crate denies it globally).
pub(crate) fn unsafe_scope(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    let toks = &cx.lx.toks;
    let unsafe_idxs: Vec<usize> = toks
        .iter()
        .enumerate()
        .filter(|(_, t)| t.kind == Kind::Ident && t.text == "unsafe")
        .map(|(i, _)| i)
        .collect();
    if unsafe_idxs.is_empty() {
        return;
    }
    let Some(scope) = UNSAFE_SCOPES.iter().find(|s| s.file == cx.rel) else {
        let mut last_line = 0;
        for &i in &unsafe_idxs {
            let line = toks[i].line;
            if line == last_line || cx.suppressed("UNSAFE-SCOPE", line, allowable) {
                continue;
            }
            last_line = line;
            push(
                out,
                cx.rel,
                line,
                "UNSAFE-SCOPE",
                "`unsafe` outside the registered scopes (kernel.rs::avx2, \
                 data/store.rs::mm); register a new scope in lint::rules \
                 with its reason, or stay safe"
                    .to_string(),
            );
        }
        return;
    };

    // (a) the scope must be opted in via a scoped #[allow(unsafe_code)]
    let has_scoped_allow = toks.windows(3).enumerate().any(|(i, w)| {
        cx.attr_tok[i]
            && w[0].kind == Kind::Ident
            && w[0].text == "allow"
            && w[2].kind == Kind::Ident
            && w[2].text == "unsafe_code"
    });
    if !has_scoped_allow {
        push(
            out,
            cx.rel,
            1,
            "UNSAFE-SCOPE",
            format!(
                "registered unsafe scope `{}` must sit under a scoped \
                 #[allow(unsafe_code)] (the crate denies unsafe_code globally)",
                scope.module
            ),
        );
    }

    // (b) locate the registered module's brace span
    let mut mod_span: Option<Span> = None;
    for i in 0..toks.len().saturating_sub(1) {
        if toks[i].kind == Kind::Ident
            && toks[i].text == "mod"
            && toks[i + 1].kind == Kind::Ident
            && toks[i + 1].text == scope.module
        {
            let mut m = i + 2;
            while m < toks.len() && !(toks[m].kind == Kind::Punct && toks[m].text == "{") {
                m += 1;
            }
            if m < toks.len() {
                mod_span = Some((m, balance(toks, m, "{", "}")));
            }
            break;
        }
    }
    let Some((mstart, mend)) = mod_span else {
        push(
            out,
            cx.rel,
            1,
            "UNSAFE-SCOPE",
            format!("registered unsafe module `{}` not found in this file", scope.module),
        );
        return;
    };

    // (c) every unsafe token: inside the module, SAFETY-justified
    let mut covered: Vec<Span> = Vec::new();
    for &i in &unsafe_idxs {
        let line = toks[i].line;
        if !(mstart..=mend).contains(&i) {
            if !cx.suppressed("UNSAFE-SCOPE", line, allowable) {
                push(
                    out,
                    cx.rel,
                    line,
                    "UNSAFE-SCOPE",
                    format!("`unsafe` outside the registered module `{}`", scope.module),
                );
            }
            continue;
        }
        if covered.iter().any(|&(s, e)| (s..=e).contains(&i)) {
            continue; // nested inside an already-justified unsafe fn/block
        }
        if cx.safety_covered(line) {
            // the justified region extends to the matching close brace, so
            // inner unsafe blocks share the justification
            let mut m = i + 1;
            while m < toks.len() && !(toks[m].kind == Kind::Punct && toks[m].text == "{") {
                m += 1;
            }
            if m < toks.len() {
                covered.push((m, balance(toks, m, "{", "}")));
            }
            continue;
        }
        if !cx.suppressed("UNSAFE-SCOPE", line, allowable) {
            push(
                out,
                cx.rel,
                line,
                "UNSAFE-SCOPE",
                "`unsafe` without a `// SAFETY:` comment on or directly above \
                 the block stating why it is sound"
                    .to_string(),
            );
        }
    }
}

/// ENV-HYGIENE: `std::env::var*` only in `runtime_config.rs` plus the
/// registered readers; no env mutation outside test code; every
/// `CREST_*` name in non-test code documented in README's env table
/// (tests may use synthetic names and already mutate env freely).
pub(crate) fn env_hygiene(
    cx: &FileCx,
    readme: &str,
    allowable: &[&str],
    out: &mut Vec<Diagnostic>,
) {
    let toks = &cx.lx.toks;
    let registered = ENV_READERS.iter().any(|r| r.file == cx.rel);
    for w in toks.windows(3) {
        let qualified = w[0].kind == Kind::Ident
            && w[0].text == "env"
            && w[1].text == "::"
            && w[2].kind == Kind::Ident;
        if !qualified {
            continue;
        }
        let call = w[2].text.as_str();
        let line = w[2].line;
        if ENV_READS.contains(&call)
            && !registered
            && !cx.suppressed("ENV-HYGIENE", line, allowable)
        {
            push(
                out,
                cx.rel,
                line,
                "ENV-HYGIENE",
                format!(
                    "`env::{call}` outside runtime_config.rs: read the knob \
                     through RuntimeConfig, or register this file in \
                     lint::rules::ENV_READERS with its reason"
                ),
            );
        }
        if ENV_WRITES.contains(&call)
            && !cx.is_test_line(line)
            && !cx.suppressed("ENV-HYGIENE", line, allowable)
        {
            push(
                out,
                cx.rel,
                line,
                "ENV-HYGIENE",
                format!("`env::{call}` outside test code mutates process-global state"),
            );
        }
    }
    // every CREST_* string literal in non-test code must appear in README
    for t in toks {
        if t.kind != Kind::Str || cx.is_test_line(t.line) {
            continue;
        }
        for name in crest_names(&t.text) {
            if !readme.contains(&name) && !cx.suppressed("ENV-HYGIENE", t.line, allowable) {
                push(
                    out,
                    cx.rel,
                    t.line,
                    "ENV-HYGIENE",
                    format!("`{name}` is not documented in README.md's env table"),
                );
            }
        }
    }
}

/// Extract `CREST_*` env-var names from one string literal. Trailing
/// underscores are trimmed (prose like "CREST_BENCH_*" names a prefix,
/// not a variable); a bare "CREST_" matches nothing.
fn crest_names(s: &str) -> Vec<String> {
    let mut names = Vec::new();
    let bytes = s.as_bytes();
    let name_byte = |b: u8| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_';
    let mut i = 0;
    while let Some(pos) = s[i..].find("CREST_") {
        let start = i + pos;
        let mut end = start + "CREST_".len();
        while end < bytes.len() && name_byte(bytes[end]) {
            end += 1;
        }
        let name = s[start..end].trim_end_matches('_');
        if name.len() > "CREST_".len() {
            names.push(name.to_string());
        }
        i = end;
    }
    names
}

/// IO-FACADE: artifact modules perform file I/O only through the
/// `artifact_io` facade — no raw `fs::` / `File::` call-sites outside
/// `use` declarations, attributes, and test code. The facade is where
/// fault injection, bounded retries, CRC verification, and the
/// fsync-before-rename discipline live; a raw call-site silently
/// bypasses all four. One finding per line (`std::fs::File::open`
/// matches twice).
pub(crate) fn io_facade(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    if !in_modules(cx.rel, ARTIFACT_MODULES) || IO_FACADE_SCOPES.contains(&cx.rel) {
        return;
    }
    let toks = &cx.lx.toks;
    let mut last_line = 0;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "fs" && t.text != "File") {
            continue;
        }
        let qualified =
            toks.get(i + 1).is_some_and(|n| n.kind == Kind::Punct && n.text == "::");
        if !qualified {
            continue; // type positions (`BufWriter<File>`) are fine
        }
        let line = t.line;
        if cx.use_tok[i] || cx.attr_tok[i] || cx.is_test_line(line) {
            continue;
        }
        if line == last_line || cx.suppressed("IO-FACADE", line, allowable) {
            continue;
        }
        last_line = line;
        push(
            out,
            cx.rel,
            line,
            "IO-FACADE",
            format!(
                "raw `{}::` call in an artifact module bypasses the artifact_io \
                 facade (fault injection, retries, CRC, fsync); route the I/O \
                 through util::artifact_io or justify with \
                 `// lint:allow(IO-FACADE) reason`",
                t.text
            ),
        );
    }
}

/// ISA-DISPATCH: `#[target_feature]` bodies live only in `kernel.rs`,
/// stay private, and are reachable only through the `KernelIsa` dispatch
/// wrappers — no direct `avx2::` or `is_x86_feature_detected!` use
/// elsewhere.
pub(crate) fn isa_dispatch(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    let toks = &cx.lx.toks;
    let in_kernel = cx.rel == "rust/src/kernel.rs";
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        let line = t.line;
        if !in_kernel {
            let bad = match t.text.as_str() {
                "target_feature" => Some(
                    "#[target_feature] outside kernel.rs: ISA-specific code \
                     belongs behind the KernelIsa dispatch table",
                ),
                "is_x86_feature_detected" => Some(
                    "feature detection outside kernel.rs: resolve_isa is the \
                     one dispatch decision point",
                ),
                "avx2" if i + 1 < toks.len() && toks[i + 1].text == "::" => Some(
                    "direct `avx2::` call outside kernel.rs: use the public \
                     `_isa` kernel wrappers so dispatch stays centralized",
                ),
                _ => None,
            };
            if let Some(msg) = bad {
                if !cx.suppressed("ISA-DISPATCH", line, allowable) {
                    push(out, cx.rel, line, "ISA-DISPATCH", msg.to_string());
                }
            }
        } else if t.text == "target_feature" && cx.attr_tok[i] {
            // the attributed fn must be private: scan from the end of the
            // attribute stack to the `fn` keyword for a `pub`
            let mut k = i;
            while k < toks.len() && cx.attr_tok[k] {
                k += 1;
            }
            let mut is_pub = false;
            while k < toks.len() && !(toks[k].kind == Kind::Ident && toks[k].text == "fn") {
                if toks[k].kind == Kind::Ident && toks[k].text == "pub" {
                    is_pub = true;
                }
                k += 1;
            }
            if is_pub && !cx.suppressed("ISA-DISPATCH", line, allowable) {
                push(
                    out,
                    cx.rel,
                    line,
                    "ISA-DISPATCH",
                    "#[target_feature] fn must be private: only the KernelIsa \
                     dispatch wrappers may reach ISA-specific bodies"
                        .to_string(),
                );
            }
        }
    }
}

/// LINT-ALLOW: every `lint:allow` directive must parse, name a real
/// rule, attach to a code line, and carry a written reason.
pub(crate) fn lint_allow(cx: &FileCx, allowable: &[&str], out: &mut Vec<Diagnostic>) {
    for a in &cx.allows {
        let problem = if a.rule.is_empty() {
            Some("malformed directive: expected `lint:allow(RULE-ID) reason`".to_string())
        } else if !allowable.contains(&a.rule.as_str()) {
            Some(format!("unknown rule id `{}` in lint:allow", a.rule))
        } else if !reason_ok(&a.reason) {
            Some(format!("lint:allow({}) carries no written reason", a.rule))
        } else if a.target == usize::MAX {
            Some(format!("lint:allow({}) has no code line to attach to", a.rule))
        } else {
            None
        };
        if let Some(msg) = problem {
            push(out, cx.rel, a.line, "LINT-ALLOW", msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lex::lex;

    fn cx_diags(rel: &str, src: &str) -> Vec<Diagnostic> {
        let lx = lex(src);
        let cx = FileCx::new(rel, &lx);
        let allowable = crate::lint::allowable_rules();
        let mut out = Vec::new();
        det_hash(&cx, &allowable, &mut out);
        det_clock(&cx, &allowable, &mut out);
        out
    }

    #[test]
    fn use_lines_and_tests_are_exempt() {
        let src = "use std::collections::HashMap;\n\
                   #[cfg(test)]\nmod tests {\n    fn f() { let m = HashMap::new(); }\n}\n";
        assert!(cx_diags("rust/src/coreset/x.rs", src).is_empty());
    }

    #[test]
    fn hash_in_code_fires_and_allow_suppresses() {
        let bad = "fn f() { let m = std::collections::HashMap::<u32, u32>::new(); }\n";
        let d = cx_diags("rust/src/sweep/x.rs", bad);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "DET-HASH");
        let ok = "// lint:allow(DET-HASH) lookup-only in this fixture\n\
                  fn f() { let m = std::collections::HashMap::<u32, u32>::new(); }\n";
        assert!(cx_diags("rust/src/sweep/x.rs", ok).is_empty());
    }

    #[test]
    fn module_scoping_is_prefix_based() {
        let bad = "fn f() { let m = std::collections::HashSet::<u32>::new(); }\n";
        assert!(!cx_diags("rust/src/coreset/deep/x.rs", bad).is_empty());
        assert!(cx_diags("rust/src/util/x.rs", bad).is_empty());
        assert!(cx_diags("rust/tests/x.rs", bad).is_empty());
    }

    #[test]
    fn crest_name_extraction() {
        assert_eq!(crest_names("CREST_THREADS"), vec!["CREST_THREADS"]);
        assert_eq!(crest_names("prefix CREST_BENCH_* prose"), vec!["CREST_BENCH"]);
        assert!(crest_names("CREST_ alone").is_empty());
        assert_eq!(crest_names("CREST_A and CREST_B"), vec!["CREST_A", "CREST_B"]);
    }
}
