//! Mean±std aggregation of completed cells into Table-1/2-style rows.
//!
//! Only deterministic report fields (accuracy, loss, step/update/exclusion
//! counts) are aggregated, so rows reproduce bitwise whether a cell was
//! restored from a checkpoint or re-executed on the bitwise-deterministic
//! native backend; wall-clock fields are intentionally left out.

use crate::config::Method;
use crate::metrics::relative_error_pct;
use crate::report::AggregateRow;
use crate::util::stats;

use super::CellResult;

/// Group completed cells by (variant, method, budget) in first-appearance
/// (= grid) order and fold each group's seeds into mean±std. Relative
/// error vs full-data training (paper Table 1) is computed per seed
/// against the `full` cell of the same (variant, seed); the rel-err
/// columns stay `None` unless every seed in the group has that reference.
pub fn aggregate(cells: &[CellResult]) -> Vec<AggregateRow> {
    let full_acc = |variant: &str, seed: u64| -> Option<f32> {
        cells
            .iter()
            .find(|c| {
                c.key.method.is_reference() && c.key.variant == variant && c.key.seed == seed
            })
            .map(|c| c.report.final_test_acc)
    };

    // group in first-appearance order (stable across resumes: cells come
    // in grid order regardless of which were restored)
    let mut groups: Vec<(String, Method, f32, Vec<&CellResult>)> = Vec::new();
    for c in cells {
        match groups.iter_mut().find(|(v, m, b, _)| {
            *v == c.key.variant && *m == c.key.method && *b == c.key.budget_frac
        }) {
            Some((_, _, _, members)) => members.push(c),
            None => {
                groups.push((c.key.variant.clone(), c.key.method, c.key.budget_frac, vec![c]))
            }
        }
    }

    groups
        .into_iter()
        .map(|(variant, method, budget_frac, members)| {
            let accs: Vec<f32> = members.iter().map(|c| c.report.final_test_acc).collect();
            let losses: Vec<f32> = members.iter().map(|c| c.report.final_test_loss).collect();
            let rels: Vec<f32> = members
                .iter()
                .filter_map(|c| {
                    full_acc(&c.key.variant, c.key.seed).map(|fa| {
                        relative_error_pct(c.report.final_test_acc * 100.0, fa * 100.0)
                    })
                })
                .collect();
            let steps: Vec<f32> = members.iter().map(|c| c.report.steps as f32).collect();
            let updates: Vec<f32> =
                members.iter().map(|c| c.report.n_selection_updates as f32).collect();
            let excluded: Vec<f32> = members.iter().map(|c| c.report.n_excluded as f32).collect();
            let have_all_refs = rels.len() == members.len();
            AggregateRow {
                variant,
                method: method.name().to_string(),
                budget_frac,
                n_seeds: members.len(),
                acc_mean: stats::mean(&accs),
                acc_std: stats::stddev(&accs),
                loss_mean: stats::mean(&losses),
                rel_err_mean: have_all_refs.then(|| stats::mean(&rels)),
                rel_err_std: have_all_refs.then(|| stats::stddev(&rels)),
                steps_mean: stats::mean(&steps),
                updates_mean: stats::mean(&updates),
                excluded_mean: stats::mean(&excluded),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::RunReport;
    use crate::sweep::CellKey;

    fn cell(method: Method, seed: u64, acc: f32) -> CellResult {
        CellResult {
            key: CellKey {
                variant: "v".to_string(),
                method,
                seed,
                budget_frac: 0.1,
            },
            report: RunReport {
                method: method.name().to_string(),
                variant: "v".to_string(),
                seed,
                final_test_acc: acc,
                final_test_loss: 1.0,
                steps: 10,
                n_selection_updates: 4,
                n_excluded: 2,
                ..Default::default()
            },
            executed: true,
        }
    }

    #[test]
    fn aggregates_match_hand_computed_values() {
        let cells = vec![
            cell(Method::full(), 1, 0.9),
            cell(Method::full(), 2, 0.8),
            cell(Method::crest(), 1, 0.6),
            cell(Method::crest(), 2, 0.7),
        ];
        let rows = aggregate(&cells);
        assert_eq!(rows.len(), 2, "one row per (variant, method, budget) group");

        let crest = &rows[1];
        assert_eq!(crest.method, "crest");
        assert_eq!(crest.n_seeds, 2);
        // mean(0.6, 0.7) = 0.65; population std = |0.6 - 0.7| / 2 = 0.05
        assert!((crest.acc_mean - 0.65).abs() < 1e-6, "acc_mean {}", crest.acc_mean);
        assert!((crest.acc_std - 0.05).abs() < 1e-6, "acc_std {}", crest.acc_std);
        // rel err per seed (Table 1 definition, percent scale):
        //   seed 1: |60 - 90| / 60 · 100 = 50
        //   seed 2: |70 - 80| / 70 · 100 = 100/7 ≈ 14.2857
        let r1 = 50.0f32;
        let r2 = 100.0f32 / 7.0;
        let m = crest.rel_err_mean.expect("full refs present for both seeds");
        let s = crest.rel_err_std.unwrap();
        assert!((m - (r1 + r2) / 2.0).abs() < 1e-3, "rel_err_mean {m}");
        assert!((s - (r1 - r2) / 2.0).abs() < 1e-3, "rel_err_std {s}");
        // count means
        assert!((crest.steps_mean - 10.0).abs() < 1e-6);
        assert!((crest.updates_mean - 4.0).abs() < 1e-6);
        assert!((crest.excluded_mean - 2.0).abs() < 1e-6);

        // the full group's relative error vs itself is exactly 0
        assert_eq!(rows[0].method, "full");
        assert_eq!(rows[0].rel_err_mean, Some(0.0));
    }

    #[test]
    fn rel_err_absent_unless_every_seed_has_a_full_reference() {
        // full run only for seed 1 -> the 2-seed crest group has no rel err
        let cells = vec![
            cell(Method::full(), 1, 0.9),
            cell(Method::crest(), 1, 0.6),
            cell(Method::crest(), 2, 0.7),
        ];
        let rows = aggregate(&cells);
        let crest = rows.iter().find(|r| r.method == "crest").unwrap();
        assert_eq!(crest.rel_err_mean, None);
        assert_eq!(crest.rel_err_std, None);
        // accuracy aggregation is unaffected
        assert!((crest.acc_mean - 0.65).abs() < 1e-6);
    }

    #[test]
    fn aggregate_is_deterministic_over_identical_inputs() {
        let cells = vec![
            cell(Method::full(), 1, 0.91),
            cell(Method::crest(), 1, 0.63),
        ];
        let render = || -> Vec<String> {
            aggregate(&cells).iter().map(|r| r.to_json().to_string_pretty()).collect()
        };
        assert_eq!(render(), render());
    }
}
