//! Grid expansion: the (variant × budget × method × seed) cross product
//! in a stable order, plus the comma-list parsers behind the `crest sweep`
//! CLI flags.

use anyhow::{bail, Context, Result};

use crate::config::Method;
use crate::util::json::Json;

/// Identity of one sweep cell. The paper's tables and figures index every
/// number by exactly this tuple, and the checkpoint store keys resume on
/// it: a cell is reproducible from its key alone (all RNG streams derive
/// from `seed`, the corpus from `(variant, seed)`).
#[derive(Debug, Clone, PartialEq)]
pub struct CellKey {
    /// Model/dataset variant name (`config::ALL_VARIANTS` or `smoke`).
    pub variant: String,
    /// Training method driving the cell (a registry handle).
    pub method: Method,
    /// Experiment seed (data, init, subsets and probes all derive from it).
    pub seed: u64,
    /// Training budget as a fraction of the full run's backprops.
    pub budget_frac: f32,
}

impl CellKey {
    /// Stable checkpoint file name — the on-disk resume identity.
    pub fn file_name(&self) -> String {
        format!(
            "{}__{}__s{}__b{}.json",
            self.variant,
            self.method.name(),
            self.seed,
            self.budget_frac
        )
    }

    /// Human-readable cell label for logs and error contexts.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/seed={}/budget={}",
            self.variant,
            self.method.name(),
            self.seed,
            self.budget_frac
        )
    }

    /// Key as a JSON object (stored inside each checkpoint so stale or
    /// renamed files can be detected on load).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("variant", self.variant.as_str())
            .set("method", self.method.name())
            .set("seed", self.seed)
            .set("budget_frac", self.budget_frac)
    }

    /// Parse a key written by [`CellKey::to_json`].
    pub fn from_json(j: &Json) -> Result<CellKey> {
        Ok(CellKey {
            variant: j.req("variant")?.as_str()?.to_string(),
            method: Method::parse(j.req("method")?.as_str()?)?,
            seed: j.req("seed")?.as_f64()? as u64,
            budget_frac: j.req("budget_frac")?.as_f64()? as f32,
        })
    }
}

/// A requested sweep grid. [`SweepGrid::cells`] expands the cross product
/// with variants outermost, then budgets, methods, and seeds innermost —
/// a stable order, so cell indices and aggregate rows never depend on
/// scheduling.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Variant names to sweep.
    pub variants: Vec<String>,
    /// Methods to run per variant.
    pub methods: Vec<Method>,
    /// Seeds per (variant, method, budget) group — the mean±std axis.
    pub seeds: Vec<u64>,
    /// Budget fractions to sweep.
    pub budgets: Vec<f32>,
}

impl SweepGrid {
    /// Expand to the full cell list in grid order.
    ///
    /// The `full` method ignores the budget (the coordinator always trains
    /// it on 100% of the data), so its cells are normalized to
    /// `budget_frac = 1` and emitted once per (variant, seed) — a
    /// multi-budget grid never re-trains or mislabels the reference run.
    /// Duplicate entries in the input lists expand to duplicate keys and
    /// are dropped, so repeated CLI values cannot double-count a seed in
    /// the aggregates or race two workers on one checkpoint file.
    pub fn cells(&self) -> Vec<CellKey> {
        let mut out: Vec<CellKey> = Vec::with_capacity(
            self.variants.len() * self.budgets.len() * self.methods.len() * self.seeds.len(),
        );
        for variant in &self.variants {
            for (bi, &budget) in self.budgets.iter().enumerate() {
                for &method in &self.methods {
                    if method.is_reference() && bi > 0 {
                        continue;
                    }
                    let budget_frac = if method.is_reference() { 1.0 } else { budget };
                    for &seed in &self.seeds {
                        let key = CellKey {
                            variant: variant.clone(),
                            method,
                            seed,
                            budget_frac,
                        };
                        if !out.contains(&key) {
                            out.push(key);
                        }
                    }
                }
            }
        }
        out
    }
}

/// Parse a comma-separated variant list (`cifar10-proxy,smoke`).
pub fn parse_variants(s: &str) -> Result<Vec<String>> {
    let out: Vec<String> =
        s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(str::to_string).collect();
    if out.is_empty() {
        bail!("empty variant list");
    }
    Ok(out)
}

/// Parse a comma-separated method list (`crest,random`); any registered
/// method name or alias is accepted.
pub fn parse_methods(s: &str) -> Result<Vec<Method>> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(Method::parse(tok)?);
    }
    if out.is_empty() {
        bail!("empty method list");
    }
    Ok(out)
}

/// Parse a comma-separated seed list (`1,2,3`).
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(tok.parse::<u64>().with_context(|| format!("bad seed {tok:?}"))?);
    }
    if out.is_empty() {
        bail!("empty seed list");
    }
    Ok(out)
}

/// Parse a comma-separated budget-fraction list (`0.1,0.2`); each entry
/// must be in (0, 1].
pub fn parse_budgets(s: &str) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let b: f32 = tok.parse().with_context(|| format!("bad budget {tok:?}"))?;
        if !(b > 0.0 && b <= 1.0) {
            bail!("budget {b} out of (0, 1]");
        }
        out.push(b);
    }
    if out.is_empty() {
        bail!("empty budget list");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_expand_in_stable_grid_order() {
        let grid = SweepGrid {
            variants: vec!["a".to_string(), "b".to_string()],
            methods: vec![Method::crest(), Method::random()],
            seeds: vec![1, 2],
            budgets: vec![0.1],
        };
        let cells = grid.cells();
        assert_eq!(cells.len(), 8);
        // variants outermost, seeds innermost
        assert_eq!(cells[0].label(), "a/crest/seed=1/budget=0.1");
        assert_eq!(cells[1].label(), "a/crest/seed=2/budget=0.1");
        assert_eq!(cells[2].label(), "a/random/seed=1/budget=0.1");
        assert_eq!(cells[4].variant, "b");
        // expansion is deterministic
        assert_eq!(cells, grid.cells());
    }

    #[test]
    fn duplicate_grid_entries_expand_to_unique_cells() {
        let grid = SweepGrid {
            variants: vec!["v".to_string()],
            methods: vec![Method::crest(), Method::crest()],
            seeds: vec![1, 1, 2],
            budgets: vec![0.1],
        };
        let cells = grid.cells();
        assert_eq!(cells.len(), 2, "duplicates must not double-count or race");
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].seed, 2);
    }

    #[test]
    fn full_cells_normalize_budget_and_dedupe_across_budgets() {
        let grid = SweepGrid {
            variants: vec!["v".to_string()],
            methods: vec![Method::full(), Method::crest()],
            seeds: vec![1, 2],
            budgets: vec![0.1, 0.2],
        };
        let cells = grid.cells();
        // full: once per seed at budget 1; crest: once per (budget, seed)
        let fulls: Vec<&CellKey> =
            cells.iter().filter(|c| c.method == Method::full()).collect();
        assert_eq!(fulls.len(), 2, "one full cell per seed, not per budget");
        assert!(fulls.iter().all(|c| c.budget_frac == 1.0));
        let crests = cells.iter().filter(|c| c.method == Method::crest()).count();
        assert_eq!(crests, 4);
        assert_eq!(cells.len(), 6);
    }

    #[test]
    fn file_name_is_stable() {
        let key = CellKey {
            variant: "smoke".to_string(),
            method: Method::crest(),
            seed: 1,
            budget_frac: 0.1,
        };
        assert_eq!(key.file_name(), "smoke__crest__s1__b0.1.json");
    }

    #[test]
    fn key_json_roundtrip() {
        let key = CellKey {
            variant: "cifar10-proxy".to_string(),
            method: Method::greedy_per_batch(),
            seed: 7,
            budget_frac: 0.2,
        };
        let j = Json::parse(&key.to_json().to_string_pretty()).unwrap();
        assert_eq!(CellKey::from_json(&j).unwrap(), key);
    }

    #[test]
    fn parsers_accept_lists_and_reject_garbage() {
        assert_eq!(parse_variants("a, b").unwrap(), vec!["a", "b"]);
        assert_eq!(
            parse_methods("crest, random").unwrap(),
            vec![Method::crest(), Method::random()]
        );
        assert_eq!(parse_seeds("1,2, 3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_budgets("0.1,1.0").unwrap(), vec![0.1, 1.0]);
        assert!(parse_methods("crest,bogus").is_err());
        assert!(parse_seeds("1,x").is_err());
        assert!(parse_budgets("0.0").is_err());
        assert!(parse_budgets("1.5").is_err());
        assert!(parse_seeds("").is_err());
    }
}
