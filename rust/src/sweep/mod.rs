//! Sweep orchestration: many experiment cells as one resumable unit.
//!
//! The [`crate::coordinator`] owns one (variant, method, seed, budget)
//! cell; this layer owns the grid. It expands a [`SweepGrid`] into
//! [`CellKey`]s, restores already-completed cells from a per-cell
//! [`CheckpointStore`], schedules the missing ones over the shared thread
//! pool (`util::pool`), persists each as it finishes, and folds the seeds
//! of every (variant, method, budget) group into mean±std
//! [`AggregateRow`]s — the shape of the paper's Tables 1/2.
//!
//! Determinism: each cell is reproduced entirely from its key (the proxy
//! corpus from `(variant, seed)`, every RNG stream from `seed`), so
//! scheduling order and the jobs count never affect results. Cell workers
//! are pool threads, so the backend and selection kernels they invoke run
//! inline (nested pool calls never oversubscribe), and because every inner
//! reduction is chunk-deterministic the per-cell reports are
//! bitwise-identical whether cells run serially, in parallel, or are
//! restored from checkpoints. Aggregates use only deterministic report
//! fields, so an interrupted-and-resumed sweep reproduces the aggregate
//! of an uninterrupted one bitwise.

pub mod agg;
pub mod grid;
pub mod store;

pub use grid::{CellKey, SweepGrid};
pub use store::{CheckpointLoad, CheckpointStore};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::api::{Experiment, SelectionStrategy};
use crate::data::{prepare_splits, Splits};
use crate::report::{AggregateRow, RunReport};
use crate::util::pool::{self, Pool};

/// A full sweep request: the grid plus execution knobs.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// The (variant × method × seed × budget) grid to run.
    pub grid: SweepGrid,
    /// Epochs of the full-data reference run (the budget denominator).
    pub epochs_full: usize,
    /// Artifact root consulted for manifest overrides; the native backend
    /// falls back to builtin manifests when the directory is absent.
    pub artifact_root: PathBuf,
    /// Selection strategy applied to every cell (part of checkpoint
    /// identity: cells checkpointed under a different strategy re-run).
    pub selection: SelectionStrategy,
    /// Checkpoint directory; `None` disables resume.
    pub checkpoint_dir: Option<PathBuf>,
    /// Cells scheduled concurrently. 0 = auto: the pool's global worker
    /// count, degrading to serial when fewer cells are pending than
    /// workers (serial cells keep their inner kernels fully parallel).
    /// An explicit value is always honored.
    pub jobs: usize,
}

impl SweepSpec {
    /// Spec over `grid` with default knobs: default artifact root, resume
    /// disabled, cells scheduled across the whole pool.
    pub fn new(grid: SweepGrid, epochs_full: usize) -> SweepSpec {
        SweepSpec {
            grid,
            epochs_full,
            artifact_root: PathBuf::from("artifacts"),
            selection: SelectionStrategy::Exact,
            checkpoint_dir: None,
            jobs: 0,
        }
    }
}

/// One completed cell of a sweep.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Cell identity.
    pub key: CellKey,
    /// The cell's run report (fresh or restored).
    pub report: RunReport,
    /// False when the report was restored from the checkpoint store
    /// instead of executing in this invocation.
    pub executed: bool,
}

/// One cell that failed (an error or a panic) while the rest of the
/// grid completed.
#[derive(Debug, Clone)]
pub struct CellFailure {
    /// Identity of the failed cell.
    pub key: CellKey,
    /// Rendered error (or panic payload) text.
    pub error: String,
}

/// Everything a finished sweep produced.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Per-cell results in grid order (failed cells excluded).
    pub cells: Vec<CellResult>,
    /// Mean±std rows per (variant, method, budget) group, in grid order.
    pub rows: Vec<AggregateRow>,
    /// Cells whose execution errored or panicked, in grid order.
    pub failed: Vec<CellFailure>,
    /// Cells whose checkpoint existed but could not be trusted (corrupt,
    /// torn, or identity-mismatched) and were therefore recomputed.
    pub recovered: usize,
}

impl SweepOutcome {
    /// Cells that actually executed in this invocation.
    pub fn n_executed(&self) -> usize {
        self.cells.iter().filter(|c| c.executed).count()
    }

    /// Cells restored from checkpoints.
    pub fn n_restored(&self) -> usize {
        self.cells.len() - self.n_executed()
    }

    /// Error out when any cell failed, listing every failed cell — the
    /// strict contract behind [`run`].
    pub fn ensure_complete(&self) -> Result<()> {
        if self.failed.is_empty() {
            return Ok(());
        }
        let list: Vec<String> =
            self.failed.iter().map(|f| format!("  {}: {}", f.key.label(), f.error)).collect();
        bail!("{} sweep cell(s) failed:\n{}", self.failed.len(), list.join("\n"))
    }
}

/// Generate the proxy corpus a cell trains on. The data derives only
/// from (variant, seed), never from the method or budget — which is what
/// lets [`run`] share one corpus across every cell of a (variant, seed)
/// pair.
pub fn cell_splits(key: &CellKey) -> Result<Arc<Splits>> {
    prepare_splits(&key.variant, key.seed)
        .with_context(|| format!("preparing corpus for variant {:?}", key.variant))
}

/// Run one cell against prepared splits (the caller owns corpus reuse).
/// The cell key maps one-to-one onto the [`Experiment`] builder.
fn run_cell_on(
    key: &CellKey,
    epochs_full: usize,
    selection: SelectionStrategy,
    artifact_root: &Path,
    splits: Arc<Splits>,
) -> Result<RunReport> {
    Experiment::builder()
        .variant(&key.variant)
        .with_method(key.method)
        .seed(key.seed)
        .budget_frac(key.budget_frac)
        .epochs_full(epochs_full)
        .selection(selection)
        .artifact_root(artifact_root)
        .splits(splits)
        .build()?
        .run()
}

/// Run one cell from scratch under exact selection: load the variant
/// runtime, regenerate its proxy corpus from the cell seed, and drive the
/// coordinator. Everything derives from the key (plus `epochs_full`), so a
/// cell is reproducible in isolation — the unit of resume.
pub fn run_cell(key: &CellKey, epochs_full: usize, artifact_root: &Path) -> Result<RunReport> {
    run_cell_on(key, epochs_full, SelectionStrategy::Exact, artifact_root, cell_splits(key)?)
}

/// Render a panic payload for a failed-cell record. Panics raised by
/// `panic!("...")` carry a `&str` or `String`; anything else (a
/// `panic_any` value) gets a fixed placeholder.
fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Execute a sweep: restore completed cells from the checkpoint store,
/// schedule the missing ones over the thread pool, persist each as it
/// finishes, and aggregate. Like [`run`], but a failing cell — an error
/// or a panic — becomes a [`CellFailure`] record in the outcome instead
/// of an error: the rest of the grid completes, its cells stay
/// checkpointed, and the caller decides whether a partial table is
/// acceptable. Only infrastructure errors (an unopenable checkpoint
/// directory) fail the call itself.
pub fn run_collect(spec: &SweepSpec) -> Result<SweepOutcome> {
    let cells = spec.grid.cells();
    let store = match &spec.checkpoint_dir {
        Some(dir) => Some(CheckpointStore::open(dir)?),
        None => None,
    };
    let sel = spec.selection.to_string();
    let mut recovered = 0usize;
    let mut restored: Vec<Option<RunReport>> = cells
        .iter()
        .map(|k| match &store {
            None => None,
            Some(s) => match s.load_outcome(k, spec.epochs_full, &sel) {
                CheckpointLoad::Restored(r) => Some(*r),
                CheckpointLoad::Missing => None,
                CheckpointLoad::Recovered => {
                    recovered += 1;
                    None
                }
            },
        })
        .collect();
    let todo: Vec<usize> = (0..cells.len()).filter(|&i| restored[i].is_none()).collect();
    log::info!(
        "sweep: {} cells ({} checkpointed, {} to run)",
        cells.len(),
        cells.len() - todo.len(),
        todo.len()
    );

    // One corpus per (variant, seed), shared by every method/budget cell
    // of that pair. A race may generate a pair twice; the first insert
    // wins and both copies are identical, so results are unaffected.
    // lint:allow(DET-HASH) membership-only cache: keyed get/insert, never
    // iterated, so hash order cannot reach any result
    let splits_cache: Mutex<HashMap<(String, u64), Arc<Splits>>> = Mutex::new(HashMap::new());
    let splits_for = |key: &CellKey| -> Result<Arc<Splits>> {
        let pair = (key.variant.clone(), key.seed);
        if let Some(s) = splits_cache.lock().unwrap().get(&pair) {
            return Ok(s.clone());
        }
        let generated = cell_splits(key)?;
        Ok(splits_cache.lock().unwrap().entry(pair).or_insert(generated).clone())
    };

    // Outer-parallel cells force their inner kernels to run inline (see
    // util::pool nesting). In auto mode (jobs = 0), when there are fewer
    // cells than workers the machine is better spent inside each cell, so
    // fall back to serial scheduling and keep the kernels' full
    // parallelism; an explicit --jobs request is always honored.
    let jobs = match spec.jobs {
        0 => {
            let t = pool::threads();
            if todo.len() < t {
                1
            } else {
                t
            }
        }
        j => j,
    };
    let fresh: Vec<Result<RunReport, String>> = Pool::new(jobs).map(todo.len(), |t| {
        let key = &cells[todo[t]];
        log::info!("sweep cell {} ({}/{})", key.label(), t + 1, todo.len());
        // A panicking cell must not take the grid down with it: catch the
        // unwind here, inside the worker, and turn it into a failed-cell
        // record. AssertUnwindSafe is sound because a failed cell's
        // captures are never reused — its only output is the error string.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> Result<RunReport> {
                let splits = splits_for(key)?;
                run_cell_on(key, spec.epochs_full, spec.selection, &spec.artifact_root, splits)
            },
        ));
        let report = match caught {
            Ok(Ok(r)) => r,
            Ok(Err(e)) => return Err(format!("{e:#}")),
            Err(payload) => return Err(format!("panicked: {}", panic_text(&*payload))),
        };
        if let Some(s) = &store {
            // A lost checkpoint only costs recomputation on the next
            // resume; the in-memory report is intact, so the cell counts
            // as completed.
            if let Err(e) = s.save(key, spec.epochs_full, &sel, &report) {
                log::warn!("checkpoint save failed for {}: {e:#}", key.label());
            }
        }
        Ok(report)
    });

    let mut fresh_iter = fresh.into_iter();
    let mut out: Vec<CellResult> = Vec::with_capacity(cells.len());
    let mut failed: Vec<CellFailure> = Vec::new();
    for (i, key) in cells.into_iter().enumerate() {
        match restored[i].take() {
            Some(report) => out.push(CellResult { key, report, executed: false }),
            None => match fresh_iter.next().expect("sweep bookkeeping: missing fresh result") {
                Ok(report) => out.push(CellResult { key, report, executed: true }),
                Err(error) => failed.push(CellFailure { key, error }),
            },
        }
    }
    let rows = agg::aggregate(&out);
    Ok(SweepOutcome { cells: out, rows, failed, recovered })
}

/// Execute a sweep with strict semantics: any failed cell fails the call,
/// listing every failed cell. Errors propagate after the whole batch has
/// been attempted, so completed cells are checkpointed even when a
/// sibling cell fails — the failed sweep resumes instead of restarting.
pub fn run(spec: &SweepSpec) -> Result<SweepOutcome> {
    let outcome = run_collect(spec)?;
    outcome.ensure_complete()?;
    Ok(outcome)
}
