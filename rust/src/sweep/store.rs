//! Resumable on-disk checkpoint store: one JSON file per completed cell.
//!
//! Layout: `<dir>/<variant>__<method>__s<seed>__b<budget>.json`, each file
//! holding `{"key": ..., "epochs_full": ..., "selection": ..., "report":
//! ..., "crc": ...}`. Writes publish through the
//! [`artifact_io`](crate::util::artifact_io) facade (temp file + fsync +
//! rename + parent fsync), so neither an interrupted sweep nor a power
//! cut can leave a half-written checkpoint under the real name; the
//! `crc` field is a CRC-32 of the serialized report, verified on load.
//!
//! A cell's checkpoint [`load_outcome`](CheckpointStore::load_outcome)
//! is three-valued: `Restored` (verified, identity matches), `Missing`
//! (no file — the quiet first-run case), or `Recovered` (a file exists
//! but is corrupt, unparseable, CRC-mismatched, or belongs to a
//! different experiment identity). `Recovered` logs one warning naming
//! the file and the cell re-executes; the sweep summary surfaces the
//! count so silent corruption can't hide inside "0 restored".

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::report::RunReport;
use crate::util::artifact_io::{self, READ_DETECTED, WRITE_DEGRADED};
use crate::util::faults::Site;
use crate::util::json::Json;

use super::grid::CellKey;

/// Handle to a checkpoint directory.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
}

/// Classified result of a checkpoint lookup.
#[derive(Debug)]
pub enum CheckpointLoad {
    /// A verified checkpoint with matching identity: use the report.
    Restored(Box<RunReport>),
    /// No checkpoint file — the quiet first-run case.
    Missing,
    /// A file exists but could not be trusted (corrupt, unparseable,
    /// CRC mismatch, or different experiment identity). A warning
    /// naming the file has been logged; the cell must re-execute.
    Recovered,
}

impl CheckpointStore {
    /// Open the store at `dir`, creating the directory if needed.
    pub fn open(dir: &Path) -> Result<CheckpointStore> {
        artifact_io::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir: dir.to_path_buf() })
    }

    /// Checkpoint path for one cell.
    pub fn path(&self, key: &CellKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Load the completed report for `key`, or `None` when the cell has no
    /// trustworthy checkpoint matching the key, the requested
    /// `epochs_full`, and the `selection` strategy — the compatibility
    /// wrapper over [`CheckpointStore::load_outcome`].
    pub fn load(&self, key: &CellKey, epochs_full: usize, selection: &str) -> Option<RunReport> {
        match self.load_outcome(key, epochs_full, selection) {
            CheckpointLoad::Restored(r) => Some(*r),
            CheckpointLoad::Missing | CheckpointLoad::Recovered => None,
        }
    }

    /// Classified checkpoint lookup. `epochs_full` is part of the
    /// identity because it sets the budget denominator, and `selection`
    /// (canonical display form) because an approximate strategy changes
    /// what the cell trained on; a cell checkpointed under either knob
    /// set differently is a different experiment and must not be
    /// restored silently. Checkpoints written before the selection layer
    /// carry no `selection` field and read as `"exact"`; checkpoints
    /// written before integrity landed carry no `crc` field and skip
    /// content verification. (Artifact-root manifest overrides are *not*
    /// tracked; point different roots at different checkpoint dirs.)
    pub fn load_outcome(
        &self,
        key: &CellKey,
        epochs_full: usize,
        selection: &str,
    ) -> CheckpointLoad {
        let path = self.path(key);
        let recovered = |reason: &str| {
            log::warn!(
                "checkpoint {}: {reason}; the cell will be recomputed",
                path.display()
            );
            CheckpointLoad::Recovered
        };
        let text = match artifact_io::read_to_string_with(Site::CkptRead, &path, READ_DETECTED) {
            Ok(text) => text,
            Err(e) if e.is_not_found() => return CheckpointLoad::Missing,
            Err(e) => return recovered(&e.to_string()),
        };
        let Ok(doc) = Json::parse(&text) else {
            return recovered("unparseable JSON");
        };
        let Some(report_doc) = doc.get("report") else {
            return recovered("no report field");
        };
        if let Some(crc_doc) = doc.get("crc") {
            let stored = crc_doc.as_usize().ok();
            let got = artifact_io::crc32(report_doc.to_string_pretty().as_bytes()) as usize;
            if stored != Some(got) {
                return recovered("report CRC-32 mismatch (torn or flipped bytes)");
            }
        }
        let identity_ok = (|| {
            let stored = CellKey::from_json(doc.get("key")?).ok()?;
            if stored != *key || doc.get("epochs_full")?.as_usize().ok()? != epochs_full {
                return None;
            }
            let stored_sel = match doc.get("selection") {
                Some(v) => v.as_str().ok()?.to_string(),
                None => "exact".to_string(),
            };
            (stored_sel == selection).then_some(())
        })()
        .is_some();
        if !identity_ok {
            return recovered("identity mismatch (different key, epochs_full, or selection)");
        }
        match RunReport::from_json(report_doc) {
            Ok(r) => CheckpointLoad::Restored(Box::new(r)),
            Err(_) => recovered("malformed report"),
        }
    }

    /// Persist a completed cell atomically (temp file + fsync + rename +
    /// parent fsync), stamping the serialized report's CRC-32.
    pub fn save(
        &self,
        key: &CellKey,
        epochs_full: usize,
        selection: &str,
        report: &RunReport,
    ) -> Result<()> {
        let report_doc = report.to_json();
        let crc = artifact_io::crc32(report_doc.to_string_pretty().as_bytes());
        let doc = Json::obj()
            .set("key", key.to_json())
            .set("epochs_full", epochs_full)
            .set("selection", selection)
            .set("report", report_doc)
            .set("crc", crc as usize);
        let path = self.path(key);
        artifact_io::publish_with(
            Site::CkptWrite,
            &path,
            doc.to_string_pretty().as_bytes(),
            WRITE_DEGRADED,
        )
        .with_context(|| format!("checkpointing {}", key.label()))
    }

    /// Delete one cell's checkpoint; returns whether a file was removed.
    pub fn remove(&self, key: &CellKey) -> bool {
        let path = self.path(key);
        let existed = path.exists();
        let _ = artifact_io::remove_file(&path);
        existed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::util::json;

    fn tmp_store(tag: &str) -> CheckpointStore {
        let dir = std::env::temp_dir().join(format!("crest-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointStore::open(&dir).unwrap()
    }

    fn key(seed: u64) -> CellKey {
        CellKey {
            variant: "smoke".to_string(),
            method: Method::crest(),
            seed,
            budget_frac: 0.1,
        }
    }

    fn report(acc: f32) -> RunReport {
        RunReport {
            method: "crest".to_string(),
            variant: "smoke".to_string(),
            seed: 1,
            final_test_acc: acc,
            steps: 12,
            n_selection_updates: 3,
            rho_history: vec![(4, 0.5)],
            ..Default::default()
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_deterministic_fields() {
        let store = tmp_store("roundtrip");
        let k = key(1);
        assert!(store.load(&k, 2, "exact").is_none(), "empty store has no checkpoint");
        assert!(matches!(store.load_outcome(&k, 2, "exact"), CheckpointLoad::Missing));
        let r = report(0.75);
        store.save(&k, 2, "exact", &r).unwrap();
        let restored = store.load(&k, 2, "exact").expect("checkpoint restores");
        assert_eq!(
            restored.deterministic_json().to_string_pretty(),
            r.deterministic_json().to_string_pretty(),
            "deterministic report core must round-trip bitwise"
        );
        // a different epochs-full setting is a different experiment
        assert!(store.load(&k, 60, "exact").is_none(), "epochs_full mismatch must not restore");
        assert!(matches!(store.load_outcome(&k, 60, "exact"), CheckpointLoad::Recovered));
    }

    #[test]
    fn mismatched_or_corrupt_checkpoints_read_as_missing() {
        let store = tmp_store("corrupt");
        let k = key(1);
        store.save(&k, 2, "exact", &report(0.5)).unwrap();
        // same file, different key -> missing (stale dir protection)
        let other = key(2);
        std::fs::rename(store.path(&k), store.path(&other)).unwrap();
        assert!(store.load(&other, 2, "exact").is_none(), "key mismatch must not restore");
        // corrupt file -> missing, not an error
        std::fs::write(store.path(&k), "{truncated").unwrap();
        assert!(store.load(&k, 2, "exact").is_none(), "corrupt checkpoint must read as missing");
        assert!(matches!(store.load_outcome(&k, 2, "exact"), CheckpointLoad::Recovered));
    }

    #[test]
    fn crc_detects_flipped_report_bytes() {
        let store = tmp_store("crc");
        let k = key(1);
        store.save(&k, 2, "exact", &report(0.625)).unwrap();
        let path = store.path(&k);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit inside the serialized report's accuracy digits —
        // the document stays parseable, only the CRC can catch it
        let at = bytes.windows(5).position(|w| w == b"0.625").expect("acc in doc") + 2;
        bytes[at] ^= 0x01; // '6' -> '7'
        std::fs::write(&path, &bytes).unwrap();
        assert!(store.load(&k, 2, "exact").is_none(), "flipped byte must not restore");
        assert!(matches!(store.load_outcome(&k, 2, "exact"), CheckpointLoad::Recovered));
    }

    #[test]
    fn selection_mismatch_and_legacy_checkpoints() {
        let store = tmp_store("selection");
        let k = key(1);
        store.save(&k, 2, "clustered:64", &report(0.5)).unwrap();
        assert!(store.load(&k, 2, "exact").is_none(), "selection mismatch must not restore");
        assert!(store.load(&k, 2, "clustered:64").is_some(), "matching strategy restores");
        // checkpoints from before the selection layer carry no selection
        // field (and none from before integrity carry a crc) and must
        // restore as exact only
        let legacy = Json::obj()
            .set("key", k.to_json())
            .set("epochs_full", 2usize)
            .set("report", report(0.5).to_json());
        json::write_atomic(&store.path(&k), &legacy).unwrap();
        assert!(store.load(&k, 2, "exact").is_some(), "legacy checkpoint reads as exact");
        assert!(store.load(&k, 2, "knn").is_none());
    }

    #[test]
    fn remove_deletes_exactly_one_cell() {
        let store = tmp_store("remove");
        let (a, b) = (key(1), key(2));
        store.save(&a, 2, "exact", &report(0.5)).unwrap();
        store.save(&b, 2, "exact", &report(0.6)).unwrap();
        assert!(store.remove(&a));
        assert!(!store.remove(&a), "second removal is a no-op");
        assert!(store.load(&a, 2, "exact").is_none());
        assert!(store.load(&b, 2, "exact").is_some(), "other cells untouched");
    }
}
