//! Resumable on-disk checkpoint store: one JSON file per completed cell.
//!
//! Layout: `<dir>/<variant>__<method>__s<seed>__b<budget>.json`, each file
//! holding `{"key": ..., "epochs_full": ..., "selection": ..., "report":
//! ...}`. Writes go through a temp file + rename, so an interrupted sweep
//! never leaves a half-written checkpoint that could poison a resume;
//! unreadable or key-mismatched files are treated as missing and the cell
//! simply re-executes.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::report::RunReport;
use crate::util::json::{self, Json};

use super::grid::CellKey;

/// Handle to a checkpoint directory.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open the store at `dir`, creating the directory if needed.
    pub fn open(dir: &Path) -> Result<CheckpointStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir: dir.to_path_buf() })
    }

    /// Checkpoint path for one cell.
    pub fn path(&self, key: &CellKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Load the completed report for `key`, or `None` when the cell has no
    /// readable checkpoint matching the key, the requested `epochs_full`,
    /// and the `selection` strategy (canonical display form) — the caller
    /// re-executes it. `epochs_full` is part of the identity because it
    /// sets the budget denominator, and `selection` because an approximate
    /// strategy changes what the cell trained on; a cell checkpointed
    /// under either knob set differently is a different experiment and
    /// must not be restored silently. Checkpoints written before the
    /// selection layer carry no `selection` field and read as `"exact"`.
    /// (Artifact-root manifest overrides are *not* tracked; point
    /// different roots at different checkpoint dirs.)
    pub fn load(&self, key: &CellKey, epochs_full: usize, selection: &str) -> Option<RunReport> {
        let text = std::fs::read_to_string(self.path(key)).ok()?;
        let doc = Json::parse(&text).ok()?;
        let stored = CellKey::from_json(doc.get("key")?).ok()?;
        if stored != *key || doc.get("epochs_full")?.as_usize().ok()? != epochs_full {
            return None;
        }
        let stored_sel = match doc.get("selection") {
            Some(v) => v.as_str().ok()?.to_string(),
            None => "exact".to_string(),
        };
        if stored_sel != selection {
            return None;
        }
        RunReport::from_json(doc.get("report")?).ok()
    }

    /// Persist a completed cell atomically (temp file + rename).
    pub fn save(
        &self,
        key: &CellKey,
        epochs_full: usize,
        selection: &str,
        report: &RunReport,
    ) -> Result<()> {
        let doc = Json::obj()
            .set("key", key.to_json())
            .set("epochs_full", epochs_full)
            .set("selection", selection)
            .set("report", report.to_json());
        json::write_atomic(&self.path(key), &doc)
            .with_context(|| format!("checkpointing {}", key.label()))
    }

    /// Delete one cell's checkpoint; returns whether a file was removed.
    pub fn remove(&self, key: &CellKey) -> bool {
        std::fs::remove_file(self.path(key)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn tmp_store(tag: &str) -> CheckpointStore {
        let dir = std::env::temp_dir().join(format!("crest-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointStore::open(&dir).unwrap()
    }

    fn key(seed: u64) -> CellKey {
        CellKey {
            variant: "smoke".to_string(),
            method: Method::crest(),
            seed,
            budget_frac: 0.1,
        }
    }

    fn report(acc: f32) -> RunReport {
        RunReport {
            method: "crest".to_string(),
            variant: "smoke".to_string(),
            seed: 1,
            final_test_acc: acc,
            steps: 12,
            n_selection_updates: 3,
            rho_history: vec![(4, 0.5)],
            ..Default::default()
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_deterministic_fields() {
        let store = tmp_store("roundtrip");
        let k = key(1);
        assert!(store.load(&k, 2, "exact").is_none(), "empty store has no checkpoint");
        let r = report(0.75);
        store.save(&k, 2, "exact", &r).unwrap();
        let restored = store.load(&k, 2, "exact").expect("checkpoint restores");
        assert_eq!(
            restored.deterministic_json().to_string_pretty(),
            r.deterministic_json().to_string_pretty(),
            "deterministic report core must round-trip bitwise"
        );
        // a different epochs-full setting is a different experiment
        assert!(store.load(&k, 60, "exact").is_none(), "epochs_full mismatch must not restore");
    }

    #[test]
    fn mismatched_or_corrupt_checkpoints_read_as_missing() {
        let store = tmp_store("corrupt");
        let k = key(1);
        store.save(&k, 2, "exact", &report(0.5)).unwrap();
        // same file, different key -> missing (stale dir protection)
        let other = key(2);
        std::fs::rename(store.path(&k), store.path(&other)).unwrap();
        assert!(store.load(&other, 2, "exact").is_none(), "key mismatch must not restore");
        // corrupt file -> missing, not an error
        std::fs::write(store.path(&k), "{truncated").unwrap();
        assert!(store.load(&k, 2, "exact").is_none(), "corrupt checkpoint must read as missing");
    }

    #[test]
    fn selection_mismatch_and_legacy_checkpoints() {
        let store = tmp_store("selection");
        let k = key(1);
        store.save(&k, 2, "clustered:64", &report(0.5)).unwrap();
        assert!(store.load(&k, 2, "exact").is_none(), "selection mismatch must not restore");
        assert!(store.load(&k, 2, "clustered:64").is_some(), "matching strategy restores");
        // checkpoints from before the selection layer carry no selection
        // field and must restore as exact only
        let legacy = Json::obj()
            .set("key", k.to_json())
            .set("epochs_full", 2usize)
            .set("report", report(0.5).to_json());
        json::write_atomic(&store.path(&k), &legacy).unwrap();
        assert!(store.load(&k, 2, "exact").is_some(), "legacy checkpoint reads as exact");
        assert!(store.load(&k, 2, "knn").is_none());
    }

    #[test]
    fn remove_deletes_exactly_one_cell() {
        let store = tmp_store("remove");
        let (a, b) = (key(1), key(2));
        store.save(&a, 2, "exact", &report(0.5)).unwrap();
        store.save(&b, 2, "exact", &report(0.6)).unwrap();
        assert!(store.remove(&a));
        assert!(!store.remove(&a), "second removal is a no-op");
        assert!(store.load(&a, 2, "exact").is_none());
        assert!(store.load(&b, 2, "exact").is_some(), "other cells untouched");
    }
}
