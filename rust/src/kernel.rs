//! Block-at-a-time CPU kernels — the shared compute substrate under both
//! hot paths (the native backend's matmuls and the facility-location
//! distance scans).
//!
//! Two kernel families live here:
//!
//! * **Register-tiled matmul microkernels** ([`add_matmul`],
//!   [`add_matmul_nt`], [`add_matmul_nt_masked`], [`accum_wgrad`]): fixed
//!   MR×NR output tiles accumulate in registers across the whole reduction
//!   dimension, so each output element is loaded/stored once instead of
//!   once per reduction step. Remainder rows/columns fall back to narrower
//!   tiles with identical per-element accumulation order.
//! * **Dot-product panels** ([`dot4`], [`dot4_rows`]): one probe row
//!   against a block of matrix rows, sharing the probe loads across the
//!   block — the building block of the blocked squared-distance kernels in
//!   `coreset::facility`.
//!
//! **Determinism contract.** Every tile and chunk boundary is a function
//! of the problem shape only — never the worker count — and every output
//! element accumulates its terms in a fixed order (ascending reduction
//! index; [`dot4`]'s four-lane order for the dot-product family). The
//! tiled kernels are therefore bitwise-identical to the scalar references
//! in [`reference`] at every thread count, which the `kernels`
//! integration-test suite asserts across odd shapes and remainder tiles.
//!
//! **SIMD dispatch.** Each public kernel resolves a [`KernelIsa`] once per
//! call (a memoized atomic load, see [`active_isa`]) and runs either the
//! portable scalar tiles or the AVX2 panels in [`avx2`]. The AVX2 panels
//! keep the exact determinism contract above: hardware lanes map across
//! *independent output elements* (the NR/column dimension, or independent
//! dot products of a panel), never across one dot product's reduction, and
//! multiplies and adds stay separate instructions (no FMA contraction), so
//! SIMD output is bitwise-identical to the scalar path — the `simd`
//! integration suite asserts exact equality, not a tolerance.
//! `CREST_FORCE_SCALAR=1` (or
//! [`RuntimeConfig::force_scalar`](crate::runtime_config::RuntimeConfig))
//! pins the scalar path; the `*_isa` entry points pin an explicit ISA for
//! differential testing and benchmarking.
//!
//! [`Workspace`] and [`WorkspacePool`] round out the layer: reusable
//! scratch-buffer arenas that let the native backend run its
//! forward/backward/HVP pipelines without per-call `vec!` allocations.

use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

use crate::tensor::MatF32;
use crate::util::pool::Pool;

/// Minimum MAC count before a matmul kernel fans out to the pool (below
/// this the scoped-thread spawn cost exceeds the parallel win).
pub const PAR_MIN_OPS: usize = 1 << 19;
/// Batch rows per parallel work unit in the row-partitioned kernels.
pub const ROW_GRAIN: usize = 16;
/// Input features per work unit in the weight-gradient kernel.
pub const K_GRAIN: usize = 32;
/// Minimum element count before the element-wise kernels (bias gradient,
/// ReLU mask) fan out — they are memory-bound, so the bar is higher.
pub const ELEM_PAR_MIN: usize = 1 << 20;
/// Elements per work unit in the element-wise kernels.
pub const ELEM_GRAIN: usize = 1 << 12;

/// Output rows per register tile (batch dimension).
const MR: usize = 4;
/// Output columns per register tile (feature dimension).
const NR: usize = 16;

// --------------------------------------------------------- ISA dispatch

/// Instruction-set family a kernel call executes with.
///
/// The two members compute bit-for-bit identical results (see the module
/// docs); the choice only affects speed. [`active_isa`] picks the widest
/// supported family at runtime unless `CREST_FORCE_SCALAR` pins scalar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar tiles — the reference accumulation order, always
    /// available on every target.
    Scalar,
    /// 256-bit AVX2 panels (`x86_64` only, runtime-detected).
    Avx2,
}

impl KernelIsa {
    /// Short stable name, used in bench records and logs.
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
        }
    }
}

impl std::fmt::Display for KernelIsa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Memoized dispatch decision: 0 = undecided, 1 = scalar, 2 = AVX2.
static ACTIVE_ISA: AtomicU8 = AtomicU8::new(0);

fn isa_from_u8(v: u8) -> Option<KernelIsa> {
    match v {
        1 => Some(KernelIsa::Scalar),
        2 => Some(KernelIsa::Avx2),
        _ => None,
    }
}

/// Pure dispatch rule: forced scalar wins; otherwise the widest ISA the
/// running CPU supports. Factored out of [`active_isa`] so tests can
/// exercise the rule without touching process state.
pub fn resolve_isa(force_scalar: bool) -> KernelIsa {
    if force_scalar {
        return KernelIsa::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2::available() {
            return KernelIsa::Avx2;
        }
    }
    KernelIsa::Scalar
}

/// The ISA the dispatching kernel entry points currently use. Resolved
/// once from [`RuntimeConfig::current`](crate::runtime_config::RuntimeConfig::current)
/// (so `CREST_FORCE_SCALAR` and session overrides apply) and memoized;
/// [`refresh_isa`] re-resolves after a configuration change.
pub fn active_isa() -> KernelIsa {
    if let Some(isa) = isa_from_u8(ACTIVE_ISA.load(Ordering::Relaxed)) {
        return isa;
    }
    refresh_isa()
}

/// Re-resolve the active ISA from the current runtime configuration and
/// install it. Called by
/// [`runtime_config::set_session`](crate::runtime_config::set_session) so
/// a session-level `force_scalar` override takes effect immediately.
pub fn refresh_isa() -> KernelIsa {
    let force = crate::runtime_config::RuntimeConfig::current().force_scalar.unwrap_or(false);
    let isa = resolve_isa(force);
    let code = match isa {
        KernelIsa::Scalar => 1,
        KernelIsa::Avx2 => 2,
    };
    ACTIVE_ISA.store(code, Ordering::Relaxed);
    isa
}

/// Every ISA the running CPU can execute, scalar first — the iteration
/// set of the SIMD differential tests.
pub fn available_isas() -> Vec<KernelIsa> {
    let mut v = vec![KernelIsa::Scalar];
    if resolve_isa(false) == KernelIsa::Avx2 {
        v.push(KernelIsa::Avx2);
    }
    v
}

// ----------------------------------------------------------- dot panels

/// 4-lane unrolled dot product (auto-vectorizes well in release builds).
/// Lane `l` accumulates elements `k ≡ l (mod 4)`; the lanes are summed
/// left-to-right and the tail elements are added in ascending order.
#[inline]
pub fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Four independent [`dot4`]s of `a` against `b0..b3`, sharing the `a`
/// loads across the panel. Each result is bitwise-identical to calling
/// [`dot4`] on that pair alone (same lanes, same fold, same tail order).
#[inline]
fn dot4_1x4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    debug_assert!(b0.len() == n && b1.len() == n && b2.len() == n && b3.len() == n);
    let c = n & !3;
    let mut acc = [[0.0f32; 4]; 4];
    let mut k = 0;
    while k < c {
        for l in 0..4 {
            let av = a[k + l];
            acc[0][l] += av * b0[k + l];
            acc[1][l] += av * b1[k + l];
            acc[2][l] += av * b2[k + l];
            acc[3][l] += av * b3[k + l];
        }
        k += 4;
    }
    let mut out = [0.0f32; 4];
    for (o, lanes) in out.iter_mut().zip(&acc) {
        *o = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    }
    for k in c..n {
        let av = a[k];
        out[0] += av * b0[k];
        out[1] += av * b1[k];
        out[2] += av * b2[k];
        out[3] += av * b3[k];
    }
    out
}

/// [`dot4`] under an explicit ISA: the SSE accumulator vector *is*
/// `dot4`'s four lanes, folded in the same left-to-right order, so both
/// members return identical bits.
pub fn dot4_isa(isa: KernelIsa, a: &[f32], b: &[f32]) -> f32 {
    match isa {
        KernelIsa::Scalar => dot4(a, b),
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => avx2::dot4(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => dot4(a, b),
    }
}

/// Dot products of probe row `a` against rows `range` of `m`, written to
/// `out` (`out.len() == range.len()`). Four matrix rows are processed per
/// panel step so the probe row is loaded once per four pairs; every value
/// is bitwise-identical to `dot4(a, m.row(i))`. Dispatches on
/// [`active_isa`].
pub fn dot4_rows(a: &[f32], m: &MatF32, range: Range<usize>, out: &mut [f32]) {
    dot4_rows_isa(active_isa(), a, m, range, out)
}

/// [`dot4_rows`] under an explicit ISA (the SIMD differential tests and
/// kernel benches pin both members).
pub fn dot4_rows_isa(isa: KernelIsa, a: &[f32], m: &MatF32, range: Range<usize>, out: &mut [f32]) {
    debug_assert_eq!(out.len(), range.len());
    debug_assert_eq!(a.len(), m.cols);
    #[cfg(target_arch = "x86_64")]
    {
        if isa == KernelIsa::Avx2 {
            avx2::dot4_rows(a, m, range, out);
            return;
        }
    }
    let _ = isa;
    let mut i = range.start;
    let mut o = 0;
    while i + 4 <= range.end {
        let r = dot4_1x4(a, m.row(i), m.row(i + 1), m.row(i + 2), m.row(i + 3));
        out[o..o + 4].copy_from_slice(&r);
        i += 4;
        o += 4;
    }
    while i < range.end {
        out[o] = dot4(a, m.row(i));
        i += 1;
        o += 1;
    }
}

// ------------------------------------------------- blocked distance panels

/// Inner block length of [`prod_block`]'s stack scratch for the
/// logit-gradient dot panel.
pub const PROD_BLOCK: usize = 64;

/// Squared Euclidean distances of row `j` of `g` to rows `range` of `g`,
/// given precomputed squared norms `sq` (`‖g_i‖² + ‖g_j‖² − 2·g_i·g_j`,
/// clamped at zero). The dot panel dispatches on [`active_isa`]; the
/// O(block) epilogue stays scalar (the O(block·d) dots dominate).
pub fn euclid_block(g: &MatF32, sq: &[f32], j: usize, range: Range<usize>, out: &mut [f32]) {
    euclid_block_isa(active_isa(), g, sq, j, range, out)
}

/// [`euclid_block`] under an explicit ISA.
pub fn euclid_block_isa(
    isa: KernelIsa,
    g: &MatF32,
    sq: &[f32],
    j: usize,
    range: Range<usize>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), range.len());
    dot4_rows_isa(isa, g.row(j), g, range.clone(), out);
    let sj = sq[j];
    for (o, i) in out.iter_mut().zip(range) {
        *o = (sq[i] + sj - 2.0 * *o).max(0.0);
    }
}

/// Gradient-product distances of example `j` to examples `range` under the
/// factorized last-layer metric (`sq[i] + sq[j] − 2(a_i·a_j)(g_i·g_j)`,
/// clamped at zero), with `sq` the precomputed per-example squared norms.
/// Two dot panels per [`PROD_BLOCK`] chunk share a stack scratch; panels
/// dispatch on [`active_isa`], the epilogue stays scalar.
pub fn prod_block(
    a: &MatF32,
    g: &MatF32,
    sq: &[f32],
    j: usize,
    range: Range<usize>,
    out: &mut [f32],
) {
    prod_block_isa(active_isa(), a, g, sq, j, range, out)
}

/// [`prod_block`] under an explicit ISA.
#[allow(clippy::too_many_arguments)]
pub fn prod_block_isa(
    isa: KernelIsa,
    a: &MatF32,
    g: &MatF32,
    sq: &[f32],
    j: usize,
    range: Range<usize>,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), range.len());
    let aj = a.row(j);
    let gj = g.row(j);
    let sj = sq[j];
    let mut gbuf = [0.0f32; PROD_BLOCK];
    let mut start = range.start;
    let mut o = 0;
    while start < range.end {
        let end = (start + PROD_BLOCK).min(range.end);
        let n = end - start;
        dot4_rows_isa(isa, aj, a, start..end, &mut out[o..o + n]);
        dot4_rows_isa(isa, gj, g, start..end, &mut gbuf[..n]);
        for (k, ov) in out[o..o + n].iter_mut().enumerate() {
            let i = start + k;
            *ov = (sq[i] + sj - 2.0 * *ov * gbuf[k]).max(0.0);
        }
        o += n;
        start = end;
    }
}

// ------------------------------------------------- tiled matmul kernels

/// `out += x·W` (x: rows×d_in, W: d_in×d_out row-major). Register-tiled
/// MR×NR microkernel, row-parallel across pool workers. Each output
/// element accumulates `x[i][k]·W[k][j]` over ascending `k` into one
/// register lane and is added to `out` exactly once, so the result is
/// bitwise-identical to [`reference::add_matmul`] at every thread count
/// and under either ISA (dispatches on [`active_isa`]).
pub fn add_matmul(out: &mut MatF32, x: &MatF32, w: &[f32], d_out: usize) {
    add_matmul_isa(active_isa(), out, x, w, d_out)
}

/// [`add_matmul`] under an explicit ISA.
pub fn add_matmul_isa(isa: KernelIsa, out: &mut MatF32, x: &MatF32, w: &[f32], d_out: usize) {
    debug_assert_eq!(out.rows, x.rows);
    debug_assert_eq!(out.cols, d_out);
    debug_assert_eq!(w.len(), x.cols * d_out);
    if d_out == 0 || x.rows == 0 {
        return;
    }
    let pool = Pool::gated(x.rows * x.cols * d_out, PAR_MIN_OPS);
    pool.for_rows(&mut out.data, d_out, ROW_GRAIN, |row0, rows_out| match isa {
        KernelIsa::Scalar => matmul_panel(rows_out, row0, x, w, d_out),
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => avx2::matmul_panel(rows_out, row0, x, w, d_out),
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => matmul_panel(rows_out, row0, x, w, d_out),
    });
}

/// One row-panel of [`add_matmul`]: `rows_out` holds the panel's output
/// rows contiguously, starting at batch row `row0`.
fn matmul_panel(rows_out: &mut [f32], row0: usize, x: &MatF32, w: &[f32], d_out: usize) {
    let rows = rows_out.len() / d_out;
    let d_in = x.cols;
    let mut i = 0;
    while i + MR <= rows {
        let x0 = x.row(row0 + i);
        let x1 = x.row(row0 + i + 1);
        let x2 = x.row(row0 + i + 2);
        let x3 = x.row(row0 + i + 3);
        let mut j = 0;
        while j + NR <= d_out {
            // full MR×NR register tile: NR-wide lanes vectorize, the W row
            // segment is loaded once per k and reused for all MR rows
            let mut acc = [[0.0f32; NR]; MR];
            for k in 0..d_in {
                let wk = &w[k * d_out + j..k * d_out + j + NR];
                let xv = [x0[k], x1[k], x2[k], x3[k]];
                for (ar, &xr) in acc.iter_mut().zip(&xv) {
                    for (a, &wv) in ar.iter_mut().zip(wk) {
                        *a += xr * wv;
                    }
                }
            }
            for (r, ar) in acc.iter().enumerate() {
                let o = &mut rows_out[(i + r) * d_out + j..(i + r) * d_out + j + NR];
                for (ov, &av) in o.iter_mut().zip(ar) {
                    *ov += av;
                }
            }
            j += NR;
        }
        // column remainder: MR rows, one column at a time
        while j < d_out {
            let mut acc = [0.0f32; MR];
            for k in 0..d_in {
                let wv = w[k * d_out + j];
                acc[0] += x0[k] * wv;
                acc[1] += x1[k] * wv;
                acc[2] += x2[k] * wv;
                acc[3] += x3[k] * wv;
            }
            for (r, &av) in acc.iter().enumerate() {
                rows_out[(i + r) * d_out + j] += av;
            }
            j += 1;
        }
        i += MR;
    }
    // row remainder: one row at a time, still NR-wide where possible
    while i < rows {
        let xi = x.row(row0 + i);
        let orow = &mut rows_out[i * d_out..(i + 1) * d_out];
        let mut j = 0;
        while j + NR <= d_out {
            let mut acc = [0.0f32; NR];
            for (k, &xv) in xi.iter().enumerate() {
                let wk = &w[k * d_out + j..k * d_out + j + NR];
                for (a, &wv) in acc.iter_mut().zip(wk) {
                    *a += xv * wv;
                }
            }
            for (o, &av) in orow[j..j + NR].iter_mut().zip(&acc) {
                *o += av;
            }
            j += NR;
        }
        while j < d_out {
            let mut acc = 0.0f32;
            for (k, &xv) in xi.iter().enumerate() {
                acc += xv * w[k * d_out + j];
            }
            orow[j] += acc;
            j += 1;
        }
        i += 1;
    }
}

/// `out += d·Wᵀ` (d: rows×d_out, W: d_in×d_out row-major, out: rows×d_in).
/// Each output element is `dot4(d.row(i), W.row(j))` added once, computed
/// through 2×2 panels that share the row loads — bitwise-identical to
/// [`reference::add_matmul_nt`] at every thread count.
pub fn add_matmul_nt(out: &mut MatF32, d: &MatF32, w: &[f32], d_out: usize) {
    add_matmul_nt_isa(active_isa(), out, d, w, d_out)
}

/// [`add_matmul_nt`] under an explicit ISA.
pub fn add_matmul_nt_isa(isa: KernelIsa, out: &mut MatF32, d: &MatF32, w: &[f32], d_out: usize) {
    debug_assert_eq!(out.rows, d.rows);
    debug_assert_eq!(d.cols, d_out);
    debug_assert_eq!(w.len(), out.cols * d_out);
    if out.cols == 0 || out.rows == 0 {
        return;
    }
    let d_in = out.cols;
    let pool = Pool::gated(d.rows * d_in * d_out, PAR_MIN_OPS);
    pool.for_rows(&mut out.data, d_in, ROW_GRAIN, |row0, rows_out| {
        nt_panel_isa(isa, rows_out, row0, d_in, d, w, d_out, None);
    });
}

/// Fused backward matmul + ReLU mask: accumulate `(d·Wᵀ)[i][j]` into
/// `out[i][j]` only where `act[i][j] > 0`, skipping the dot product for
/// masked elements entirely. With a fresh zeroed `out` this equals
/// `relu_mask(matmul_nt(d, W), act)` without the extra full-matrix pass;
/// repeated calls accumulate under the same mask (the HVP tangent path).
pub fn add_matmul_nt_masked(
    out: &mut MatF32,
    d: &MatF32,
    w: &[f32],
    d_out: usize,
    act: &MatF32,
) {
    add_matmul_nt_masked_isa(active_isa(), out, d, w, d_out, act)
}

/// [`add_matmul_nt_masked`] under an explicit ISA.
pub fn add_matmul_nt_masked_isa(
    isa: KernelIsa,
    out: &mut MatF32,
    d: &MatF32,
    w: &[f32],
    d_out: usize,
    act: &MatF32,
) {
    debug_assert_eq!(out.rows, d.rows);
    debug_assert_eq!(d.cols, d_out);
    debug_assert_eq!(w.len(), out.cols * d_out);
    debug_assert_eq!(act.rows, out.rows);
    debug_assert_eq!(act.cols, out.cols);
    if out.cols == 0 || out.rows == 0 {
        return;
    }
    let d_in = out.cols;
    let pool = Pool::gated(d.rows * d_in * d_out, PAR_MIN_OPS);
    pool.for_rows(&mut out.data, d_in, ROW_GRAIN, |row0, rows_out| {
        nt_panel_isa(isa, rows_out, row0, d_in, d, w, d_out, Some(act));
    });
}

/// ISA fan-out for one row-panel of the Wᵀ product.
#[allow(clippy::too_many_arguments)]
fn nt_panel_isa(
    isa: KernelIsa,
    rows_out: &mut [f32],
    row0: usize,
    d_in: usize,
    d: &MatF32,
    w: &[f32],
    d_out: usize,
    act: Option<&MatF32>,
) {
    match isa {
        KernelIsa::Scalar => nt_panel(rows_out, row0, d_in, d, w, d_out, act),
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => avx2::nt_panel(rows_out, row0, d_in, d, w, d_out, act),
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => nt_panel(rows_out, row0, d_in, d, w, d_out, act),
    }
}

/// Four independent [`dot4`]s forming a 2×2 panel (`a0·b0, a0·b1, a1·b0,
/// a1·b1`), sharing the row loads. Each result is bitwise-identical to
/// [`dot4`] on that pair alone.
#[inline]
fn dot4_2x2(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32]) -> [f32; 4] {
    let n = a0.len();
    debug_assert!(a1.len() == n && b0.len() == n && b1.len() == n);
    let c = n & !3;
    let mut acc = [[0.0f32; 4]; 4];
    let mut k = 0;
    while k < c {
        for l in 0..4 {
            let x0 = a0[k + l];
            let x1 = a1[k + l];
            let y0 = b0[k + l];
            let y1 = b1[k + l];
            acc[0][l] += x0 * y0;
            acc[1][l] += x0 * y1;
            acc[2][l] += x1 * y0;
            acc[3][l] += x1 * y1;
        }
        k += 4;
    }
    let mut out = [0.0f32; 4];
    for (o, lanes) in out.iter_mut().zip(&acc) {
        *o = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    }
    for k in c..n {
        let x0 = a0[k];
        let x1 = a1[k];
        let y0 = b0[k];
        let y1 = b1[k];
        out[0] += x0 * y0;
        out[1] += x0 * y1;
        out[2] += x1 * y0;
        out[3] += x1 * y1;
    }
    out
}

/// One row-panel of the Wᵀ product, optionally ReLU-masked. 2×2 tiles of
/// independent [`dot4`]s share the `d`-row and `W`-row loads; masked
/// elements are never computed or written.
#[allow(clippy::too_many_arguments)]
fn nt_panel(
    rows_out: &mut [f32],
    row0: usize,
    d_in: usize,
    d: &MatF32,
    w: &[f32],
    d_out: usize,
    act: Option<&MatF32>,
) {
    let rows = rows_out.len() / d_in;
    let mut i = 0;
    while i + 2 <= rows {
        let d0 = d.row(row0 + i);
        let d1 = d.row(row0 + i + 1);
        let mut j = 0;
        while j + 2 <= d_in {
            let keep = match act {
                Some(a) => [
                    a.row(row0 + i)[j] > 0.0,
                    a.row(row0 + i)[j + 1] > 0.0,
                    a.row(row0 + i + 1)[j] > 0.0,
                    a.row(row0 + i + 1)[j + 1] > 0.0,
                ],
                None => [true; 4],
            };
            if keep.iter().any(|&k| k) {
                let w0 = &w[j * d_out..(j + 1) * d_out];
                let w1 = &w[(j + 1) * d_out..(j + 2) * d_out];
                let s = dot4_2x2(d0, d1, w0, w1);
                if keep[0] {
                    rows_out[i * d_in + j] += s[0];
                }
                if keep[1] {
                    rows_out[i * d_in + j + 1] += s[1];
                }
                if keep[2] {
                    rows_out[(i + 1) * d_in + j] += s[2];
                }
                if keep[3] {
                    rows_out[(i + 1) * d_in + j + 1] += s[3];
                }
            }
            j += 2;
        }
        while j < d_in {
            let wj = &w[j * d_out..(j + 1) * d_out];
            for (r, dr) in [d0, d1].into_iter().enumerate() {
                let keep = match act {
                    Some(a) => a.row(row0 + i + r)[j] > 0.0,
                    None => true,
                };
                if keep {
                    rows_out[(i + r) * d_in + j] += dot4(dr, wj);
                }
            }
            j += 1;
        }
        i += 2;
    }
    while i < rows {
        let di = d.row(row0 + i);
        for j in 0..d_in {
            let keep = match act {
                Some(a) => a.row(row0 + i)[j] > 0.0,
                None => true,
            };
            if keep {
                rows_out[i * d_in + j] += dot4(di, &w[j * d_out..(j + 1) * d_out]);
            }
        }
        i += 1;
    }
}

// ------------------------------------------------------- weight gradient

/// `gw += inputᵀ·d` accumulated into the flat weight-gradient slice
/// (`gw[k][j] += Σ_i input[i][k]·d[i][j]`, batch order ascending).
/// Parallel over input features: each worker owns a disjoint k-range of
/// `gw` rows. Rows of `input` equal to zero for a feature are skipped
/// (ReLU sparsity), exactly as in [`reference::accum_wgrad`].
pub fn accum_wgrad(gw: &mut [f32], input: &MatF32, d: &MatF32, d_out: usize) {
    accum_wgrad_isa(active_isa(), gw, input, d, d_out)
}

/// [`accum_wgrad`] under an explicit ISA.
pub fn accum_wgrad_isa(isa: KernelIsa, gw: &mut [f32], input: &MatF32, d: &MatF32, d_out: usize) {
    debug_assert_eq!(input.rows, d.rows);
    debug_assert_eq!(gw.len(), input.cols * d_out);
    if d_out == 0 || gw.is_empty() {
        return;
    }
    let pool = Pool::gated(input.rows * input.cols * d_out, PAR_MIN_OPS);
    pool.for_rows(gw, d_out, K_GRAIN, |k0, gw_rows| match isa {
        KernelIsa::Scalar => wgrad_panel(gw_rows, k0, input, d, d_out),
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => avx2::wgrad_panel(gw_rows, k0, input, d, d_out),
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => wgrad_panel(gw_rows, k0, input, d, d_out),
    });
}

/// One k-panel of [`accum_wgrad`]: `gw_rows` holds the gradient rows for
/// input features `k0..k0 + gw_rows.len()/d_out`.
fn wgrad_panel(gw_rows: &mut [f32], k0: usize, input: &MatF32, d: &MatF32, d_out: usize) {
    let kn = gw_rows.len() / d_out;
    let rows = input.rows;
    let mut kk = 0;
    while kk + MR <= kn {
        let mut j = 0;
        while j + NR <= d_out {
            let mut acc = [[0.0f32; NR]; MR];
            for i in 0..rows {
                let hi = input.row(i);
                let di = &d.row(i)[j..j + NR];
                let hv = [hi[k0 + kk], hi[k0 + kk + 1], hi[k0 + kk + 2], hi[k0 + kk + 3]];
                for (ar, &h) in acc.iter_mut().zip(&hv) {
                    if h == 0.0 {
                        continue;
                    }
                    for (a, &dv) in ar.iter_mut().zip(di) {
                        *a += h * dv;
                    }
                }
            }
            for (r, ar) in acc.iter().enumerate() {
                let g = &mut gw_rows[(kk + r) * d_out + j..(kk + r) * d_out + j + NR];
                for (gv, &av) in g.iter_mut().zip(ar) {
                    *gv += av;
                }
            }
            j += NR;
        }
        while j < d_out {
            let mut acc = [0.0f32; MR];
            for i in 0..rows {
                let hi = input.row(i);
                let dv = d.row(i)[j];
                for (r, a) in acc.iter_mut().enumerate() {
                    let h = hi[k0 + kk + r];
                    if h != 0.0 {
                        *a += h * dv;
                    }
                }
            }
            for (r, &av) in acc.iter().enumerate() {
                gw_rows[(kk + r) * d_out + j] += av;
            }
            j += 1;
        }
        kk += MR;
    }
    // feature remainder: one k at a time
    while kk < kn {
        let mut j = 0;
        while j + NR <= d_out {
            let mut acc = [0.0f32; NR];
            for i in 0..rows {
                let h = input.row(i)[k0 + kk];
                if h == 0.0 {
                    continue;
                }
                let di = &d.row(i)[j..j + NR];
                for (a, &dv) in acc.iter_mut().zip(di) {
                    *a += h * dv;
                }
            }
            for (g, &av) in gw_rows[kk * d_out + j..kk * d_out + j + NR].iter_mut().zip(&acc)
            {
                *g += av;
            }
            j += NR;
        }
        while j < d_out {
            let mut acc = 0.0f32;
            for i in 0..rows {
                let h = input.row(i)[k0 + kk];
                if h != 0.0 {
                    acc += h * d.row(i)[j];
                }
            }
            gw_rows[kk * d_out + j] += acc;
            j += 1;
        }
        kk += 1;
    }
}

// ----------------------------------------------------- element-wise ops

/// `gb += Σ_rows d` (column sums). Column-partitioned across workers;
/// every column accumulates its rows in ascending order, so the result is
/// thread-count independent.
pub fn accum_bgrad(gb: &mut [f32], d: &MatF32) {
    debug_assert_eq!(gb.len(), d.cols);
    if gb.is_empty() {
        return;
    }
    let pool = Pool::gated(d.rows * d.cols, ELEM_PAR_MIN);
    pool.for_rows(gb, 1, ELEM_GRAIN.min(gb.len()).max(1), |j0, gbc| {
        for i in 0..d.rows {
            let di = &d.row(i)[j0..j0 + gbc.len()];
            for (g, &dv) in gbc.iter_mut().zip(di) {
                *g += dv;
            }
        }
    });
}

/// Zero entries of `m` wherever the matching post-ReLU activation is zero
/// (element-wise, chunk-partitioned — thread-count independent).
pub fn relu_mask(m: &mut MatF32, act: &MatF32) {
    debug_assert_eq!(m.data.len(), act.data.len());
    if m.data.is_empty() {
        return;
    }
    let pool = Pool::gated(m.data.len(), ELEM_PAR_MIN);
    let act_data: &[f32] = &act.data;
    pool.for_rows(&mut m.data, 1, ELEM_GRAIN, |o0, chunk| {
        for (v, &a) in chunk.iter_mut().zip(&act_data[o0..o0 + chunk.len()]) {
            if a <= 0.0 {
                *v = 0.0;
            }
        }
    });
}

// ------------------------------------------------------------ workspace

/// Reusable scratch-buffer arena for one backend call chain.
///
/// Buffers are recycled LIFO: the capacities in the free list converge to
/// the call sequence's working set after one warmup call, after which the
/// forward/backward/HVP pipelines run allocation-free. Buffers handed out
/// for values that escape the call (e.g. `grad_embed`'s embeddings) simply
/// never come back — the free list shrinks and is refilled by the next
/// allocation, so reuse degrades gracefully instead of leaking.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
}

impl Workspace {
    /// Fresh workspace with an empty free list.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zeroed buffer of `len` elements, reusing pooled capacity.
    pub fn buf(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// A buffer initialized as a copy of `src`, reusing pooled capacity.
    pub fn buf_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn mat(&mut self, rows: usize, cols: usize) -> MatF32 {
        MatF32 { rows, cols, data: self.buf(rows * cols) }
    }

    /// A matrix copy of `src` backed by a pooled buffer.
    pub fn mat_copy(&mut self, src: &MatF32) -> MatF32 {
        MatF32 { rows: src.rows, cols: src.cols, data: self.buf_copy(&src.data) }
    }

    /// A `rows × row.len()` matrix with every row initialized to `row`
    /// (the broadcast-bias pattern of the affine kernels).
    pub fn mat_rows(&mut self, rows: usize, row: &[f32]) -> MatF32 {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.reserve(rows * row.len());
        for _ in 0..rows {
            v.extend_from_slice(row);
        }
        MatF32 { rows, cols: row.len(), data: v }
    }

    /// Return a buffer to the free list for reuse.
    pub fn recycle(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.free.push(v);
        }
    }

    /// Return a matrix's backing buffer to the free list.
    pub fn recycle_mat(&mut self, m: MatF32) {
        self.recycle(m.into_data());
    }

    /// Number of buffers currently pooled (for tests).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Shared pool of [`Workspace`]s: each concurrent backend call borrows one
/// for its duration, so a backend behind `&self` reuses buffers across
/// steps without serializing concurrent callers.
#[derive(Debug, Default)]
pub struct WorkspacePool {
    stack: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    /// Empty pool.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Borrow a workspace for the duration of `f`. The workspace (with
    /// whatever buffers `f` recycled into it) returns to the pool when `f`
    /// completes; on panic it is simply dropped.
    pub fn with<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        let mut ws = self
            .stack
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        let out = f(&mut ws);
        self.stack.lock().unwrap_or_else(|e| e.into_inner()).push(ws);
        out
    }
}

// ------------------------------------------------------------ AVX2 panels

/// AVX2 implementations of the microkernels.
///
/// Same tiling, same per-element accumulation order as the scalar panels:
/// hardware lanes map across *independent output elements* (the NR/column
/// dimension, or the independent dot products of a panel), never across
/// one dot product's reduction, and multiplies and adds stay separate
/// instructions — `_mm256_mul_ps` + `_mm256_add_ps`, never `fmadd`, whose
/// fused rounding would change bits. Horizontal folds of a dot product's
/// four lanes are done in scalar code in the exact left-to-right order of
/// [`dot4`](super::dot4). Every function here is therefore
/// bitwise-identical to its scalar counterpart, which `tests/simd.rs`
/// asserts exactly.
///
/// The public wrappers assert AVX2 support before entering the
/// `#[target_feature]` bodies, so dispatching [`KernelIsa::Avx2`] on an
/// unsupported CPU panics instead of executing illegal instructions.
#[cfg(target_arch = "x86_64")]
#[allow(unsafe_code)] // scoped exception (see Cargo.toml): std::arch SIMD intrinsics
#[allow(clippy::needless_range_loop)] // tile loops index several arrays in lockstep
mod avx2 {
    use core::arch::x86_64::{
        __m128, __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
        _mm256_set_m128, _mm256_setzero_ps, _mm256_storeu_ps, _mm_add_ps, _mm_loadu_ps,
        _mm_mul_ps, _mm_setzero_ps, _mm_storeu_ps,
    };
    use std::ops::Range;

    use super::{MR, NR};
    use crate::tensor::MatF32;

    /// True when the running CPU supports AVX2 (std memoizes the CPUID
    /// probe, so this is an atomic load after the first call).
    #[inline]
    pub(super) fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[inline]
    fn assert_avx2() {
        assert!(available(), "KernelIsa::Avx2 dispatched on a CPU without AVX2");
    }

    /// Fold one dot product's four accumulator lanes exactly as
    /// [`super::dot4`] does: left-to-right.
    #[inline]
    fn fold4(l: &[f32]) -> f32 {
        l[0] + l[1] + l[2] + l[3]
    }

    // ------------------------------------------------------ dot products

    /// AVX2/SSE [`super::dot4`]: the 128-bit accumulator vector *is* the
    /// scalar version's four lanes.
    pub(super) fn dot4(a: &[f32], b: &[f32]) -> f32 {
        assert_avx2();
        // SAFETY: AVX2 support was just asserted — the impl's only
        // precondition beyond safe-slice access
        unsafe { dot4_impl(a, b) }
    }

    // SAFETY: requires AVX2 (wrappers assert it). Vector loads stay in
    // bounds: k < c ≤ min(a.len(), b.len()) rounded down to a multiple
    // of the 4-lane width.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_impl(a: &[f32], b: &[f32]) -> f32 {
        unsafe {
            let n = a.len().min(b.len());
            let c = n & !3;
            let mut acc = _mm_setzero_ps();
            let mut k = 0;
            while k < c {
                let av = _mm_loadu_ps(a.as_ptr().add(k));
                let bv = _mm_loadu_ps(b.as_ptr().add(k));
                acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
                k += 4;
            }
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut s = fold4(&lanes);
            for k in c..n {
                s += a[k] * b[k];
            }
            s
        }
    }

    /// Duplicate a 128-bit row chunk into both halves of a ymm register.
    // SAFETY: requires AVX2 (reached only from avx2-enabled callers);
    // pure register shuffle, touches no memory
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn dup128(v: __m128) -> __m256 {
        _mm256_set_m128(v, v)
    }

    /// AVX2 [`super::dot4_1x4`]: two ymm registers hold the four
    /// independent dot products (two per register, one per 128-bit half);
    /// each half accumulates lanes `k ≡ l (mod 4)` in ascending `k`,
    /// exactly the scalar lane assignment.
    // SAFETY: requires AVX2 and b0..b3 at least a.len() long (callers
    // pass equal-length rows of one matrix); loads stop at the 4-lane
    // floor of a.len()
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_1x4_impl(
        a: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> [f32; 4] {
        unsafe {
            let n = a.len();
            let c = n & !3;
            let mut acc01 = _mm256_setzero_ps();
            let mut acc23 = _mm256_setzero_ps();
            let mut k = 0;
            while k < c {
                let ad = dup128(_mm_loadu_ps(a.as_ptr().add(k)));
                let b01 = _mm256_set_m128(
                    _mm_loadu_ps(b1.as_ptr().add(k)),
                    _mm_loadu_ps(b0.as_ptr().add(k)),
                );
                let b23 = _mm256_set_m128(
                    _mm_loadu_ps(b3.as_ptr().add(k)),
                    _mm_loadu_ps(b2.as_ptr().add(k)),
                );
                acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(ad, b01));
                acc23 = _mm256_add_ps(acc23, _mm256_mul_ps(ad, b23));
                k += 4;
            }
            let mut l01 = [0.0f32; 8];
            let mut l23 = [0.0f32; 8];
            _mm256_storeu_ps(l01.as_mut_ptr(), acc01);
            _mm256_storeu_ps(l23.as_mut_ptr(), acc23);
            let mut out =
                [fold4(&l01[..4]), fold4(&l01[4..]), fold4(&l23[..4]), fold4(&l23[4..])];
            for k in c..n {
                let av = a[k];
                out[0] += av * b0[k];
                out[1] += av * b1[k];
                out[2] += av * b2[k];
                out[3] += av * b3[k];
            }
            out
        }
    }

    /// AVX2 [`super::dot4_rows`].
    pub(super) fn dot4_rows(a: &[f32], m: &MatF32, range: Range<usize>, out: &mut [f32]) {
        assert_avx2();
        // SAFETY: AVX2 support was just asserted
        unsafe { dot4_rows_impl(a, m, range, out) }
    }

    // SAFETY: requires AVX2; delegates to the dot kernels with rows of
    // one matrix (equal lengths by construction)
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_rows_impl(a: &[f32], m: &MatF32, range: Range<usize>, out: &mut [f32]) {
        unsafe {
            let mut i = range.start;
            let mut o = 0;
            while i + 4 <= range.end {
                let r = dot4_1x4_impl(a, m.row(i), m.row(i + 1), m.row(i + 2), m.row(i + 3));
                out[o..o + 4].copy_from_slice(&r);
                i += 4;
                o += 4;
            }
            while i < range.end {
                out[o] = dot4_impl(a, m.row(i));
                i += 1;
                o += 1;
            }
        }
    }

    /// AVX2 [`super::dot4_2x2`]: `acc01 = [a0·b0 | a0·b1]`,
    /// `acc23 = [a1·b0 | a1·b1]`, scalar lane fold and tail.
    // SAFETY: requires AVX2 and a1/b0/b1 at least a0.len() long (callers
    // pass equal-length matrix rows); loads stop at the 4-lane floor
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_2x2_impl(a0: &[f32], a1: &[f32], b0: &[f32], b1: &[f32]) -> [f32; 4] {
        unsafe {
            let n = a0.len();
            let c = n & !3;
            let mut acc01 = _mm256_setzero_ps();
            let mut acc23 = _mm256_setzero_ps();
            let mut k = 0;
            while k < c {
                let bb = _mm256_set_m128(
                    _mm_loadu_ps(b1.as_ptr().add(k)),
                    _mm_loadu_ps(b0.as_ptr().add(k)),
                );
                let x0 = dup128(_mm_loadu_ps(a0.as_ptr().add(k)));
                let x1 = dup128(_mm_loadu_ps(a1.as_ptr().add(k)));
                acc01 = _mm256_add_ps(acc01, _mm256_mul_ps(x0, bb));
                acc23 = _mm256_add_ps(acc23, _mm256_mul_ps(x1, bb));
                k += 4;
            }
            let mut l01 = [0.0f32; 8];
            let mut l23 = [0.0f32; 8];
            _mm256_storeu_ps(l01.as_mut_ptr(), acc01);
            _mm256_storeu_ps(l23.as_mut_ptr(), acc23);
            let mut out =
                [fold4(&l01[..4]), fold4(&l01[4..]), fold4(&l23[..4]), fold4(&l23[4..])];
            for k in c..n {
                let x0 = a0[k];
                let x1 = a1[k];
                let y0 = b0[k];
                let y1 = b1[k];
                out[0] += x0 * y0;
                out[1] += x0 * y1;
                out[2] += x1 * y0;
                out[3] += x1 * y1;
            }
            out
        }
    }

    // ---------------------------------------------------- matmul panels

    /// AVX2 [`super::matmul_panel`]: the MR×NR tile's NR lanes live in two
    /// ymm registers per row; each output element still accumulates
    /// `x[i][k]·W[k][j]` over ascending `k` in its own lane.
    pub(super) fn matmul_panel(
        rows_out: &mut [f32],
        row0: usize,
        x: &MatF32,
        w: &[f32],
        d_out: usize,
    ) {
        assert_avx2();
        // SAFETY: AVX2 support was just asserted
        unsafe { matmul_panel_impl(rows_out, row0, x, w, d_out) }
    }

    // SAFETY: requires AVX2 and the panel layout invariants
    // (rows_out.len() = rows·d_out, w.len() = d_in·d_out): every pointer
    // offset k·d_out + j keeps j + NR ≤ d_out, so the 8-lane loads and
    // stores stay inside their slices
    #[target_feature(enable = "avx2")]
    unsafe fn matmul_panel_impl(
        rows_out: &mut [f32],
        row0: usize,
        x: &MatF32,
        w: &[f32],
        d_out: usize,
    ) {
        unsafe {
            let rows = rows_out.len() / d_out;
            let d_in = x.cols;
            let mut i = 0;
            while i + MR <= rows {
                let xr =
                    [x.row(row0 + i), x.row(row0 + i + 1), x.row(row0 + i + 2), x.row(row0 + i + 3)];
                let mut j = 0;
                while j + NR <= d_out {
                    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                    for k in 0..d_in {
                        let wp = w.as_ptr().add(k * d_out + j);
                        let w0 = _mm256_loadu_ps(wp);
                        let w1 = _mm256_loadu_ps(wp.add(8));
                        for r in 0..MR {
                            let xv = _mm256_set1_ps(xr[r][k]);
                            acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(xv, w0));
                            acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(xv, w1));
                        }
                    }
                    for r in 0..MR {
                        let op = rows_out.as_mut_ptr().add((i + r) * d_out + j);
                        _mm256_storeu_ps(op, _mm256_add_ps(_mm256_loadu_ps(op), acc[r][0]));
                        _mm256_storeu_ps(
                            op.add(8),
                            _mm256_add_ps(_mm256_loadu_ps(op.add(8)), acc[r][1]),
                        );
                    }
                    j += NR;
                }
                // column remainder: scalar, identical to the scalar panel
                while j < d_out {
                    let mut acc = [0.0f32; MR];
                    for k in 0..d_in {
                        let wv = w[k * d_out + j];
                        for (a, xrr) in acc.iter_mut().zip(&xr) {
                            *a += xrr[k] * wv;
                        }
                    }
                    for (r, &av) in acc.iter().enumerate() {
                        rows_out[(i + r) * d_out + j] += av;
                    }
                    j += 1;
                }
                i += MR;
            }
            while i < rows {
                let xi = x.row(row0 + i);
                let mut j = 0;
                while j + NR <= d_out {
                    let mut a0 = _mm256_setzero_ps();
                    let mut a1 = _mm256_setzero_ps();
                    for (k, &xv) in xi.iter().enumerate() {
                        let wp = w.as_ptr().add(k * d_out + j);
                        let xb = _mm256_set1_ps(xv);
                        a0 = _mm256_add_ps(a0, _mm256_mul_ps(xb, _mm256_loadu_ps(wp)));
                        a1 = _mm256_add_ps(a1, _mm256_mul_ps(xb, _mm256_loadu_ps(wp.add(8))));
                    }
                    let op = rows_out.as_mut_ptr().add(i * d_out + j);
                    _mm256_storeu_ps(op, _mm256_add_ps(_mm256_loadu_ps(op), a0));
                    _mm256_storeu_ps(op.add(8), _mm256_add_ps(_mm256_loadu_ps(op.add(8)), a1));
                    j += NR;
                }
                while j < d_out {
                    let mut acc = 0.0f32;
                    for (k, &xv) in xi.iter().enumerate() {
                        acc += xv * w[k * d_out + j];
                    }
                    rows_out[i * d_out + j] += acc;
                    j += 1;
                }
                i += 1;
            }
        }
    }

    /// AVX2 [`super::nt_panel`]: same 2×2 tiling and mask skips, with the
    /// four independent dot products in ymm halves.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn nt_panel(
        rows_out: &mut [f32],
        row0: usize,
        d_in: usize,
        d: &MatF32,
        w: &[f32],
        d_out: usize,
        act: Option<&MatF32>,
    ) {
        assert_avx2();
        // SAFETY: AVX2 support was just asserted
        unsafe { nt_panel_impl(rows_out, row0, d_in, d, w, d_out, act) }
    }

    // SAFETY: requires AVX2; memory access happens only through safe
    // slice indexing and the dot kernels, whose equal-length row
    // precondition the `w[j·d_out..(j+1)·d_out]` windows satisfy
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn nt_panel_impl(
        rows_out: &mut [f32],
        row0: usize,
        d_in: usize,
        d: &MatF32,
        w: &[f32],
        d_out: usize,
        act: Option<&MatF32>,
    ) {
        unsafe {
            let rows = rows_out.len() / d_in;
            let mut i = 0;
            while i + 2 <= rows {
                let d0 = d.row(row0 + i);
                let d1 = d.row(row0 + i + 1);
                let mut j = 0;
                while j + 2 <= d_in {
                    let keep = match act {
                        Some(a) => [
                            a.row(row0 + i)[j] > 0.0,
                            a.row(row0 + i)[j + 1] > 0.0,
                            a.row(row0 + i + 1)[j] > 0.0,
                            a.row(row0 + i + 1)[j + 1] > 0.0,
                        ],
                        None => [true; 4],
                    };
                    if keep.iter().any(|&k| k) {
                        let w0 = &w[j * d_out..(j + 1) * d_out];
                        let w1 = &w[(j + 1) * d_out..(j + 2) * d_out];
                        let s = dot4_2x2_impl(d0, d1, w0, w1);
                        if keep[0] {
                            rows_out[i * d_in + j] += s[0];
                        }
                        if keep[1] {
                            rows_out[i * d_in + j + 1] += s[1];
                        }
                        if keep[2] {
                            rows_out[(i + 1) * d_in + j] += s[2];
                        }
                        if keep[3] {
                            rows_out[(i + 1) * d_in + j + 1] += s[3];
                        }
                    }
                    j += 2;
                }
                while j < d_in {
                    let wj = &w[j * d_out..(j + 1) * d_out];
                    for (r, dr) in [d0, d1].into_iter().enumerate() {
                        let keep = match act {
                            Some(a) => a.row(row0 + i + r)[j] > 0.0,
                            None => true,
                        };
                        if keep {
                            rows_out[(i + r) * d_in + j] += dot4_impl(dr, wj);
                        }
                    }
                    j += 1;
                }
                i += 2;
            }
            while i < rows {
                let di = d.row(row0 + i);
                for j in 0..d_in {
                    let keep = match act {
                        Some(a) => a.row(row0 + i)[j] > 0.0,
                        None => true,
                    };
                    if keep {
                        rows_out[i * d_in + j] += dot4_impl(di, &w[j * d_out..(j + 1) * d_out]);
                    }
                }
                i += 1;
            }
        }
    }

    /// AVX2 [`super::wgrad_panel`]: the MR×NR tile's NR lanes live in two
    /// ymm registers per feature row, with the same `h == 0` sparsity skip
    /// and ascending batch order per output element.
    pub(super) fn wgrad_panel(
        gw_rows: &mut [f32],
        k0: usize,
        input: &MatF32,
        d: &MatF32,
        d_out: usize,
    ) {
        assert_avx2();
        // SAFETY: AVX2 support was just asserted
        unsafe { wgrad_panel_impl(gw_rows, k0, input, d, d_out) }
    }

    // SAFETY: requires AVX2 and the panel layout invariants
    // (gw_rows.len() = kn·d_out, d rows of length d_out): the 8-lane
    // loads and stores at offset j keep j + NR ≤ d_out
    #[target_feature(enable = "avx2")]
    unsafe fn wgrad_panel_impl(
        gw_rows: &mut [f32],
        k0: usize,
        input: &MatF32,
        d: &MatF32,
        d_out: usize,
    ) {
        unsafe {
            let kn = gw_rows.len() / d_out;
            let rows = input.rows;
            let mut kk = 0;
            while kk + MR <= kn {
                let mut j = 0;
                while j + NR <= d_out {
                    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                    for i in 0..rows {
                        let hi = input.row(i);
                        let dp = d.row(i).as_ptr().add(j);
                        let d0 = _mm256_loadu_ps(dp);
                        let d1 = _mm256_loadu_ps(dp.add(8));
                        for r in 0..MR {
                            let h = hi[k0 + kk + r];
                            if h == 0.0 {
                                continue;
                            }
                            let hb = _mm256_set1_ps(h);
                            acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(hb, d0));
                            acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(hb, d1));
                        }
                    }
                    for r in 0..MR {
                        let gp = gw_rows.as_mut_ptr().add((kk + r) * d_out + j);
                        _mm256_storeu_ps(gp, _mm256_add_ps(_mm256_loadu_ps(gp), acc[r][0]));
                        _mm256_storeu_ps(
                            gp.add(8),
                            _mm256_add_ps(_mm256_loadu_ps(gp.add(8)), acc[r][1]),
                        );
                    }
                    j += NR;
                }
                // column remainder: scalar, identical to the scalar panel
                while j < d_out {
                    let mut acc = [0.0f32; MR];
                    for i in 0..rows {
                        let hi = input.row(i);
                        let dv = d.row(i)[j];
                        for (r, a) in acc.iter_mut().enumerate() {
                            let h = hi[k0 + kk + r];
                            if h != 0.0 {
                                *a += h * dv;
                            }
                        }
                    }
                    for (r, &av) in acc.iter().enumerate() {
                        gw_rows[(kk + r) * d_out + j] += av;
                    }
                    j += 1;
                }
                kk += MR;
            }
            while kk < kn {
                let mut j = 0;
                while j + NR <= d_out {
                    let mut a0 = _mm256_setzero_ps();
                    let mut a1 = _mm256_setzero_ps();
                    for i in 0..rows {
                        let h = input.row(i)[k0 + kk];
                        if h == 0.0 {
                            continue;
                        }
                        let hb = _mm256_set1_ps(h);
                        let dp = d.row(i).as_ptr().add(j);
                        a0 = _mm256_add_ps(a0, _mm256_mul_ps(hb, _mm256_loadu_ps(dp)));
                        a1 = _mm256_add_ps(a1, _mm256_mul_ps(hb, _mm256_loadu_ps(dp.add(8))));
                    }
                    let gp = gw_rows.as_mut_ptr().add(kk * d_out + j);
                    _mm256_storeu_ps(gp, _mm256_add_ps(_mm256_loadu_ps(gp), a0));
                    _mm256_storeu_ps(gp.add(8), _mm256_add_ps(_mm256_loadu_ps(gp.add(8)), a1));
                    j += NR;
                }
                while j < d_out {
                    let mut acc = 0.0f32;
                    for i in 0..rows {
                        let h = input.row(i)[k0 + kk];
                        if h != 0.0 {
                            acc += h * d.row(i)[j];
                        }
                    }
                    gw_rows[kk * d_out + j] += acc;
                    j += 1;
                }
                kk += 1;
            }
        }
    }
}

// ------------------------------------------------------------ references

/// Scalar reference kernels: the semantics the tiled kernels must match
/// bitwise. Used by the `kernels` equivalence tests and kept deliberately
/// naive — one accumulator per output element, reduction index ascending.
pub mod reference {
    use super::dot4;
    use crate::tensor::MatF32;

    /// Scalar `out += x·W`: per element, accumulate over ascending `k`
    /// into one register, then add to `out` once.
    pub fn add_matmul(out: &mut MatF32, x: &MatF32, w: &[f32], d_out: usize) {
        for i in 0..x.rows {
            let xi = x.row(i);
            for j in 0..d_out {
                let mut acc = 0.0f32;
                for (k, &xv) in xi.iter().enumerate() {
                    acc += xv * w[k * d_out + j];
                }
                out.data[i * d_out + j] += acc;
            }
        }
    }

    /// Scalar `out += d·Wᵀ`: per element, one [`dot4`] added to `out`.
    pub fn add_matmul_nt(out: &mut MatF32, d: &MatF32, w: &[f32], d_out: usize) {
        let d_in = out.cols;
        for i in 0..d.rows {
            let di = d.row(i);
            for j in 0..d_in {
                out.data[i * d_in + j] += dot4(di, &w[j * d_out..(j + 1) * d_out]);
            }
        }
    }

    /// Scalar masked `out += d·Wᵀ`: elements with `act ≤ 0` are skipped.
    pub fn add_matmul_nt_masked(
        out: &mut MatF32,
        d: &MatF32,
        w: &[f32],
        d_out: usize,
        act: &MatF32,
    ) {
        let d_in = out.cols;
        for i in 0..d.rows {
            let di = d.row(i);
            for j in 0..d_in {
                if act.data[i * d_in + j] > 0.0 {
                    out.data[i * d_in + j] += dot4(di, &w[j * d_out..(j + 1) * d_out]);
                }
            }
        }
    }

    /// Scalar `gw += inputᵀ·d` with the ReLU-sparsity skip (`input == 0`
    /// contributes nothing), batch index ascending per element.
    pub fn accum_wgrad(gw: &mut [f32], input: &MatF32, d: &MatF32, d_out: usize) {
        let d_in = input.cols;
        for k in 0..d_in {
            for j in 0..d_out {
                let mut acc = 0.0f32;
                for i in 0..input.rows {
                    let h = input.row(i)[k];
                    if h != 0.0 {
                        acc += h * d.row(i)[j];
                    }
                }
                gw[k * d_out + j] += acc;
            }
        }
    }

    /// Scalar `gb += Σ_rows d`, row index ascending per column.
    pub fn accum_bgrad(gb: &mut [f32], d: &MatF32) {
        for j in 0..d.cols {
            for i in 0..d.rows {
                gb[j] += d.row(i)[j];
            }
        }
    }
}
