//! Host-side parameter initialization for the AOT-compiled MLP.
//!
//! The flat layout (per layer: row-major W then b) must match
//! `python/compile/model.py::unflatten`. Initialization is He-normal for
//! weights and zero for biases — the same distribution the python test-side
//! init draws from (bit equality is not required; see model.py docstring).

use crate::runtime::manifest::VariantManifest;
use crate::util::rng::Rng;

/// He-normal initial parameter vector for a variant.
pub fn init_params(man: &VariantManifest, rng: &mut Rng) -> Vec<f32> {
    let mut p = Vec::with_capacity(man.p_dim);
    for &(fan_in, fan_out) in &man.layer_shapes {
        let std = (2.0f32 / fan_in as f32).sqrt();
        for _ in 0..fan_in * fan_out {
            p.push(rng.normal() * std);
        }
        for _ in 0..fan_out {
            p.push(0.0);
        }
    }
    debug_assert_eq!(p.len(), man.p_dim);
    p
}

/// Offsets of each layer's (weights, biases) inside the flat vector —
/// mirrors `VariantSpec.param_offsets` on the python side.
pub fn param_offsets(man: &VariantManifest) -> Vec<(usize, (usize, usize), usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    for &(i, o) in &man.layer_shapes {
        let w_off = off;
        off += i * o;
        let b_off = off;
        off += o;
        out.push((w_off, (i, o), b_off, o));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::VariantManifest;

    fn man() -> VariantManifest {
        // minimal manifest via JSON (same path production uses)
        VariantManifest::parse(
            r#"{
          "name": "t", "d_in": 4, "hidden": [8], "classes": 3,
          "m": 2, "r": 4, "eval_chunk": 4, "p_dim": 67, "momentum": 0.9,
          "layer_shapes": [[4, 8], [8, 3]],
          "artifacts": {
            "train_step": {"file": "t.hlo.txt",
              "inputs": [
                {"name": "params", "dtype": "f32", "shape": [67]},
                {"name": "momentum", "dtype": "f32", "shape": [67]},
                {"name": "x", "dtype": "f32", "shape": [2, 4]},
                {"name": "y", "dtype": "i32", "shape": [2]},
                {"name": "gamma", "dtype": "f32", "shape": [2]},
                {"name": "lr", "dtype": "f32", "shape": []}],
              "outputs": []},
            "grad_embed": {"file": "g", "inputs": [], "outputs": []},
            "eval_chunk": {"file": "e", "inputs": [], "outputs": []},
            "hess_probe": {"file": "h", "inputs": [], "outputs": []},
            "select_greedy": {"file": "s", "inputs": [], "outputs": []}
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn init_has_right_length_and_zero_biases() {
        let man = man();
        let mut rng = Rng::new(0);
        let p = init_params(&man, &mut rng);
        assert_eq!(p.len(), 67);
        // layer 1 biases at offset 32..40, layer 2 biases at 64..67
        assert!(p[32..40].iter().all(|&v| v == 0.0));
        assert!(p[64..67].iter().all(|&v| v == 0.0));
        // weights not all zero
        assert!(p[..32].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn init_std_tracks_fan_in() {
        let man = man();
        let mut rng = Rng::new(1);
        let p = init_params(&man, &mut rng);
        let w1 = &p[..32]; // fan_in 4 -> std sqrt(0.5) ~ 0.707
        let s1 = crate::util::stats::stddev(w1);
        assert!((0.4..1.1).contains(&s1), "std {s1}");
    }

    #[test]
    fn offsets_match_python_layout() {
        let man = man();
        let offs = param_offsets(&man);
        assert_eq!(offs, vec![(0, (4, 8), 32, 8), (40, (8, 3), 64, 3)]);
    }
}
