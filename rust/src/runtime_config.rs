//! Typed runtime configuration — the single place the `CREST_*` process
//! environment is read.
//!
//! Eight knobs tune how a process executes without changing *what* any
//! experiment computes: worker threads, the opt-in gram cache, the on-disk
//! gradient-embedding cache, the default data-store backend, the packed
//! corpus root, the kernel ISA escape hatch (`CREST_FORCE_SCALAR`,
//! which pins the scalar microkernels even where AVX2 is available — the
//! SIMD and scalar paths are bitwise-identical, so this only trades
//! speed), the fault-injection schedule (`CREST_FAULTS`, testing only),
//! and the mmap degradation target (`CREST_STORE_FALLBACK`). Historically each consumer read its own env var; every such
//! site now goes through [`RuntimeConfig::current`], which merges
//! session-level overrides (installed by
//! [`Experiment::builder().runtime_config(..)`](crate::api::ExperimentBuilder::runtime_config)
//! or [`set_session`]) over a fresh read of the environment.
//!
//! Reading the environment *fresh on every call* is deliberate: tests and
//! embedding applications flip `CREST_PACK_DIR`/`CREST_GRAM_CACHE` between
//! phases and expect the change to take effect. The two consumers that
//! memoize their value ([`pool::threads`](crate::util::pool::threads)
//! caches the worker count on first use; the data-store default is a
//! process-wide cell) keep their own caching semantics — this module only
//! centralizes *where the value comes from*.

use std::path::PathBuf;
use std::sync::RwLock;

use crate::coreset::facility::gram_cap;
use crate::data::{StoreFallback, StoreKind};

/// One env var's name and its one-line role (drives `--help` text and the
/// README-coverage test).
pub const VARS: &[(&str, &str)] = &[
    ("CREST_THREADS", "worker thread count (default: available cores)"),
    ("CREST_GRAM_CACHE", "opt-in n\u{00d7}n distance table: 1/true or an element cap"),
    ("CREST_EMBED_CACHE", "directory for the on-disk gradient-embedding cache"),
    ("CREST_DATA_STORE", "default dataset backend: mem | mmap"),
    ("CREST_PACK_DIR", "root directory for packed (sharded) corpora"),
    ("CREST_FORCE_SCALAR", "pin the scalar kernel path (disable SIMD dispatch): 1/true"),
    ("CREST_FAULTS", "fault-injection schedule for artifact I/O (testing only)"),
    ("CREST_STORE_FALLBACK", "degradation target when mmap fails: pread | mem"),
];

/// Typed snapshot of the runtime knobs. `None` everywhere means "use the
/// built-in default" — the struct distinguishes *unset* from *set to the
/// default* so overrides compose.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RuntimeConfig {
    /// Worker thread count (`CREST_THREADS`); `None` = available cores.
    pub threads: Option<usize>,
    /// Gram-cache element cap (`CREST_GRAM_CACHE`); `None` = cache off.
    pub gram_cache: Option<usize>,
    /// Gradient-embedding cache directory (`CREST_EMBED_CACHE`);
    /// `None` = cache off.
    pub embed_cache: Option<PathBuf>,
    /// Default data-store backend (`CREST_DATA_STORE`); `None` = mem.
    pub data_store: Option<StoreKind>,
    /// Packed-corpus root (`CREST_PACK_DIR`); `None` = `<tmp>/crest-pack`.
    pub pack_dir: Option<PathBuf>,
    /// Pin the scalar kernel ISA (`CREST_FORCE_SCALAR`); `None` = runtime
    /// feature dispatch picks the widest supported ISA.
    pub force_scalar: Option<bool>,
    /// Fault-injection schedule for artifact I/O (`CREST_FAULTS`);
    /// `None` = injection off. See [`crate::util::faults`].
    pub faults: Option<String>,
    /// Degradation target when `mmap(2)` refuses a shard mapping
    /// (`CREST_STORE_FALLBACK`); `None` = pread.
    pub store_fallback: Option<StoreFallback>,
}

/// Session-level overrides installed by [`set_session`]. Fields left `None`
/// fall through to the environment.
fn session() -> &'static RwLock<RuntimeConfig> {
    static SESSION: RwLock<RuntimeConfig> = RwLock::new(RuntimeConfig {
        threads: None,
        gram_cache: None,
        embed_cache: None,
        data_store: None,
        pack_dir: None,
        force_scalar: None,
        faults: None,
        store_fallback: None,
    });
    &SESSION
}

impl RuntimeConfig {
    /// Read every `CREST_*` runtime var from the process environment. This
    /// function is the only place in the crate those names are consulted.
    pub fn from_env() -> RuntimeConfig {
        let var = |name: &str| std::env::var(name).ok().filter(|v| !v.is_empty());
        RuntimeConfig {
            threads: var("CREST_THREADS").and_then(|s| s.parse().ok()).filter(|&n| n >= 1),
            gram_cache: gram_cap(std::env::var("CREST_GRAM_CACHE").ok().as_deref()),
            embed_cache: var("CREST_EMBED_CACHE").map(PathBuf::from),
            data_store: var("CREST_DATA_STORE").and_then(|v| StoreKind::parse(&v).ok()),
            pack_dir: var("CREST_PACK_DIR").map(PathBuf::from),
            force_scalar: var("CREST_FORCE_SCALAR")
                .map(|v| v != "0" && !v.eq_ignore_ascii_case("false")),
            faults: var("CREST_FAULTS"),
            store_fallback: var("CREST_STORE_FALLBACK")
                .and_then(|v| StoreFallback::parse(&v).ok()),
        }
    }

    /// The effective runtime config: session overrides merged over a fresh
    /// environment read (override fields win when set).
    pub fn current() -> RuntimeConfig {
        let env = RuntimeConfig::from_env();
        session().read().unwrap().merged_over(env)
    }

    /// `self`'s set fields layered over `fallback` (the merge behind
    /// [`RuntimeConfig::current`]).
    pub fn merged_over(&self, fallback: RuntimeConfig) -> RuntimeConfig {
        RuntimeConfig {
            threads: self.threads.or(fallback.threads),
            gram_cache: self.gram_cache.or(fallback.gram_cache),
            embed_cache: self.embed_cache.clone().or(fallback.embed_cache),
            data_store: self.data_store.or(fallback.data_store),
            pack_dir: self.pack_dir.clone().or(fallback.pack_dir),
            force_scalar: self.force_scalar.or(fallback.force_scalar),
            faults: self.faults.clone().or(fallback.faults),
            store_fallback: self.store_fallback.or(fallback.store_fallback),
        }
    }

    /// Effective packed-corpus root.
    pub fn resolved_pack_root(&self) -> PathBuf {
        self.pack_dir.clone().unwrap_or_else(|| std::env::temp_dir().join("crest-pack"))
    }

    /// Effective default store backend.
    pub fn resolved_store(&self) -> StoreKind {
        self.data_store.unwrap_or(StoreKind::Mem)
    }
}

/// Install `rc` as the session override set (merged over the environment by
/// every subsequent [`RuntimeConfig::current`] call) and push the three
/// consumers with their own process-wide cells: the pool worker count, the
/// data-store default, and the memoized kernel ISA.
pub fn set_session(rc: RuntimeConfig) {
    if let Some(t) = rc.threads {
        crate::util::pool::set_threads(t);
    }
    if let Some(k) = rc.data_store {
        crate::data::set_default_store(k);
    }
    *session().write().unwrap() = rc;
    // after the session cell is updated so refresh_isa sees the new value
    crate::kernel::refresh_isa();
    // ...and so the fault injector re-samples its schedule
    crate::util::faults::refresh();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readme_documents_every_runtime_var() {
        // the README env table must cover each consolidated var — a new
        // knob cannot ship undocumented
        let readme = include_str!("../../README.md");
        for (name, _) in VARS {
            assert!(readme.contains(name), "README.md env table is missing {name}");
        }
    }

    #[test]
    fn overrides_merge_over_fallback_fieldwise() {
        // pure merge check — deliberately does not touch the global session
        // cell, which concurrently running tests read
        let over = RuntimeConfig {
            gram_cache: Some(12345),
            pack_dir: Some(PathBuf::from("/tmp/rc-test")),
            ..RuntimeConfig::default()
        };
        let fallback = RuntimeConfig {
            threads: Some(3),
            gram_cache: Some(999),
            data_store: Some(StoreKind::Mmap),
            ..RuntimeConfig::default()
        };
        let m = over.merged_over(fallback);
        assert_eq!(m.threads, Some(3), "unset override falls through");
        assert_eq!(m.gram_cache, Some(12345), "set override wins");
        assert_eq!(m.data_store, Some(StoreKind::Mmap));
        assert_eq!(m.pack_dir.as_deref(), Some(std::path::Path::new("/tmp/rc-test")));
        assert_eq!(m.embed_cache, None);
    }

    #[test]
    fn resolved_defaults() {
        let rc = RuntimeConfig::default();
        assert_eq!(rc.resolved_store(), StoreKind::Mem);
        assert!(rc.resolved_pack_root().ends_with("crest-pack"));
    }
}
